# Developer / CI entry points.
#
#   make test-fast   fast tier-1 gate: skips @slow end-to-end tests, hard
#                    timeout so a hung jit can never wedge a pre-merge check
#   make test        the full suite (slow end-to-end tests included)
#   make bench       all fast benchmarks (CSV to stdout)

PY       := python
PYTHONPATH := src
TIMEOUT  := 900

.PHONY: test-fast test bench

test-fast:
	PYTHONPATH=$(PYTHONPATH) timeout $(TIMEOUT) $(PY) -m pytest -q -m "not slow"

test:
	PYTHONPATH=$(PYTHONPATH) $(PY) -m pytest -q

bench:
	PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.run
