# Developer / CI entry points.
#
#   make lint           replint (the repo's JAX/Pallas linter) over the whole
#                       tree, plus the ruff F-rule baseline when ruff exists
#   make lint-self-test replint's own fixture suite (each pass proven against
#                       known-bad/known-good corpora)
#   make test-fast      fast tier-1 gate: skips @slow end-to-end tests, hard
#                       timeout so a hung jit can never wedge a pre-merge check
#   make test           the full suite (slow end-to-end tests included)
#   make bench          all fast benchmarks (CSV to stdout)

PY       := python
PYTHONPATH := src
TIMEOUT  := 900

.PHONY: lint lint-self-test test-fast test bench

lint:
	PYTHONPATH=$(PYTHONPATH) $(PY) -m repro.tools.lint src tests benchmarks examples
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check .; \
	else \
		echo "ruff not installed; skipping the F-rule baseline (CI runs it)"; \
	fi

lint-self-test:
	PYTHONPATH=$(PYTHONPATH) $(PY) -m pytest -q tests/test_lint.py

test-fast:
	PYTHONPATH=$(PYTHONPATH) timeout $(TIMEOUT) $(PY) -m pytest -q -m "not slow"

test:
	PYTHONPATH=$(PYTHONPATH) $(PY) -m pytest -q

bench:
	PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.run
