"""Communication-volume benchmark — the paper's "~100x reduction" claim.

Analytic bytes/worker/step for DDP vs DiLoCo (fp32 / bf16 / int8 deltas) at
the paper's H values, cross-checked against the collective bytes parsed from
the compiled multi-pod dry-run (dryrun_multipod.json / outer-step runs).
"""
from __future__ import annotations

import json
import os
from typing import List

from repro.configs import get_config


def rows_for(arch_id: str) -> List[dict]:
    cfg = get_config(arch_id)
    n = cfg.param_count()
    out = []
    ddp = 4 * n  # fp32 grad all-reduce payload per step
    for h, stage in ((100, "base"), (30, "mid/sft")):
        for dtype, width in (("float32", 4), ("bfloat16", 2), ("int8", 1)):
            per_sync = width * n
            per_step = per_sync / h
            out.append({
                "arch": arch_id, "stage": stage, "H": h, "delta": dtype,
                "params": n,
                "ddp_bytes_per_step": ddp,
                "diloco_bytes_per_step": per_step,
                "reduction": ddp / per_step,
            })
    return out


def main(arch_id: str = "nanochat-d20") -> None:
    print("name,us_per_call,derived")
    for r in rows_for(arch_id):
        print(f"comm/{r['arch']}/H{r['H']}/{r['delta']},0.0,"
              f"reduction={r['reduction']:.0f}x "
              f"ddp={r['ddp_bytes_per_step']/1e6:.1f}MB/step "
              f"diloco={r['diloco_bytes_per_step']/1e6:.3f}MB/step")
    # cross-check vs dry-run parse if present
    path = os.path.join(os.path.dirname(__file__), "..",
                        "dryrun_outer.json")
    if os.path.exists(path):
        with open(path) as f:
            for r in json.load(f):
                print(f"comm/dryrun/{r['arch']}/{r['shape']},0.0,"
                      f"wire={r['collectives']['wire_bytes_per_device']:.3e}B/dev")


if __name__ == "__main__":
    main()
