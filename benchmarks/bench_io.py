"""Shared JSON I/O for the benchmark writers.

Several benchmarks contribute sections to the same artifact (e.g.
``BENCH_train.json`` holds train_bench's loop arms AND
strategies_bench's gossip section).  A plain ``json.dump`` from either
writer would clobber the other's section, so every writer goes through
``merge_json``: read-modify-write, preserving keys it does not own.
"""
from __future__ import annotations

import json
import os
from typing import Dict


def merge_json(path: str, updates: Dict) -> Dict:
    """Merge ``updates`` into the JSON object at ``path`` (top-level keys;
    created if missing or unreadable) and write it back atomically.
    Returns the merged object."""
    data: Dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                loaded = json.load(f)
            if isinstance(loaded, dict):
                data = loaded
        except (json.JSONDecodeError, OSError):
            pass   # corrupt artifact: rebuild from this writer's section
    data.update(updates)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1)
    os.replace(tmp, path)
    return data
