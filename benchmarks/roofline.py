"""Roofline analysis (deliverable g): per (arch × shape × mesh) the three
terms

    compute    = analytic FLOPs/device   / 197e12        (peak bf16)
    memory     = analytic HBM bytes/dev  / 819e9          (HBM bw)
    collective = while-weighted HLO wire bytes/dev / 50e9 (ICI link)

FLOPs/bytes are analytic (XLA's cost_analysis counts scanned layer bodies
once — see src/repro/launch/hlo_analysis.py); collective bytes come from the
compiled HLO with while-trip weighting.  MODEL_FLOPS = 6·N·D (6·N_active·D
for MoE); useful = MODEL_FLOPS / analytic-total (captures remat + attention
overhead vs. the classic parameter-flops floor).
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def load(path: str) -> List[dict]:
    with open(path) as f:
        return json.load(f)


def analyze(rows: List[dict]) -> List[Dict]:
    out = []
    for r in rows:
        a = r["analytic"]
        t_c = a["total_flops"] / PEAK_FLOPS_BF16
        t_m = a["bytes"] / HBM_BW
        t_x = r["collectives_weighted"]["wire_bytes_per_device"] / ICI_BW
        bound = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
        useful = (a["model_flops_6nd"] / a["total_flops"]
                  if a["total_flops"] else 0.0)
        live = (r["memory"]["argument_size_in_bytes"]
                + r["memory"]["temp_size_in_bytes"]
                + r["memory"]["output_size_in_bytes"])
        step = max(t_c, t_m, t_x)
        out.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh_desc"],
            "kind": r["step_kind"], "compute_s": t_c, "memory_s": t_m,
            "collective_s": t_x, "bound": bound,
            "useful_ratio": useful, "mem_gib": live / 2 ** 30,
            "step_s": step,
            "roofline_frac": t_c / step if step else 0.0,
        })
    return out


def render(rows: List[Dict], title: str) -> str:
    lines = [f"### {title}", "",
             "| arch | shape | step | compute s | memory s | coll s | bound "
             "| 6ND/total | compute/step | live GiB |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | **{r['bound']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_frac']:.2f} "
            f"| {r['mem_gib']:.1f} |")
    return "\n".join(lines)


def main(csv: bool = True) -> None:
    for name, fn in (("single-pod (16x16)", "dryrun_singlepod.json"),
                     ("multi-pod (2x16x16)", "dryrun_multipod.json"),
                     ("hillclimbed profiles (§Perf)", "dryrun_optimized.json")):
        path = os.path.join(REPO, fn)
        if not os.path.exists(path):
            print(f"roofline/{fn},0.0,missing (run python -m repro.launch.dryrun --all)")
            continue
        rows = analyze(load(path))
        if csv:
            for r in rows:
                print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},0.0,"
                      f"bound={r['bound']} compute={r['compute_s']:.2e}s "
                      f"memory={r['memory_s']:.2e}s coll={r['collective_s']:.2e}s "
                      f"frac={r['roofline_frac']:.2f} live={r['mem_gib']:.1f}GiB")
        else:
            print(render(rows, name))
            print()


if __name__ == "__main__":
    main(csv="--markdown" not in sys.argv)
