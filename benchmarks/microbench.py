"""Microbenchmarks: wall-time per call for the jitted train / decode /
outer-sync steps and the Pallas kernel reference paths, on the CPU host.

(These are CPU numbers for regression tracking — the TPU performance story
lives in the roofline analysis, which is derived from the compiled HLO.)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, iters=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6   # us


def main() -> None:
    from repro.configs.base import (DiLoCoConfig, ModelConfig,
                                    OptimizerConfig)
    from repro.core import DDPTrainer, DiLoCoTrainer
    from repro.models.transformer import build_model, init_params

    print("name,us_per_call,derived")
    cfg = ModelConfig(num_layers=4, d_model=128, num_heads=4, num_kv_heads=4,
                      d_ff=512, vocab_size=512)
    model = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    toks = jax.random.randint(jax.random.key(1), (8, 128), 0, 512)
    batch = {"tokens": toks, "labels": (toks + 1) % 512}

    ddp = DDPTrainer(model.loss, OptimizerConfig(total_steps=100))
    dstate = ddp.init(params)
    step = jax.jit(ddp.train_step)
    us = _time(lambda s, b: step(s, b)[0], dstate, batch)
    tok_s = 8 * 128 / (us / 1e6)
    print(f"train_step/ddp/{cfg.num_layers}L_d{cfg.d_model},{us:.0f},"
          f"{tok_s:.0f}tok/s params={n}")

    tr = DiLoCoTrainer(model.loss, OptimizerConfig(total_steps=100),
                       DiLoCoConfig(num_workers=4, h_inner_steps=10))
    state = tr.init(params)
    inner, outer = tr.jit_steps()
    wb = {k: jnp.broadcast_to(v, (4,) + v.shape) for k, v in batch.items()}
    us = _time(lambda s, b: inner(s, b)[0], state, wb)
    print(f"train_step/diloco_inner_k4,{us:.0f},{4*8*128/(us/1e6):.0f}tok/s")
    us = _time(outer, state)
    print(f"outer_sync/diloco_k4,{us:.0f},{n*4/1e6:.1f}MB_deltas")

    cache = model.init_cache(8, 256)
    dec = jax.jit(model.decode_step)
    db = {"token": jnp.zeros((8, 1), jnp.int32), "position": jnp.int32(0)}
    us = _time(lambda p, c, b: dec(p, c, b)[0], params, cache, db)
    print(f"decode_step/b8_cache256,{us:.0f},{8/(us/1e6):.0f}tok/s")

    # kernel reference paths (pure jnp; the Pallas bodies run interpret-mode
    # on CPU and are validated for correctness, not speed)
    from repro.kernels.flash_attention.ref import reference_attention
    q = jax.random.normal(jax.random.key(2), (1, 4, 512, 64))
    k = jax.random.normal(jax.random.key(3), (1, 2, 512, 64))
    v = jax.random.normal(jax.random.key(4), (1, 2, 512, 64))
    ref = jax.jit(lambda q, k, v: reference_attention(q, k, v))
    us = _time(ref, q, k, v)
    print(f"attention_ref/S512_H4,{us:.0f},")

    from repro.models.ssm import ssd_chunked
    x = jax.random.normal(jax.random.key(5), (2, 256, 4, 32))
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(6), (2, 256, 4)))
    A = -jnp.exp(jax.random.uniform(jax.random.key(7), (4,)))
    Bm = jax.random.normal(jax.random.key(8), (2, 256, 16))
    Cm = jax.random.normal(jax.random.key(9), (2, 256, 16))
    D = jnp.ones((4,))
    f = jax.jit(lambda *a: ssd_chunked(*a, chunk=64)[0])
    us = _time(f, x, dt, A, Bm, Cm, D)
    print(f"ssd_ref/S256_H4,{us:.0f},")


if __name__ == "__main__":
    main()
