"""Per-strategy communication benchmark: payload bytes AND modeled time.

For each sync strategy (DDP / DiLoCo / Streaming / Overlapped) this emits
the total boundary traffic over a fixed step budget plus the wall-clock the
event-driven simulator (``repro.launch.comm_sim``) models for it on the
production constants (inner step from the analytic roofline at 40% MFU,
exchange over the ``DCN_BW`` inter-pod boundary).

CSV rows: ``strategies/<arch>/<strategy>,0.0,<derived>`` with bytes,
modeled wall-clock, exposed-comm stall, and speedup over DDP.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.configs.base import DiLoCoConfig, TRAIN_4K
from repro.core.sync import (DDPSync, DiLoCoSync, OverlappedSync,
                             StreamingSync)
from repro.launch.analytic import flops_per_device
from repro.launch.comm_sim import (default_comm_model, modeled_step_time,
                                   simulate_schedule)

CHIPS_PER_WORKER = 256   # one pod per DiLoCo worker


def rows_for(arch_id: str, steps: int = 500, h: int = 100,
             delta_dtype: str = "float32"):
    cfg = get_config(arch_id)
    n = cfg.param_count()
    dcfg = DiLoCoConfig(h_inner_steps=h, delta_dtype=delta_dtype)
    step_time = modeled_step_time(
        flops_per_device(cfg, TRAIN_4K, CHIPS_PER_WORKER)["total_flops"])
    comm = default_comm_model()
    strategies = [
        DDPSync(),
        DiLoCoSync(),
        StreamingSync(num_fragments=dcfg.num_fragments),
        OverlappedSync(delay=h // 2),
    ]
    out = []
    ddp_wall = None
    for strat in strategies:
        events = strat.payload_schedule(n, steps, dcfg)
        r = simulate_schedule(events, steps, step_time, comm)
        r.update(arch=arch_id, strategy=strat.name, params=n,
                 step_time_s=step_time)
        if strat.name == "ddp":
            ddp_wall = r["wall_clock_s"]
        r["speedup_vs_ddp"] = ddp_wall / r["wall_clock_s"]
        out.append(r)
    return out


def main(arch_id: str = "nanochat-d20", steps: int = 500) -> None:
    print("name,us_per_call,derived")
    for r in rows_for(arch_id, steps):
        print(f"strategies/{r['arch']}/{r['strategy']},0.0,"
              f"bytes={r['total_bytes']/1e9:.2f}GB "
              f"wall={r['wall_clock_s']:.1f}s "
              f"compute={r['compute_s']:.1f}s "
              f"stall={r['stall_s']:.1f}s "
              f"overhead={100 * r['overhead_frac']:.1f}% "
              f"speedup_vs_ddp={r['speedup_vs_ddp']:.2f}x")


if __name__ == "__main__":
    main()
