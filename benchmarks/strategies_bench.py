"""Codec × strategy × fleet communication grid: bytes, modeled time, loss.

For every wire codec (f32 / bf16 / int8 / fp8) × sync strategy (blocking
DiLoCo / streaming fragments / overlapped full delta / pipelined DiLoCoX
fragments) × fleet (homogeneous / heterogeneous per-worker step clocks)
this emits the total boundary traffic over a fixed step budget plus the
wall-clock the event-driven simulator (``repro.launch.comm_sim``) models
on the production constants (inner step from the analytic roofline at 40%
MFU — or calibrated from a ``launch.dryrun`` JSON via ``--calibration`` —
and the ``DCN_BW`` inter-pod boundary).  A DDP f32 row anchors the
speedups, and compressed-DDP rows (per-step update exchange through the
int8 / fp8 codecs) anchor the "just compress the gradients" alternative
DiLoCo's H-step cadence is competing with.

The ``loss-impact`` rows then actually TRAIN a tiny model under a sample
of (codec, strategy) combos on identical data and report the final loss
against the f32 blocking-DiLoCo baseline — quantization is only a win if
the loss curve holds, so the grid shows bytes × wall-clock × loss side by
side.

The ``gossip`` section (merged into ``BENCH_train.json["gossip"]``)
measures the no-all-reduce claims: per-worker bytes stay FLAT as the
fleet grows 8 -> 64 (each worker ships one peer payload per round, vs
the all-reduce gather's (K-1)x), the async pair-barrier wall-clock never
exceeds the fleet-barrier baseline at the same staleness bound, and a
tiny ring-gossip training run lands within 1% of blocking DiLoCo's
final loss.

CSV rows: ``strategies/<arch>/<codec>/<strategy>/<fleet>,0.0,<derived>``,
``strategies/loss/<codec>-<strategy>,0.0,<derived>`` and
``strategies/gossip/...`` rows for the gossip section.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.configs import get_config
from repro.configs.base import DiLoCoConfig, TRAIN_4K
from repro.core.sync import (AsyncGossipSync, CompressedDDPSync, DDPSync,
                             DiLoCoSync, GossipSync, OverlappedSync,
                             PipelinedSync, StreamingSync,
                             compressed_ddp_config)
from repro.core.transport import wire_width
from repro.launch.analytic import flops_per_device
from repro.launch.comm_sim import (CommCalibration, default_comm_model,
                                   load_calibration, modeled_step_time,
                                   simulate_gossip, simulate_heterogeneous,
                                   simulate_schedule)

CHIPS_PER_WORKER = 256   # one pod per DiLoCo worker
CODECS = ("float32", "bfloat16", "int8", "fp8")
DDP_COMPRESS = ("int8", "fp8")   # per-step compressed-DDP anchor arms
# heterogeneous fleet: relative per-worker step-time multipliers (one pod
# throttled 1.5x, a couple mildly slow — a realistic mixed-generation fleet)
HET_SPEEDS = (1.0, 1.0, 1.0, 1.0, 1.05, 1.1, 1.25, 1.5)


def _strategies(h: int, fragments: int = 4):
    return [
        ("blocking", DiLoCoSync()),
        ("streaming", StreamingSync(num_fragments=fragments)),
        ("overlapped", OverlappedSync(delay=h // 2)),
        ("pipelined", PipelinedSync(num_fragments=fragments, delay=h // 2)),
    ]


def _scale_events(events, byte_scale: float):
    if byte_scale == 1.0:
        return events
    return [dataclasses.replace(
        e, bytes_per_worker=int(e.bytes_per_worker * byte_scale))
        for e in events]


def _byte_scale(calibration: Optional[CommCalibration], n_params: int
                ) -> float:
    """Ratio of the HLO-measured outer-exchange wire bytes to the analytic
    width×n for the dtype the dry-run was compiled with — scales every
    schedule proportionally (captures sharding/protocol overhead the
    width×n model misses)."""
    if calibration is None or not calibration.sync_bytes_per_worker:
        return 1.0
    analytic = wire_width(calibration.sync_dtype) * float(n_params)
    return calibration.sync_bytes_per_worker / analytic


def rows_for(arch_id: str, steps: int = 500, h: int = 100,
             calibration: Optional[CommCalibration] = None):
    cfg = get_config(arch_id)
    n = cfg.param_count()
    k = len(HET_SPEEDS)
    step_time = modeled_step_time(
        flops_per_device(cfg, TRAIN_4K, CHIPS_PER_WORKER)["total_flops"],
        calibration=calibration)
    byte_scale = _byte_scale(calibration, n)
    comm = default_comm_model()
    staleness = max(h // 4, 1)

    out = []
    ddp_events = _scale_events(
        DDPSync().payload_schedule(n, steps, DiLoCoConfig()), byte_scale)
    ddp = simulate_schedule(ddp_events, steps, step_time, comm)
    ddp.update(arch=arch_id, codec="f32", strategy="ddp",
               fleet="homogeneous", params=n, step_time_s=step_time)
    out.append(ddp)
    for gc in DDP_COMPRESS:
        ccfg = compressed_ddp_config(dataclasses.replace(
            DiLoCoConfig(num_workers=k), grad_compress=gc))
        events = _scale_events(
            CompressedDDPSync().payload_schedule(n, steps, ccfg), byte_scale)
        r = simulate_schedule(events, steps, step_time, comm)
        r.update(arch=arch_id, codec=events[0].codec, strategy="ddp_compressed",
                 fleet="homogeneous", params=n, step_time_s=step_time,
                 speedup_vs_ddp=ddp["wall_clock_s"] / r["wall_clock_s"])
        out.append(r)
    f32_diloco_bytes = None
    for codec in CODECS:
        dcfg = DiLoCoConfig(num_workers=k, h_inner_steps=h,
                            delta_dtype=codec)
        for sname, strat in _strategies(h):
            events = _scale_events(strat.payload_schedule(n, steps, dcfg),
                                   byte_scale)
            for fleet in ("homogeneous", "heterogeneous"):
                if fleet == "homogeneous":
                    r = simulate_schedule(events, steps, step_time, comm)
                else:
                    r = simulate_heterogeneous(
                        events, steps, [step_time * m for m in HET_SPEEDS],
                        comm, staleness_steps=staleness)
                r.update(arch=arch_id, codec=events[0].codec if events
                         else "f32", strategy=sname, fleet=fleet, params=n,
                         step_time_s=step_time)
                if codec == "float32" and sname == "blocking":
                    f32_diloco_bytes = r["total_bytes"]
                r["speedup_vs_ddp"] = (ddp["wall_clock_s"]
                                       / r["wall_clock_s"])
                r["xbytes_vs_f32_diloco"] = (
                    f32_diloco_bytes / max(r["total_bytes"], 1.0))
                out.append(r)
    return out


# ---------------------------------------------------------------------------
# Gossip section — fleet sweep, async-vs-barrier wall, tiny loss run
# ---------------------------------------------------------------------------

GOSSIP_FLEET = (8, 16, 32, 64)


def gossip_fleet_sweep(arch_id: str, steps: int, h: int) -> Dict:
    """Per-worker boundary bytes over ``steps`` as the fleet grows:
    ring gossip ships one peer payload per round regardless of K, the
    all-reduce DiLoCo gather ships (K-1) payloads, DDP's summable ring
    all-reduce 2(K-1)/K per step."""
    n = get_config(arch_id).param_count()
    per_k = {}
    for k in GOSSIP_FLEET:
        dcfg = DiLoCoConfig(num_workers=k, h_inner_steps=h)
        row = {}
        for name, strat in (("gossip", GossipSync(topology="ring")),
                            ("diloco", DiLoCoSync()),
                            ("ddp", DDPSync())):
            row[name] = sum(e.bytes_per_worker
                            for e in strat.payload_schedule(n, steps, dcfg))
        per_k[str(k)] = row
    lo, hi = str(GOSSIP_FLEET[0]), str(GOSSIP_FLEET[-1])
    return {"per_worker_bytes": per_k, "params": n, "steps": steps, "h": h,
            "gossip_bytes_flat": per_k[lo]["gossip"] == per_k[hi]["gossip"],
            "diloco_growth": per_k[hi]["diloco"] / per_k[lo]["diloco"]}


def gossip_async_wall(arch_id: str, steps: int, h: int,
                      calibration: Optional[CommCalibration] = None) -> Dict:
    """Modeled wall-clock on the heterogeneous fleet: async gossip's
    per-pair barriers vs the SAME payload events replayed through the
    fleet-barrier simulator at the same staleness bound.  A pair maximum
    can never exceed the fleet maximum, so async <= barrier by
    construction — the row quantifies by how much."""
    cfg = get_config(arch_id)
    n = cfg.param_count()
    k = len(HET_SPEEDS)
    step_time = modeled_step_time(
        flops_per_device(cfg, TRAIN_4K, CHIPS_PER_WORKER)["total_flops"],
        calibration=calibration)
    times = [step_time * m for m in HET_SPEEDS]
    comm = default_comm_model()
    bound = max(h // 4, 1)
    jitter = max(h // 10, 1)
    dcfg = DiLoCoConfig(num_workers=k, h_inner_steps=h, topology="ring",
                        staleness_bound=bound, h_jitter=jitter)
    strat = AsyncGossipSync(topology="ring", staleness_bound=bound,
                            jitter=jitter)
    rounds = strat.gossip_rounds(n, steps, dcfg)
    events = strat.payload_schedule(n, steps, dcfg)
    gossip = simulate_gossip(rounds, steps, times, comm,
                             staleness_steps=bound)
    barrier = simulate_heterogeneous(events, steps, times, comm,
                                     staleness_steps=bound)
    # context row: what the same fleet pays for the full all-reduce gather
    allreduce = simulate_heterogeneous(
        DiLoCoSync().payload_schedule(n, steps, dcfg), steps, times, comm,
        staleness_steps=bound)
    return {"staleness_bound": bound, "jitter": jitter, "k": k,
            "async_wall_s": gossip["wall_clock_s"],
            "barrier_wall_s": barrier["wall_clock_s"],
            "allreduce_wall_s": allreduce["wall_clock_s"],
            "async_leq_barrier": (gossip["wall_clock_s"]
                                  <= barrier["wall_clock_s"] + 1e-9)}


def gossip_loss_rows(steps: int = 48, k: int = 4, h: int = 8) -> Dict:
    """Tiny REAL training run: ring gossip vs blocking DiLoCo on
    nanochat-d20-tiny (train_bench's CPU-regime config), identical data.
    Ring gossip pays half the mixing per round, so the acceptance bar is
    a final loss within 1% of the all-reduce mean."""
    import jax
    from repro.configs import get_reduced
    from repro.configs.base import OptimizerConfig
    from repro.core import DistTrainer
    from repro.models import build_model
    from repro.models.transformer import init_params

    cfg = dataclasses.replace(
        get_reduced("nanochat-d20"), name="nanochat-d20-tiny",
        num_layers=1, d_model=16, num_heads=1, num_kv_heads=1, head_dim=16,
        d_ff=64, vocab_size=512)
    model = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    opt = OptimizerConfig(total_steps=steps, warmup_steps=0,
                          schedule="constant", learning_rate=0.02,
                          adam_lr=1e-3, muon_ns_steps=2, grad_clip=0.0)
    dcfg = DiLoCoConfig(num_workers=k, h_inner_steps=h)

    def data(step):
        key = jax.random.key(1000 + step)
        toks = jax.random.randint(key, (k, 4, 16), 0, cfg.vocab_size)
        return {"tokens": toks, "labels": (toks + 1) % cfg.vocab_size}

    losses = {}
    for name, strat in (("diloco", DiLoCoSync()),
                        ("gossip_ring", GossipSync(topology="ring"))):
        dt = DistTrainer(model.loss, opt, dcfg, strat)
        state = dt.init(params)
        _, hist = dt.run(state, data, steps)
        losses[name] = hist["loss"][-1]
    frac = ((losses["gossip_ring"] - losses["diloco"])
            / abs(losses["diloco"]))
    return {"arch": cfg.name, "steps": steps, "k": k, "h": h,
            "diloco_loss": losses["diloco"],
            "gossip_ring_loss": losses["gossip_ring"],
            "loss_vs_diloco_frac": frac,
            "within_1pct": abs(frac) <= 0.01}


def gossip_section(arch_id: str, steps: int, h: int, small: bool = False,
                   calibration: Optional[CommCalibration] = None) -> Dict:
    return {
        "fleet_sweep": gossip_fleet_sweep(arch_id, steps, h),
        "async_wall": gossip_async_wall(arch_id, steps, h,
                                        calibration=calibration),
        "loss": gossip_loss_rows(steps=32 if small else 48),
    }


# ---------------------------------------------------------------------------
# Loss impact — tiny real runs on identical data
# ---------------------------------------------------------------------------

LOSS_COMBOS = (
    ("float32", "blocking"),      # baseline
    ("bfloat16", "blocking"),
    ("int8", "blocking"),
    ("int8", "overlapped"),
    ("int8", "pipelined"),
    ("fp8", "blocking"),
    ("fp8", "pipelined"),
    ("fp8", "ddp_compressed"),    # per-step compressed-DDP anchor
)


def loss_impact_rows(steps: int = 24, workers: int = 2, h: int = 4):
    import jax
    from repro.configs.base import ModelConfig, OptimizerConfig
    from repro.core import DistTrainer
    from repro.models.transformer import build_model, init_params

    cfg = ModelConfig(name="lossgrid", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=4, d_ff=128, vocab_size=128)
    model = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    opt = OptimizerConfig(total_steps=steps, warmup_steps=0,
                          schedule="constant", learning_rate=0.02,
                          adam_lr=1e-3)

    def data(step):
        key = jax.random.key(1000 + step)
        toks = jax.random.randint(key, (workers, 4, 16), 0, cfg.vocab_size)
        return {"tokens": toks, "labels": (toks + 1) % cfg.vocab_size}

    strat_by_name = dict(_strategies(h, fragments=2))

    rows = []
    base_loss = None
    for codec, sname in LOSS_COMBOS:
        if sname == "ddp_compressed":
            dcfg = compressed_ddp_config(dataclasses.replace(
                DiLoCoConfig(num_workers=workers), grad_compress=codec))
            strat = CompressedDDPSync()
        else:
            dcfg = DiLoCoConfig(num_workers=workers, h_inner_steps=h,
                                delta_dtype=codec)
            strat = strat_by_name[sname]
        dt = DistTrainer(model.loss, opt, dcfg, strat)
        state = dt.init(params)
        state, hist = dt.run(state, data, steps)
        final = hist["loss"][-1]
        if base_loss is None:
            base_loss = final
        rows.append({"codec": codec, "strategy": sname, "final_loss": final,
                     "vs_f32_frac": (final - base_loss) / base_loss})
    return rows


def main(arch_id: str = "nanochat-d20", steps: int = 500,
         small: bool = False, calibration_path: Optional[str] = None,
         loss_impact: bool = True) -> None:
    cal = load_calibration(calibration_path, arch=arch_id) \
        if calibration_path else None
    if small:
        steps, h = 60, 20
    else:
        h = 100
    print("name,us_per_call,derived")
    if calibration_path and cal is None:
        print(f"strategies/calibration,0.0,WARNING: {calibration_path} "
              f"unreadable or has no usable entries for {arch_id} — "
              f"falling back to the analytic 40%-MFU model")
    if cal is not None:
        scale = _byte_scale(cal, get_config(arch_id).param_count())
        print(f"strategies/calibration,0.0,source={cal.source} "
              f"step_time_s={cal.step_time_s} "
              f"sync_bytes={cal.sync_bytes_per_worker} "
              f"sync_dtype={cal.sync_dtype} byte_scale={scale:.3f}")
    for r in rows_for(arch_id, steps, h=h, calibration=cal):
        extra = ""
        if "xbytes_vs_f32_diloco" in r:
            extra = (f" xbytes_vs_f32_diloco="
                     f"{r['xbytes_vs_f32_diloco']:.1f}x")
        if "straggler_s" in r:
            extra += f" straggler={r['straggler_s']:.1f}s"
        print(f"strategies/{r['arch']}/{r['codec']}/{r['strategy']}/"
              f"{r['fleet']},0.0,"
              f"bytes={r['total_bytes']/1e9:.3f}GB "
              f"wall={r['wall_clock_s']:.1f}s "
              f"compute={r['compute_s']:.1f}s "
              f"stall={r['stall_s']:.1f}s "
              f"overhead={100 * r['overhead_frac']:.1f}% "
              f"speedup_vs_ddp={r.get('speedup_vs_ddp', 1.0):.2f}x"
              + extra)
    if loss_impact:
        lsteps = 16 if small else 24
        for r in loss_impact_rows(steps=lsteps):
            print(f"strategies/loss/{r['codec']}-{r['strategy']},0.0,"
                  f"final_loss={r['final_loss']:.4f} "
                  f"vs_f32={100 * r['vs_f32_frac']:+.2f}%")

    from benchmarks.bench_io import merge_json
    sec = gossip_section(arch_id, steps, h, small=small, calibration=cal)
    merge_json("BENCH_train.json", {"gossip": sec})
    sweep = sec["fleet_sweep"]
    for k in GOSSIP_FLEET:
        row = sweep["per_worker_bytes"][str(k)]
        print(f"strategies/gossip/fleet/k{k},0.0,"
              f"gossip={row['gossip']/1e9:.3f}GB "
              f"diloco={row['diloco']/1e9:.3f}GB "
              f"ddp={row['ddp']/1e9:.3f}GB")
    print(f"strategies/gossip/fleet,0.0,"
          f"bytes_flat={sweep['gossip_bytes_flat']} "
          f"diloco_growth={sweep['diloco_growth']:.1f}x")
    aw = sec["async_wall"]
    print(f"strategies/gossip/async_wall,0.0,"
          f"async={aw['async_wall_s']:.1f}s "
          f"barrier={aw['barrier_wall_s']:.1f}s "
          f"allreduce={aw['allreduce_wall_s']:.1f}s "
          f"bound={aw['staleness_bound']} jitter={aw['jitter']} "
          f"async_leq_barrier={aw['async_leq_barrier']}")
    lo = sec["loss"]
    print(f"strategies/gossip/loss,0.0,"
          f"diloco={lo['diloco_loss']:.4f} "
          f"gossip_ring={lo['gossip_ring_loss']:.4f} "
          f"vs_diloco={100 * lo['loss_vs_diloco_frac']:+.2f}% "
          f"within_1pct={lo['within_1pct']}")


if __name__ == "__main__":
    main()
