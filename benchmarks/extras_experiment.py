"""One-shot experiment bundle (results recorded in EXPERIMENTS.md):
drift quantification (paper §4.3), adaptive-H (paper §5), delta-compression
convergence (beyond-paper)."""
import json
import sys

import jax
import jax.numpy as jnp


def main(out="runs/extras.json"):
    from repro.configs.base import DiLoCoConfig, ModelConfig, OptimizerConfig
    from repro.core import AdaptiveH, DiLoCoTrainer, FixedH, run_diloco
    from repro.data import PackedDataset, build_tokenizer, synthetic

    world = synthetic.World.make(40)
    texts = synthetic.gen_pretrain_texts(world, 4000)
    tok = build_tokenizer(texts[:1500], 512)
    ds = PackedDataset.from_texts(texts, tok, seq_len=128)
    cfg = ModelConfig(num_layers=4, d_model=128, num_heads=4, num_kv_heads=4,
                      d_ff=512, vocab_size=tok.vocab_size)
    from repro.models.transformer import build_model, init_params
    model = build_model(cfg)
    params0, _ = init_params(cfg, jax.random.key(0))
    steps = 160
    opt = OptimizerConfig(total_steps=steps, warmup_steps=10,
                          learning_rate=0.02, adam_lr=1e-3)

    def data(s):
        return {k: jnp.asarray(v) for k, v in
                ds.worker_batches(s, 4, 8).items()}

    results = {}

    # --- delta-dtype convergence (beyond-paper) -------------------------
    for dd in ("float32", "bfloat16", "int8"):
        tr = DiLoCoTrainer(model.loss, opt,
                           DiLoCoConfig(num_workers=4, h_inner_steps=20,
                                        delta_dtype=dd))
        st = tr.init(params0)
        st, h = run_diloco(tr, st, data, steps)
        results[f"delta_{dd}"] = {"final_loss": h["loss"][-1],
                                  "syncs": len(h["sync_steps"])}
        print("delta", dd, results[f"delta_{dd}"], flush=True)

    # --- drift-aware averaging (paper §5 future work) --------------------
    tr = DiLoCoTrainer(model.loss, opt,
                       DiLoCoConfig(num_workers=4, h_inner_steps=20,
                                    drift_aware=True))
    st = tr.init(params0)
    st, h = run_diloco(tr, st, data, steps)
    results["drift_aware"] = {"final_loss": h["loss"][-1]}
    print("drift_aware", results["drift_aware"], flush=True)

    # --- adaptive H (paper §5 future work) --------------------------------
    for name, hs in (("fixed_h20", FixedH(20)),
                     ("adaptive", AdaptiveH(h0=20, h_min=5, h_max=80))):
        tr = DiLoCoTrainer(model.loss, opt, DiLoCoConfig(num_workers=4))
        st = tr.init(params0)
        st, h = run_diloco(tr, st, data, steps, h_schedule=hs)
        mb = len(h["sync_steps"]) * tr.bytes_per_sync(params0) / 1e6
        results[name] = {"final_loss": h["loss"][-1],
                         "syncs": len(h["sync_steps"]), "comm_mb": mb}
        print(name, results[name], flush=True)

    import os
    os.makedirs("runs", exist_ok=True)
    with open(out, "w") as f:
        json.dump(results, f, indent=1, default=float)


if __name__ == "__main__":
    main(*sys.argv[1:])
