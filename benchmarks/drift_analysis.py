"""Drift analysis — quantifying the paper's §4.3 "representation drift"
hypothesis.

Trains the same tiny model with DDP and with DiLoCo, then measures:
  * per-worker parameter-delta dispersion during DiLoCo training,
  * pairwise CKA between workers' hidden representations just before a sync,
  * CKA between the final DiLoCo model and the final DDP model on a probe
    batch (low = drifted representation geometry, the paper's explanation
    for the Hybrid configuration's failure).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import DiLoCoConfig, ModelConfig, OptimizerConfig
from repro.core import DDPTrainer, DiLoCoTrainer, drift, run_ddp
from repro.data import PackedDataset, build_tokenizer, synthetic
from repro.models.layers import apply_norm, embed
from repro.models.transformer import _run_layers, build_model, init_params


def hidden_states(params, batch, cfg):
    """Final pre-logits hidden states (B*S, d) as the representation probe."""
    h = embed(params["embed"], batch["tokens"], cfg)
    h, _ = _run_layers(params, h, cfg, jnp.arange(h.shape[1]))
    h = apply_norm(params["final_norm"], h, cfg)
    return h.reshape(-1, h.shape[-1])


def main(steps: int = 120) -> None:
    world = synthetic.World.make(40)
    texts = synthetic.gen_pretrain_texts(world, 3000)
    tok = build_tokenizer(texts[:1200], 512)
    ds = PackedDataset.from_texts(texts, tok, seq_len=128)
    cfg = ModelConfig(num_layers=4, d_model=128, num_heads=4, num_kv_heads=4,
                      d_ff=512, vocab_size=tok.vocab_size)
    model = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    opt = OptimizerConfig(total_steps=steps, warmup_steps=10,
                          learning_rate=0.02, adam_lr=1e-3)

    probe = {k: jnp.asarray(v) for k, v in ds.batch(999999, 8).items()}
    probe_fn = jax.jit(lambda p, b: hidden_states(p, b, cfg))

    print("name,us_per_call,derived")

    # --- DiLoCo with drift measured at each sync ----------------------------
    tr = DiLoCoTrainer(model.loss, opt, DiLoCoConfig(num_workers=4,
                                                     h_inner_steps=20))
    state = tr.init(params)
    inner, outer = tr.jit_steps()
    for step in range(steps):
        b = ds.worker_batches(step, 4, 8)
        state, loss, _ = inner(state, {k: jnp.asarray(v) for k, v in b.items()})
        if (step + 1) % 20 == 0:
            d = drift.param_drift(state.worker_params, state.global_params)
            cka = drift.worker_cka_matrix(state.worker_params, probe_fn, probe)
            k = cka.shape[0]
            off = (float(jnp.sum(cka)) - k) / (k * (k - 1))
            print(f"drift/step{step+1},0.0,"
                  f"delta_norm={float(d['delta_norm_mean']):.4f} "
                  f"pairwise_param_cos={float(d['pairwise_cos']):.4f} "
                  f"worker_cka={off:.4f}")
            state = outer(state)
    diloco_params = state.global_params

    # --- DDP reference -------------------------------------------------------
    ddp = DDPTrainer(model.loss, opt)
    dstate = ddp.init(params)
    dstate, _ = run_ddp(ddp, dstate, lambda s: {
        k: jnp.asarray(v) for k, v in ds.batch(s, 32).items()}, steps)

    a = probe_fn(diloco_params, probe)
    b = probe_fn(dstate.params, probe)
    cka = float(drift.linear_cka(a, b))
    sub = float(drift.subspace_overlap(a, b, r=8))
    print(f"drift/final_diloco_vs_ddp,0.0,cka={cka:.4f} "
          f"subspace_overlap_r8={sub:.4f}")


if __name__ == "__main__":
    main()
