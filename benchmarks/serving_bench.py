"""Serving benchmark: static bucketing vs continuous batching vs
continuous + speculative decoding on a mixed-length synthetic request
stream.

The static arm is the legacy engine path: FIFO buckets of ``slots``
requests, LEFT-padded to the bucket's longest prompt, every slot decoding
until the bucket's largest ``max_new`` — the whole bucket stalls on its
slowest member.  The continuous arm runs the same requests through the
paged-KV scheduler: slots free as soon as their request finishes and queued
requests backfill immediately.  The spec arm adds the prompt-lookup
drafter (``spec_k`` drafts per slot per round) with batched paged
verification — one model traversal scores all ``spec_k + 1`` positions, so
accepted drafts multiply tokens per traversal.

The stream is deliberately *repetitive* (prompts tile short motifs — the
extraction/template-traffic regime prompt lookup exists for) and greedy,
and the bench model is briefly TRAINED on that distribution first
(``_train_copy_model``, ~10 s) so its greedy output actually follows the
templates; all arms serve the IDENTICAL stream with the IDENTICAL model,
and greedy speculation is lossless (bit-exact tokens), so the speedup is
pure scheduling/verification, never quality.

Both baseline arms are warmed before timing (the static path's
per-bucket-shape recompiles are its own, separately reported, pathology)
and all arms count only *useful* tokens — each request's own ``max_new`` —
so the static arm's padded decode steps show up as lost throughput, which
is exactly the point.

Emits ``BENCH_serving.json`` (mirroring ``train_bench.py``'s
``BENCH_train.json``) and ``name,us_per_call,derived`` CSV rows
(serving/speedup carries the headline ratios); ``--only serving`` in
``benchmarks/run.py`` runs it (``--small`` for the CI-smoke size).
"""
from __future__ import annotations

import json
import time
from typing import List, Tuple

import numpy as np

from repro.launch.serve import percentile as _pct


def make_stream(n: int = 24, seed: int = 0,
                vocab: int = 256) -> List[Tuple[List[int], int]]:
    """Mixed-length synthetic stream: (prompt_ids, max_new) per request.
    Prompts tile a short random motif — repetitive, template-like traffic
    where prompt-lookup drafting should shine."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        plen = int(rng.integers(8, 40))
        motif = rng.integers(1, vocab, size=int(rng.integers(1, 3)))
        prompt = np.tile(motif, -(-plen // len(motif)))[:plen].tolist()
        max_new = int(rng.choice([16, 24, 32, 48]))
        out.append((prompt, max_new))
    return out


REPS = 5        # best-of-N with the arms INTERLEAVED: the host is a
                # shared/quota'd CPU, so back-to-back arms sample different
                # throttling windows — alternating reps and taking each
                # arm's best measures the engines, not the scheduler du jour


def _run_static(engine, stream, slots: int):
    buckets = [stream[i:i + slots] for i in range(0, len(stream), slots)]
    t0 = time.perf_counter()
    done_at = []
    for bucket in buckets:
        prompts = [p for p, _ in bucket]
        engine.generate_ids_static(prompts,
                                   max_new=max(m for _, m in bucket))
        done_at.extend([time.perf_counter() - t0] * len(bucket))
    return time.perf_counter() - t0, done_at


def _run_continuous(engine, stream):
    from repro.serving import Request
    rs = [Request(rid=i, prompt=list(p), max_new=m)
          for i, (p, m) in enumerate(stream)]
    stats = engine.run(rs, use_time=True)
    return (stats, [r.finish_time - r.arrival for r in rs],
            [r.ttft for r in rs])


def bench_all(engines: dict, stream, slots: int):
    """Warm every arm, then alternate timed reps; best-of-REPS each.
    ``engines``: {"static": eng, "continuous": eng, "continuous_spec": eng}.
    Returns {arm: {tokens_per_s, p50, p95, ttft_p50/p95, stats?}}."""
    useful = sum(m for _, m in stream)
    _run_static(engines["static"], stream, slots)     # warm (bucket compiles)
    _run_continuous(engines["continuous"], stream)    # warm (scan step)
    _run_continuous(engines["continuous_spec"], stream)  # warm (verify step)
    best = {}
    for _ in range(REPS):
        wall, done_at = _run_static(engines["static"], stream, slots)
        if "static" not in best or wall < best["static"][0]:
            best["static"] = (wall, done_at)
        for arm in ("continuous", "continuous_spec"):
            stats, lats, ttfts = _run_continuous(engines[arm], stream)
            if arm not in best or stats["wall"] < best[arm][0]["wall"]:
                best[arm] = (stats, lats, ttfts)
    out = {}
    wall, done_at = best["static"]
    out["static"] = {"tokens_per_s": useful / wall,
                     "latency_p50": _pct(done_at, 50),
                     "latency_p95": _pct(done_at, 95)}
    for arm in ("continuous", "continuous_spec"):
        stats, lats, ttfts = best[arm]
        out[arm] = {"tokens_per_s": stats["generated"] / stats["wall"],
                    "latency_p50": _pct(lats, 50),
                    "latency_p95": _pct(lats, 95),
                    "ttft_p50": _pct(ttfts, 50),
                    "ttft_p95": _pct(ttfts, 95),
                    "stats": stats}
    return out


def _train_copy_model(model, params, steps: int = 80, lr: float = 3e-3):
    """Teach the bench model the stream's repetitive structure (~10 s on
    the CI CPU): a few AdamW steps on motif-tiled sequences — the
    template/extraction-traffic regime prompt-lookup drafting exists for.
    With random weights a "repetitive stream" would be a misnomer: greedy
    *output* would still be chaotic, and no drafter (this one or a learned
    one) could beat that.  All arms serve the same trained model, so the
    comparison stays apples-to-apples."""
    import jax
    from repro.optim.adamw import adamw
    from repro.optim.base import apply_updates

    opt = adamw(lr=lr)
    ostate = opt.init(params)

    def batch(step, B=8, S=48):
        rng = np.random.default_rng(step)
        rows = []
        for _ in range(B):
            motif = rng.integers(1, 256, size=int(rng.integers(1, 4)))
            rows.append(np.tile(motif, -(-(S + 1) // len(motif)))[:S + 1])
        arr = np.stack(rows).astype(np.int32)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}

    @jax.jit
    def step(params, ostate, b, s):
        (_, _), g = jax.value_and_grad(model.loss, has_aux=True)(params, b)
        up, ostate = opt.update(g, ostate, params, s)
        return apply_updates(params, up), ostate

    for s in range(steps):
        params, ostate = step(params, ostate, batch(s), s)
    return params


def bench_serving(n: int = 24, slots: int = 8, spec_k: int = 9,
                  train_steps: int = 80) -> dict:
    import jax
    from repro.configs.base import ModelConfig
    from repro.kernels.common import pallas_mode
    from repro.models.transformer import build_model, init_params
    from repro.serving import Engine

    cfg = ModelConfig(name="bench-serve", num_layers=4, d_model=128,
                      num_heads=4, num_kv_heads=4, d_ff=512, vocab_size=256)
    model = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    params = _train_copy_model(model, params, steps=train_steps)
    kw = dict(max_len=128, num_slots=slots, block_size=16)
    # prefill_chunk=12: the sweet spot on CPU between per-call dispatch
    # amortization and finish-boundary waste for this stream's max_new mix
    base = Engine(model, params, prefill_chunk=12, **kw)
    spec = Engine(model, params, spec_k=spec_k, **kw)
    stream = make_stream(n=n)

    res = bench_all({"static": base, "continuous": base,
                     "continuous_spec": spec}, stream, slots)
    cs = res["continuous"].pop("stats")
    ss = res["continuous_spec"].pop("stats")
    res["continuous"]["slot_util"] = (
        (cs["generated"] + cs["prefill_tokens"]) / max(cs["token_slots"], 1))
    res["continuous"]["step_calls"] = cs["step_calls"]
    res["continuous_spec"].update(
        step_calls=ss["step_calls"], accept_rate=ss["accept_rate"],
        drafted=ss["drafted"], accepted=ss["accepted"],
        rolled_back=ss["rolled_back"])
    res["speedup_continuous"] = (res["continuous"]["tokens_per_s"]
                                 / res["static"]["tokens_per_s"])
    res["speedup_spec"] = (res["continuous_spec"]["tokens_per_s"]
                           / res["continuous"]["tokens_per_s"])
    res["speedup_spec_vs_static"] = (res["continuous_spec"]["tokens_per_s"]
                                     / res["static"]["tokens_per_s"])
    res["spec_k"] = spec_k
    res["pallas_mode"] = pallas_mode()
    res["backend"] = jax.default_backend()
    res["attn_impl"] = base.attn_impl
    return res


def bench_kv_capacity(slots: int = 8, n: int = 10) -> dict:
    """Quantized-KV capacity arm: the SAME byte budget, a bf16 pool vs an
    fp8 pool, identical fixed-shape request stream.

    Every request needs exactly ``ceil((plen + max_new) / bs)`` pool
    blocks, so ``peak_admitted`` is a pure pool-capacity readout: the fp8
    pool packs ~1.8x the blocks into the budget (narrow payload + f32
    per-token-per-head scales), and block-granular admission floors that
    into 2x the concurrently admitted requests at this budget point.
    Throughput must hold (fp8 within 10% of bf16) or the capacity is free
    only on paper."""
    import dataclasses
    import time

    import jax
    from repro.configs.base import ModelConfig
    from repro.models.transformer import (build_model, init_params,
                                          paged_block_bytes)
    from repro.serving import Engine, Request

    cfg = ModelConfig(name="bench-kv", num_layers=4, d_model=128,
                      num_heads=4, num_kv_heads=4, d_ff=512, vocab_size=256)
    params, _ = init_params(cfg, jax.random.key(0))
    bs, plen, max_new = 16, 16, 40            # 56 tokens -> 4 blocks/request
    pool_bytes = 10 * paged_block_bytes(
        dataclasses.replace(cfg, kv_cache_dtype="bf16"), bs)
    rng = np.random.default_rng(7)

    def stream():
        return [Request(rid=i,
                        prompt=rng.integers(1, 256, size=plen).tolist(),
                        max_new=max_new)
                for i in range(n)]

    out = {"pool_bytes": pool_bytes, "blocks_per_request": 4}
    for arm, kv in (("bf16", "bf16"), ("fp8", "fp8")):
        acfg = dataclasses.replace(cfg, kv_cache_dtype=kv)
        eng = Engine(build_model(acfg), params, max_len=64, num_slots=slots,
                     block_size=bs, pool_bytes=pool_bytes, prefill_chunk=12)
        eng.run(stream(), use_time=True)                  # warm
        best = None
        for _ in range(3):
            stats = eng.run(stream(), use_time=True)
            if best is None or stats["wall"] < best["wall"]:
                best = stats
        out[arm] = {"tokens_per_s": best["generated"] / best["wall"],
                    "peak_admitted": best["peak_admitted"],
                    "num_blocks": eng.kv_report()["num_blocks"],
                    "bytes_per_block": eng.bytes_per_block,
                    "kv_pool_dtype": eng.kv_report()["kv_pool_dtype"]}
    out["admitted_ratio"] = out["fp8"]["peak_admitted"] \
        / max(out["bf16"]["peak_admitted"], 1)
    out["tokens_per_s_ratio"] = out["fp8"]["tokens_per_s"] \
        / max(out["bf16"]["tokens_per_s"], 1e-9)
    return out


def bench_prefix_sharing(slots: int = 8, n: int = 12,
                         small: bool = False) -> dict:
    """Prefix-sharing capacity arm: the SAME pool byte budget, sharing off
    vs on, identical shared-template request stream (a long common system
    prompt + a short distinct user tail — the chat-serving regime the
    radix cache exists for).

    With sharing off every request reserves its full footprint, so the
    fixed pool admits ``num_blocks // blocks_per_request`` requests at a
    time and prefills the whole template per request.  With sharing on the
    template blocks are resident ONCE (tree reference), each request
    reserves only its tail budget and skips the matched prefill, so the
    same bytes admit more concurrent requests AND each admission reaches
    sampling sooner — the admitted and tokens/s ratios are the headline;
    TTFT is the per-request view of the same win."""
    import dataclasses
    import time

    import jax
    from repro.configs.base import ModelConfig
    from repro.models.transformer import (build_model, init_params,
                                          paged_block_bytes)
    from repro.serving import Engine, Request

    cfg = ModelConfig(name="bench-prefix", num_layers=4, d_model=128,
                      num_heads=4, num_kv_heads=4, d_ff=512, vocab_size=256)
    model = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    bs, max_new = 16, 16
    tpl_blocks = 4 if small else 6
    pool_blocks = 12 if small else 24
    if small:
        slots, n = 6, 8
    tpl = np.random.default_rng(11).integers(
        1, 256, size=tpl_blocks * bs).tolist()
    pool_bytes = pool_blocks * paged_block_bytes(cfg, bs)
    bpr = (len(tpl) + 8 + max_new + bs - 1) // bs   # blocks per request

    def stream():
        # distinct first tail token per request -> no cross-tail forks;
        # re-serving the same stream forks each request's OWN cached tail
        return [Request(rid=i, prompt=tpl + [200 + i] * 8, max_new=max_new)
                for i in range(n)]

    out = {"pool_bytes": pool_bytes, "num_blocks": pool_blocks,
           "blocks_per_request": bpr, "template_tokens": len(tpl),
           "requests": n, "slots": slots}
    for arm, share in (("sharing_off", False), ("sharing_on", True)):
        eng = Engine(model, params, max_len=(tpl_blocks + 2) * bs,
                     num_slots=slots, block_size=bs, pool_bytes=pool_bytes,
                     prefill_chunk=12, prefix_cache=share)
        eng.run(stream(), use_time=True)    # warm: compiles + primes cache
        best, rs = None, None
        for _ in range(3):
            reqs = stream()
            stats = eng.run(reqs, use_time=True)
            if best is None or stats["wall"] < best["wall"]:
                best, rs = stats, reqs
        ttfts = [r.ttft for r in rs if r.first_token_time is not None]
        out[arm] = {"tokens_per_s": best["generated"] / best["wall"],
                    "peak_admitted": best["peak_admitted"],
                    "prefill_tokens": best["prefill_tokens"],
                    "ttft_p50": _pct(ttfts, 50),
                    "ttft_p95": _pct(ttfts, 95)}
        if share:
            p = best["prefix"]
            out[arm].update(
                hit_rate=p["hit_rate"], matched_frac=p["matched_frac"],
                shared_blocks=p["resident_blocks"], forked=p["forked"],
                bytes_saved=p["bytes_saved"],
                skipped_prefill_tokens=best["prefix_skipped_tokens"])
    out["admitted_ratio"] = out["sharing_on"]["peak_admitted"] \
        / max(out["sharing_off"]["peak_admitted"], 1)
    out["tokens_per_s_ratio"] = out["sharing_on"]["tokens_per_s"] \
        / max(out["sharing_off"]["tokens_per_s"], 1e-9)
    return out


def main(n: int = 24, slots: int = 8, small: bool = False) -> None:
    kw = {}
    if small:
        n, slots = 10, 4
        kw["train_steps"] = 40
    res = bench_serving(n=n, slots=slots, **kw)
    res["kv_capacity"] = bench_kv_capacity(n=6 if small else 10)
    res["prefix_sharing"] = bench_prefix_sharing(small=small)
    with open("BENCH_serving.json", "w") as f:
        json.dump(res, f, indent=1)
    print("name,us_per_call,derived")
    for arm in ("static", "continuous", "continuous_spec"):
        r = res[arm]
        tps = r["tokens_per_s"]
        extra = ""
        if arm == "continuous":
            extra = (f" step_calls={r['step_calls']}"
                     f" slot_util={r['slot_util']:.2f}")
        if arm == "continuous_spec":
            extra = (f" step_calls={r['step_calls']}"
                     f" accept_rate={r['accept_rate']:.2f}"
                     f" rolled_back={r['rolled_back']}")
        print(f"serving/{arm},{1e6 / tps:.0f},"
              f"tokens_per_s={tps:.1f} p50={r['latency_p50']:.2f}s "
              f"p95={r['latency_p95']:.2f}s{extra}")
    print(f"serving/speedup,0.0,"
          f"continuous_vs_static={res['speedup_continuous']:.2f}x "
          f"spec_vs_continuous={res['speedup_spec']:.2f}x "
          f"spec_vs_static={res['speedup_spec_vs_static']:.2f}x "
          f"(acceptance: spec_vs_continuous >= 1.3x)")
    print(f"serving/pallas,0.0,attn_impl={res['attn_impl']} "
          f"mode={res['pallas_mode']} backend={res['backend']}")
    kv = res["kv_capacity"]
    for arm in ("bf16", "fp8"):
        a = kv[arm]
        print(f"serving/kv_capacity/{arm},0.0,"
              f"tokens_per_s={a['tokens_per_s']:.1f} "
              f"peak_admitted={a['peak_admitted']} "
              f"num_blocks={a['num_blocks']} "
              f"bytes_per_block={a['bytes_per_block']}")
    print(f"serving/kv_capacity/ratio,0.0,"
          f"admitted={kv['admitted_ratio']:.1f}x "
          f"tokens_per_s={kv['tokens_per_s_ratio']:.2f}x "
          f"pool_bytes={kv['pool_bytes']} "
          f"(acceptance: admitted >= 2x, tokens_per_s >= 0.9x)")
    px = res["prefix_sharing"]
    for arm in ("sharing_off", "sharing_on"):
        a = px[arm]
        extra = ""
        if arm == "sharing_on":
            extra = (f" hit_rate={a['hit_rate']:.2f}"
                     f" shared_blocks={a['shared_blocks']}"
                     f" bytes_saved={a['bytes_saved']}")
        print(f"serving/prefix/{arm},0.0,"
              f"tokens_per_s={a['tokens_per_s']:.1f} "
              f"peak_admitted={a['peak_admitted']} "
              f"prefill_tokens={a['prefill_tokens']} "
              f"ttft_p50={a['ttft_p50']:.3f}s{extra}")
    print(f"serving/prefix/ratio,0.0,"
          f"admitted={px['admitted_ratio']:.1f}x "
          f"tokens_per_s={px['tokens_per_s_ratio']:.2f}x "
          f"pool_bytes={px['pool_bytes']} "
          f"(acceptance: admitted >= 1.5x, tokens_per_s >= 1.3x)")


if __name__ == "__main__":
    main()
