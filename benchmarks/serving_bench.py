"""Serving benchmark: static bucketing vs continuous batching on a
mixed-length synthetic request stream.

The static arm is the legacy engine path: FIFO buckets of ``slots``
requests, LEFT-padded to the bucket's longest prompt, every slot decoding
until the bucket's largest ``max_new`` — the whole bucket stalls on its
slowest member.  The continuous arm runs the same requests through the
paged-KV scheduler: slots free as soon as their request finishes and queued
requests backfill immediately.

Both arms are warmed before timing (the static path's per-bucket-shape
recompiles are its own, separately reported, pathology) and both count only
*useful* tokens — each request's own ``max_new`` — so the static arm's
padded decode steps show up as lost throughput, which is exactly the point.

Prints ``name,us_per_call,derived`` CSV rows (serving/speedup carries the
headline continuous-vs-static tokens/s ratio).
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.launch.serve import percentile as _pct


def make_stream(n: int = 24, seed: int = 0,
                vocab: int = 256) -> List[Tuple[List[int], int]]:
    """Mixed-length synthetic stream: (prompt_ids, max_new) per request."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        plen = int(rng.integers(4, 40))
        max_new = int(rng.choice([4, 8, 12, 16, 24, 32, 48]))
        out.append((rng.integers(1, vocab, size=plen).tolist(), max_new))
    return out


REPS = 3        # best-of-N with the two arms INTERLEAVED: the host is a
                # shared/quota'd CPU, so back-to-back arms sample different
                # throttling windows — alternating reps and taking each
                # arm's best measures the engines, not the scheduler du jour


def _run_static(engine, stream, slots: int):
    buckets = [stream[i:i + slots] for i in range(0, len(stream), slots)]
    t0 = time.perf_counter()
    done_at = []
    for bucket in buckets:
        prompts = [p for p, _ in bucket]
        engine.generate_ids_static(prompts,
                                   max_new=max(m for _, m in bucket))
        done_at.extend([time.perf_counter() - t0] * len(bucket))
    return time.perf_counter() - t0, done_at


def _run_continuous(engine, stream):
    from repro.serving import Request
    rs = [Request(rid=i, prompt=list(p), max_new=m)
          for i, (p, m) in enumerate(stream)]
    stats = engine.run(rs, use_time=True)
    return stats, [r.finish_time - r.arrival for r in rs]


def bench_both(engine, stream, slots: int):
    """Warm both arms, then alternate timed reps; best-of-REPS each.
    Returns (static (tps, p50, p95), continuous (tps, p50, p95, stats))."""
    useful = sum(m for _, m in stream)
    _run_static(engine, stream, slots)            # warm (bucket compiles)
    _run_continuous(engine, stream)               # warm (persistent step)
    best_s, best_c = None, None
    for _ in range(REPS):
        wall, done_at = _run_static(engine, stream, slots)
        if best_s is None or wall < best_s[0]:
            best_s = (wall, done_at)
        stats, lats = _run_continuous(engine, stream)
        if best_c is None or stats["wall"] < best_c[0]["wall"]:
            best_c = (stats, lats)
    wall, done_at = best_s
    stats, lats = best_c
    return ((useful / wall, _pct(done_at, 50), _pct(done_at, 95)),
            (stats["generated"] / stats["wall"], _pct(lats, 50),
             _pct(lats, 95), stats))


def main(n: int = 24, slots: int = 8) -> None:
    import jax
    from repro.configs.base import ModelConfig
    from repro.kernels.decode_attention import pallas_mode
    from repro.models.transformer import build_model, init_params
    from repro.serving import Engine

    print("name,us_per_call,derived")
    cfg = ModelConfig(name="bench-serve", num_layers=4, d_model=128,
                      num_heads=4, num_kv_heads=4, d_ff=512, vocab_size=256)
    model = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    # prefill_chunk=12: the sweet spot on CPU between per-call dispatch
    # amortization and finish-boundary waste for this stream's max_new mix
    engine = Engine(model, params, max_len=128, num_slots=slots,
                    block_size=16, prefill_chunk=12)
    stream = make_stream(n=n)

    (s_tps, s_p50, s_p95), (c_tps, c_p50, c_p95, stats) = bench_both(
        engine, stream, slots)
    print(f"serving/static,{1e6 / s_tps:.0f},"
          f"tokens_per_s={s_tps:.1f} p50={s_p50:.2f}s p95={s_p95:.2f}s")
    util = (stats["generated"] + stats["prefill_tokens"]) / max(
        stats["token_slots"], 1)
    print(f"serving/continuous,{1e6 / c_tps:.0f},"
          f"tokens_per_s={c_tps:.1f} p50={c_p50:.2f}s p95={c_p95:.2f}s "
          f"step_calls={stats['step_calls']} slot_util={util:.2f}")

    print(f"serving/speedup,0.0,continuous_vs_static={c_tps / s_tps:.2f}x "
          f"(acceptance >= 1.3x)")
    print(f"serving/pallas,0.0,attn_impl={engine.attn_impl} "
          f"mode={pallas_mode()} backend={jax.default_backend()}")


if __name__ == "__main__":
    main()
