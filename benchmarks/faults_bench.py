"""Elastic-training degradation benchmark: K=8 DiLoCo through a scripted
crash/rejoin scenario vs the same run fault-free, on identical data.

The tentpole robustness claim, measured: losing 2 of 8 workers mid-run
(one of them rejoining later at the current anchor) must cost almost
nothing — the acceptance bar is a final loss within 2% of the no-fault
run.  The section also records the per-round quorum sizes (the fleet
shrinking 8 -> 7 -> 6 -> 7 across the scripted events), every fault
record the tracker emitted, and the rejoin drift metrics (parameter-delta
norm + cosine to the live mean at the adoption boundary) — the
observability surface ``core/drift.py`` feeds.

Merged into ``BENCH_train.json["faults"]`` (see ``bench_io.merge_json``).
CSV rows: ``faults/<arch>/...,0.0,<derived>``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


def degradation_rows(steps: int = 48, k: int = 8, h: int = 8) -> Dict:
    import jax
    from repro.configs import get_reduced
    from repro.configs.base import DiLoCoConfig, OptimizerConfig
    from repro.core import DistTrainer, make_strategy
    from repro.core.faults import FaultSchedule

    cfg = dataclasses.replace(
        get_reduced("nanochat-d20"), name="nanochat-d20-tiny",
        num_layers=1, d_model=16, num_heads=1, num_kv_heads=1, head_dim=16,
        d_ff=64, vocab_size=512)
    from repro.models import build_model
    from repro.models.transformer import init_params
    model = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    opt = OptimizerConfig(total_steps=steps, warmup_steps=0,
                          schedule="constant", learning_rate=0.02,
                          adam_lr=1e-3, muon_ns_steps=2, grad_clip=0.0)
    dcfg = DiLoCoConfig(num_workers=k, h_inner_steps=h, strategy="diloco")

    def data(step):
        key = jax.random.key(1000 + step)
        toks = jax.random.randint(key, (k, 4, 16), 0, cfg.vocab_size)
        return {"tokens": toks, "labels": (toks + 1) % cfg.vocab_size}

    # 2 crashes + 1 rejoin, spread over the middle of the run: worker 2
    # dies in round 2, worker 5 in round 3, worker 2 returns for the
    # second-to-last round and adopts the current anchor
    c1, c2, rj = h + h // 2, 2 * h + h // 2, steps - 2 * h - 1
    spec = f"crash:2@{c1},crash:5@{c2},rejoin:2@{rj}"

    losses = {}
    faulted_hist = None
    for name, faults in (("no_fault", None),
                         ("faulted", FaultSchedule.from_spec(spec))):
        dt = DistTrainer(model.loss, opt, dcfg, make_strategy(dcfg))
        state = dt.init(params)
        _, hist = dt.run(state, data, steps, faults=faults)
        losses[name] = float(hist["loss"][-1])
        if name == "faulted":
            faulted_hist = hist
    frac = ((losses["faulted"] - losses["no_fault"])
            / abs(losses["no_fault"]))
    return {
        "arch": cfg.name, "steps": steps, "k": k, "h": h,
        "schedule": spec,
        "no_fault_loss": losses["no_fault"],
        "faulted_loss": losses["faulted"],
        "loss_vs_no_fault_frac": frac,
        "within_2pct": abs(frac) <= 0.02,
        "quorum_per_round": [list(q) for q in faulted_hist["quorum"]],
        "events": [list(e) for e in faulted_hist.get("fault", [])],
        "rejoin_drift": [list(r)
                         for r in faulted_hist.get("rejoin_drift", [])],
    }


def main(small: bool = False) -> None:
    steps, h = (32, 6) if small else (48, 8)
    sec = degradation_rows(steps=steps, h=h)
    from benchmarks.bench_io import merge_json
    merge_json("BENCH_train.json", {"faults": sec})
    print("name,us_per_call,derived")
    print(f"faults/{sec['arch']}/degradation,0.0,"
          f"no_fault={sec['no_fault_loss']:.4f} "
          f"faulted={sec['faulted_loss']:.4f} "
          f"delta={100 * sec['loss_vs_no_fault_frac']:+.2f}% "
          f"within_2pct={sec['within_2pct']}")
    print(f"faults/{sec['arch']}/quorum,0.0,"
          f"sizes={[n for _, n in sec['quorum_per_round']]}")
    for step, worker, norm, cos in sec["rejoin_drift"]:
        print(f"faults/{sec['arch']}/rejoin_drift,0.0,"
              f"step={step} worker={worker} norm={norm:.4f} cos={cos:.4f}")


if __name__ == "__main__":
    main()
