"""Benchmark entry point — one benchmark per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all fast benches
  PYTHONPATH=src python -m benchmarks.run --only comm,roofline

| paper artifact              | benchmark                                   |
|-----------------------------|---------------------------------------------|
| Table 1 (DDP/DiLoCo/Hybrid) | table1 (reads runs/table1/table1.json, the  |
|                             | output of examples/pipeline_table1.py)      |
| Fig 1-3 loss curves         | table1 (per-stage loss trajectories)        |
| "~100x comm reduction"      | comm                                        |
| codec x strategy x fleet    | strategies (bytes x modeled wall-clock x    |
| grid (DiLoCoX transport)    | loss-impact, event-driven comm simulator)   |
| §4.3 drift hypothesis       | drift                                       |
| TPU deployment (e,g)        | roofline (from the dry-run JSONs)           |
| engine/step latencies       | micro                                       |
| static vs continuous vs     | serving (paged-KV scheduler vs buckets vs   |
| continuous+spec batch       | prompt-lookup speculative decode,           |
|                             | BENCH_serving.json)                         |
| device-speed inner loop     | train (per-step vs scan-chunked vs          |
|                             | chunked+donate+prefetch, BENCH_train.json)  |
| elastic fault tolerance     | faults (K=8 crash/rejoin degradation vs     |
|                             | no-fault loss, BENCH_train.json["faults"])  |

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import json
import os

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def bench_table1() -> None:
    print("name,us_per_call,derived")
    path = os.path.join(REPO, "runs", "table1", "table1.json")
    if not os.path.exists(path):
        print("table1/missing,0.0,run examples/pipeline_table1.py first")
        return
    with open(path) as f:
        res = json.load(f)
    for method, r in res.items():
        for stage, e in r["stages"].items():
            t = e.get("tasks", {})
            c = e.get("core", {})
            print(f"table1/{stage}/{method},0.0,"
                  f"loss={e['loss_last']:.4f} "
                  f"core={c.get('core_proxy', float('nan')):.4f} "
                  f"mc={t.get('mc', float('nan')):.4f} "
                  f"arith={t.get('arith', float('nan')):.4f} "
                  f"pattern={t.get('pattern', float('nan')):.4f} "
                  f"chatcore={t.get('chatcore', float('nan')):.4f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None,
                    help="comma list: micro,comm,strategies,roofline,"
                         "table1,drift,serving,train,faults")
    ap.add_argument("--small", action="store_true",
                    help="CI-smoke sizes (fewer steps, smaller loss runs)")
    ap.add_argument("--calibration", type=str, default=None,
                    help="launch.dryrun JSON (e.g. dryrun_outer.json) to "
                         "calibrate the strategies grid's step time / sync "
                         "bytes against")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    if want("micro"):
        from benchmarks import microbench
        microbench.main()
    if want("comm"):
        from benchmarks import comm_volume
        comm_volume.main()
    if want("strategies"):
        from benchmarks import strategies_bench
        strategies_bench.main(small=args.small,
                              calibration_path=args.calibration)
    if want("roofline"):
        from benchmarks import roofline
        roofline.main(csv=True)
    if want("table1"):
        bench_table1()
    if want("drift"):
        from benchmarks import drift_analysis
        drift_analysis.main(steps=80)
    if want("serving"):
        from benchmarks import serving_bench
        serving_bench.main(small=args.small)
    if want("train"):
        from benchmarks import train_bench
        train_bench.main(small=args.small)
    if want("faults"):
        from benchmarks import faults_bench
        faults_bench.main(small=args.small)


if __name__ == "__main__":
    main()
