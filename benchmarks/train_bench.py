"""Training-loop benchmark: the pre-PR per-step loop vs scan-fused chunks
vs chunks + donation + async prefetch (ISSUE 4's headline numbers).

Four arms run the SAME DiLoCo config (nanochat-d20-tiny — the d20 family
shrunk to the CPU CI regime where fixed per-step costs rival device
compute) on the same synthetic token stream:

  legacy_per_step   the pre-PR loop, faithfully: one dispatch + one EAGER
                    ``float(jnp.mean(loss))`` device round-trip + one
                    synchronous host batch assembly PER INNER STEP
  per_step          today's ``run(chunked=False)`` reference loop (still
                    per-step dispatch, but the loss sync is a raw fetch
                    + host-side mean)
  chunked           ``lax.scan`` from sync boundary to sync boundary, one
                    loss fetch per chunk (H fewer dispatches + host syncs
                    per outer round)
  chunked_donate_prefetch
                    chunked with donated state buffers and the background
                    ``Prefetcher`` assembling/device-putting batches
                    ahead of the loop

steps/s uses each run's ``step_seconds`` (median per-step seconds —
robust to the first-chunk compile spike), so the numbers feed the same
comm-simulator calibration contract as training runs.  The headline
``speedup_full`` compares chunked+donate+prefetch against the pre-PR
loop (``legacy_per_step``), which is the loop this PR replaced.

Emits ``BENCH_train.json`` and ``name,us_per_call,derived`` CSV rows;
``--only train`` in ``benchmarks/run.py`` runs it (``--small`` for the
CI-smoke size).
"""
from __future__ import annotations

import os
import time
from typing import Dict

# tiny-op regime: one XLA worker thread beats thread-pool handoffs for
# sub-ms kernels, and it leaves the second CI core free for the
# prefetcher (best-effort: a no-op if another bench initialised jax
# first, and force-overridable by setting XLA_FLAGS yourself)
os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1")

import jax
import jax.numpy as jnp
import numpy as np


def _make_data_fn(k: int, B: int, S: int, tok, texts, seed: int = 0):
    """Tokenise-on-demand per-worker batches — the honest host cost of a
    pretraining data pipeline (BPE encode + pack + shard per step), which
    the per-step loop pays synchronously and the prefetcher overlaps."""
    def data(step):
        need = B * (S + 1)
        outs = []
        for w in range(k):
            rng = np.random.default_rng((seed, step, w))
            ids: list = []
            while len(ids) < need:
                ids.extend(tok.encode(texts[int(rng.integers(len(texts)))]))
            outs.append(np.asarray(ids[:need], np.int32).reshape(B, S + 1))
        c = np.stack(outs)
        return {"tokens": c[:, :, :-1], "labels": c[:, :, 1:]}
    return data


def _legacy_per_step_run(dt, state, data_fn, num_steps: int):
    """The pre-PR ``DistTrainer.run`` loop, verbatim: per-step jit
    dispatch, per-step EAGER ``float(jnp.mean(loss))`` host round-trip,
    synchronous per-step batch assembly.  This is the baseline the
    chunked hot path replaced."""
    eng = dt.engine()
    runner = dt.strategy.bind(eng, state.global_params, donate=False)
    inner_jit = jax.jit(eng.inner_step)
    losses = []
    durs = []
    t_prev = time.time()
    for step in range(num_steps):
        state, loss, _ = inner_jit(state, data_fn(step))
        loss_mean = float(jnp.mean(loss))
        losses.append(loss_mean)
        state, _ = runner.after_step(state, step, loss_mean)
        t_now = time.time()
        durs.append(t_now - t_prev)
        t_prev = t_now
    state, _ = runner.finalize(state, num_steps)
    return state, {"loss": losses,
                   "step_seconds": sorted(durs)[len(durs) // 2]}


def bench_train(steps: int = 96, k: int = 2, B: int = 6, S: int = 16,
                h: int = 32, small: bool = False) -> Dict:
    import dataclasses

    from repro.configs import get_reduced
    from repro.configs.base import DiLoCoConfig, OptimizerConfig
    from repro.core import DiLoCoSync, DistTrainer
    from repro.data import build_tokenizer, synthetic
    from repro.models import build_model
    from repro.models.transformer import init_params

    if small:
        steps, h = 48, 16

    # nanochat-d20-tiny: the d20 family shrunk until per-step FIXED costs
    # (dispatch, host loss sync, batch assembly) rival device compute —
    # the regime the chunked loop exists to fix, and the regime every
    # tiny-config CI run and paper-repro simulation actually lives in
    # (muon_ns_steps/grad_clip trimmed for the same reason: identical in
    # every arm, fewer sub-ms ops drowning the loop mechanics)
    cfg = dataclasses.replace(
        get_reduced("nanochat-d20"), name="nanochat-d20-tiny",
        num_layers=1, d_model=16, num_heads=1, num_kv_heads=1, head_dim=16,
        d_ff=64, vocab_size=512)
    model = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    opt_cfg = OptimizerConfig(total_steps=steps, warmup_steps=0,
                              schedule="constant", learning_rate=0.02,
                              adam_lr=1e-3, muon_ns_steps=2, grad_clip=0.0)
    dcfg = DiLoCoConfig(num_workers=k, h_inner_steps=h)
    world = synthetic.World.make(40, seed=1234)
    texts = synthetic.gen_pretrain_texts(world, 2000, seed=0)
    tok = build_tokenizer(texts[:500], cfg.vocab_size)
    data = _make_data_fn(k, B, S, tok, texts)

    arms = {
        "legacy_per_step": None,
        "per_step": dict(chunked=False),
        "chunked": dict(chunked=True, donate=False, prefetch=0),
        "chunked_donate_prefetch": dict(chunked=True, donate=True,
                                        prefetch=2 * h),
    }
    results: Dict = {"config": {"arch": cfg.name, "steps": steps, "k": k,
                                "B": B, "S": S, "h": h}}
    tokens_per_step = k * B * S
    for name, kw in arms.items():
        dt = DistTrainer(model.loss, opt_cfg, dcfg, DiLoCoSync())
        state = dt.init(params)
        if kw is None:
            _, hist = _legacy_per_step_run(dt, state, data, steps)
        else:
            _, hist = dt.run(state, data, steps, **kw)
        sec = hist["step_seconds"]
        results[name] = {
            "step_seconds": sec,
            "steps_per_s": 1.0 / sec if sec else float("inf"),
            "tokens_per_s": tokens_per_step / sec if sec else float("inf"),
            "loss_first": hist["loss"][0],
            "loss_last": hist["loss"][-1],
        }
    legacy = results["legacy_per_step"]["step_seconds"]
    results["speedup_chunked"] = (legacy
                                  / results["chunked"]["step_seconds"])
    results["speedup_full"] = (
        legacy / results["chunked_donate_prefetch"]["step_seconds"])
    # the arms run identical math on identical data (chunked-vs-per-step
    # bit-exactness is enforced by tests/test_chunked.py) — a diverging
    # loss beyond reduction-order noise means the benchmark is comparing
    # different runs
    losses = [results[a]["loss_last"] for a in arms]
    results["losses_agree"] = all(abs(l - losses[0]) < 1e-5 for l in losses)

    # -- fp8 wire arm: outer-sync bytes vs the int8 pipelined reference ----
    # Pipelined DiLoCoX syncs ONE n/F fragment per outer round, so doubling
    # the fragment count halves the boundary bytes outright (each parameter
    # then syncs every F·H steps).  fp8's error-fed codec tolerates the
    # staler per-fragment cadence, so fp8 F=8 is the same wire discipline
    # as int8 F=4 at half the bytes — the claim this arm measures.  The
    # f32 pipelined arm anchors the loss comparison at MATCHED strategy
    # (same cadence family, lossless wire), so loss_vs_f32 isolates what
    # the codec + halved fragments cost, not what pipelining itself costs
    # relative to blocking DiLoCo.
    from repro.core.sync import PipelinedSync
    n = cfg.param_count()
    base_loss = None
    results["wire"] = {}
    for name, codec, frags in (("f32_pipelined", "float32", 4),
                               ("int8_pipelined", "int8", 4),
                               ("fp8_pipelined", "fp8", 8)):
        wcfg = dataclasses.replace(dcfg, strategy="pipelined",
                                   delta_dtype=codec, num_fragments=frags)
        strat = PipelinedSync(num_fragments=frags, delay=h // 2)
        dt = DistTrainer(model.loss, opt_cfg, wcfg, strat)
        state = dt.init(params)
        _, hist = dt.run(state, data, steps)
        sync_bytes = sum(e.bytes_per_worker
                         for e in strat.payload_schedule(n, steps, wcfg))
        if base_loss is None:
            base_loss = hist["loss"][-1]
        results["wire"][name] = {
            "codec": codec, "fragments": frags,
            "outer_sync_bytes": sync_bytes,
            "loss_last": hist["loss"][-1],
            "loss_vs_f32_frac": (hist["loss"][-1] - base_loss) / base_loss,
        }
    results["wire"]["fp8_bytes_ratio_vs_int8"] = (
        results["wire"]["int8_pipelined"]["outer_sync_bytes"]
        / max(results["wire"]["fp8_pipelined"]["outer_sync_bytes"], 1))
    return results


def main(small: bool = False) -> None:
    from benchmarks.bench_io import merge_json
    res = bench_train(small=small)
    # merge, don't overwrite: strategies_bench owns the "gossip" section
    # of the same artifact
    merge_json("BENCH_train.json", res)
    print("name,us_per_call,derived")
    for arm in ("legacy_per_step", "per_step", "chunked",
                "chunked_donate_prefetch"):
        r = res[arm]
        print(f"train/{arm},{r['step_seconds'] * 1e6:.1f},"
              f"steps_per_s={r['steps_per_s']:.2f} "
              f"tokens_per_s={r['tokens_per_s']:.0f} "
              f"loss_last={r['loss_last']:.4f}")
    print(f"train/speedup,0.0,"
          f"chunked={res['speedup_chunked']:.2f}x "
          f"chunked_donate_prefetch={res['speedup_full']:.2f}x "
          f"losses_agree={res['losses_agree']}")
    for arm in ("f32_pipelined", "int8_pipelined", "fp8_pipelined"):
        w = res["wire"][arm]
        print(f"train/wire/{arm},0.0,"
              f"outer_sync_bytes={w['outer_sync_bytes']} "
              f"loss_last={w['loss_last']:.4f} "
              f"loss_vs_f32={100 * w['loss_vs_f32_frac']:+.2f}%")
    print(f"train/wire/fp8_vs_int8,0.0,"
          f"bytes_ratio={res['wire']['fp8_bytes_ratio_vs_int8']:.1f}x")


if __name__ == "__main__":
    main()
