"""Adaptive-H ablation — the paper's §5 future-work proposal, implemented.

Compares fixed-H DiLoCo against the AdaptiveH controller (H shrinks in
critical phases, grows when the loss is flat) at matched total step budget,
reporting final loss and the realized communication volume.

  PYTHONPATH=src python examples/adaptive_h.py --steps 160
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import DiLoCoConfig, ModelConfig, OptimizerConfig
from repro.core import AdaptiveH, DiLoCoTrainer, FixedH, run_diloco
from repro.data import PackedDataset, build_tokenizer, synthetic
from repro.models.transformer import build_model, init_params


def run(h_schedule, steps, label):
    world = synthetic.World.make(40)
    texts = synthetic.gen_pretrain_texts(world, 3000)
    tok = build_tokenizer(texts[:1200], 512)
    ds = PackedDataset.from_texts(texts, tok, seq_len=128)
    cfg = ModelConfig(num_layers=4, d_model=128, num_heads=4, num_kv_heads=4,
                      d_ff=512, vocab_size=tok.vocab_size)
    model = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    tr = DiLoCoTrainer(model.loss,
                       OptimizerConfig(total_steps=steps, warmup_steps=10,
                                       learning_rate=0.02, adam_lr=1e-3),
                       DiLoCoConfig(num_workers=4))
    state = tr.init(params)

    def data(step):
        b = ds.worker_batches(step, 4, 8)
        return {k: jnp.asarray(v) for k, v in b.items()}

    state, hist = run_diloco(tr, state, data, steps, h_schedule=h_schedule)
    syncs = len(hist["sync_steps"])
    mb = syncs * tr.bytes_per_sync(params) / 1e6
    print(f"{label:12s} final loss={hist['loss'][-1]:.4f} "
          f"syncs={syncs} comm={mb:.1f} MB")
    return hist


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=160)
    args = ap.parse_args()
    run(FixedH(20), args.steps, "fixed H=20")
    run(FixedH(40), args.steps, "fixed H=40")
    run(AdaptiveH(h0=20, h_min=5, h_max=80), args.steps, "adaptive")


if __name__ == "__main__":
    main()
