"""End-to-end driver — the paper's Table 1 experiment at CPU scale.

Runs the full nanochat-style pipeline (base pretrain -> dialogue mid-train ->
SFT) under all three configurations (Standard DDP / DiLoCo / Hybrid), with
the CORE-proxy and the three task evals after every stage, and the drift
diagnostics from repro.core.drift.

  PYTHONPATH=src python examples/pipeline_table1.py --steps 300 --out runs/table1
"""
import argparse
import json
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--out", type=str, default="runs/table1")
    ap.add_argument("--methods", type=str, default="ddp,diloco,hybrid")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.launch.train import run_pipeline

    os.makedirs(args.out, exist_ok=True)
    all_results = {}
    for method in args.methods.split(","):
        print(f"=== {method} ===")
        all_results[method] = run_pipeline(
            method=method, arch="tiny",
            steps={"base": args.steps, "mid": args.steps // 2,
                   "sft": args.steps // 2},
            workers=args.workers, per_worker_batch=8, seq_len=128,
            seed=args.seed, out_dir=args.out)

    # Table-1-shaped summary
    cols = ["core", "mc", "mc_heldout", "arith", "pattern", "chatcore"]
    print("\nstage   method   " + "  ".join(f"{c:>9s}" for c in cols))
    for stage in ("base", "mid", "sft"):
        for method, res in all_results.items():
            e = res["stages"][stage]
            vals = {"core": e["core"]["core_proxy"], **e["tasks"]}
            print(f"{stage:7s} {method:8s} "
                  + "  ".join(f"{vals.get(c, float('nan')):9.4f}"
                              for c in cols))
    with open(os.path.join(args.out, "table1.json"), "w") as f:
        json.dump(all_results, f, indent=1, default=float)
    print(f"\nwritten to {args.out}/table1.json")


if __name__ == "__main__":
    main()
