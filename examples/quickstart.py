"""Quickstart: pretrain a tiny nanochat-style model with DiLoCo (4 workers,
H=10) on the synthetic corpus, then chat with it.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import DiLoCoConfig, ModelConfig, OptimizerConfig
from repro.core import DiLoCoTrainer, run_diloco
from repro.data import PackedDataset, build_tokenizer, synthetic
from repro.models.transformer import build_model, init_params
from repro.serving import Engine


def main():
    # --- data: synthetic "FineWeb-Edu" proxy + BPE trained from scratch ----
    world = synthetic.World.make(40)
    texts = synthetic.gen_pretrain_texts(world, 4000)
    tok = build_tokenizer(texts[:1500], 512)
    ds = PackedDataset.from_texts(texts, tok, seq_len=128)
    print(f"tokenizer vocab={tok.vocab_size}, corpus={ds.num_tokens} tokens")

    # --- model + DiLoCo trainer (paper hyper-parameters, scaled down) ------
    cfg = ModelConfig(name="quickstart", num_layers=4, d_model=128,
                      num_heads=4, num_kv_heads=4, d_ff=512,
                      vocab_size=tok.vocab_size)
    model = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    trainer = DiLoCoTrainer(
        model.loss,
        OptimizerConfig(total_steps=120, warmup_steps=10, learning_rate=0.02,
                        adam_lr=1e-3),
        DiLoCoConfig(num_workers=4, h_inner_steps=10))  # mu=.9, eta=.8 default
    state = trainer.init(params)

    def data(step):
        b = ds.worker_batches(step, 4, 8)
        return {k: jnp.asarray(v) for k, v in b.items()}

    state, hist = run_diloco(trainer, state, data, 120)
    print(f"loss {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f} "
          f"({len(hist['sync_steps'])} outer syncs, "
          f"{trainer.bytes_per_sync(params)/1e6:.1f} MB per sync vs "
          f"{trainer.ddp_bytes_per_step(params)/1e6:.1f} MB/step under DDP)")

    # --- serve --------------------------------------------------------------
    engine = Engine(model, state.global_params, tok)
    prompts = ["<|bos|>the color of ent3 is",
               "<|bos|>12 + 7 ="]
    for p, o in zip(prompts, engine.chat(prompts, max_new=8)):
        print(f"{p!r} -> {o[len(p):]!r}")


if __name__ == "__main__":
    main()
