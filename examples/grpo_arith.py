"""GRPO stage — nanochat's optional reward-model-free RL on GSM8K,
reproduced on the synthetic arithmetic task: SFT a tiny model first, then
improve arithmetic exact-match with group-relative policy gradients.

  PYTHONPATH=src python examples/grpo_arith.py --iters 10
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import DiLoCoConfig, ModelConfig, OptimizerConfig
from repro.core import DiLoCoTrainer, GRPOTrainer, arith_reward_fn, run_diloco
from repro.data import PackedDataset, build_tokenizer, synthetic
from repro.models.transformer import build_model, init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--sft-steps", type=int, default=150)
    args = ap.parse_args()

    world = synthetic.World.make(20)
    sft_texts = synthetic.gen_sft_texts(world, 4000)
    tok = build_tokenizer(sft_texts[:1500], 512)
    ds = PackedDataset.from_texts(sft_texts, tok, seq_len=96)
    cfg = ModelConfig(num_layers=4, d_model=128, num_heads=4, num_kv_heads=4,
                      d_ff=512, vocab_size=tok.vocab_size)
    model = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))

    # --- SFT warm start (DiLoCo, as the paper's pipeline would) -----------
    tr = DiLoCoTrainer(model.loss,
                       OptimizerConfig(total_steps=args.sft_steps,
                                       warmup_steps=10, learning_rate=0.02,
                                       adam_lr=1e-3),
                       DiLoCoConfig(num_workers=2, h_inner_steps=15))
    st = tr.init(params)
    st, hist = run_diloco(
        tr, st, lambda s: {k: jnp.asarray(v) for k, v in
                           ds.worker_batches(s, 2, 8).items()},
        args.sft_steps)
    params = st.global_params
    print(f"SFT loss {hist['loss'][0]:.2f} -> {hist['loss'][-1]:.2f}")

    # --- GRPO on arithmetic -------------------------------------------------
    items = synthetic.gen_arith_eval(16, seed=31)
    prompts = [tok.encode(it["prompt"]) for it in items]
    reward = arith_reward_fn(tok, items)
    grpo = GRPOTrainer(model,
                       OptimizerConfig(total_steps=args.iters,
                                       warmup_steps=0, schedule="constant",
                                       learning_rate=0.01, adam_lr=1e-3),
                       group_size=8, max_new=6)
    state = grpo.init(params)
    for it in range(args.iters):
        state, loss, mean_r = grpo.rollout_and_step(
            state, prompts, reward, pad_id=tok.pad, seed=it)
        print(f"iter {it:2d} loss {loss:+.4f} mean_reward {mean_r:.3f}")


if __name__ == "__main__":
    main()
