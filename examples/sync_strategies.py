"""Compare all sync strategies on one model through the unified runtime.

Trains the same tiny nanochat-style model under DDP, DiLoCo, Streaming
DiLoCo, and Overlapped DiLoCo (delayed outer application + straggler
jitter), all through the single ``DistTrainer`` loop, then reports final
loss, boundary traffic, and the wall-clock the event-driven communication
simulator models for a production fleet (DCN inter-pod links).

  PYTHONPATH=src python examples/sync_strategies.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import DiLoCoConfig, ModelConfig, OptimizerConfig
from repro.core import (DDPSync, DiLoCoSync, DistTrainer, OverlappedSync,
                        PipelinedSync, StreamingSync)
from repro.data import PackedDataset, build_tokenizer, synthetic
from repro.launch.comm_sim import default_comm_model, simulate_schedule
from repro.models.transformer import build_model, init_params

STEPS = 60
WORKERS = 4
H = 10


def main():
    world = synthetic.World.make(40)
    texts = synthetic.gen_pretrain_texts(world, 2000)
    tok = build_tokenizer(texts[:1000], 512)
    ds = PackedDataset.from_texts(texts, tok, seq_len=64)

    cfg = ModelConfig(name="strategies", num_layers=2, d_model=128,
                      num_heads=4, num_kv_heads=4, d_ff=256,
                      vocab_size=tok.vocab_size)
    model = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    opt = OptimizerConfig(total_steps=STEPS, warmup_steps=5,
                          learning_rate=0.02, adam_lr=1e-3)

    def worker_data(step):
        b = ds.worker_batches(step, WORKERS, 4)
        return {k: jnp.asarray(v) for k, v in b.items()}

    def global_data(step):  # DDP: K=1, merged global batch
        b = ds.batch(step, WORKERS * 4)
        return {k: jnp.asarray(v)[None] for k, v in b.items()}

    dcfg = DiLoCoConfig(num_workers=WORKERS, h_inner_steps=H)
    int8_cfg = DiLoCoConfig(num_workers=WORKERS, h_inner_steps=H,
                            delta_dtype="int8")
    ddp_cfg = DiLoCoConfig(num_workers=1, h_inner_steps=1, outer_lr=1.0,
                           outer_momentum=0.0, nesterov=False)
    runs = [
        ("ddp", DDPSync(), ddp_cfg, global_data),
        ("diloco", DiLoCoSync(), dcfg, worker_data),
        ("streaming", StreamingSync(num_fragments=4), dcfg, worker_data),
        ("overlapped", OverlappedSync(delay=3, jitter=2), dcfg, worker_data),
        # DiLoCoX shape: int8 fragments, one per round, overlapped apply
        ("pipelined8", PipelinedSync(num_fragments=4, delay=3), int8_cfg,
         worker_data),
    ]
    comm = default_comm_model()
    step_time = 0.25  # assumed inner-step seconds on the production fleet
    print(f"{'strategy':<11} {'loss':>7} {'syncs':>5} {'GB':>7} "
          f"{'modeled wall':>12} {'overhead':>8}")
    for name, strat, c, data in runs:
        trainer = DistTrainer(model.loss, opt, c, strat)
        state = trainer.init(params)
        state, hist = trainer.run(state, data, STEPS)
        events = trainer.payload_schedule(params, STEPS)
        sim = simulate_schedule(events, STEPS, step_time, comm)
        syncs = len(hist["sync_steps"]) or len(hist["frag_syncs"])
        print(f"{name:<11} {hist['loss'][-1]:>7.3f} {syncs:>5} "
              f"{sim['total_bytes']/1e9:>7.3f} {sim['wall_clock_s']:>11.1f}s "
              f"{100 * sim['overhead_frac']:>7.1f}%")


if __name__ == "__main__":
    main()
