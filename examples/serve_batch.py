"""Batched serving example: briefly pretrain, then serve a ragged batch of
chat requests through the KV-cache engine (greedy + sampled).

  PYTHONPATH=src python examples/serve_batch.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import DiLoCoConfig, ModelConfig, OptimizerConfig
from repro.core import DiLoCoTrainer, run_diloco
from repro.data import PackedDataset, build_tokenizer, synthetic
from repro.models.transformer import build_model, init_params
from repro.serving import Engine


def main():
    world = synthetic.World.make(20)
    texts = synthetic.gen_sft_texts(world, 3000)
    tok = build_tokenizer(texts[:1200], 512)
    ds = PackedDataset.from_texts(texts, tok, seq_len=128)
    cfg = ModelConfig(num_layers=4, d_model=128, num_heads=4, num_kv_heads=4,
                      d_ff=512, vocab_size=tok.vocab_size)
    model = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    tr = DiLoCoTrainer(model.loss,
                       OptimizerConfig(total_steps=100, warmup_steps=10,
                                       learning_rate=0.02, adam_lr=1e-3),
                       DiLoCoConfig(num_workers=2, h_inner_steps=10))
    state = tr.init(params)
    state, hist = run_diloco(
        tr, state,
        lambda s: {k: jnp.asarray(v)
                   for k, v in ds.worker_batches(s, 2, 8).items()}, 100)
    print(f"train loss {hist['loss'][0]:.2f} -> {hist['loss'][-1]:.2f}")

    engine = Engine(model, state.global_params, tok)
    ents = world.train_entities()[:4]
    requests = [f"<|bos|><|user_start|>what is the color of {e} ?"
                f"<|user_end|><|assistant_start|>" for e in ents]
    requests.append("<|bos|><|user_start|>compute 3 + 4 .<|user_end|>"
                    "<|assistant_start|>")  # ragged batch: shorter prompt
    outs = engine.chat(requests, max_new=16)
    for r, o in zip(requests, outs):
        q = r.split("<|user_start|>")[1].split("<|user_end|>")[0]
        print(f"Q: {q}\nA: {o.split('<|assistant_start|>')[-1].strip()}\n")


if __name__ == "__main__":
    main()
