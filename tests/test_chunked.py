"""The chunked (scan-fused + donated + prefetched) DistTrainer hot path.

Contract under test (ISSUE 4 acceptance):
* chunked loop is bit-exact with the per-step reference loop for EVERY
  ``SyncStrategy`` — same final params, same history records;
* exactly ONE device->host fetch per chunk (the per-chunk loss array);
* ``eval_every`` landing mid-chunk splits the chunk instead of drifting;
* buffer donation cannot invalidate the caller's state or the
  eval/refresh path;
* the async ``Prefetcher`` is a drop-in batch source.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import tiny_cfg
from repro.configs.base import DiLoCoConfig, OptimizerConfig
from repro.core import (AdaptiveH, DDPSync, DiLoCoSync, DistTrainer,
                        OverlappedSync, PipelinedSync, StreamingSync)
from repro.core import dist_trainer as dist_trainer_mod
from repro.data.pipeline import Prefetcher, stack_batches
from repro.models.transformer import build_model, init_params

OPT = OptimizerConfig(total_steps=100, warmup_steps=0, schedule="constant",
                      learning_rate=0.02, adam_lr=1e-3)

CFG = tiny_cfg("dense")
MODEL = build_model(CFG)
PARAMS, _ = init_params(CFG, jax.random.key(0))


def _data(k, step, B=4, S=16):
    key = jax.random.key(1000 + step)
    toks = jax.random.randint(key, (k, B, S), 0, CFG.vocab_size)
    return {"tokens": toks, "labels": (toks + 1) % CFG.vocab_size}


def _dcfg(k, h):
    if k == 1:  # the DDP degenerate config (outer step = identity hand-off)
        return DiLoCoConfig(num_workers=1, h_inner_steps=1, outer_lr=1.0,
                            outer_momentum=0.0, nesterov=False)
    return DiLoCoConfig(num_workers=k, h_inner_steps=h)


def _run(strategy, k, h, steps, **kw):
    dt = DistTrainer(MODEL.loss, OPT, _dcfg(k, h), strategy)
    state = dt.init(PARAMS)
    return dt.run(state, lambda s: _data(k, s), steps, **kw)


def _assert_tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _assert_hist_equal(a, b):
    for key in ("step", "loss", "sync_steps", "frag_syncs", "evals"):
        assert a[key] == b[key], key


# strategy factories (fresh per run: runners/H-schedules are stateful)
STRATEGIES = {
    "ddp": (1, lambda: DDPSync()),
    "diloco": (2, lambda: DiLoCoSync()),
    "streaming": (2, lambda: StreamingSync(num_fragments=2)),
    "overlapped": (3, lambda: OverlappedSync(delay=2, jitter=1, seed=3)),
    "pipelined": (2, lambda: PipelinedSync(num_fragments=2, delay=1)),
}


@pytest.mark.parametrize("name", sorted(STRATEGIES))
def test_chunked_bit_exact_per_strategy(name):
    """Chunked == per-step: final params AND history records, for every
    strategy — 14 steps with h=4 covers trailing partial rounds and the
    finalize flush paths."""
    k, make = STRATEGIES[name]
    ref_state, ref_hist = _run(make(), k, 4, 14, chunked=False)
    chk_state, chk_hist = _run(make(), k, 4, 14, chunked=True)
    _assert_tree_equal(ref_state.global_params, chk_state.global_params)
    _assert_tree_equal(ref_state.worker_params, chk_state.worker_params)
    _assert_tree_equal(ref_state.inner_opt, chk_state.inner_opt)
    _assert_hist_equal(ref_hist, chk_hist)


def test_chunked_eval_mid_chunk_splits():
    """eval_every=3 with h=4: evals land mid-round, so chunks split at the
    eval step — same (step, value) pairs as the per-step loop, and syncs
    do not drift."""
    evals = lambda p: float(np.asarray(
        jnp.concatenate([x.ravel() for x in jax.tree.leaves(p)]).sum()))
    ref_state, ref_hist = _run(DiLoCoSync(), 2, 4, 12, chunked=False,
                               eval_fn=evals, eval_every=3)
    chk_state, chk_hist = _run(DiLoCoSync(), 2, 4, 12, chunked=True,
                               eval_fn=evals, eval_every=3)
    assert [s for s, _ in chk_hist["evals"]] == [2, 5, 8, 11]
    assert chk_hist["sync_steps"] == [3, 7, 11]
    _assert_hist_equal(ref_hist, chk_hist)
    _assert_tree_equal(ref_state.global_params, chk_state.global_params)


def test_chunked_record_every():
    ref_state, ref_hist = _run(DiLoCoSync(), 2, 4, 12, chunked=False,
                               record_every=3)
    chk_state, chk_hist = _run(DiLoCoSync(), 2, 4, 12, chunked=True,
                               record_every=3)
    assert chk_hist["step"] == [0, 3, 6, 9]
    _assert_hist_equal(ref_hist, chk_hist)
    _assert_tree_equal(ref_state.global_params, chk_state.global_params)


def test_chunked_adaptive_h():
    """AdaptiveH feeds on per-step losses: the chunked loop replays the
    fetched chunk losses through should_sync in order, so the adaptive
    boundary decisions are identical."""
    mk = lambda: DiLoCoSync(h_schedule=AdaptiveH(h0=3, h_min=2, h_max=8,
                                                 window=4))
    ref_state, ref_hist = _run(mk(), 2, 3, 15, chunked=False)
    chk_state, chk_hist = _run(mk(), 2, 3, 15, chunked=True)
    _assert_hist_equal(ref_hist, chk_hist)
    _assert_tree_equal(ref_state.global_params, chk_state.global_params)


def test_one_fetch_per_chunk(monkeypatch):
    """The mechanism claim: the chunked loop performs exactly one
    device->host transfer per chunk (the (T,) loss array) — no per-step
    host syncs."""
    calls = []
    real = dist_trainer_mod._fetch
    monkeypatch.setattr(dist_trainer_mod, "_fetch",
                        lambda x: calls.append(1) or real(x))
    _, hist = _run(DiLoCoSync(), 2, 4, 12, chunked=True)
    # 12 steps of h=4 -> exactly 3 chunks -> exactly 3 fetches
    assert len(calls) == 3
    assert hist["sync_steps"] == [3, 7, 11]
    assert len(hist["loss"]) == 12


def test_chunk_boundaries_are_sync_events(monkeypatch):
    """Fragment schedules chunk at their own (denser) event cadence."""
    calls = []
    real = dist_trainer_mod._fetch
    monkeypatch.setattr(dist_trainer_mod, "_fetch",
                        lambda x: calls.append(1) or real(x))
    _, hist = _run(StreamingSync(num_fragments=2), 2, 4, 8, chunked=True)
    # period = h/F = 2 -> 4 fragment events -> 4 chunks
    assert len(calls) == 4
    assert [s for s, _ in hist["frag_syncs"]] == [1, 3, 5, 7]


def test_ddp_runs_as_one_chunk(monkeypatch):
    calls = []
    real = dist_trainer_mod._fetch
    monkeypatch.setattr(dist_trainer_mod, "_fetch",
                        lambda x: calls.append(1) or real(x))
    _, hist = _run(DDPSync(), 1, 1, 10, chunked=True)
    assert len(calls) == 1          # no sync events: the run IS one chunk
    assert hist["sync_steps"] == list(range(10))


def test_donation_preserves_callers_state():
    """run(donate=True) must not invalidate the state object the caller
    passed in (the loop defensively copies before the first donated
    chunk): running twice from the same init state gives identical
    results."""
    dt = DistTrainer(MODEL.loss, OPT, _dcfg(2, 4), DiLoCoSync())
    state0 = dt.init(PARAMS)
    s1, h1 = dt.run(state0, lambda s: _data(2, s), 8, chunked=True,
                    donate=True)
    s2, h2 = dt.run(state0, lambda s: _data(2, s), 8, chunked=True,
                    donate=True)
    _assert_tree_equal(s1.global_params, s2.global_params)
    _assert_hist_equal(h1, h2)


def test_donation_safe_on_eval_refresh_path():
    """Donated chunks + the refresh/eval observer path + a snapshotting
    strategy (the in-flight snapshot must be a copy, not an alias of
    donated buffers) — donate on/off is bit-identical."""
    mk = lambda: OverlappedSync(delay=2, jitter=0, seed=0)
    evals = lambda p: float(np.asarray(jax.tree.leaves(p)[0]).sum())
    a_state, a_hist = _run(mk(), 2, 4, 12, chunked=True, donate=True,
                           eval_fn=evals, eval_every=3)
    b_state, b_hist = _run(mk(), 2, 4, 12, chunked=True, donate=False,
                           eval_fn=evals, eval_every=3)
    _assert_tree_equal(a_state.global_params, b_state.global_params)
    _assert_tree_equal(a_state.worker_params, b_state.worker_params)
    _assert_hist_equal(a_hist, b_hist)


def test_prefetch_is_drop_in():
    ref_state, ref_hist = _run(DiLoCoSync(), 2, 4, 12, chunked=True,
                               prefetch=0)
    pf_state, pf_hist = _run(DiLoCoSync(), 2, 4, 12, chunked=True,
                             prefetch=6)
    _assert_tree_equal(ref_state.global_params, pf_state.global_params)
    _assert_hist_equal(ref_hist, pf_hist)


def test_max_chunk_caps_scan_length(monkeypatch):
    calls = []
    real = dist_trainer_mod._fetch
    monkeypatch.setattr(dist_trainer_mod, "_fetch",
                        lambda x: calls.append(1) or real(x))
    ref_state, _ = _run(DiLoCoSync(), 2, 8, 8, chunked=True)
    assert len(calls) == 1
    calls.clear()
    cap_state, _ = _run(DiLoCoSync(), 2, 8, 8, chunked=True, max_chunk=3)
    assert len(calls) == 3          # 3 + 3 + 2
    _assert_tree_equal(ref_state.global_params, cap_state.global_params)


def test_early_firing_schedule_raises_under_chunking():
    """An HSchedule that fires before since_sync reaches current_h
    violates the next_event contract: the chunked loop must fail loudly
    (the per-step loop still supports such schedules via chunked=False)."""
    from repro.core.schedule import HSchedule

    class SpikeH(HSchedule):
        def should_sync(self, step, since_sync, loss):
            return step == 1        # before the advertised boundary

        @property
        def current_h(self):
            return 4

    with pytest.raises(RuntimeError, match="mid-chunk"):
        _run(DiLoCoSync(h_schedule=SpikeH()), 2, 4, 8, chunked=True)
    # the reference loop still runs it
    _, hist = _run(DiLoCoSync(h_schedule=SpikeH()), 2, 4, 8, chunked=False)
    assert 1 in hist["sync_steps"]


# ---------------------------------------------------------------------------
# Prefetcher units
# ---------------------------------------------------------------------------

def test_prefetcher_orders_and_stacks():
    pf = Prefetcher(lambda s: {"x": np.full((2, 3), s, np.int32)}, 7,
                    depth=2)
    try:
        a = pf.take(0, 3)
        assert a["x"].shape == (3, 2, 3)
        assert [int(a["x"][i, 0, 0]) for i in range(3)] == [0, 1, 2]
        b = pf.take(3, 4)
        assert [int(b["x"][i, 0, 0]) for i in range(4)] == [3, 4, 5, 6]
    finally:
        pf.close()


def test_prefetcher_surfaces_producer_error():
    def bad(step):
        if step == 2:
            raise RuntimeError("boom")
        return {"x": np.zeros(2)}

    pf = Prefetcher(bad, 5, depth=2)
    try:
        with pytest.raises(RuntimeError):
            pf.take(0, 5)
    finally:
        pf.close()


def test_prefetcher_close_unblocks_full_queue():
    pf = Prefetcher(lambda s: {"x": np.zeros(4)}, 1000, depth=2)
    pf.take(0, 1)
    pf.close()      # must not hang with the producer parked on a full queue
    assert not pf._thread.is_alive()


def test_prefetcher_prime_matches_take():
    """A primed chunk with matching bounds is returned verbatim; priming
    never changes what take() produces."""
    pf = Prefetcher(lambda s: {"x": np.full((2,), s, np.int32)}, 9, depth=3)
    try:
        pf.take(0, 2)
        pf.prime(2, 3)
        b = pf.take(2, 3)
        assert [int(b["x"][i, 0]) for i in range(3)] == [2, 3, 4]
        pf.prime(5, 4)
        c = pf.take(5, 4)
        assert [int(c["x"][i, 0]) for i in range(4)] == [5, 6, 7, 8]
    finally:
        pf.close()


def test_prefetcher_prime_mismatch_falls_back_losslessly():
    """If the consumer's chunk bounds moved after priming (a sync runner
    shifted its next event), take() recovers the raw items and serves the
    requested bounds exactly."""
    pf = Prefetcher(lambda s: {"x": np.full((2,), s, np.int32)}, 10,
                    depth=4)
    try:
        pf.prime(0, 4)                        # guess: steps 0..3
        a = pf.take(0, 2)                     # actual chunk is shorter
        assert [int(a["x"][i, 0]) for i in range(2)] == [0, 1]
        b = pf.take(2, 5)                     # next chunk spans leftovers
        assert [int(b["x"][i, 0]) for i in range(5)] == [2, 3, 4, 5, 6]
        pf.prime(7, 2)
        c = pf.take(7, 3)                     # longer than primed
        assert [int(c["x"][i, 0]) for i in range(3)] == [7, 8, 9]
    finally:
        pf.close()


def test_prefetcher_prime_surfaces_producer_error():
    def bad(step):
        if step == 1:
            raise RuntimeError("boom")
        return {"x": np.zeros(2)}

    pf = Prefetcher(bad, 5, depth=2)
    try:
        pf.prime(0, 3)
        with pytest.raises(RuntimeError):
            pf.take(0, 3)
    finally:
        pf.close()


def test_stack_batches():
    out = stack_batches([{"a": np.arange(3)}, {"a": np.arange(3) + 10}])
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  [[0, 1, 2], [10, 11, 12]])
