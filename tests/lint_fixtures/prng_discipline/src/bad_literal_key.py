"""Known-bad (library-code path: note the src/ segment): literal keys."""
import jax


def fresh_params(init_fn, cfg):
    params = init_fn(cfg, jax.random.key(0))  # LINT-EXPECT prng-discipline
    return params


def legacy(init_fn, cfg):
    return init_fn(cfg, jax.random.PRNGKey(42))  # LINT-EXPECT prng-discipline
