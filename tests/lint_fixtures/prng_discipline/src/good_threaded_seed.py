"""Known-good library code: seeds threaded, eval_shape literals exempt."""
import jax


def fresh_params(init_fn, cfg, seed):
    return init_fn(cfg, jax.random.key(seed))   # seed comes from config/CLI


def capture_shapes(capture):
    # abstract evaluation only — no randomness is ever generated
    return jax.eval_shape(capture, jax.random.key(0))
