"""Known-good: split/fold_in between draws, branch-exclusive draws."""
import jax


def split_between(key, shape):
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, shape)
    b = jax.random.uniform(k2, shape)
    return a, b


def rebind_chain(key, shape):
    key, sub = jax.random.split(key)
    a = jax.random.normal(sub, shape)
    key, sub = jax.random.split(key)    # key rebound: reusable
    b = jax.random.uniform(sub, shape)
    return a, b


def fold_per_step(key, xs):
    out = []
    for i, x in enumerate(xs):
        out.append(jax.random.normal(jax.random.fold_in(key, i), x.shape))
    return out


def branch_exclusive(key, shape, init):
    # the layers.py param-init pattern: one draw per mutually-exclusive arm
    if init == "normal":
        v = jax.random.truncated_normal(key, -3.0, 3.0, shape)
    elif init == "embed":
        v = jax.random.normal(key, shape)
    else:
        v = jax.random.uniform(key, shape)
    return v


def distinct_subscripts(key):
    keys = jax.random.split(key, 3)
    a = jax.random.normal(keys[0], ())
    b = jax.random.uniform(keys[1], ())
    return a, b
