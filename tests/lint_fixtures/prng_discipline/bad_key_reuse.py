"""Known-bad: the same key drawn from twice, and split without rebind."""
import jax


def double_draw(key, shape):
    a = jax.random.normal(key, shape)
    b = jax.random.uniform(key, shape)  # LINT-EXPECT prng-discipline
    return a, b


def split_then_reuse(key):
    subs = jax.random.split(key, 2)
    noise = jax.random.normal(key, ())  # LINT-EXPECT prng-discipline
    return subs, noise


def reuse_after_rebind_of_other(rng, shape):
    a = jax.random.bernoulli(rng, 0.5, shape)
    other = jax.random.key(7)
    b = jax.random.categorical(rng, a)  # LINT-EXPECT prng-discipline
    return other, b


def subscript_reuse(key):
    keys = jax.random.split(key, 3)
    a = jax.random.normal(keys[0], ())
    b = jax.random.uniform(keys[0], ())  # LINT-EXPECT prng-discipline
    return a, b
