"""Known-bad: reads a buffer after donating it to a jitted callable."""
import jax


def train(state, batch):
    step = jax.jit(lambda s, b: s, donate_argnums=(0,))
    new_state = step(state, batch)
    return state, new_state  # LINT-EXPECT donate-safety


def train_attr(state, batch):
    step = jax.jit(lambda s, b: s, donate_argnums=(0,))
    new_state = step(state, batch)
    return state.params, new_state  # LINT-EXPECT donate-safety


class Trainer:
    def __init__(self, fn):
        self._step = jax.jit(fn, donate_argnums=(1,))

    def run(self, params, state):
        out = self._step(params, state)
        print(state)  # LINT-EXPECT donate-safety
        return out


def toggle(state, batch, donate=True):
    step = jax.jit(lambda s, b: s, donate_argnums=(0,) if donate else ())
    new_state = step(state, batch)
    return state, new_state  # LINT-EXPECT donate-safety
