"""Known-good: every donated buffer is rebound or never read again."""
import jax
import jax.numpy as jnp


def train(state, batches):
    step = jax.jit(lambda s, b: s, donate_argnums=(0,))
    for b in batches:
        state = step(state, b)          # rebound in the same statement
    return state


def train_tail(state, batch):
    step = jax.jit(lambda s, b: s, donate_argnums=(0,))
    return step(state, batch)           # tail call: no later read


def train_snapshot(state, batch):
    step = jax.jit(lambda s, b: s, donate_argnums=(0,))
    snap = jax.tree.map(jnp.copy, state)
    state = step(state, batch)
    return state, snap                  # the copy is read, not the donated


class Runner:
    def __init__(self, fn):
        self._outer = jax.jit(fn, donate_argnums=(0, 1))

    def sync(self, state):
        state, self.residual = self._outer(state, self.residual)
        return state                    # both donated args rebound


def undonated(state, batch):
    step = jax.jit(lambda s, b: s)      # no donation at all
    new_state = step(state, batch)
    return state, new_state
