from repro.kernels.bar import bar, reference_bar


def test_bar_matches_oracle():
    assert bar(3) == reference_bar(3)
