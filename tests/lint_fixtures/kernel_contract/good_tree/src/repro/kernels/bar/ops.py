from repro.kernels.common import resolve_interpret

from repro.kernels.bar.kernel import bar_fwd


def bar(x, interpret=None):
    interpret = resolve_interpret(interpret)
    return bar_fwd(x, interpret=interpret)
