from repro.kernels.bar.ops import bar  # noqa: F401
from repro.kernels.bar.ref import reference_bar  # noqa: F401
