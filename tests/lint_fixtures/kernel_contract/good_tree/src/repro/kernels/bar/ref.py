def reference_bar(x):
    return x
