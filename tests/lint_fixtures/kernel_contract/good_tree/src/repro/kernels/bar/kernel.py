def bar_fwd(x, *, interpret):
    return x
