from repro.kernels.foo import foo


def test_shapes():
    assert foo(1) == 1  # exercises the op but never the (missing) oracle
