from repro.kernels.quant_dequant.ops import dequant  # noqa: F401
