def dequant_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = q_ref[...] * s_ref[...]
