"""Known-bad: the three-file layout and the shared interpret helper are in
place, but no test under tests/ ever imports the dequant variant's ref
oracle — a new kernel variant shipped without its kernel-vs-oracle test."""
from repro.kernels.common import resolve_interpret


def dequant(q, scale, interpret=None):
    interpret = resolve_interpret(interpret)
    return q * scale
