def reference_dequant(q, scale):
    return q * scale
