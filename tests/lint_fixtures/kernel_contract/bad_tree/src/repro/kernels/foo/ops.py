"""Known-bad ops.py: private interpret copy, no shared helper, no ref.py
next door, and no oracle-backed test anywhere under tests/."""
import os

import jax


def default_interpret():
    env = os.environ.get("FOO_INTERPRET")
    if env is not None:
        return env == "1"
    return jax.default_backend() == "cpu"


def foo(x, interpret=None):
    if interpret is None:
        interpret = default_interpret()
    return x
