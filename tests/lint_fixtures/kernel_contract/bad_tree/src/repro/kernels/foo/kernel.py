def foo_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]
