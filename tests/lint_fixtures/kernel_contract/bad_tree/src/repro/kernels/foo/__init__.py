from repro.kernels.foo.ops import foo  # noqa: F401
