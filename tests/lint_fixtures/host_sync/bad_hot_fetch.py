"""Known-bad: device->host syncs inside the serving/training hot loops."""
import jax
import numpy as np


class Engine:
    def __init__(self, step):
        self._step_fn = jax.jit(step, donate_argnums=(1,))

    def run(self, params, state, steps):
        for _ in range(steps):
            tok, state = self._step_fn(params, state)
            tok = np.asarray(tok)  # LINT-EXPECT host-sync-in-hot-path
            self._emit(tok, state)
        return state

    def _emit(self, tok, state):
        print(state.loss.item())  # LINT-EXPECT host-sync-in-hot-path


class DistTrainer:
    def __init__(self, chunk):
        self.inner_chunk = jax.jit(chunk, donate_argnums=(0,))

    def run(self, state, batches):
        for b in batches:
            state, losses = self.inner_chunk(state, b)
            mean = float(losses)  # LINT-EXPECT host-sync-in-hot-path
            self.record(mean)
        return state

    def record(self, mean):
        pass
