"""Known-good: fetches routed through the module's _fetch point; host
work outside the hot roots syncs freely."""
import jax
import numpy as np

_fetch = np.asarray


class Engine:
    def __init__(self, step):
        self._step_fn = jax.jit(step, donate_argnums=(1,))

    def run(self, params, state, steps):
        for _ in range(steps):
            tok, state = self._step_fn(params, state)
            tok = _fetch(tok)           # the documented fetch point
            self._emit(tok)
        return state

    def _emit(self, tok):
        print(int(tok[0]))              # tok is host-side after _fetch


class DistTrainer:
    def __init__(self, chunk):
        self.inner_chunk = jax.jit(chunk, donate_argnums=(0,))

    def run(self, state, batches):
        for b in batches:
            state, losses = self.inner_chunk(state, b)
            losses_host = _fetch(losses)
            self.record(float(np.mean(losses_host)))
        return state

    def record(self, mean):
        pass


def offline_eval(step_fn, state):
    # not reachable from any hot root: syncing here is fine
    out = step_fn(state)
    return float(np.asarray(out).mean())
