"""Known-good: construction hoisted, hashable statics."""
import jax
from jax.experimental import pallas as pl


def hoisted(f, xs):
    g = jax.jit(f)                      # built once, outside the loop
    out = []
    for x in xs:
        out.append(g(x))
    return out


def wrapper(kernel, shape, x):
    # pallas_call inside a def: the function boundary makes per-call
    # construction the *caller's* cache problem, and wrappers like this
    # are themselves jitted in this codebase.
    call = pl.pallas_call(kernel, out_shape=shape)
    return call(x)


def loop_over_wrapper(kernel, shape, xs):
    return [wrapper(kernel, shape, x) for x in xs]


step = jax.jit(lambda x, dims: x, static_argnames=("dims",))
chunk = jax.jit(lambda x, n: x, static_argnums=(1,))


def good_static(x):
    a = step(x, dims=(1, 2))            # tuple: hashable cache key
    b = chunk(x, 8)                     # int: hashable cache key
    return a, b


def straight_line_immediate(f, x):
    # immediate invoke at straight-line level: compiles once per trace,
    # the idiom the test-suite uses freely.
    return jax.jit(f)(x)
