"""Known-bad: fresh jit/pallas_call per iteration, non-hashable statics."""
import jax
from jax.experimental import pallas as pl


def per_step_jit(f, xs):
    out = []
    for x in xs:
        g = jax.jit(f)  # LINT-EXPECT retrace-hazard
        out.append(g(x))
    return out


def per_step_pallas(kernel, xs, shape):
    out = []
    while xs:
        call = pl.pallas_call(kernel, out_shape=shape)  # LINT-EXPECT retrace-hazard
        out.append(call(xs.pop()))
    return out


def immediate_invoke_in_loop(f, xs):
    return [jax.jit(f)(x) for x in xs]  # LINT-EXPECT retrace-hazard


step = jax.jit(lambda x, dims: x, static_argnames=("dims",))
chunk = jax.jit(lambda x, n: x, static_argnums=(1,))


def bad_static_kw(x):
    return step(x, dims=[1, 2])  # LINT-EXPECT retrace-hazard


def bad_static_pos(x):
    return chunk(x, [4, 8])  # LINT-EXPECT retrace-hazard
