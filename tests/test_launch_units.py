"""Unit tests for the launch layer: logical sharding resolution, profiles,
registry variants, analytic estimators."""
from jax.sharding import PartitionSpec as P

from repro.configs import (SHAPES, decode_cache_capacity, get_config,
                           input_specs, long_context_variant)
from repro.launch.analytic import bytes_per_device, flops_per_device
from repro.launch.dryrun_lib import PROFILES, auto_profile
from repro.models.sharding import spec_for, sharding_ctx


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_spec_for_divisibility_fallback():
    mesh = FakeMesh({"data": 16, "model": 16})
    with sharding_ctx(None):
        pass
    # 25 heads cannot shard 16-way -> None; 4096 seq unsharded by default
    spec = spec_for(("batch", "seq", "heads"), (256, 4096, 25), mesh)
    assert spec == P("data", None, None)
    spec = spec_for(("batch", "seq", "heads"), (256, 4096, 32), mesh)
    assert spec == P("data", None, "model")
    # axis used once only
    spec = spec_for(("model", "ffn"), (1024, 4096), mesh)
    assert spec == P("model", None)


def test_long_context_variant_subquadratic():
    for aid in ("command-r-plus-104b", "mistral-large-123b", "qwen1.5-0.5b"):
        cfg = long_context_variant(get_config(aid))
        assert cfg.window or cfg.window_pattern, aid
    ssm = long_context_variant(get_config("mamba2-1.3b"))
    assert ssm.window == 0  # untouched
    mix = long_context_variant(get_config("mixtral-8x7b"))
    assert mix.window == 4096  # native SWA kept


def test_decode_cache_capacity():
    long = SHAPES["long_500k"]
    dec = SHAPES["decode_32k"]
    cfg = long_context_variant(get_config("mistral-large-123b"))
    assert decode_cache_capacity(cfg, long) == 8192        # ring buffer
    assert decode_cache_capacity(get_config("mistral-large-123b"), dec) == 32768


def test_input_specs_shapes():
    cfg = get_config("internvl2-26b")
    sp = input_specs(cfg, SHAPES["train_4k"])
    assert sp["tokens"].shape == (256, 4096 - 256)
    assert sp["patches"].shape == (256, 256, 6144)
    cfg = get_config("seamless-m4t-medium")
    sp = input_specs(cfg, SHAPES["prefill_32k"])
    assert sp["frames"].shape == (32, 1024, 1024)
    sp = input_specs(cfg, SHAPES["decode_32k"])
    assert sp["token"].shape == (128, 1)


def test_auto_profile_selection():
    tp = 16
    assert auto_profile(get_config("qwen1.5-0.5b"), SHAPES["train_4k"], tp) \
        == PROFILES["dp"]
    assert auto_profile(get_config("mamba2-1.3b"), SHAPES["train_4k"], tp) \
        == PROFILES["dp_fsdp"]
    l4 = auto_profile(get_config("llama4-scout-17b-a16e"),
                      SHAPES["prefill_32k"], tp)
    assert l4.get("expert") == ("model",)
    ml = auto_profile(get_config("mistral-large-123b"), SHAPES["train_4k"], tp)
    assert ml.get("seq") == ("model",)
    # decode untouched
    assert auto_profile(get_config("qwen1.5-0.5b"), SHAPES["decode_32k"], tp) \
        == {}
    # measured regressions stay excluded: dp on small-batch prefill,
    # attention-DP for kv-only indivisibility
    assert auto_profile(get_config("qwen1.5-0.5b"), SHAPES["prefill_32k"],
                        tp) == {}
    assert auto_profile(get_config("nemotron-4-15b"), SHAPES["train_4k"],
                        tp) == {}


def test_analytic_flops_scale_with_layers_and_tokens():
    cfg = get_config("qwen1.5-0.5b")
    f1 = flops_per_device(cfg, SHAPES["train_4k"], 256)
    f2 = flops_per_device(cfg.with_(num_layers=48), SHAPES["train_4k"], 256)
    assert f2["total_flops"] > 1.7 * f1["total_flops"]
    # 6ND sanity: within 3x of the analytic total for training
    assert 0.3 < f1["model_flops_6nd"] / f1["total_flops"] < 3.0
    # decode flops are ~tokens/step smaller
    fd = flops_per_device(cfg, SHAPES["decode_32k"], 256)
    assert fd["total_flops"] < f1["total_flops"] / 1e3


def test_analytic_bytes_monotonic():
    cfg = get_config("qwen1.5-0.5b")
    b1 = bytes_per_device(cfg, SHAPES["train_4k"], 256)["bytes"]
    b2 = bytes_per_device(cfg.with_(num_layers=48), SHAPES["train_4k"], 256)["bytes"]
    assert b2 > b1
    bd = bytes_per_device(cfg, SHAPES["decode_32k"], 256,
                          cache_capacity=32768)["bytes"]
    assert bd > 0


def test_auto_flag_resolves_in_dryrun_rules():
    """The __auto__ sentinel must be consumed and replaced by the per-arch
    profile (regression: the sweep once ran with the sentinel ignored)."""
    from repro.launch.dryrun_lib import auto_profile, PROFILES
    rules = {"__auto__": True}
    eff = dict(rules)
    assert eff.pop("__auto__", False)
    got = auto_profile(get_config("qwen1.5-0.5b"), SHAPES["train_4k"], 16)
    assert got == PROFILES["dp"]
