"""Speculative decoding (draft -> verify -> rollback) and its supporting
machinery: the prompt-lookup drafter, the multi-query verify kernel path,
``KVBlockPool.truncate``/reservations, and per-slot sliding-window block
recycling.  The contract under test:

* greedy speculative generation is EXACTLY the non-speculative engine's
  output on ragged mixed-length request streams (acceptance is lossless:
  every emitted token is the target model's own next token);
* the verify step compiles ONCE across arbitrary request mixes (drafts are
  padded to ``spec_k`` and masked per slot);
* rejection-sampling acceptance reproduces the target softmax distribution
  (two-sample test against the non-speculative sampler on a fixed seed);
* rollback (``truncate``) and window recycling keep the pool invariants
  intact under churn, with blocks genuinely reclaimed.
"""
import jax
import numpy as np
import pytest

from helpers import tiny_cfg
from repro.models.transformer import build_model, init_params
from repro.serving import (Engine, KVBlockPool, Request, Scheduler,
                           draft_propose)

RAGGED = [[5, 6, 7], [1, 2, 3, 4, 5, 6, 7, 8, 9, 10], [2, 9], [7] * 17,
          [4, 4, 4, 4, 4], [11, 3], [1] * 30, [8]]


def _engine(**kw):
    cfg = tiny_cfg("dense", **kw.pop("cfg_kw", {}))
    m = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    kw.setdefault("num_slots", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("block_size", 8)
    return cfg, Engine(m, params, **kw)


# ---------------------------------------------------------------------------
# Drafter units
# ---------------------------------------------------------------------------

def test_drafter_matches_most_recent_ngram():
    #           0  1  2  3  4  5  6  7
    hist = [1, 2, 3, 9, 1, 2, 3, 7, 1, 2, 3]
    # suffix (1,2,3) most recently occurred at index 4 -> followed by 7, ...
    assert draft_propose(hist, 2)[:1] == [7]


def test_drafter_unrolls_periodic_tail_to_full_budget():
    hist = [9, 9, 4, 7, 4, 7, 4, 7]
    d = draft_propose(hist, 6)
    assert d == [4, 7, 4, 7, 4, 7]      # loop unrolled past the period


def test_drafter_empty_on_no_match_and_degenerate_inputs():
    assert draft_propose([1, 2, 3, 4], 4) == []
    assert draft_propose([], 4) == []
    assert draft_propose([5], 4) == []
    assert draft_propose([1, 1, 1], 0) == []


# ---------------------------------------------------------------------------
# Greedy speculative == greedy baseline, bit-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec_k", [1, 4, 7])
def test_greedy_speculative_matches_static_on_ragged_batch(spec_k):
    cfg, eng = _engine(spec_k=spec_k)
    a = eng.generate_ids(RAGGED, max_new=13)
    b = eng.generate_ids_static(RAGGED, max_new=13)
    np.testing.assert_array_equal(a, b)


def test_greedy_speculative_matches_nonspec_engine_with_eos_eviction():
    """Same requests through spec_k=0 and spec_k>0 engines: identical
    tokens, including early EOS eviction mid-draft."""
    cfg, base = _engine()
    full = base.generate_ids([[3, 1, 4, 1, 5]], max_new=10)[0]
    eos = int(full[4])
    cfg, spec = _engine(spec_k=5)
    r0 = Request(rid=0, prompt=[3, 1, 4, 1, 5], max_new=10, eos_id=eos)
    r1 = Request(rid=1, prompt=[3, 1, 4, 1, 5], max_new=10, eos_id=eos)
    base.run([r0])
    spec.run([r1])
    assert r1.tokens == r0.tokens and r1.tokens[-1] == eos


def test_verify_step_compiles_once_across_request_mixes():
    cfg, eng = _engine(spec_k=4)
    eng.generate_ids([[1, 2, 3]], max_new=4)
    eng.generate_ids(RAGGED, max_new=9)                      # queueing
    eng.run([Request(rid=0, prompt=[4, 2], max_new=3, eos_id=1)])
    assert eng._verify_greedy_fn._cache_size() == 1, \
        "greedy verify step recompiled across request mixes"
    eng.generate_ids([[6] * 20], max_new=4, greedy=False, seed=3)
    assert eng._verify_fn._cache_size() == 1, \
        "sampling verify step recompiled"
    assert eng._verify_greedy_fn._cache_size() == 1


def test_speculation_reports_accept_counters():
    cfg, eng = _engine(spec_k=4)
    reqs = [Request(rid=i, prompt=list(p), max_new=12)
            for i, p in enumerate(RAGGED)]
    stats = eng.run(reqs)
    assert stats["drafted"] > 0 and 0 <= stats["accepted"] <= stats["drafted"]
    assert stats["accept_rate"] == stats["accepted"] / stats["drafted"]
    assert sum(r.drafted for r in reqs) == stats["drafted"]
    assert sum(r.accepted for r in reqs) == stats["accepted"]


def test_sampled_speculation_is_schedule_independent():
    """Sampled tokens under speculation stay a pure function of
    (seed, rid, own history): the drafter is deterministic per slot and
    every draw is keyed by (seed, rid, position)."""
    cfg, eng = _engine(spec_k=3)
    alone = Request(rid=7, prompt=[5, 5, 5], max_new=6, greedy=False,
                    temperature=1.3)
    eng.run([alone], seed=11)
    cfg, eng2 = _engine(spec_k=3)
    crowd = [Request(rid=i, prompt=[i + 1] * (i + 1), max_new=4,
                     greedy=False) for i in range(5)]
    together = Request(rid=7, prompt=[5, 5, 5], max_new=6, greedy=False,
                       temperature=1.3)
    eng2.run(crowd + [together], seed=11)
    assert together.tokens == alone.tokens


# ---------------------------------------------------------------------------
# Rejection sampling preserves the target distribution
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("temperature", [1.0, 1.5])
def test_rejection_sampling_matches_target_distribution(temperature):
    """Two-sample test on a fixed seed: N independent requests (independent
    PRNG streams keyed by rid) through the speculative engine vs the
    non-speculative one.  Marginal token distributions at the positions
    the drafter speculates on must agree within sampling noise."""
    N, MAX_NEW, V = 300, 4, 17
    cfg_kw = {"cfg_kw": dict(vocab_size=V)}
    prompt = [5, 5, 5, 5, 5]            # repetitive -> the drafter fires

    def collect(spec_k):
        cfg, eng = _engine(spec_k=spec_k, num_slots=8, **dict(cfg_kw))
        reqs = [Request(rid=i, prompt=list(prompt), max_new=MAX_NEW,
                        greedy=False, temperature=temperature)
                for i in range(N)]
        stats = eng.run(reqs, seed=0)
        return np.array([r.tokens for r in reqs]), stats

    spec_toks, spec_stats = collect(spec_k=3)
    base_toks, _ = collect(spec_k=0)
    assert spec_stats["drafted"] >= N, "drafter never fired; test is vacuous"
    assert spec_stats["accepted"] > 0

    def tv(a, b):
        pa = np.bincount(a, minlength=V) / len(a)
        pb = np.bincount(b, minlength=V) / len(b)
        return 0.5 * np.abs(pa - pb).sum()

    # position 0 is the plain post-prefill sample (same math both paths);
    # positions 1.. are where acceptance/residual sampling kicks in
    for pos in range(MAX_NEW):
        d = tv(spec_toks[:, pos], base_toks[:, pos])
        assert d < 0.20, f"position {pos}: TV {d:.3f} vs baseline"
    agg = tv(spec_toks[:, 1:].ravel(), base_toks[:, 1:].ravel())
    assert agg < 0.10, f"aggregate TV {agg:.3f}"
    # power check: the same statistic DOES separate a wrong distribution
    assert tv(base_toks[:, 1:].ravel(),
              np.zeros(N * (MAX_NEW - 1), np.int64)) > 0.5


# ---------------------------------------------------------------------------
# truncate / reservations / rollback invariants
# ---------------------------------------------------------------------------

def test_pool_truncate_reclaims_blocks_and_recredits_budget():
    pool = KVBlockPool(num_blocks=8, block_size=4)
    sched = Scheduler(1, pool, max_blocks_per_slot=8)
    sched.submit(Request(rid=0, prompt=[1] * 10, max_new=10))  # 5 blocks
    sched.admit()
    slot = sched.slots[0]
    sched.ensure_mapped(0, 17)          # 18 positions -> 5 blocks mapped
    assert pool.num_allocated == 5 and slot.reserved == 0
    slot.pos = 11                       # committed through position 10
    freed = pool.truncate(slot, slot.pos)
    assert freed == 2                   # blocks 3,4 (positions 12..19)
    assert pool.num_allocated == 3 and slot.reserved == 2
    assert len(slot.blocks) == 3
    pool.check_invariants()
    sched.ensure_mapped(0, 17)          # re-map from the re-credited budget
    assert pool.num_allocated == 5 and slot.reserved == 0
    pool.check_invariants()
    sched.finish(0)
    assert pool.num_free == 8 and pool.num_reserved == 0
    pool.check_invariants()


def test_pool_reservation_ledger_raises_on_misuse():
    pool = KVBlockPool(num_blocks=4, block_size=4)
    pool.reserve(3)
    with pytest.raises(RuntimeError):
        pool.reserve(2)                 # over-reserve
    with pytest.raises(RuntimeError):
        pool.alloc(2)                   # unreserved alloc into reservation
    got = pool.alloc(3, reserved=True)
    assert len(got) == 3 and pool.num_reserved == 0
    with pytest.raises(RuntimeError):
        pool.release(1)                 # nothing reserved anymore
    pool.check_invariants()


def test_speculative_churn_preserves_pool_invariants():
    """Admission/eviction churn + rollback through the speculative engine
    with a pool too small to hold all requests at once: every request
    completes with the exact greedy tokens, and the pool ends fully free."""
    rng = np.random.default_rng(0)
    cfg, eng = _engine(num_slots=2, max_len=24, block_size=8, spec_k=4)
    prompts = [rng.integers(1, 90, size=int(rng.integers(1, 12))).tolist()
               for _ in range(9)]
    reqs = [Request(rid=i, prompt=p, max_new=int(rng.integers(1, 8)))
            for i, p in enumerate(prompts)]
    eng.run(reqs)
    for r in reqs:
        assert len(r.tokens) == r.max_new, r.rid
        np.testing.assert_array_equal(
            np.asarray(r.tokens),
            eng.generate_ids_static([r.prompt], max_new=r.max_new)[0])


# ---------------------------------------------------------------------------
# Per-slot sliding-window block recycling
# ---------------------------------------------------------------------------

def test_windowed_engine_recycles_blocks_and_matches_static():
    """Uniform-window arch: blocks that fall out of the attention window
    are freed mid-request (stat > 0), outputs still match the static
    windowed reference exactly."""
    cfg, eng = _engine(cfg_kw=dict(window=8), block_size=4, max_len=64,
                       num_slots=2)
    assert eng._recycle_w == 8
    prompts = [[7] * 20, [1, 2, 3] * 6]
    reqs = [Request(rid=i, prompt=list(p), max_new=16)
            for i, p in enumerate(prompts)]
    stats = eng.run(reqs)
    assert stats["recycled_blocks"] > 0
    ref = eng.generate_ids_static(prompts, max_new=16)
    for r, row in zip(reqs, ref):
        np.testing.assert_array_equal(np.asarray(r.tokens), row)


def test_windowed_budget_admits_more_than_full_footprint_would():
    """The windowed budget covers the live window, not prompt+max_new —
    a pool too small for two full footprints still admits both requests."""
    pool = KVBlockPool(num_blocks=10, block_size=4)
    sched = Scheduler(2, pool, max_blocks_per_slot=16, window=8)
    sched.chunk_tokens = 4
    for i in range(2):
        sched.submit(Request(rid=i, prompt=[1] * 40, max_new=20))  # 15 blk
    # windowed budget: blocks_for(8 + 4) + 2 = 5 each; full footprints (30)
    # would overflow the 10-block pool, the windowed budgets fit exactly
    assert len(sched.admit()) == 2


@pytest.mark.parametrize("spec_k", [0, 3])
def test_windowed_churn_with_recycling_preserves_invariants(spec_k):
    """Windowed arch + tight pool + (optionally) speculation: requests
    whose full footprint would overflow the pool run to completion thanks
    to recycling; the pool ends fully free with invariants intact."""
    rng = np.random.default_rng(1)
    cfg, eng = _engine(cfg_kw=dict(window=8), num_slots=2, max_len=48,
                       block_size=4, num_blocks=10, spec_k=spec_k)
    prompts = [rng.integers(1, 90, size=int(rng.integers(4, 30))).tolist()
               for _ in range(7)]
    reqs = [Request(rid=i, prompt=p, max_new=int(rng.integers(4, 14)))
            for i, p in enumerate(prompts)]
    stats = eng.run(reqs)
    assert stats["recycled_blocks"] > 0
    for r in reqs:
        assert len(r.tokens) == r.max_new, r.rid
    # per-request equivalence against the static windowed reference
    for r in reqs:
        np.testing.assert_array_equal(
            np.asarray(r.tokens),
            eng.generate_ids_static([r.prompt], max_new=r.max_new)[0])
