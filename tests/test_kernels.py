"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import (flash_attention,
                                           reference_attention,
                                           reference_attention_fp8)
from repro.kernels.rmsnorm import (reference_rmsnorm,
                                   reference_rmsnorm_residual, rmsnorm,
                                   rmsnorm_residual)
from repro.kernels.ssd import reference_ssd, ssd


@pytest.mark.parametrize("B,H,KV,S,D", [
    (1, 4, 2, 256, 64),
    (2, 2, 2, 128, 32),
    (1, 8, 4, 256, 128),
    (1, 2, 1, 384, 64),
    (1, 1, 1, 128, 128),
])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64),
                                           (False, None)])
def test_flash_attention_sweep(B, H, KV, S, D, causal, window):
    ks = jax.random.split(jax.random.key(B * S + H + D), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, KV, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, KV, S, D), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window)
    ref = reference_attention(q, k, v, causal=causal, window=window)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 2, 128, 64)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 2, 128, 64)).astype(dtype)
    out = flash_attention(q, k, v)
    ref = reference_attention(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert out.dtype == dtype
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                 - ref.astype(jnp.float32)))) < tol


@pytest.mark.parametrize("causal,window", [(True, None), (True, 64),
                                           (False, None)])
def test_flash_attention_fp8_matches_oracle(causal, window):
    """``fp8=True`` runs QK^T on per-row e4m3 tiles; the oracle pushes the
    same rows through quantize-dequantize and runs the exact math.  The
    fp8 result must match ITS oracle tightly while differing measurably
    from the exact attention (proof the narrow path is live)."""
    ks = jax.random.split(jax.random.key(42), 3)
    q = jax.random.normal(ks[0], (1, 4, 256, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 256, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 256, 64), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window, fp8=True)
    ref = reference_attention_fp8(q, k, v, causal=causal, window=window)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-5
    exact = reference_attention(q, k, v, causal=causal, window=window)
    assert float(jnp.max(jnp.abs(ref - exact))) > 1e-3


def test_flash_attention_mismatched_qk_len():
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    out = flash_attention(q, k, v, causal=False)
    ref = reference_attention(q, k, v, causal=False)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-5


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 64, 4, 16, 32, 16),
    (1, 100, 2, 8, 16, 32),     # non-multiple S -> padding path
    (1, 128, 8, 32, 64, 128),   # single chunk
    (2, 96, 1, 64, 8, 16),
])
def test_ssd_sweep(B, S, H, P, N, chunk):
    ks = jax.random.split(jax.random.key(S + N), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.uniform(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    D = jnp.ones((H,))
    y, h = ssd(x, dt, A, Bm, Cm, D, chunk=chunk)
    yr, hr = reference_ssd(x, dt, A, Bm, Cm, D, chunk=chunk)
    assert float(jnp.max(jnp.abs(y - yr))) < 1e-3
    assert float(jnp.max(jnp.abs(h - hr))) < 1e-3


def test_ssd_matches_sequential_recurrence():
    """Chunked SSD == naive per-token recurrence (the gold-standard oracle)."""
    B, S, H, P, N = 1, 32, 2, 8, 4
    ks = jax.random.split(jax.random.key(9), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.uniform(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    D = 0.5 * jnp.ones((H,))
    y, hT = ssd(x, dt, A, Bm, Cm, D, chunk=8)

    from repro.models.ssm import ssd_decode_step
    h = jnp.zeros((B, H, N, P))
    ys = []
    for t in range(S):
        yt, h = ssd_decode_step(h, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], D)
        ys.append(yt)
    y_seq = jnp.stack(ys, axis=1)
    assert float(jnp.max(jnp.abs(y - y_seq))) < 1e-3
    assert float(jnp.max(jnp.abs(hT - h))) < 1e-3


@pytest.mark.parametrize("R,d", [(40, 96), (256, 64), (7, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(R, d, dtype):
    x = jax.random.normal(jax.random.key(R), (R, d)).astype(dtype)
    s = jax.random.normal(jax.random.key(d), (d,))
    out = rmsnorm(x, s)
    ref = reference_rmsnorm(x, s)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                 - ref.astype(jnp.float32)))) < tol


def test_rmsnorm_residual():
    x = jax.random.normal(jax.random.key(0), (3, 40, 96))
    r = jax.random.normal(jax.random.key(1), (3, 40, 96))
    s = jnp.ones((96,))
    o, res = rmsnorm_residual(x, r, s)
    orf, resr = reference_rmsnorm_residual(x, r, s)
    assert float(jnp.max(jnp.abs(o - orf))) < 1e-5
    assert float(jnp.max(jnp.abs(res - resr))) < 1e-5


def test_ssd_kernel_in_model_path():
    """cfg.use_pallas=True must produce identical logits to the jnp path."""
    from helpers import tiny_cfg
    from repro.models.transformer import build_model, forward_lm, init_params
    cfg = tiny_cfg("ssm", ssm_chunk=16)
    m = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 32), 0, cfg.vocab_size)
    ref, _ = m.forward(params, {"tokens": toks})
    out, _ = forward_lm(params, {"tokens": toks}, cfg.with_(use_pallas=True))
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-3


@pytest.mark.parametrize("B,KV,G,S,D,window", [
    (2, 2, 2, 256, 64, 0),
    (1, 4, 1, 512, 128, 0),
    (2, 1, 4, 256, 64, 64),     # sliding window
    (1, 2, 3, 256, 32, 0),      # odd group size
])
def test_decode_attention_kernel(B, KV, G, S, D, window):
    from repro.kernels.decode_attention import (decode_attention,
                                                reference_decode_attention)
    ks = jax.random.split(jax.random.key(B * S + D), 4)
    q = jax.random.normal(ks[0], (B, KV, G, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, KV, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, KV, S, D), jnp.float32)
    # ring-buffer-ish positions: first 3/4 filled with a WRAPPED layout,
    # last 1/4 empty (-1)
    fill = 3 * S // 4
    base = jax.random.randint(ks[3], (B, 1), fill, fill + 100)
    pos = (base - 1 - jnp.arange(S)[None, :]) % (base + 1)
    pos = jnp.where(jnp.arange(S)[None, :] < fill, pos, -1).astype(jnp.int32)
    q_pos = base[:, 0].astype(jnp.int32)
    out = decode_attention(q, k, v, pos, q_pos, window=window, bk=128)
    ref = reference_decode_attention(q, k, v, pos, q_pos, window=window)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-5


def test_decode_attention_ignores_empty_slots():
    from repro.kernels.decode_attention import (decode_attention,
                                                reference_decode_attention)
    ks = jax.random.split(jax.random.key(5), 3)
    B, KV, G, S, D = 1, 1, 2, 128, 32
    q = jax.random.normal(ks[0], (B, KV, G, D))
    k = jax.random.normal(ks[1], (B, KV, S, D))
    v = jax.random.normal(ks[2], (B, KV, S, D))
    pos_full = jnp.arange(S, dtype=jnp.int32)[None]
    # poisoning slots beyond q_pos must not change the output
    q_pos = jnp.asarray([63], jnp.int32)
    out1 = decode_attention(q, k, v, pos_full, q_pos, bk=64)
    k2 = k.at[:, :, 100:].set(1e4)
    v2 = v.at[:, :, 100:].set(-1e4)
    out2 = decode_attention(q, k2, v2, pos_full, q_pos, bk=64)
    assert float(jnp.max(jnp.abs(out1 - out2))) == 0.0


@pytest.mark.parametrize("S,KV,G,NB,bs,MB,D,window", [
    (3, 2, 2, 8, 16, 3, 32, 0),
    (2, 1, 4, 6, 8, 4, 64, 0),
    (4, 2, 1, 8, 16, 2, 32, 12),    # sliding window
])
def test_paged_decode_attention_kernel(S, KV, G, NB, bs, MB, D, window):
    """Block-table-indexed kernel vs the paged jnp oracle, and the paged
    oracle vs the contiguous oracle on the gathered layout."""
    from repro.kernels.decode_attention import (
        paged_decode_attention, reference_decode_attention,
        reference_paged_decode_attention)
    ks = jax.random.split(jax.random.key(S * NB + D), 4)
    q = jax.random.normal(ks[0], (S, KV, G, D), jnp.float32)
    kp = jax.random.normal(ks[1], (NB, bs, KV, D), jnp.float32)
    vp = jax.random.normal(ks[2], (NB, bs, KV, D), jnp.float32)
    # each slot owns a random prefix of mapped (shuffled) physical blocks
    rng = np.random.default_rng(S + NB)
    tables = np.full((S, MB), -1, np.int32)
    perm = rng.permutation(NB)
    q_pos = np.zeros((S,), np.int32)
    off = 0
    for s in range(S):
        n = int(rng.integers(1, MB + 1))
        tables[s, :n] = perm[off:off + n]
        off += n
        q_pos[s] = int(rng.integers((n - 1) * bs, n * bs))
    tables, q_pos = jnp.asarray(tables), jnp.asarray(q_pos)
    out = paged_decode_attention(q, kp, vp, tables, q_pos, window=window)
    ref = reference_paged_decode_attention(q, kp, vp, tables, q_pos,
                                           window=window)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-5
    # cross-check against the contiguous oracle on the gathered layout
    kc = kp[jnp.maximum(tables, 0)].reshape(S, MB * bs, KV, D)
    vc = vp[jnp.maximum(tables, 0)].reshape(S, MB * bs, KV, D)
    pos = jnp.where(jnp.repeat(tables >= 0, bs, axis=1),
                    jnp.arange(MB * bs)[None], -1)
    ref2 = reference_decode_attention(q, kc.transpose(0, 2, 1, 3),
                                      vc.transpose(0, 2, 1, 3), pos, q_pos,
                                      window=window)
    assert float(jnp.max(jnp.abs(ref - ref2))) < 2e-5


@pytest.mark.parametrize("S,T,KV,G,NB,bs,MB,D,window", [
    (3, 4, 2, 2, 8, 16, 3, 32, 0),
    (2, 6, 1, 4, 6, 8, 4, 64, 0),
    (4, 3, 2, 1, 8, 16, 2, 32, 12),   # sliding window
])
def test_paged_verify_attention_kernel(S, T, KV, G, NB, bs, MB, D, window):
    """Multi-query-per-slot (speculative verification) kernel vs the jnp
    oracle, with ragged per-slot query counts, padding rows and an
    inactive slot."""
    from repro.kernels.decode_attention import (
        paged_verify_attention, reference_paged_verify_attention)
    ks = jax.random.split(jax.random.key(S * NB + T), 4)
    q = jax.random.normal(ks[0], (S, T, KV, G, D), jnp.float32)
    kp = jax.random.normal(ks[1], (NB, bs, KV, D), jnp.float32)
    vp = jax.random.normal(ks[2], (NB, bs, KV, D), jnp.float32)
    rng = np.random.default_rng(S + NB + T)
    tables = np.full((S, MB), -1, np.int32)
    perm = rng.permutation(NB)
    start = np.zeros((S,), np.int32)
    n_tok = np.zeros((S,), np.int32)
    off = 0
    for s in range(S):
        n = int(rng.integers(1, MB + 1))
        tables[s, :n] = perm[off:off + n]
        off += n
        n_tok[s] = int(rng.integers(1, T + 1))   # ragged live counts
        start[s] = int(rng.integers(0, n * bs - int(n_tok[s]) + 1))
    start[-1], n_tok[-1] = -1, 0                 # one inactive slot
    tables = jnp.asarray(tables)
    start, n_tok = jnp.asarray(start), jnp.asarray(n_tok)
    out = paged_verify_attention(q, kp, vp, tables, start, n_tok,
                                 window=window)
    ref = reference_paged_verify_attention(q, kp, vp, tables, start, n_tok,
                                           window=window)
    # compare live rows only (padding rows are documented garbage)
    for s in range(S):
        n = int(n_tok[s]) if int(start[s]) >= 0 else 0
        if n:
            d = jnp.max(jnp.abs(out[s, :n] - ref[s, :n]))
            assert float(d) < 2e-5, (s, float(d))


def test_paged_verify_attention_t1_matches_single_query_kernel():
    """T=1 degenerates to the single-query paged kernel exactly."""
    from repro.kernels.decode_attention import (paged_decode_attention,
                                                paged_verify_attention)
    ks = jax.random.split(jax.random.key(3), 3)
    S, KV, G, NB, bs, MB, D = 3, 2, 2, 6, 8, 3, 32
    q = jax.random.normal(ks[0], (S, 1, KV, G, D), jnp.float32)
    kp = jax.random.normal(ks[1], (NB, bs, KV, D), jnp.float32)
    vp = jax.random.normal(ks[2], (NB, bs, KV, D), jnp.float32)
    tables = jnp.asarray([[0, 1, -1], [2, -1, -1], [3, 4, 5]], jnp.int32)
    q_pos = jnp.asarray([9, 4, 20], jnp.int32)
    a = paged_verify_attention(q, kp, vp, tables, q_pos,
                               jnp.ones((S,), jnp.int32))
    b = paged_decode_attention(q[:, 0], kp, vp, tables, q_pos)
    assert float(jnp.max(jnp.abs(a[:, 0] - b))) < 2e-5


def test_paged_verify_attention_causal_among_fresh_tokens():
    """Query token t must see tokens 0..t of the same round (positional
    causality) and never later ones: poisoning the pool at positions
    beyond each query's own position leaves its row unchanged."""
    from repro.kernels.decode_attention import paged_verify_attention
    ks = jax.random.split(jax.random.key(5), 3)
    S, T, KV, G, NB, bs, MB, D = 1, 4, 1, 2, 4, 8, 2, 32
    q = jax.random.normal(ks[0], (S, T, KV, G, D))
    kp = jax.random.normal(ks[1], (NB, bs, KV, D))
    vp = jax.random.normal(ks[2], (NB, bs, KV, D))
    tables = jnp.asarray([[1, 3]], jnp.int32)
    start = jnp.asarray([5], jnp.int32)          # queries at 5,6,7,8
    n_tok = jnp.asarray([T], jnp.int32)
    out1 = paged_verify_attention(q, kp, vp, tables, start, n_tok)
    # poison position 8 (block 3, offset 0) — only query t=3 may see it
    kp2 = kp.at[3, 0].set(1e4)
    vp2 = vp.at[3, 0].set(-1e4)
    out2 = paged_verify_attention(q, kp2, vp2, tables, start, n_tok)
    assert float(jnp.max(jnp.abs(out1[0, :3] - out2[0, :3]))) == 0.0
    assert float(jnp.max(jnp.abs(out1[0, 3] - out2[0, 3]))) > 1.0


def _paged_case(S, NB, bs, MB, KV, D, seed, T=0):
    """Random pools + shuffled block tables shared by the fp8/dequant
    paged-kernel tests (same construction as the plain sweeps)."""
    ks = jax.random.split(jax.random.key(seed), 4)
    qshape = (S, T, KV, 2, D) if T else (S, KV, 2, D)
    q = jax.random.normal(ks[0], qshape, jnp.float32)
    kp = jax.random.normal(ks[1], (NB, bs, KV, D), jnp.float32)
    vp = jax.random.normal(ks[2], (NB, bs, KV, D), jnp.float32)
    rng = np.random.default_rng(seed)
    tables = np.full((S, MB), -1, np.int32)
    perm = rng.permutation(NB)
    pos = np.zeros((S,), np.int32)
    n_tok = np.zeros((S,), np.int32)
    off = 0
    for s in range(S):
        n = int(rng.integers(1, MB + 1))
        tables[s, :n] = perm[off:off + n]
        off += n
        if T:
            n_tok[s] = int(rng.integers(1, T + 1))
            pos[s] = int(rng.integers(0, n * bs - int(n_tok[s]) + 1))
        else:
            pos[s] = int(rng.integers((n - 1) * bs, n * bs))
    return (q, kp, vp, jnp.asarray(tables), jnp.asarray(pos),
            jnp.asarray(n_tok))


@pytest.mark.parametrize("window", [0, 12])
def test_paged_decode_attention_fp8_matches_oracle(window):
    from repro.kernels.decode_attention import (
        paged_decode_attention, reference_paged_decode_attention,
        reference_paged_decode_attention_fp8)
    q, kp, vp, tables, q_pos, _ = _paged_case(3, 8, 16, 3, 2, 32, seed=11)
    out = paged_decode_attention(q, kp, vp, tables, q_pos, window=window,
                                 fp8=True)
    ref = reference_paged_decode_attention_fp8(q, kp, vp, tables, q_pos,
                                               window=window)
    assert float(jnp.max(jnp.abs(out - ref))) < 5e-6
    exact = reference_paged_decode_attention(q, kp, vp, tables, q_pos,
                                             window=window)
    assert float(jnp.max(jnp.abs(ref - exact))) > 1e-3


@pytest.mark.parametrize("window", [0, 12])
def test_paged_verify_attention_fp8_matches_oracle(window):
    from repro.kernels.decode_attention import (
        paged_verify_attention, reference_paged_verify_attention_fp8)
    q, kp, vp, tables, start, n_tok = _paged_case(3, 8, 16, 3, 2, 32,
                                                  seed=13, T=4)
    out = paged_verify_attention(q, kp, vp, tables, start, n_tok,
                                 window=window, fp8=True)
    ref = reference_paged_verify_attention_fp8(q, kp, vp, tables, start,
                                               n_tok, window=window)
    for s in range(q.shape[0]):
        n = int(n_tok[s]) if int(start[s]) >= 0 else 0
        if n:
            d = jnp.max(jnp.abs(out[s, :n] - ref[s, :n]))
            assert float(d) < 5e-6, (s, float(d))


def _quantized_pool(kp, vp, dtype):
    """Quantize-on-scatter view of a full-precision pool: narrow payload
    plus (NB, bs, KV) per-token-per-head scales — the exact layout
    ``init_paged_cache`` stores."""
    from repro.kernels.quantize import reference_quantize_axis
    kq, ks = reference_quantize_axis(kp, axis=-1, dtype=dtype)
    vq, vs = reference_quantize_axis(vp, axis=-1, dtype=dtype)
    return kq, vq, ks[..., 0], vs[..., 0]


@pytest.mark.parametrize("dtype", ["int8", "fp8_e4m3", "fp8_e5m2"])
@pytest.mark.parametrize("window", [0, 12])
def test_paged_decode_attention_dequant_matches_oracle(dtype, window):
    """Dequant-on-load kernel vs the materialize-then-attend oracle on all
    three pool dtypes, and the quantized result genuinely differs from the
    full-precision pool's (the narrow payload is what's being read)."""
    from repro.kernels.decode_attention import (
        paged_decode_attention_dequant, reference_paged_decode_attention,
        reference_paged_decode_attention_dequant)
    q, kp, vp, tables, q_pos, _ = _paged_case(3, 8, 16, 3, 2, 32, seed=17)
    kq, vq, ks, vs = _quantized_pool(kp, vp, dtype)
    out = paged_decode_attention_dequant(q, kq, vq, ks, vs, tables, q_pos,
                                         window=window)
    ref = reference_paged_decode_attention_dequant(
        q, kq, vq, ks, vs, tables, q_pos, window=window)
    assert float(jnp.max(jnp.abs(out - ref))) < 5e-6
    exact = reference_paged_decode_attention(q, kp, vp, tables, q_pos,
                                             window=window)
    assert float(jnp.max(jnp.abs(ref - exact))) > 1e-4


@pytest.mark.parametrize("dtype", ["int8", "fp8_e4m3"])
def test_paged_verify_attention_dequant_matches_oracle(dtype):
    from repro.kernels.decode_attention import (
        paged_verify_attention_dequant,
        reference_paged_verify_attention_dequant)
    q, kp, vp, tables, start, n_tok = _paged_case(3, 8, 16, 3, 2, 32,
                                                  seed=19, T=4)
    kq, vq, ks, vs = _quantized_pool(kp, vp, dtype)
    out = paged_verify_attention_dequant(q, kq, vq, ks, vs, tables, start,
                                         n_tok)
    ref = reference_paged_verify_attention_dequant(
        q, kq, vq, ks, vs, tables, start, n_tok)
    for s in range(q.shape[0]):
        n = int(n_tok[s]) if int(start[s]) >= 0 else 0
        if n:
            d = jnp.max(jnp.abs(out[s, :n] - ref[s, :n]))
            assert float(d) < 5e-6, (s, float(d))


def test_paged_decode_attention_ignores_unmapped_and_stale():
    """Poisoning unmapped blocks and positions beyond q_pos must not change
    the output."""
    from repro.kernels.decode_attention import paged_decode_attention
    ks = jax.random.split(jax.random.key(9), 3)
    S, KV, G, NB, bs, MB, D = 1, 1, 2, 4, 16, 3, 32
    q = jax.random.normal(ks[0], (S, KV, G, D))
    kp = jax.random.normal(ks[1], (NB, bs, KV, D))
    vp = jax.random.normal(ks[2], (NB, bs, KV, D))
    tables = jnp.asarray([[2, 0, -1]], jnp.int32)
    q_pos = jnp.asarray([20], jnp.int32)          # valid: block 2 + 5 of blk 0
    out1 = paged_decode_attention(q, kp, vp, tables, q_pos)
    kp2 = kp.at[1].set(1e4).at[3].set(1e4)        # unmapped blocks
    vp2 = vp.at[1].set(-1e4).at[3].set(-1e4)
    kp2 = kp2.at[0, 5:].set(1e4)                  # stale: beyond q_pos
    vp2 = vp2.at[0, 5:].set(-1e4)
    out2 = paged_decode_attention(q, kp2, vp2, tables, q_pos)
    assert float(jnp.max(jnp.abs(out1 - out2))) == 0.0
