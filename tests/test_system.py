"""End-to-end system behaviour: the three-stage pipeline under all three of
the paper's configurations, checkpoint hand-off, and the multi-pod sharding
contract (in a subprocess with fake devices)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


import pytest


@pytest.mark.slow
def test_pipeline_all_three_methods_tiny():
    """base->mid->sft for ddp / diloco / hybrid on a tiny model; losses must
    be finite, and the hybrid run must switch methods per stage."""
    from repro.launch.train import run_pipeline
    for method in ("ddp", "diloco", "hybrid"):
        res = run_pipeline(method=method, arch="tiny",
                           steps={"base": 8, "mid": 4, "sft": 4},
                           workers=2, per_worker_batch=2, seq_len=64,
                           eval_after_each_stage=False)
        for stage, e in res["stages"].items():
            assert np.isfinite(e["loss_last"]), (method, stage)
        assert res["stages"]["base"]["method"] == (
            "diloco" if method in ("diloco", "hybrid") else "ddp")
        assert res["stages"]["sft"]["method"] == (
            "diloco" if method == "diloco" else "ddp")


def test_checkpoint_crosses_trainers():
    """DiLoCo global params -> checkpoint -> DDP trainer (Hybrid hand-off)."""
    import tempfile
    from helpers import tiny_batch, tiny_cfg
    from repro.checkpoint import load_pytree, save_pytree
    from repro.configs.base import DiLoCoConfig, OptimizerConfig
    from repro.core import DDPTrainer, DiLoCoTrainer
    from repro.models.transformer import build_model, init_params

    cfg = tiny_cfg("dense")
    m = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    opt = OptimizerConfig(total_steps=10, schedule="constant")
    tr = DiLoCoTrainer(m.loss, opt, DiLoCoConfig(num_workers=2,
                                                 h_inner_steps=2))
    state = tr.init(params)
    inner, outer = tr.jit_steps()
    batch = jax.tree.map(lambda x: jnp.stack([x, x]), tiny_batch(cfg))
    state, _, _ = inner(state, batch)
    state = outer(state)

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck")
        save_pytree(state.global_params, path)
        restored = load_pytree(params, path)
    ddp = DDPTrainer(m.loss, opt)
    dstate = ddp.init(restored)
    dstate, loss, _ = jax.jit(ddp.train_step)(dstate, tiny_batch(cfg))
    assert bool(jnp.isfinite(loss))


_MULTIPOD_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import re, sys, json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

sys.path.insert(0, SRCPATH)
from repro.configs.registry import get_reduced
from repro.launch import steps as steps_mod
from repro.launch.mesh import _make_mesh
from repro.launch.state import abstract_diloco_state, shardings_from_names
from repro.launch.dryrun_lib import _batch_shardings
from repro.configs.base import DiLoCoConfig, OptimizerConfig
from repro.models.sharding import sharding_ctx
from repro.models.transformer import build_model

mesh = _make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = get_reduced("qwen1.5-0.5b").with_(compute_dtype="bfloat16")
model = build_model(cfg)
opt = OptimizerConfig(total_steps=10)
dcfg = DiLoCoConfig(num_workers=2)
with sharding_ctx(mesh, {"batch": ("data",), "pod": ("pod",)}):
    state_sds, names = abstract_diloco_state(cfg, opt, dcfg)
    st_sh = shardings_from_names(names, state_sds, mesh)
    batch = {"tokens": jax.ShapeDtypeStruct((2, 4, 64), jnp.int32),
             "labels": jax.ShapeDtypeStruct((2, 4, 64), jnp.int32)}
    b_sh = _batch_shardings(batch, mesh, stacked=True)
    inner, outer = steps_mod.make_diloco_steps(model, opt, dcfg)
    jitted = jax.jit(inner, in_shardings=(st_sh, b_sh),
                     out_shardings=(st_sh, NamedSharding(mesh, P("pod"))))
    compiled = jitted.lower(state_sds, batch).compile()
    txt = compiled.as_text()

# The DiLoCo contract: no inner-step collective may MIX data across pod-0
# (devices 0-3) and pod-1 (devices 4-7) — no cross-pod all-reduce /
# reduce-scatter / all-gather.  One carve-out: this XLA's SPMD partitioner
# reshards tiny (sub-MiB) optimizer tensors via cross-pod all-to-all device
# permutations (a layout shuffle of per-worker values, not a reduction);
# those move ~KBs of housekeeping data and are waived by a byte threshold.
WIDTH = {"f32": 4, "bf16": 2, "s32": 4, "u32": 4, "f16": 2, "s8": 1, "u8": 1}

OPS = r"all-reduce|all-gather|all-to-all|reduce-scatter|collective-permute"

def local_bytes(line):
    # count only the RESULT shape(s), i.e. text left of the op invocation —
    # operand shapes on the same line would double-count the payload
    m = re.match(r"%\\S+ = (.*?)(?:" + OPS + r")", line.strip())
    result = m.group(1) if m else line
    total = 0
    for dt, dims in re.findall(r"(\\w+)\\[([0-9,]*)\\]", result):
        if dt not in WIDTH:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * WIDTH[dt]
    return total

bad = []
for line in txt.splitlines():
    if "replica_groups" not in line:
        continue
    cross = False
    for g in re.findall(r"replica_groups=\\{([^}]*(?:\\}[^}]*)*?)\\}\\}", line):
        for grp in re.findall(r"\\{([0-9, ]+)\\}", g):
            devs = [int(x) for x in grp.replace(" ", "").split(",") if x]
            if devs and min(devs) < 4 <= max(devs):
                cross = True
    # iota-form groups spanning all 8 devices mix pods too
    for m in re.findall(r"replica_groups=\\[(\\d+),(\\d+)\\]", line):
        if int(m[0]) == 1 and int(m[1]) == 8:
            cross = True
    if not cross:
        continue
    if "all-to-all" in line and local_bytes(line) < (1 << 20):
        continue  # waived: small cross-pod layout permutation (see above)
    op = line.strip().split("=", 1)[0].strip()
    bad.append([op, local_bytes(line)])
print(json.dumps({"ok": not bad, "bad": bad[:5]}))
"""


@pytest.mark.slow
def test_multipod_inner_step_has_no_cross_pod_collectives():
    """Compile the vmapped DiLoCo inner step on a (2,2,2) fake-device mesh in
    a subprocess and verify no collective crosses the pod boundary."""
    code = f"SRCPATH = {SRC!r}\n" + _MULTIPOD_SNIPPET
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, (out.stderr[-3000:], out.stdout[-500:])
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ok"], res


@pytest.mark.slow
def test_outer_step_crosses_pods_and_inner_does_not_mix_grads():
    """Numerical check on 8 fake devices: per-pod losses differ (no gradient
    mixing) and the outer step equalizes worker params."""
    code = f"SRCPATH = {SRC!r}\n" + """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
import jax, jax.numpy as jnp
sys.path.insert(0, SRCPATH)
from helpers_not_needed import *  # noqa
""".replace("from helpers_not_needed import *  # noqa", """
from repro.configs.base import DiLoCoConfig, OptimizerConfig, ModelConfig
from repro.core import DiLoCoTrainer
from repro.launch.mesh import _make_mesh
from repro.models.sharding import sharding_ctx
from repro.models.transformer import build_model, init_params

mesh = _make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                  d_ff=128, vocab_size=128)
model = build_model(cfg)
params, _ = init_params(cfg, jax.random.key(0))
tr = DiLoCoTrainer(model.loss, OptimizerConfig(total_steps=10,
                                               schedule="constant"),
                   DiLoCoConfig(num_workers=2, h_inner_steps=2))
with sharding_ctx(mesh, {"batch": ("data",), "pod": ("pod",)}):
    state = tr.init(params)
    inner, outer = tr.jit_steps()
    k = jax.random.key(7)
    toks = jax.random.randint(k, (2, 4, 32), 0, 128)
    batch = {"tokens": toks, "labels": (toks + 1) % 128}
    state, loss, _ = inner(state, batch)
    diverged = float(jnp.max(jnp.abs(
        jax.tree.leaves(state.worker_params)[3][0]
        - jax.tree.leaves(state.worker_params)[3][1])))
    state = outer(state)
    resynced = float(jnp.max(jnp.abs(
        jax.tree.leaves(state.worker_params)[3][0]
        - jax.tree.leaves(state.worker_params)[3][1])))
print(json.dumps({"losses_differ": bool(abs(float(loss[0]) - float(loss[1])) > 1e-7),
                  "diverged": diverged > 0, "resynced": resynced == 0.0}))
""")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["losses_differ"] and res["diverged"] and res["resynced"], res
