"""Streaming DiLoCo (fragment-wise staggered sync — paper reference [4])."""
import jax
import jax.numpy as jnp
import pytest

from helpers import tiny_cfg
from repro.configs.base import DiLoCoConfig, OptimizerConfig
from repro.core.streaming import (StreamingDiLoCoTrainer, fragment_fraction,
                                  fragment_masks, run_streaming_diloco)
from repro.core import DiLoCoTrainer, run_diloco
from repro.models.transformer import build_model, init_params

OPT = OptimizerConfig(total_steps=100, warmup_steps=0, schedule="constant",
                      learning_rate=0.02, adam_lr=1e-3)


def _setup(k=2, h=8, F=4):
    cfg = tiny_cfg("dense", num_layers=4)
    m = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    tr = StreamingDiLoCoTrainer(
        m.loss, OPT, DiLoCoConfig(num_workers=k, h_inner_steps=h),
        num_fragments=F)
    return cfg, m, params, tr


def _data(cfg, k, step, B=4, S=16):
    key = jax.random.key(100 + step)
    toks = jax.random.randint(key, (k, B, S), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": (toks + 1) % cfg.vocab_size}


def test_fragments_partition_params():
    cfg, m, params, tr = _setup()
    masks = fragment_masks(params, 4)
    # every parameter belongs to exactly one fragment
    total = jax.tree.map(lambda *ms: sum(m.astype(jnp.int32) for m in ms),
                         *masks)
    for leaf in jax.tree.leaves(total):
        assert bool(jnp.all(leaf == 1))
    fracs = [fragment_fraction(params, mk) for mk in masks]
    assert abs(sum(fracs) - 1.0) < 1e-6
    assert all(f > 0 for f in fracs)


def test_fragment_sync_touches_only_fragment():
    cfg, m, params, tr = _setup(k=2)
    state = tr.init(params)
    inner = jax.jit(tr.inner_step)
    for s in range(3):
        state, _, _ = inner(state, _data(cfg, 2, s))
    masks = fragment_masks(params, 4)
    before = state.worker_params
    state2 = jax.jit(tr.outer_step_fragment)(state, masks[1])
    for b, a, mk in zip(jax.tree.leaves(before),
                        jax.tree.leaves(state2.worker_params),
                        jax.tree.leaves(masks[1])):
        outside = jnp.where(mk[None], 0.0,
                            (a.astype(jnp.float32) - b.astype(jnp.float32)))
        assert float(jnp.max(jnp.abs(outside))) == 0.0  # untouched outside
        # inside the fragment, workers are equalized
        diff_in = jnp.where(mk[None], a - a[:1], 0.0)
        assert float(jnp.max(jnp.abs(diff_in))) < 1e-6


@pytest.mark.slow
def test_streaming_converges_like_vanilla():
    cfg, m, params, tr = _setup(k=2, h=8, F=4)
    state = tr.init(params)
    state, hist = run_streaming_diloco(
        tr, state, lambda s: _data(cfg, 2, s), 40)
    assert len(hist["frag_syncs"]) == 20          # every H/F=2 steps
    # all fragments visited
    assert {f for _, f in hist["frag_syncs"]} == {0, 1, 2, 3}

    vtr = DiLoCoTrainer(m.loss, OPT, DiLoCoConfig(num_workers=2,
                                                  h_inner_steps=8))
    vstate = vtr.init(params)
    vstate, vhist = run_diloco(vtr, vstate, lambda s: _data(cfg, 2, s), 40)
    # comparable convergence (within 15%)
    assert hist["loss"][-1] < vhist["loss"][-1] * 1.15
    # per-sync communication is ~1/F of vanilla
    masks = fragment_masks(params, 4)
    frac = max(fragment_fraction(params, mk) for mk in masks)
    assert frac < 0.6  # largest fragment carries the embedding, still <60%
