"""Data pipeline: BPE roundtrip, special tokens, packing, worker sharding."""
import numpy as np

from repro.data import PackedDataset, build_tokenizer, synthetic


def _tok():
    w = synthetic.World.make(10)
    texts = synthetic.gen_pretrain_texts(w, 300)
    return w, texts, build_tokenizer(texts[:200], 384)


def test_bpe_roundtrip():
    w, texts, tok = _tok()
    for t in texts[:20]:
        assert tok.decode(tok.encode(t)).strip() == t.strip()


def test_special_tokens_atomic():
    w, texts, tok = _tok()
    s = "<|user_start|>compute 1 + 1 .<|user_end|>"
    ids = tok.encode(s)
    assert tok.special_id("<|user_start|>") in ids
    assert tok.special_id("<|user_end|>") in ids
    # byte-level BPE appends a word-boundary space; roundtrip is exact up to
    # whitespace before special tokens
    assert tok.decode(ids).replace(" <|", "<|") == s


def test_bos_prepended():
    w, texts, tok = _tok()
    ids = tok.encode("hello", add_bos=True)
    assert ids[0] == tok.bos


def test_packing_labels_shift():
    w, texts, tok = _tok()
    ds = PackedDataset.from_texts(texts, tok, seq_len=32)
    b = ds.batch(0, 4)
    assert b["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_worker_batches_deterministic_and_disjoint_regions():
    w, texts, tok = _tok()
    ds = PackedDataset.from_texts(texts, tok, seq_len=32)
    a = ds.worker_batches(0, 4, 2)
    b = ds.worker_batches(0, 4, 2)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.worker_batches(1, 4, 2)
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].shape == (4, 2, 32)


def test_eval_items_well_formed():
    w = synthetic.World.make(10)
    for it in synthetic.gen_mc_eval(w, 10):
        assert len(it["options"]) == 4
        assert 0 <= it["answer"] < 4
        gold = it["options"][it["answer"]]
        assert isinstance(gold, str)
    for it in synthetic.gen_arith_eval(10):
        lhs = it["prompt"].split("compute ")[1].split(" .")[0]
        a, op, b = lhs.split(" ")
        expect = {"+": int(a) + int(b), "-": int(a) - int(b),
                  "*": int(a) * int(b)}[op]
        assert int(it["answer"]) == expect


def test_heldout_entities_disjoint():
    w = synthetic.World.make(20)
    assert not set(w.train_entities()) & set(w.eval_entities())
