"""Fused AdamW Pallas kernel: kernel-vs-oracle sweeps (shapes, dtypes,
decay/step edge cases) and fused-vs-unfused optimizer agreement —
including under vmap over the worker dim, which is how the inner loop
actually runs it.

Exactness contract: the kernel runs the oracle's f32 ops in the oracle's
order, but bit-identical outputs are NOT attainable on this backend —
XLA:CPU's FMA contraction depends on the surrounding program (a
``pallas_call`` is a fusion barrier pure-jnp code does not have, and the
kernel computes on flattened (1, M) views while the unfused path sees
each leaf's natural shape), so one multiply-add may round differently.
``_ULP_RTOL/_ULP_ATOL`` bound that noise tightly (observed ~1e-7
relative, i.e. 1-2 ulp); anything beyond it is a real kernel bug.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OptimizerConfig
from repro.kernels.fused_adamw import (fused_adamw_update,
                                       reference_fused_adamw)
from repro.optim import adamw, apply_updates, nanochat_optimizer

B1, B2, EPS = 0.9, 0.95, 1e-10
_ULP_RTOL, _ULP_ATOL = 1e-5, 1e-8       # FMA-contraction noise bound


def _leaf(shape, dtype, key):
    ks = jax.random.split(jax.random.key(key), 4)
    p = jax.random.normal(ks[0], shape, dtype)
    g = jax.random.normal(ks[1], shape, dtype)
    m = jax.random.normal(ks[2], shape, jnp.float32)
    v = jnp.abs(jax.random.normal(ks[3], shape, jnp.float32))
    return p, g, m, v


def _scalars(t):
    tt = jnp.float32(t) + 1.0
    return jnp.float32(3e-4), 1 - B1 ** tt, 1 - B2 ** tt


def _assert_ulp_close(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=_ULP_RTOL, atol=_ULP_ATOL)


@pytest.mark.parametrize("shape", [(8,), (3, 5), (2, 64, 3), (127,), (128,),
                                   (1, 300)])
@pytest.mark.parametrize("wd", [0.0, 0.1])
def test_kernel_matches_oracle(shape, wd):
    p, g, m, v = _leaf(shape, jnp.float32, 0)
    lr, bc1, bc2 = _scalars(0)
    kw = dict(b1=B1, b2=B2, eps=EPS, wd=wd)
    got = fused_adamw_update(p, g, m, v, lr, bc1, bc2, **kw)
    want = jax.jit(functools.partial(reference_fused_adamw, **kw))(
        p, g, m, v, lr, bc1, bc2)
    _assert_ulp_close(got, want)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("t", [0, 10, 1000])
def test_kernel_dtype_and_step_sweep(dtype, t):
    p, g, m, v = _leaf((33, 7), dtype, t)
    lr, bc1, bc2 = _scalars(t)
    kw = dict(b1=B1, b2=B2, eps=EPS, wd=0.01)
    got = fused_adamw_update(p, g, m, v, lr, bc1, bc2, **kw)
    want = jax.jit(functools.partial(reference_fused_adamw, **kw))(
        p, g, m, v, lr, bc1, bc2)
    assert got[0].dtype == jnp.float32
    _assert_ulp_close(got, want)


def test_kernel_zero_size_sentinel():
    """The partitioned optimizer masks leaves it does not own to (0,);
    the fused path must pass them through (a Pallas grid cannot be
    empty)."""
    p = jnp.zeros((0,), jnp.float32)
    lr, bc1, bc2 = _scalars(0)
    u, m, v = fused_adamw_update(p, p, p, p, lr, bc1, bc2,
                                 b1=B1, b2=B2, eps=EPS, wd=0.1)
    assert u.shape == m.shape == v.shape == (0,)


def _tree(key=0):
    ks = jax.random.split(jax.random.key(key), 3)
    return {"w": jax.random.normal(ks[0], (17, 9)),
            "b": jax.random.normal(ks[1], (9,)),
            "e": jax.random.normal(ks[2], (5, 4, 3))}


@pytest.mark.parametrize("wd", [0.0, 0.05])
def test_fused_optimizer_agrees(wd):
    """adamw(fused=True) vs adamw() under jit on a whole tree: same math,
    agreement bounded by shape-dependent FMA contraction."""
    params, grads = _tree(0), _tree(1)
    ref_opt = adamw(1e-3, (B1, B2), EPS, wd)
    fus_opt = adamw(1e-3, (B1, B2), EPS, wd, fused=True)
    state = ref_opt.init(params)

    @jax.jit
    def step_ref(g, s, p):
        return ref_opt.update(g, s, p, 3)

    @jax.jit
    def step_fus(g, s, p):
        return fus_opt.update(g, s, p, 3)

    _assert_ulp_close(step_ref(grads, state, params),
                      step_fus(grads, state, params))


def test_fused_optimizer_agrees_under_vmap():
    """The inner loop runs the optimizer inside vmap over the K worker
    dim — the Pallas batching rule must hold up there."""
    K = 3
    params = jax.tree.map(lambda x: jnp.broadcast_to(x, (K,) + x.shape) *
                          (1 + jnp.arange(K, dtype=jnp.float32)
                           .reshape((K,) + (1,) * x.ndim)), _tree(0))
    grads = jax.tree.map(lambda x: jnp.broadcast_to(x, (K,) + x.shape),
                         _tree(1))
    ref_opt = adamw(1e-3, (B1, B2), EPS, 0.01)
    fus_opt = adamw(1e-3, (B1, B2), EPS, 0.01, fused=True)
    state = jax.vmap(ref_opt.init)(params)

    def run(opt):
        return jax.jit(jax.vmap(lambda g, s, p: opt.update(g, s, p, 0)))(
            grads, state, params)

    _assert_ulp_close(run(ref_opt), run(fus_opt))


def test_nanochat_optimizer_fused_flag_agrees():
    """OptimizerConfig.fused_adamw flips only the adamw partition's
    implementation: one full nanochat (Muon+AdamW) step agrees to within
    FMA-contraction noise, including the 0-sized sentinel leaves the
    partition router creates."""
    from helpers import tiny_batch, tiny_cfg
    from repro.models import build_model
    from repro.models.transformer import init_params

    cfg = tiny_cfg("dense")
    model = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    batch = tiny_batch(cfg)
    base = OptimizerConfig(total_steps=10, warmup_steps=0,
                           schedule="constant", weight_decay=0.01)

    def one_step(ocfg):
        opt = nanochat_optimizer(ocfg)

        @jax.jit
        def step(p, s):
            (_, _), grads = jax.value_and_grad(
                model.loss, has_aux=True)(p, batch)
            u, s = opt.update(grads, s, p, 0)
            return apply_updates(p, u), s

        return step(params, opt.init(params))

    import dataclasses
    _assert_ulp_close(one_step(base),
                      one_step(dataclasses.replace(base, fused_adamw=True)))
