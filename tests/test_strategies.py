"""Unified SyncStrategy runtime: strategy equivalences + comm simulator."""
import jax
import numpy as np
import pytest

from helpers import tiny_cfg
from repro.configs.base import DiLoCoConfig, OptimizerConfig
from repro.core import (DDPSync, DiLoCoSync, DistTrainer, GossipSync,
                        OverlappedSync, StreamingSync, make_strategy)
from repro.core.sync import SyncEvent
from repro.launch.comm_sim import (CommModel, modeled_step_time,
                                   simulate_schedule)
from repro.models.transformer import build_model, init_params

OPT = OptimizerConfig(total_steps=100, warmup_steps=0, schedule="constant",
                      learning_rate=0.02, adam_lr=1e-3)


def _setup(k=2, h=4, **dkw):
    cfg = tiny_cfg("dense")
    m = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    dcfg = DiLoCoConfig(num_workers=k, h_inner_steps=h, **dkw)
    return cfg, m, params, dcfg


def _data(cfg, k, step, B=4, S=16):
    key = jax.random.key(1000 + step)
    toks = jax.random.randint(key, (k, B, S), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": (toks + 1) % cfg.vocab_size}


def _run(m, params, dcfg, strategy, cfg, steps, k):
    dt = DistTrainer(m.loss, OPT, dcfg, strategy)
    state = dt.init(params)
    return dt.run(state, lambda s: _data(cfg, k, s), steps)


def _assert_tree_close(a, b, atol=0.0):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=atol,
                                   rtol=0)


# ---------------------------------------------------------------------------
# Equivalences
# ---------------------------------------------------------------------------

def test_diloco_k1_h1_lr1_mu0_matches_ddp():
    """DiLoCoSync degenerates to DDPSync when the outer step is the
    identity hand-off: K=1, H=1, eta=1, mu=0."""
    cfg, m, params, _ = _setup()
    dcfg = DiLoCoConfig(num_workers=1, h_inner_steps=1, outer_lr=1.0,
                        outer_momentum=0.0, nesterov=False)
    ddp_state, ddp_hist = _run(m, params, dcfg, DDPSync(), cfg, 6, k=1)
    dlc_state, dlc_hist = _run(m, params, dcfg, DiLoCoSync(), cfg, 6, k=1)
    # eta*(theta_w - theta_g) addition round-trips through f32 arithmetic
    _assert_tree_close(ddp_state.global_params, dlc_state.global_params,
                       atol=1e-6)
    np.testing.assert_allclose(ddp_hist["loss"], dlc_hist["loss"], rtol=1e-6)


def test_overlapped_delay0_matches_diloco_exactly():
    """With delay=0 and jitter=0 the overlapped runner applies the outer
    update at the boundary from the boundary snapshot — bit-for-bit
    DiLoCoSync."""
    cfg, m, params, dcfg = _setup(k=2, h=4)
    a_state, a_hist = _run(m, params, dcfg, DiLoCoSync(), cfg, 12, k=2)
    b_state, b_hist = _run(m, params, dcfg, OverlappedSync(delay=0), cfg,
                           12, k=2)
    _assert_tree_close(a_state.global_params, b_state.global_params)
    _assert_tree_close(a_state.worker_params, b_state.worker_params)
    assert a_hist["sync_steps"] == b_hist["sync_steps"] == [3, 7, 11]
    np.testing.assert_array_equal(a_hist["loss"], b_hist["loss"])


def test_streaming_f1_matches_diloco():
    """One fragment covering all params == vanilla DiLoCo (same boundary
    steps, same masks-free math)."""
    cfg, m, params, dcfg = _setup(k=2, h=4)
    a_state, _ = _run(m, params, dcfg, DiLoCoSync(), cfg, 8, k=2)
    b_state, b_hist = _run(m, params, dcfg, StreamingSync(num_fragments=1),
                           cfg, 8, k=2)
    assert [s for s, _ in b_hist["frag_syncs"]] == [3, 7]
    _assert_tree_close(a_state.global_params, b_state.global_params,
                       atol=1e-6)


def test_overlapped_delay_and_jitter_converges():
    """Delayed application with straggler jitter still trains: losses stay
    finite and decrease, and every round produces exactly one sync."""
    cfg, m, params, dcfg = _setup(k=3, h=6)
    state, hist = _run(m, params, dcfg,
                       OverlappedSync(delay=2, jitter=2, seed=7), cfg, 18,
                       k=3)
    assert np.isfinite(hist["loss"]).all()
    assert hist["loss"][-1] < hist["loss"][0]
    # boundaries at 5, 11, 17 -> applications at 7, 13, then the final
    # pending round is flushed by finalize at the last step
    assert hist["sync_steps"] == [7, 13, 17]


def test_overlapped_rejects_bad_delay_jitter():
    cfg, m, params, dcfg = _setup(k=2, h=4)
    dt = DistTrainer(m.loss, OPT, dcfg, OverlappedSync(delay=4))
    state = dt.init(params)
    with pytest.raises(ValueError):
        dt.run(state, lambda s: _data(cfg, 2, s), 4)
    dt = DistTrainer(m.loss, OPT, dcfg, OverlappedSync(delay=2, jitter=2))
    state = dt.init(params)
    with pytest.raises(ValueError):
        dt.run(state, lambda s: _data(cfg, 2, s), 4)


def test_ddp_sync_rejects_multiple_workers():
    """DDPSync is the K=1 + global-batch baseline; K>1 under it would be
    silently-unsynchronized workers, so bind() must refuse."""
    cfg, m, params, dcfg = _setup(k=2, h=4)
    dt = DistTrainer(m.loss, OPT, dcfg, DDPSync())
    state = dt.init(params)
    with pytest.raises(ValueError, match="num_workers"):
        dt.run(state, lambda s: _data(cfg, 2, s), 2)


def test_empty_fault_schedule_is_byte_identical_for_every_strategy():
    """Fault-tolerance no-op contract: passing an EMPTY FaultSchedule must
    leave every registered strategy's run byte-identical to faults=None —
    no tracker, no quorum jits, the original compiled programs."""
    from repro.core.faults import FaultSchedule
    from repro.core.sync import compressed_ddp_config, strategy_names
    cfg = tiny_cfg("dense")
    m = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    assert len(strategy_names()) >= 8
    for name in strategy_names():
        if name == "ddp":
            dcfg = DiLoCoConfig(strategy="ddp", num_workers=1,
                                h_inner_steps=1, outer_lr=1.0,
                                outer_momentum=0.0, nesterov=False)
        elif name == "ddp_compressed":
            dcfg = compressed_ddp_config(
                DiLoCoConfig(num_workers=2, grad_compress="int8"))
        else:
            dcfg = DiLoCoConfig(strategy=name, num_workers=2,
                                h_inner_steps=2)
        k = dcfg.num_workers
        runs = []
        for faults in (None, FaultSchedule()):
            dt = DistTrainer(m.loss, OPT, dcfg, make_strategy(dcfg))
            state = dt.init(params)
            runs.append(dt.run(state, lambda s: _data(cfg, k, s), 4,
                               faults=faults))
        (sa, ha), (sb, hb) = runs
        for x, y in zip(jax.tree.leaves(sa), jax.tree.leaves(sb)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=f"strategy {name}")
        for key in set(ha) | set(hb):
            if key == "step_seconds":    # wall-clock, not math
                continue
            assert ha[key] == hb[key], f"strategy {name}: history[{key}]"


def test_make_strategy_from_config():
    assert make_strategy(DiLoCoConfig(strategy="ddp")).name == "ddp"
    assert make_strategy(DiLoCoConfig(strategy="diloco")).name == "diloco"
    s = make_strategy(DiLoCoConfig(strategy="streaming", num_fragments=8))
    assert s.num_fragments == 8
    s = make_strategy(DiLoCoConfig(strategy="overlapped", sync_delay=5,
                                   h_jitter=3))
    assert (s.delay, s.jitter) == (5, 3)
    s = make_strategy(DiLoCoConfig(strategy="gossip", topology="random",
                                   sync_seed=11))
    assert (s.name, s.topology, s.seed) == ("gossip", "random", 11)
    s = make_strategy(DiLoCoConfig(strategy="async_gossip",
                                   staleness_bound=3, h_jitter=2,
                                   sync_seed=5))
    assert (s.name, s.staleness_bound, s.jitter, s.seed) == (
        "async_gossip", 3, 2, 5)
    with pytest.raises(ValueError, match="gossip"):
        # the registry error enumerates every registered name
        make_strategy(DiLoCoConfig(strategy="nope"))


# ---------------------------------------------------------------------------
# Payload schedules + event-driven simulator
# ---------------------------------------------------------------------------

def test_payload_schedules_bytes_ratio():
    """Over one H window, DDP ships H full fp32 payloads, DiLoCo one —
    the paper's ~H× reduction, strategy-for-strategy.  K=2 so the
    collective factors (ring reduce 2(K-1)/K, gather K-1) are both 1 and
    the per-hop payload is the raw 4n."""
    dcfg = DiLoCoConfig(num_workers=2, h_inner_steps=10)
    n = 1000
    ddp = DDPSync().payload_schedule(n, 10, dcfg)
    dlc = DiLoCoSync().payload_schedule(n, 10, dcfg)
    stream = StreamingSync(num_fragments=5).payload_schedule(n, 10, dcfg)
    assert sum(e.bytes_per_worker for e in ddp) == 10 * 4 * n
    assert sum(e.bytes_per_worker for e in dlc) == 4 * n
    assert sum(e.bytes_per_worker for e in stream) == 4 * n
    # streaming: 5 slots of 1/5 the payload, staggered
    assert len(stream) == 5 and len({e.fragment for e in stream}) == 5
    # overlapped: same bytes as diloco, but a delay window to hide them in
    ov = OverlappedSync(delay=4).payload_schedule(n, 10, dcfg)
    assert [e.apply_step - e.step for e in ov] == [4]


def test_per_worker_bytes_scaling_in_k():
    """K-scaling regression: the all-reduce/gather strategies' per-worker
    boundary bytes GROW with fleet size, gossip's stay flat — the
    tentpole claim, pinned at the payload-schedule level."""
    n, steps = 1000, 20

    def total(strat, k):
        dcfg = DiLoCoConfig(num_workers=k, h_inner_steps=10)
        return sum(e.bytes_per_worker
                   for e in strat.payload_schedule(n, steps, dcfg))

    for k in (8, 16, 32, 64):
        # gather: each worker receives the other K-1 codec'd rows
        assert total(DiLoCoSync(), k) == (k - 1) * total(DiLoCoSync(), 2)
        # ring reduce: 2(K-1)/K per hop, monotone in K
        assert total(DDPSync(), k) > total(DDPSync(), 2)
        # gossip: one flat peer payload, independent of K
        assert total(GossipSync(), k) == total(GossipSync(), 2)


def test_simulator_blocking_vs_overlapped():
    """A transfer with an apply window hides behind compute; a blocking one
    stalls the timeline by exactly its transfer time."""
    comm = CommModel(bandwidth=100.0, latency=0.0)
    blocking = [SyncEvent(step=4, bytes_per_worker=200, kind="delta",
                          apply_step=4)]
    r = simulate_schedule(blocking, 10, step_time_s=1.0, comm=comm)
    assert r["wall_clock_s"] == pytest.approx(12.0)   # 10 compute + 2 stall
    assert r["stall_s"] == pytest.approx(2.0)
    hidden = [SyncEvent(step=4, bytes_per_worker=200, kind="delta",
                        apply_step=8)]
    r = simulate_schedule(hidden, 10, step_time_s=1.0, comm=comm)
    assert r["wall_clock_s"] == pytest.approx(10.0)   # fully overlapped
    assert r["stall_s"] == 0.0
    # window too small to hide everything: only the excess is exposed
    partial = [SyncEvent(step=4, bytes_per_worker=300, kind="delta",
                         apply_step=5)]
    r = simulate_schedule(partial, 10, step_time_s=1.0, comm=comm)
    assert r["stall_s"] == pytest.approx(2.0)         # 3s transfer, 1s hidden


def test_simulator_serializes_link():
    """Two transfers emitted back-to-back share one link: the second waits
    for the first."""
    comm = CommModel(bandwidth=100.0, latency=0.0)
    evs = [SyncEvent(step=0, bytes_per_worker=500, kind="delta",
                     apply_step=1),
           SyncEvent(step=1, bytes_per_worker=500, kind="delta",
                     apply_step=2)]
    r = simulate_schedule(evs, 3, step_time_s=1.0, comm=comm)
    # transfer #1: starts t=1, done t=6 (stall at step 1 -> now=6);
    # transfer #2 waits for the link (emitted t=2, starts t=6), done t=11
    assert r["wall_clock_s"] == pytest.approx(11.0)
    assert r["comm_s"] == pytest.approx(10.0)


def test_simulator_ddp_slower_than_diloco():
    """End-to-end: modeled wall-clock orders the strategies the way the
    paper argues — DDP pays every step, DiLoCo every H, overlapped hides
    the exchange.  K=2 keeps the reduce/gather hop factors equal (both 1)
    so the byte ratio is exactly the cadence ratio H."""
    dcfg = DiLoCoConfig(num_workers=2, h_inner_steps=10)
    n = 10_000_000
    comm = CommModel(bandwidth=1e9, latency=0.0)
    step_t = 0.01
    res = {}
    for strat in (DDPSync(), DiLoCoSync(), OverlappedSync(delay=5)):
        evs = strat.payload_schedule(n, 100, dcfg)
        res[strat.name] = simulate_schedule(evs, 100, step_t, comm)
    assert res["ddp"]["wall_clock_s"] > res["diloco"]["wall_clock_s"]
    assert res["diloco"]["wall_clock_s"] > res["overlapped"]["wall_clock_s"]
    assert res["ddp"]["total_bytes"] == pytest.approx(
        10 * res["diloco"]["total_bytes"])


def test_modeled_step_time_positive():
    assert modeled_step_time(1e15) > 0
