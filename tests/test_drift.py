"""Representation-drift diagnostics (repro.core.drift): identity,
orthogonality, and invariance anchors for each metric."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.drift import (delta_cosine, linear_cka, param_drift,
                              subspace_overlap, worker_cka_matrix)


def _approx(v, tol=1e-5):
    return pytest.approx(v, abs=tol)


def _acts(seed, n=64, d=8):
    return jax.random.normal(jax.random.key(seed), (n, d))


def _orthogonal_pair(n=64, d=4, seed=0):
    """Two (n, d) activation matrices with exactly orthogonal, zero-mean
    columns: center a random matrix, then QR — each Q column is a linear
    combination of zero-mean columns, so linear_cka's internal centering
    is a no-op and X^T Y == 0 holds exactly."""
    a = np.asarray(jax.random.normal(jax.random.key(seed), (n, 2 * d)))
    a = a - a.mean(axis=0)
    q, _ = np.linalg.qr(a)
    return jnp.asarray(q[:, :d]), jnp.asarray(q[:, d:2 * d])


# ---------------------------------------------------------------------------
# linear_cka
# ---------------------------------------------------------------------------

def test_linear_cka_identity_is_one():
    x = _acts(0)
    assert float(linear_cka(x, x)) == _approx(1.0)


def test_linear_cka_scale_invariant():
    x = _acts(1)
    assert float(linear_cka(x, 3.7 * x)) == _approx(1.0)
    assert float(linear_cka(x, -0.2 * x)) == _approx(1.0)


def test_linear_cka_orthogonal_is_zero():
    x, y = _orthogonal_pair()
    assert abs(float(linear_cka(x, y))) < 1e-6
    assert float(linear_cka(x, x)) == _approx(1.0)


# ---------------------------------------------------------------------------
# subspace_overlap
# ---------------------------------------------------------------------------

def test_subspace_overlap_identity_is_one():
    x = _acts(2, n=64, d=6)
    assert float(subspace_overlap(x, x, r=4)) == _approx(1.0)


def test_subspace_overlap_disjoint_supports_is_zero():
    """Activations living on disjoint coordinate blocks span orthogonal
    right-singular subspaces."""
    n, d = 64, 4
    a = np.zeros((n, 2 * d), np.float32)
    b = np.zeros((n, 2 * d), np.float32)
    a[:, :d] = np.asarray(jax.random.normal(jax.random.key(3), (n, d)))
    b[:, d:] = np.asarray(jax.random.normal(jax.random.key(4), (n, d)))
    got = float(subspace_overlap(jnp.asarray(a), jnp.asarray(b), r=d))
    assert abs(got) < 1e-6


def test_subspace_overlap_rotation_invariant():
    """The top-r right subspace is a property of the span, not the basis:
    an orthogonal feature rotation leaves the overlap at 1."""
    x = np.asarray(_acts(5, n=64, d=6))
    q, _ = np.linalg.qr(np.asarray(
        jax.random.normal(jax.random.key(6), (6, 6))))
    got = float(subspace_overlap(jnp.asarray(x), jnp.asarray(x @ q), r=6))
    assert got == _approx(1.0, tol=1e-4)


# ---------------------------------------------------------------------------
# delta_cosine
# ---------------------------------------------------------------------------

def test_delta_cosine_identity_and_scale():
    t = {"a": jnp.asarray([1.0, 2.0, -3.0]), "b": jnp.ones((2, 2))}
    assert float(delta_cosine(t, t)) == _approx(1.0)
    t5 = jax.tree.map(lambda x: 5.0 * x, t)
    assert float(delta_cosine(t, t5)) == _approx(1.0)
    tneg = jax.tree.map(lambda x: -x, t)
    assert float(delta_cosine(t, tneg)) == _approx(-1.0)


def test_delta_cosine_orthogonal_is_zero():
    a = {"w": jnp.asarray([1.0, 0.0, 0.0, 0.0])}
    b = {"w": jnp.asarray([0.0, 1.0, 0.0, 0.0])}
    assert abs(float(delta_cosine(a, b))) < 1e-7


# ---------------------------------------------------------------------------
# param_drift / worker_cka_matrix
# ---------------------------------------------------------------------------

def test_param_drift_identical_workers():
    """All workers at global + the SAME delta: zero norm dispersion,
    perfect alignment to the mean and to each other."""
    g = {"w": jnp.ones((3, 2)), "b": jnp.zeros((2,))}
    delta = {"w": jnp.full((3, 2), 0.1), "b": jnp.full((2,), -0.2)}
    wp = jax.tree.map(lambda gg, d: jnp.stack([gg + d] * 4), g, delta)
    out = param_drift(wp, g)
    assert float(out["delta_norm_std"]) == _approx(0.0)
    assert float(out["cos_to_mean"]) == _approx(1.0)
    assert float(out["pairwise_cos"]) == _approx(1.0)


def test_param_drift_opposed_workers():
    """Two workers with exactly opposite deltas: pairwise cosine -1 and a
    vanishing mean direction."""
    g = {"w": jnp.zeros((4,))}
    d = jnp.asarray([1.0, -2.0, 0.5, 0.0])
    wp = {"w": jnp.stack([d, -d])}
    out = param_drift(wp, g)
    assert float(out["pairwise_cos"]) == _approx(-1.0)
    assert float(out["delta_norm_std"]) == _approx(0.0)


def test_worker_cka_matrix_identical_workers():
    k, d = 3, 4
    params = {"w": jnp.stack([jnp.eye(d)] * k)}
    batch = jax.random.normal(jax.random.key(7), (16, d))

    def probe(p, x):
        return x @ p["w"]

    mat = np.asarray(worker_cka_matrix(params, probe, batch))
    assert mat.shape == (k, k)
    np.testing.assert_allclose(mat, np.ones((k, k)), atol=1e-5)
    np.testing.assert_allclose(mat, mat.T, atol=1e-6)
