"""Per-architecture smoke tests (deliverable f): every assigned architecture
instantiates a REDUCED variant of the same family and runs one forward +
one train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_IDS, get_config, get_reduced

pytestmark = pytest.mark.slow  # one fwd+train step per architecture: ~100s
from repro.configs.base import OptimizerConfig
from repro.models.transformer import build_model, init_params
from repro.optim import apply_updates, nanochat_optimizer


def _batch(cfg, B=2, S=64):
    k = jax.random.key(0)
    toks = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
    b = {"tokens": toks, "labels": (toks + 1) % cfg.vocab_size}
    if cfg.num_image_tokens:
        b["patches"] = 0.1 * jnp.ones((B, cfg.num_image_tokens, cfg.d_model))
    if cfg.is_encoder_decoder:
        b["frames"] = 0.1 * jnp.ones((B, cfg.encoder_seq_len, cfg.d_model))
    return b


@pytest.mark.parametrize("arch_id", ALL_IDS)
def test_reduced_variant_constraints(arch_id):
    red = get_reduced(arch_id)
    full = get_config(arch_id)
    assert red.num_layers == 2
    assert red.d_model <= 512
    assert red.num_experts <= 4
    assert red.arch_type == full.arch_type
    assert red.hybrid == full.hybrid
    assert red.is_encoder_decoder == full.is_encoder_decoder
    assert (red.mlp_activation == full.mlp_activation)


@pytest.mark.parametrize("arch_id", ALL_IDS)
def test_smoke_forward_and_train_step(arch_id):
    cfg = get_reduced(arch_id)
    model = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    batch = _batch(cfg)
    logits, _ = jax.jit(model.forward)(params, batch)
    assert logits.shape == (2, batch["tokens"].shape[1], cfg.padded_vocab())
    assert bool(jnp.all(jnp.isfinite(logits))), arch_id

    opt = nanochat_optimizer(OptimizerConfig(total_steps=10, warmup_steps=0))

    @jax.jit
    def step(params, st, batch):
        (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch)
        upd, st = opt.update(grads, st, params, 0)
        return apply_updates(params, upd), st, loss

    st = opt.init(params)
    new_params, st, loss = step(params, st, batch)
    assert bool(jnp.isfinite(loss)), arch_id
    # params actually changed
    changed = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert changed, arch_id


@pytest.mark.parametrize("arch_id", [a for a in ALL_IDS
                                     if a != "seamless-m4t-medium"])
def test_smoke_decode_step(arch_id):
    cfg = get_reduced(arch_id)
    if cfg.num_image_tokens:
        cfg = cfg.with_(num_image_tokens=0)  # text-only decode
    model = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    cache = model.init_cache(2, 32)
    logits, new_cache = jax.jit(model.decode_step)(
        params, cache,
        {"token": jnp.zeros((2, 1), jnp.int32), "position": jnp.int32(0)})
    assert logits.shape == (2, 1, cfg.padded_vocab())
    assert bool(jnp.all(jnp.isfinite(logits))), arch_id


def test_exact_assigned_specs():
    """The full configs carry the exact assigned hyper-parameters."""
    spec = {
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "mamba2-1.3b": (48, 2048, None, None, 0, 50280),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
    }
    for aid, (L, d, H, KV, ff, V) in spec.items():
        c = get_config(aid)
        assert c.num_layers == L, aid
        assert c.d_model == d, aid
        if H is not None:
            assert c.num_heads == H and c.num_kv_heads == KV, aid
        assert c.d_ff == ff, aid
        assert c.vocab_size == V, aid
    assert get_config("qwen1.5-0.5b").qkv_bias
    assert get_config("nemotron-4-15b").mlp_activation == "relu2"
    assert get_config("mixtral-8x7b").num_experts == 8
    assert get_config("mixtral-8x7b").window == 4096
    assert get_config("llama4-scout-17b-a16e").num_experts_per_tok == 1
    assert get_config("hymba-1.5b").hybrid
    assert get_config("mamba2-1.3b").ssm_state_size == 128
    assert get_config("hymba-1.5b").ssm_state_size == 16
