"""GRPO stage (nanochat's optional final stage): the policy-gradient update
must increase the probability of rewarded completions."""
import jax
import jax.numpy as jnp
import pytest

from helpers import tiny_cfg
from repro.configs.base import OptimizerConfig
from repro.core.grpo import GRPOTrainer, grpo_loss
from repro.models.transformer import build_model, init_params


def test_grpo_loss_sign():
    """Positive-advantage sequences must have gradients that increase their
    logprob (loss decreases when their probability rises)."""
    cfg = tiny_cfg("dense")
    m = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    toks = jnp.asarray([[1, 2, 3, 4, 5, 6]], jnp.int32)
    labels = jnp.asarray([[-1, -1, 4, 5, 6, -1]], jnp.int32)
    batch = {"tokens": toks, "labels": labels, "adv": jnp.asarray([1.0])}
    loss, met = grpo_loss(params, batch, m)
    assert bool(jnp.isfinite(loss))
    # loss = -adv * logprob/tok; with adv>0, loss = -mean_logprob
    assert abs(float(loss) + float(met["mean_logprob"])) < 1e-5


@pytest.mark.slow
def test_grpo_increases_reward_probability():
    """Reward completions whose FIRST token is a fixed target id; a few GRPO
    iterations must raise the probability of that token."""
    # small vocab so random-init sampling hits the reward often enough for
    # the group advantage to be non-degenerate (hit rate ~1/16 per sample)
    cfg = tiny_cfg("dense", vocab_size=16)
    m = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(1))
    target = 7
    tr = GRPOTrainer(m, OptimizerConfig(total_steps=30, warmup_steps=0,
                                        schedule="constant",
                                        learning_rate=0.02, adam_lr=3e-3),
                     group_size=16, max_new=2)
    state = tr.init(params)
    prompts = [[1, 2, 3], [4, 5]]

    def reward(_, row):
        return 1.0 if int(row[0]) == target else 0.0

    def p_target(params):
        logits, _ = m.forward(params, {"tokens": jnp.asarray([prompts[0]])})
        return float(jax.nn.softmax(logits[0, -1])[target])

    before = p_target(state["params"])
    for it in range(8):
        state, loss, mean_r = tr.rollout_and_step(
            state, prompts, reward, pad_id=0, seed=it)
    after = p_target(state["params"])
    assert after > before, (before, after)
