import os
import sys

# Tests run on the single real CPU device (the dry-run manages its own
# device count in a subprocess).  Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")
