"""Model substrate: forward shapes, no NaNs, decode==teacher-forced forward
for every stateful family, attention path equivalence."""
import jax
import jax.numpy as jnp
import pytest

from helpers import tiny_batch, tiny_cfg
from repro.models import attention as attn_mod
from repro.models.transformer import build_model, init_params

FAMILIES = ["dense", "moe", "ssm", "hybrid", "audio", "vlm"]


@pytest.mark.parametrize("family", FAMILIES)
def test_forward_shapes_and_finite(family):
    cfg = tiny_cfg(family)
    m = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    batch = tiny_batch(cfg)
    logits, aux = jax.jit(m.forward)(params, batch)
    assert logits.shape == (2, 16, cfg.padded_vocab())
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, metrics = jax.jit(m.loss)(params, batch)
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("family", ["dense", "moe", "ssm", "hybrid", "vlm"])
def test_decode_matches_forward(family):
    # capacity high enough that no token is dropped — otherwise prefill
    # (capacity per 24 tokens) and decode (capacity per 1 token) legitimately
    # differ, as in any capacity-based MoE system
    cfg = tiny_cfg(family, moe_capacity_factor=8.0)
    m = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(1))
    S = 24
    toks = jax.random.randint(jax.random.key(2), (2, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.num_image_tokens:
        batch["patches"] = 0.1 * jnp.ones((2, cfg.num_image_tokens,
                                           cfg.d_model))
    full, _ = jax.jit(m.forward)(params, batch)
    cache = m.init_cache(2, S + cfg.num_image_tokens)
    step = jax.jit(m.decode_step)
    if cfg.num_image_tokens:
        pytest.skip("vlm decode starts after a prefill with patches; "
                    "covered by serving tests for text-only")
    outs = []
    for t in range(S):
        lg, cache = step(params, cache,
                         {"token": toks[:, t:t + 1], "position": jnp.int32(t)})
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    assert float(jnp.max(jnp.abs(dec - full))) < 3e-3


def test_decode_matches_forward_encdec():
    cfg = tiny_cfg("audio")
    m = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(1))
    S = 12
    toks = jax.random.randint(jax.random.key(2), (2, S), 0, cfg.vocab_size)
    frames = 0.3 * jax.random.normal(jax.random.key(3),
                                     (2, cfg.encoder_seq_len, cfg.d_model))
    full, _ = jax.jit(m.forward)(params, {"tokens": toks, "frames": frames})

    # build the cross cache from the encoder output, then decode step-wise
    from repro.models.attention import precompute_cross_cache
    from repro.models.transformer import encode
    memory = encode(params, frames, cfg)
    cache = m.init_cache(2, S)
    crosses = [precompute_cross_cache(
        jax.tree.map(lambda x: x[i], params["cross"])["attn"], memory, cfg)
        for i in range(cfg.num_layers)]
    cache["cross"] = {
        "k": jnp.stack([c["k"] for c in crosses]),
        "v": jnp.stack([c["v"] for c in crosses]),
    }
    step = jax.jit(m.decode_step)
    outs = []
    for t in range(S):
        lg, cache = step(params, cache,
                         {"token": toks[:, t:t + 1], "position": jnp.int32(t)})
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    assert float(jnp.max(jnp.abs(dec - full))) < 3e-3


def test_sliding_window_ring_buffer_decode():
    cfg = tiny_cfg("dense", window=8)
    m = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(1))
    S = 24
    toks = jax.random.randint(jax.random.key(2), (2, S), 0, cfg.vocab_size)
    full, _ = jax.jit(m.forward)(params, {"tokens": toks})
    cache = m.init_cache(2, 8)     # ring buffer == window
    step = jax.jit(m.decode_step)
    outs = []
    for t in range(S):
        lg, cache = step(params, cache,
                         {"token": toks[:, t:t + 1], "position": jnp.int32(t)})
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    assert float(jnp.max(jnp.abs(dec - full))) < 3e-3


@pytest.mark.parametrize("impl", ["blocked"])
def test_attention_impl_equivalence(impl):
    cfg = tiny_cfg("dense")
    m = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(5), (2, 32), 0, cfg.vocab_size)
    ref, _ = m.forward(params, {"tokens": toks})
    from repro.models.transformer import forward_lm
    out, _ = forward_lm(params, {"tokens": toks}, cfg, impl=impl)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-3


def test_banded_swa_equals_direct():
    """The O(S·W) banded prefill must match the O(S²) masked path."""
    cfg = tiny_cfg("dense", window=8)
    build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(6), (2, 32), 0, cfg.vocab_size)
    from repro.models.transformer import forward_lm
    ref, _ = forward_lm(params, {"tokens": toks}, cfg, impl="direct")
    old_bq = attn_mod.BLOCK_Q
    attn_mod.BLOCK_Q = 16
    try:
        out, _ = forward_lm(params, {"tokens": toks}, cfg, impl="banded")
    finally:
        attn_mod.BLOCK_Q = old_bq
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-3


def test_per_layer_window_pattern():
    """Layers with different windows really see different contexts."""
    cfg_all_global = tiny_cfg("dense")
    cfg_windowed = tiny_cfg("dense", window_pattern=(4, 4))
    pa, _ = init_params(cfg_all_global, jax.random.key(0))
    ma = build_model(cfg_all_global)
    mw = build_model(cfg_windowed)
    toks = jax.random.randint(jax.random.key(7), (1, 32), 0, 97)
    la, _ = ma.forward(pa, {"tokens": toks})
    lw, _ = mw.forward(pa, {"tokens": toks})
    assert float(jnp.max(jnp.abs(la - lw))) > 1e-4  # must differ


@pytest.mark.slow
def test_chunked_ce_matches_full():
    """cfg.loss_chunk must not change the loss value or its gradients."""
    from repro.models.transformer import lm_loss
    cfg = tiny_cfg("dense")
    build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 30), 0, cfg.vocab_size)
    batch = {"tokens": toks,
             "labels": ((toks + 1) % cfg.vocab_size).at[:, :3].set(-1)}
    l0, _ = lm_loss(params, batch, cfg)
    l1, _ = lm_loss(params, batch, cfg.with_(loss_chunk=8))
    assert abs(float(l0) - float(l1)) < 1e-5
    g0 = jax.grad(lambda p: lm_loss(p, batch, cfg)[0])(params)
    g1 = jax.grad(lambda p: lm_loss(p, batch, cfg.with_(loss_chunk=8))[0])(params)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)))
    assert err < 1e-5
