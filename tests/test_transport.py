"""Codec-aware outer-sync transport: codecs, Pallas quant kernels,
pipelined strategy, heterogeneous comm simulator, calibration."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import tiny_cfg
from repro.configs.base import DiLoCoConfig, OptimizerConfig
from repro.core import (DiLoCoSync, DistTrainer, PipelinedSync,
                        StreamingSync, make_strategy)
from repro.core.sync import SyncEvent
from repro.core.transport import (BF16Cast, F32Passthrough, Fp8Codec,
                                  Int8Symmetric, make_codec)
from repro.kernels.quantize import (dequantize, quantize_ef,
                                    reference_dequantize,
                                    reference_quantize_ef)
from repro.launch.comm_sim import (CommCalibration, CommModel,
                                   load_calibration, modeled_step_time,
                                   simulate_heterogeneous, simulate_schedule)
from repro.models.transformer import build_model, init_params

OPT = OptimizerConfig(total_steps=100, warmup_steps=0, schedule="constant",
                      learning_rate=0.02, adam_lr=1e-3)


# ---------------------------------------------------------------------------
# Codec round-trips
# ---------------------------------------------------------------------------

def _tree(seed=0, scale=0.01):
    ks = jax.random.split(jax.random.key(seed), 3)
    return {"w": jax.random.normal(ks[0], (3, 8, 5)) * scale,
            "b": jax.random.normal(ks[1], (3, 7)) * scale,
            "s": jax.random.normal(ks[2], (3,)) * scale}


def test_f32_codec_is_identity():
    delta = _tree()
    codec = F32Passthrough()
    payload, res = codec.encode(delta)
    assert res is None and payload.codec == "f32" and payload.scales is None
    back = codec.decode(payload)
    for a, b in zip(jax.tree.leaves(delta), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bf16_codec_exact_on_representable_values():
    """bf16 has an 8-bit mantissa: values already representable in bf16
    round-trip exactly; everything else within relative 2^-8."""
    exact = {"w": jnp.asarray([[1.0, -0.5, 0.375, 2.0 ** -20, 0.0]])}
    codec = BF16Cast()
    back = codec.decode(codec.encode(exact)[0])
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(exact["w"]))
    fuzzy = _tree(seed=3)
    back = codec.decode(codec.encode(fuzzy)[0])
    for a, b in zip(jax.tree.leaves(fuzzy), jax.tree.leaves(back)):
        rel = np.abs(np.asarray(a) - np.asarray(b))
        assert (rel <= np.abs(np.asarray(a)) * 2.0 ** -8 + 1e-12).all()


@pytest.mark.parametrize("use_kernel", [True, False])
def test_int8_codec_error_bound(use_kernel):
    """|dec(enc(x)) - x| <= scale/2 = amax/254 per worker row."""
    delta = _tree(seed=4, scale=0.1)
    codec = Int8Symmetric(use_kernel=use_kernel)
    payload, _ = codec.encode(delta)
    assert payload.codec == "int8" and payload.scales is not None
    back = codec.decode(payload)
    for key in delta:
        x = np.asarray(delta[key]).reshape(3, -1)
        b = np.asarray(back[key]).reshape(3, -1)
        for i in range(3):
            amax = np.abs(x[i]).max()
            assert np.abs(b[i] - x[i]).max() <= amax / 254 + 1e-9


def test_int8_error_feedback_residual_is_the_roundtrip_error():
    delta = _tree(seed=5)
    residual = jax.tree.map(jnp.zeros_like, delta)
    codec = Int8Symmetric()
    payload, new_res = codec.encode(delta, residual)
    back = codec.decode(payload)
    for key in delta:
        np.testing.assert_allclose(
            np.asarray(new_res[key]),
            np.asarray(delta[key]) - np.asarray(back[key]), atol=1e-6)


def test_error_feedback_recovers_accumulated_truncation():
    """A delta far below one quantization step is truncated to zero every
    round WITHOUT error feedback, but accumulates in the residual and
    eventually crosses the wire WITH it."""
    big, tiny = 1.0, 1e-3   # scale = 1/127, tiny << scale/2
    delta = {"w": jnp.asarray([[big, tiny]])}
    codec = Int8Symmetric()
    # no EF: tiny never ships
    shipped = codec.decode(codec.encode(delta)[0])
    assert float(shipped["w"][0, 1]) == 0.0
    # EF: after enough rounds the carried residual ships
    residual = {"w": jnp.zeros((1, 2))}
    total = np.zeros(2)
    for _ in range(10):
        payload, residual = codec.encode(delta, residual)
        total += np.asarray(codec.decode(payload)["w"][0])
    np.testing.assert_allclose(total[1], 10 * tiny, rtol=0.3)


@pytest.mark.parametrize("flavor,qmax,rel", [("e4m3", 448.0, 2.0 ** -4),
                                             ("e5m2", 57344.0, 2.0 ** -3)])
@pytest.mark.parametrize("use_kernel", [True, False])
def test_fp8_codec_error_bound(flavor, qmax, rel, use_kernel):
    """Per element: |dec(enc(x)) - x| <= |x| * half-ulp(flavor) + scale
    (the scale term covers the subnormal region near zero)."""
    delta = _tree(seed=6, scale=0.1)
    codec = Fp8Codec(use_kernel=use_kernel, flavor=flavor)
    payload, _ = codec.encode(delta)
    assert payload.codec == ("fp8" if flavor == "e4m3" else "fp8_e5m2")
    assert payload.scales is not None
    back = codec.decode(payload)
    for key in delta:
        x = np.asarray(delta[key]).reshape(3, -1)
        b = np.asarray(back[key]).reshape(3, -1)
        assert np.asarray(payload.data[key]).dtype.itemsize == 1
        for i in range(3):
            s = max(np.abs(x[i]).max(), 1e-12) / qmax
            assert (np.abs(b[i] - x[i]) <= np.abs(x[i]) * rel + s).all()


@pytest.mark.parametrize("flavor", ["e4m3", "e5m2"])
def test_fp8_error_feedback_residual_is_the_roundtrip_error(flavor):
    delta = _tree(seed=7)
    residual = jax.tree.map(jnp.zeros_like, delta)
    codec = Fp8Codec(flavor=flavor)
    payload, new_res = codec.encode(delta, residual)
    back = codec.decode(payload)
    for key in delta:
        np.testing.assert_allclose(
            np.asarray(new_res[key]),
            np.asarray(delta[key]) - np.asarray(back[key]), atol=1e-6)


def test_fp8_error_feedback_recovers_accumulated_truncation():
    """e4m3's smallest subnormal is 2^-9: with amax 1.0 the scale is 1/448,
    so anything below ~2.2e-6 truncates to zero every round without error
    feedback but accumulates in the residual and ships with it."""
    big, tiny = 1.0, 1e-6
    delta = {"w": jnp.asarray([[big, tiny]])}
    codec = Fp8Codec()
    shipped = codec.decode(codec.encode(delta)[0])
    assert float(shipped["w"][0, 1]) == 0.0
    residual = {"w": jnp.zeros((1, 2))}
    total = np.zeros(2)
    for _ in range(10):
        payload, residual = codec.encode(delta, residual)
        total += np.asarray(codec.decode(payload)["w"][0])
    np.testing.assert_allclose(total[1], 10 * tiny, rtol=0.5)


def test_payload_nbytes_counts_wire_dtype_and_scales():
    delta = {"w": jnp.zeros((2, 16))}
    assert F32Passthrough().encode(delta)[0].nbytes() == 2 * 16 * 4
    assert BF16Cast().encode(delta)[0].nbytes() == 2 * 16 * 2
    # int8/fp8: 1 byte/elem + one f32 scale per worker row
    assert Int8Symmetric().encode(delta)[0].nbytes() == 2 * 16 + 2 * 4
    assert Fp8Codec().encode(delta)[0].nbytes() == 2 * 16 + 2 * 4


def test_make_codec_aliases_and_unknown():
    assert make_codec("float32").name == "f32"
    assert make_codec("bf16").name == "bf16"
    assert make_codec("int8").width == 1
    for spelling in ("fp8", "float8", "e4m3", "fp8_e4m3"):
        c = make_codec(spelling)
        assert c.name == "fp8" and c.width == 1 and c.qdtype == "fp8_e4m3"
    for spelling in ("e5m2", "fp8_e5m2"):
        c = make_codec(spelling)
        assert c.name == "fp8_e5m2" and c.qdtype == "fp8_e5m2"
    with pytest.raises(ValueError):
        make_codec("fp4")


@pytest.mark.parametrize("use_kernel", [True, False])
@pytest.mark.parametrize("qd", ["int8", "fp8_e4m3", "fp8_e5m2"])
def test_codec_scale_shapes_scalar_and_empty_sentinel_leaves(qd, use_kernel):
    """Regression: the (K, 1, ...) keepdims scale contract assumed >=1-d
    tensors — scalar params (0-d) must quantize elementwise with a 0-d
    scale, and 0-size sentinel leaves must pass through with unit scales
    instead of producing NaN scales from an empty amax."""
    codec = make_codec(qd if qd != "fp8_e4m3" else "fp8",
                       use_kernel=use_kernel)
    delta = {"w": jnp.asarray([[0.25, -1.0], [3.0, 0.5]]),
             "scalar": jnp.asarray(0.75),
             "sentinel": jnp.zeros((2, 0))}
    residual = jax.tree.map(jnp.zeros_like, delta)
    payload, new_res = codec.encode(delta, residual)
    assert payload.scales["w"].shape == (2, 1)
    assert payload.scales["scalar"].shape == ()
    assert payload.scales["sentinel"].shape == (2, 1)
    assert not np.isnan(np.asarray(payload.scales["sentinel"])).any()
    back = codec.decode(payload)
    for key in delta:
        assert back[key].shape == delta[key].shape
        assert new_res[key].shape == delta[key].shape
    assert np.asarray(back["sentinel"]).size == 0
    # a scalar is its own amax, so it lands exactly on the top bucket
    np.testing.assert_allclose(float(back["scalar"]), 0.75, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(new_res["scalar"]),
                               0.75 - np.asarray(back["scalar"]), atol=1e-7)


# ---------------------------------------------------------------------------
# Pallas kernels vs jnp oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(2, 128), (3, 5, 7), (1, 100), (4,),
                                   (2, 64, 3)])
def test_quantize_kernel_matches_oracle(shape):
    ks = jax.random.split(jax.random.key(sum(shape)), 2)
    x = jax.random.normal(ks[0], shape) * 0.05
    r = jax.random.normal(ks[1], shape) * 0.005
    q, nr, s = quantize_ef(x, r, interpret=True)
    qr, nrr, sr = reference_quantize_ef(x, r)
    # the kernel reduces amax over the flattened padded row: reduction
    # order may differ from the oracle's by 1 ulp, shifting boundary
    # elements by at most one quantization level
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    assert np.abs(np.asarray(q, np.int32)
                  - np.asarray(qr, np.int32)).max() <= 1
    tol = float(np.max(np.asarray(sr))) * 1.5 + 1e-9
    np.testing.assert_allclose(np.asarray(nr), np.asarray(nrr), atol=tol)
    out = dequantize(q, s, interpret=True)
    ref = reference_dequantize(qr, sr)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=tol)


def test_quantize_kernel_no_residual_path():
    x = jax.random.normal(jax.random.key(9), (2, 40)) * 0.1
    q, nr, s = quantize_ef(x, interpret=True)
    qr, nrr, _ = reference_quantize_ef(x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(nr), np.asarray(nrr), atol=1e-7)


@pytest.mark.parametrize("dtype", ["fp8_e4m3", "fp8_e5m2"])
@pytest.mark.parametrize("shape", [(2, 128), (3, 5, 7), (1, 100), (4,)])
def test_quantize_kernel_matches_oracle_fp8(dtype, shape):
    """Same contract as the int8 sweep for both fp8 flavors: scales agree
    to reduction-order noise and the dequantized payloads agree within one
    quantization level."""
    ks = jax.random.split(jax.random.key(sum(shape) + len(dtype)), 2)
    x = jax.random.normal(ks[0], shape) * 0.05
    r = jax.random.normal(ks[1], shape) * 0.005
    q, nr, s = quantize_ef(x, r, dtype=dtype, interpret=True)
    qr, nrr, sr = reference_quantize_ef(x, r, dtype=dtype)
    assert q.dtype == qr.dtype and q.dtype.itemsize == 1
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    out = dequantize(q, s, interpret=True)
    ref = reference_dequantize(qr, sr)
    rel = 2.0 ** -3 if dtype == "fp8_e4m3" else 2.0 ** -2
    tol = float(np.max(np.abs(np.asarray(ref)))) * rel \
        + float(np.max(np.asarray(sr)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=tol)
    np.testing.assert_allclose(np.asarray(nr), np.asarray(nrr), atol=tol)


# ---------------------------------------------------------------------------
# Strategy integration
# ---------------------------------------------------------------------------

def _setup(k=2, h=4, **dkw):
    cfg = tiny_cfg("dense")
    m = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    dcfg = DiLoCoConfig(num_workers=k, h_inner_steps=h, **dkw)
    return cfg, m, params, dcfg


def _data(cfg, k, step, B=4, S=16):
    key = jax.random.key(1000 + step)
    toks = jax.random.randint(key, (k, B, S), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": (toks + 1) % cfg.vocab_size}


def _run(m, params, dcfg, strategy, cfg, steps, k):
    dt = DistTrainer(m.loss, OPT, dcfg, strategy)
    state = dt.init(params)
    return dt.run(state, lambda s: _data(cfg, k, s), steps)


def test_pipelined_f1_delay0_matches_diloco_exactly():
    """One fragment covering everything, applied at the boundary — the
    pipelined runner degenerates bit-for-bit to DiLoCoSync."""
    cfg, m, params, dcfg = _setup(k=2, h=4)
    a_state, a_hist = _run(m, params, dcfg, DiLoCoSync(), cfg, 12, k=2)
    b_state, b_hist = _run(m, params, dcfg,
                           PipelinedSync(num_fragments=1, delay=0), cfg,
                           12, k=2)
    for x, y in zip(jax.tree.leaves(a_state.global_params),
                    jax.tree.leaves(b_state.global_params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert [s for s, _ in b_hist["frag_syncs"]] == a_hist["sync_steps"]
    np.testing.assert_array_equal(a_hist["loss"], b_hist["loss"])


def test_pipelined_fragments_rotate_and_converge():
    cfg, m, params, dcfg = _setup(k=2, h=4)
    state, hist = _run(m, params, dcfg,
                       PipelinedSync(num_fragments=2, delay=2), cfg, 16, k=2)
    assert np.isfinite(hist["loss"]).all()
    assert hist["loss"][-1] < hist["loss"][0]
    # boundary at 3,7,11,15 -> applies at 5,9,13, final flushed by finalize
    assert hist["frag_syncs"] == [(5, 0), (9, 1), (13, 0), (15, 1)]


def test_pipelined_rejects_bad_delay():
    cfg, m, params, dcfg = _setup(k=2, h=4)
    dt = DistTrainer(m.loss, OPT, dcfg, PipelinedSync(delay=4))
    state = dt.init(params)
    with pytest.raises(ValueError):
        dt.run(state, lambda s: _data(cfg, 2, s), 4)


def test_int8_error_feedback_tracks_f32_loss():
    """Acceptance: the int8 error-feedback toy run matches the f32 final
    loss within 2%."""
    cfg, m, params, dcfg = _setup(k=2, h=4)
    _, f32_hist = _run(m, params, dcfg, DiLoCoSync(), cfg, 20, k=2)
    dcfg8 = DiLoCoConfig(num_workers=2, h_inner_steps=4, delta_dtype="int8")
    _, i8_hist = _run(m, params, dcfg8, DiLoCoSync(), cfg, 20, k=2)
    rel = abs(i8_hist["loss"][-1] - f32_hist["loss"][-1]) \
        / f32_hist["loss"][-1]
    assert rel < 0.02, rel


def test_fp8_error_feedback_tracks_f32_loss():
    """The fp8 (e4m3) error-feedback toy run matches the f32 final loss
    within 2% — same acceptance bar as int8."""
    cfg, m, params, dcfg = _setup(k=2, h=4)
    _, f32_hist = _run(m, params, dcfg, DiLoCoSync(), cfg, 20, k=2)
    dcfg8 = DiLoCoConfig(num_workers=2, h_inner_steps=4, delta_dtype="fp8")
    _, fp8_hist = _run(m, params, dcfg8, DiLoCoSync(), cfg, 20, k=2)
    rel = abs(fp8_hist["loss"][-1] - f32_hist["loss"][-1]) \
        / f32_hist["loss"][-1]
    assert rel < 0.02, rel


def test_streaming_int8_error_feedback_converges():
    cfg, m, params, _ = _setup()
    dcfg = DiLoCoConfig(num_workers=2, h_inner_steps=4, delta_dtype="int8")
    _, hist = _run(m, params, dcfg, StreamingSync(num_fragments=2), cfg,
                   12, k=2)
    assert np.isfinite(hist["loss"]).all()
    assert hist["loss"][-1] < hist["loss"][0]


def test_make_strategy_pipelined_and_seed():
    s = make_strategy(DiLoCoConfig(strategy="pipelined", num_fragments=8,
                                   sync_delay=5))
    assert s.name == "pipelined" and s.num_fragments == 8 and s.delay == 5
    s = make_strategy(DiLoCoConfig(strategy="overlapped", sync_seed=42))
    assert s.seed == 42


def test_codec_aware_payload_schedules():
    """Acceptance: int8 pipelined fragments ship >= 8x fewer bytes than f32
    blocking DiLoCo over the same step budget."""
    n, steps, h = 1_000_000, 400, 100
    f32 = DiLoCoConfig(h_inner_steps=h)
    i8 = DiLoCoConfig(h_inner_steps=h, delta_dtype="int8")
    base = sum(e.bytes_per_worker
               for e in DiLoCoSync().payload_schedule(n, steps, f32))
    pipe = PipelinedSync(num_fragments=4, delay=h // 2)
    events = pipe.payload_schedule(n, steps, i8)
    assert all(e.codec == "int8" and e.kind == "fragment" for e in events)
    assert all(e.apply_step - e.step == h // 2 for e in events)
    got = sum(e.bytes_per_worker for e in events)
    assert base / got >= 8, (base, got)
    # bf16 halves f32; fragment ids rotate
    bf = DiLoCoConfig(h_inner_steps=h, delta_dtype="bfloat16")
    bf_bytes = sum(e.bytes_per_worker
                   for e in DiLoCoSync().payload_schedule(n, steps, bf))
    assert bf_bytes * 2 == base
    assert [e.fragment for e in events] == [0, 1, 2, 3]


def test_fp8_pipelined_ships_half_the_int8_bytes():
    """The BENCH_train acceptance arm, as a unit statement: fp8 wire width
    equals int8's, so doubling the fragment count (one n/F fragment per
    outer round) halves the boundary bytes exactly."""
    n, steps, h = 1_000_000, 400, 100
    i8 = DiLoCoConfig(h_inner_steps=h, delta_dtype="int8")
    f8 = DiLoCoConfig(h_inner_steps=h, delta_dtype="fp8")
    i8_ev = PipelinedSync(num_fragments=4,
                          delay=h // 2).payload_schedule(n, steps, i8)
    f8_ev = PipelinedSync(num_fragments=8,
                          delay=h // 2).payload_schedule(n, steps, f8)
    assert all(e.codec == "fp8" for e in f8_ev)
    i8_bytes = sum(e.bytes_per_worker for e in i8_ev)
    f8_bytes = sum(e.bytes_per_worker for e in f8_ev)
    assert i8_bytes == 2 * f8_bytes, (i8_bytes, f8_bytes)


# ---------------------------------------------------------------------------
# Heterogeneous simulator + calibration
# ---------------------------------------------------------------------------

def _delta_events(n=200, every=5, steps=10, window=0):
    return [SyncEvent(step=s, bytes_per_worker=n, kind="delta",
                      apply_step=s + window)
            for s in range(every - 1, steps, every)]


def test_heterogeneous_reduces_to_symmetric_on_equal_speeds():
    comm = CommModel(bandwidth=100.0, latency=0.0)
    events = _delta_events()
    a = simulate_schedule(events, 10, 1.0, comm)
    b = simulate_heterogeneous(events, 10, [1.0, 1.0, 1.0], comm)
    assert b["wall_clock_s"] == pytest.approx(a["wall_clock_s"])
    assert b["stall_s"] == pytest.approx(a["stall_s"])
    assert b["total_bytes"] == a["total_bytes"]
    assert b["straggler_s"] == 0.0


def test_heterogeneous_straggler_sets_the_pace():
    comm = CommModel(bandwidth=1e12, latency=0.0)  # comm ~free
    events = _delta_events()
    r = simulate_heterogeneous(events, 10, [1.0, 1.0, 1.5], comm)
    assert r["wall_clock_s"] == pytest.approx(15.0)
    assert r["straggler_s"] == pytest.approx(5.0)


def test_bounded_staleness_hides_transfer():
    """A 2s transfer due at its emit step stalls the fleet 2s; two steps of
    staleness budget hide it entirely."""
    comm = CommModel(bandwidth=100.0, latency=0.0)
    events = [SyncEvent(step=4, bytes_per_worker=200, kind="delta",
                        apply_step=4)]
    blocked = simulate_heterogeneous(events, 10, [1.0, 1.0], comm,
                                     staleness_steps=0)
    assert blocked["stall_s"] == pytest.approx(2.0)
    relaxed = simulate_heterogeneous(events, 10, [1.0, 1.0], comm,
                                     staleness_steps=2)
    assert relaxed["stall_s"] == 0.0
    assert relaxed["wall_clock_s"] == pytest.approx(10.0)


def test_bytes_by_codec_breakdown():
    comm = CommModel(bandwidth=100.0, latency=0.0)
    events = [SyncEvent(step=0, bytes_per_worker=100, kind="delta",
                        apply_step=0, codec="int8"),
              SyncEvent(step=1, bytes_per_worker=400, kind="delta",
                        apply_step=1, codec="f32")]
    r = simulate_schedule(events, 2, 1.0, comm)
    assert r["bytes_by_codec"] == {"int8": 100.0, "f32": 400.0}


def test_load_calibration_from_dryrun_json(tmp_path):
    entries = [
        {"arch": "nanochat-d20", "step_kind": "diloco-inner",
         # flops-bound: 197e12 peak -> 1.0s; hbm term 1e9/819e9 ~ 1.2ms
         "analytic": {"total_flops": 197e12, "bytes": 1e9}},
        {"arch": "nanochat-d20", "step_kind": "diloco-outer",
         "shape": "outer[int8]",
         "collectives_weighted": {"wire_bytes_per_device": 5e9,
                                  "cross_pod_bytes_per_device": 2.2e9}},
        {"arch": "other", "step_kind": "diloco-inner", "measured_step_s": 9.9,
         "analytic": {}},
    ]
    path = tmp_path / "dryrun_outer.json"
    path.write_text(json.dumps(entries))
    cal = load_calibration(str(path), arch="nanochat-d20")
    assert cal is not None
    assert cal.step_time_s == pytest.approx(1.0)   # flops / PEAK_FLOPS_BF16
    assert cal.sync_bytes_per_worker == pytest.approx(2.2e9)
    assert cal.sync_dtype == "int8"   # parsed from the outer[...] shape tag
    # measured seconds take precedence over the roofline derivation
    other = load_calibration(str(path), arch="other")
    assert other.step_time_s == pytest.approx(9.9)
    assert load_calibration(str(path), arch="missing") is None
    assert load_calibration(str(tmp_path / "nope.json")) is None


def test_modeled_step_time_calibration_precedence():
    assert modeled_step_time(1e15) > 0
    cal = CommCalibration(step_time_s=0.123)
    assert modeled_step_time(1e15, calibration=cal) == 0.123
    assert modeled_step_time(1e15,
                             calibration=CommCalibration()) == \
        modeled_step_time(1e15)
