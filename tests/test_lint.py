"""Self-tests for replint (``repro.tools.lint``).

Fixture contract: files under ``tests/lint_fixtures/<pass>/`` marked
``bad_*`` carry ``# LINT-EXPECT <pass>`` trailing comments on exactly the
lines the pass must flag; ``good_*`` twins must lint clean.  The walker
never descends into ``lint_fixtures`` (the corpus exists to *hold*
violations), so these tests lint the fixtures explicitly — and the final
test asserts the real repo tree is clean end to end.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.tools.lint import (FileContext, LintError, Violation, lint_file,
                              run_lint, select_passes)
from repro.tools.lint.core import SKIP_DIRS, iter_python_files
from repro.tools.lint.passes.donate_safety import DonateSafetyPass
from repro.tools.lint.passes.host_sync import HostSyncPass
from repro.tools.lint.passes.kernel_contract import KernelContractPass
from repro.tools.lint.passes.prng_discipline import PrngDisciplinePass
from repro.tools.lint.passes.retrace_hazard import RetraceHazardPass
from repro.tools.lint.reporter import render_human, render_json

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "lint_fixtures"

PASS_BY_DIR = {
    "donate_safety": DonateSafetyPass,
    "retrace_hazard": RetraceHazardPass,
    "prng_discipline": PrngDisciplinePass,
    "host_sync": HostSyncPass,
}


def expected_lines(path: Path):
    return {i for i, line in enumerate(path.read_text().splitlines(), 1)
            if "LINT-EXPECT" in line}


def run_pass(path: Path, pass_cls):
    """Per-file or project pass, suppressions honored."""
    ctx = FileContext.parse(str(path))
    p = pass_cls()
    vios = p.check_file(ctx) + p.check_project([ctx], None)
    return sorted(v for v in vios if not ctx.suppressed(v))


def fixture_files(kind):
    out = []
    for d, cls in PASS_BY_DIR.items():
        for f in sorted((FIXTURES / d).rglob(f"{kind}_*.py")):
            out.append(pytest.param(f, cls, id=f"{d}/{f.name}"))
    return out


@pytest.mark.parametrize("path,pass_cls", fixture_files("bad"))
def test_bad_fixtures_flagged_at_expected_lines(path, pass_cls):
    want = expected_lines(path)
    assert want, f"fixture {path} has no LINT-EXPECT markers"
    got = {v.line for v in run_pass(path, pass_cls)}
    assert got == want, (f"{path.name}: expected lines {sorted(want)}, "
                         f"got {sorted(got)}")


@pytest.mark.parametrize("path,pass_cls", fixture_files("good"))
def test_good_fixtures_clean(path, pass_cls):
    vios = run_pass(path, pass_cls)
    assert vios == [], "\n".join(v.format() for v in vios)


# ---------------------------------------------------------------------------
# kernel-contract: project pass over miniature repo trees
# ---------------------------------------------------------------------------

def test_kernel_contract_bad_tree():
    root = FIXTURES / "kernel_contract" / "bad_tree"
    vios = KernelContractPass().check_project([], root=root)
    msgs = [v.message for v in vios]
    assert any("missing ref.py" in m for m in msgs)
    assert any("private 'default_interpret'" in m for m in msgs)
    assert any("does not import" in m for m in msgs)
    assert any("ref oracle" in m for m in msgs)
    assert all(v.path.startswith(str(root)) for v in vios)


def test_kernel_contract_flags_dequant_variant_without_oracle_test():
    """The quant_dequant fixture ships the full three-file layout AND the
    shared interpret helper — its only sin is that no test imports its ref
    oracle.  The pass must flag exactly that, and nothing else, so a new
    kernel *variant* (e.g. a dequant flavor of an existing op) cannot land
    untested just because the package otherwise looks healthy."""
    root = FIXTURES / "kernel_contract" / "bad_tree"
    vios = [v for v in KernelContractPass().check_project([], root=root)
            if "quant_dequant" in v.path]
    assert len(vios) == 1, "\n".join(v.format() for v in vios)
    assert "ref oracle" in vios[0].message


def test_kernel_contract_good_tree():
    root = FIXTURES / "kernel_contract" / "good_tree"
    vios = KernelContractPass().check_project([], root=root)
    assert vios == [], "\n".join(v.format() for v in vios)


# ---------------------------------------------------------------------------
# suppression + framework plumbing
# ---------------------------------------------------------------------------

BAD_SRC = (
    "import jax\n"
    "\n"
    "def f(state, batch):\n"
    "    step = jax.jit(lambda s, b: s, donate_argnums=(0,))\n"
    "    out = step(state, batch)\n"
    "    return state, out{}\n"
)


def test_line_suppression():
    dirty = lint_file("fix.py", src=BAD_SRC.format(""))
    assert [v.pass_name for v in dirty] == ["donate-safety"]
    clean = lint_file(
        "fix.py", src=BAD_SRC.format("  # replint: disable=donate-safety"))
    assert clean == []
    wildcard = lint_file("fix.py", src=BAD_SRC.format(
        "  # replint: disable=all"))
    assert wildcard == []


def test_file_suppression():
    src = "# replint: disable-file=donate-safety\n" + BAD_SRC.format("")
    assert lint_file("fix.py", src=src) == []
    other = "# replint: disable-file=retrace-hazard\n" + BAD_SRC.format("")
    assert len(lint_file("fix.py", src=other)) == 1


def test_unknown_pass_selection_raises():
    with pytest.raises(LintError, match="unknown pass"):
        select_passes(["nope"])
    names = [p.name for p in select_passes(None)]
    assert names == ["donate-safety", "retrace-hazard", "prng-discipline",
                     "host-sync-in-hot-path", "kernel-contract"]


def test_walker_skips_fixture_corpus():
    files = iter_python_files([str(REPO / "tests")])
    assert not any("lint_fixtures" in str(f) for f in files)
    assert "lint_fixtures" in SKIP_DIRS


def test_syntax_error_is_collected_not_fatal(tmp_path):
    bad = tmp_path / "broken.py"
    bad.write_text("def oops(:\n")
    ok = tmp_path / "fine.py"
    ok.write_text("x = 1\n")
    violations, files, errors = run_lint([str(tmp_path)])
    assert len(files) == 2
    assert len(errors) == 1 and "syntax error" in errors[0]
    assert violations == []


def test_reporters():
    v = Violation(path="a.py", line=3, col=7, pass_name="donate-safety",
                  message="boom")
    human = render_human([v], ["a.py"], [])
    assert "a.py:3:7: [donate-safety] boom" in human
    assert "1 violation in 1 files" in human
    data = json.loads(render_json([v], ["a.py"], ["a parse error"]))
    assert data["violations"][0]["line"] == 3
    assert data["files_checked"] == 1
    assert data["errors"] == ["a parse error"]


# ---------------------------------------------------------------------------
# CLI + the merge gate: the real tree lints clean
# ---------------------------------------------------------------------------

def _cli(*args):
    return subprocess.run(
        [sys.executable, "-m", "repro.tools.lint", *args],
        cwd=REPO, capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})


def test_cli_exit_codes():
    bad = str(FIXTURES / "donate_safety" / "bad_use_after_donate.py")
    r = _cli(bad)
    assert r.returncode == 1 and "donate-safety" in r.stdout
    good = str(FIXTURES / "donate_safety" / "good_rebound.py")
    r = _cli(good, "--select", "donate-safety")
    assert r.returncode == 0, r.stdout + r.stderr
    r = _cli("--list-passes")
    assert r.returncode == 0
    for name in ("donate-safety", "retrace-hazard", "prng-discipline",
                 "host-sync-in-hot-path", "kernel-contract"):
        assert name in r.stdout


def test_cli_json_report():
    bad = str(FIXTURES / "prng_discipline" / "bad_key_reuse.py")
    r = _cli(bad, "--json", "--select", "prng-discipline")
    assert r.returncode == 1
    data = json.loads(r.stdout)
    assert data["violations"] and data["files_checked"] == 1


def test_repo_lints_clean():
    """The merge gate: every pass, whole tree, zero violations."""
    paths = [str(REPO / p) for p in ("src", "tests", "benchmarks",
                                     "examples")
             if (REPO / p).is_dir()]
    violations, files, errors = run_lint(paths)
    assert errors == []
    assert len(files) > 100
    assert violations == [], "\n".join(v.format() for v in violations)
