"""Continuous-batching serving stack: paged KV pool, scheduler, persistent
step.  The contract under test:

* scheduler-path greedy generation is EXACTLY the legacy static-bucket
  output on ragged batches (admission/eviction/chunked prefill are pure
  scheduling — they may never change the math);
* the block pool's alloc/free invariants hold under admission/eviction
  churn, and misuse (double-free, exhaustion) raises instead of corrupting;
* the persistent step compiles ONCE across arbitrary request mixes.
"""
import jax
import numpy as np
import pytest

from helpers import tiny_cfg
from repro.models.transformer import build_model, init_params
from repro.serving import (Engine, KVBlockPool, PrefixTree, Request,
                           Scheduler)


def _engine(**kw):
    cfg = tiny_cfg("dense")
    m = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    kw.setdefault("num_slots", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("block_size", 8)
    return cfg, Engine(m, params, **kw)


RAGGED = [[5, 6, 7], [1, 2, 3, 4, 5, 6, 7, 8, 9, 10], [2, 9], [7] * 17,
          [4, 4, 4, 4, 4], [11, 3], [1] * 30, [8]]


# ---------------------------------------------------------------------------
# Greedy equivalence: scheduler path == legacy static buckets
# ---------------------------------------------------------------------------

def test_greedy_scheduler_matches_static_on_ragged_batch():
    """More requests than slots, prompts longer than the prefill chunk,
    max_new indivisible by the chunk — outputs must be identical."""
    cfg, eng = _engine()
    a = eng.generate_ids(RAGGED, max_new=13)
    assert eng._step_fn._cache_size() == 1     # persistent step ran
    b = eng.generate_ids_static(RAGGED, max_new=13)
    np.testing.assert_array_equal(a, b)


def test_greedy_scheduler_matches_static_across_mixes():
    cfg, eng = _engine()
    for prompts, max_new in [([[3, 1, 4]], 5), (RAGGED[:5], 8),
                             ([[9] * 25, [1, 2]], 3)]:
        np.testing.assert_array_equal(
            eng.generate_ids(prompts, max_new=max_new),
            eng.generate_ids_static(prompts, max_new=max_new))


def test_policies_give_identical_outputs_different_order():
    """Admission order is scheduling, not math: all policies produce the
    same per-request greedy tokens."""
    outs = {}
    for policy in ("fifo", "longest_prefill", "cache_aware"):
        cfg, eng = _engine(policy=policy, num_slots=2)
        outs[policy] = eng.generate_ids(RAGGED[:6], max_new=6)
    np.testing.assert_array_equal(outs["fifo"], outs["longest_prefill"])
    np.testing.assert_array_equal(outs["fifo"], outs["cache_aware"])


def test_longest_prefill_no_head_of_line_blocking():
    """Satellite regression: a big request whose budget doesn't fit yet
    must not starve smaller ready ones under longest_prefill — the policy
    scans the remaining ready queue when its pick doesn't fit.  fifo keeps
    the documented head-of-line semantics."""
    pool = KVBlockPool(4, 8)
    sched = Scheduler(3, pool, max_blocks_per_slot=4,
                      policy="longest_prefill")
    sched.submit(Request(rid=0, prompt=[1] * 10, max_new=5))   # 2 blocks
    assert sched.admit() == [0]
    sched.submit(Request(rid=1, prompt=[2] * 25, max_new=6))   # 4 blocks:
    sched.submit(Request(rid=2, prompt=[3] * 3, max_new=4))    # parked; 1
    newly = sched.admit()                                      # block: fits
    assert [sched.slots[i].req.rid for i in newly] == [2]
    assert [r.rid for r in sched.waiting] == [1]
    pool.check_invariants()
    # fifo: same shape parks the whole queue behind the big request
    pool2 = KVBlockPool(4, 8)
    f = Scheduler(3, pool2, max_blocks_per_slot=4, policy="fifo")
    f.submit(Request(rid=0, prompt=[1] * 10, max_new=5))
    f.admit()
    f.submit(Request(rid=1, prompt=[2] * 25, max_new=6))
    f.submit(Request(rid=2, prompt=[3] * 3, max_new=4))
    assert f.admit() == []


# ---------------------------------------------------------------------------
# Recompile guard
# ---------------------------------------------------------------------------

def test_persistent_step_compiles_once_across_request_mixes():
    cfg, eng = _engine()
    eng.generate_ids([[1, 2, 3]], max_new=4)
    eng.generate_ids(RAGGED, max_new=9)                      # queueing
    eng.generate_ids([[6] * 20], max_new=2, greedy=False, seed=3)
    eng.run([Request(rid=0, prompt=[4, 2], max_new=3, eos_id=1)])
    assert eng._step_fn._cache_size() == 1, \
        "persistent step recompiled across request mixes"


# ---------------------------------------------------------------------------
# KV block pool invariants
# ---------------------------------------------------------------------------

def test_block_pool_alloc_free_invariants():
    pool = KVBlockPool(num_blocks=8, block_size=4)
    a = pool.alloc(3)
    b = pool.alloc(2)
    assert len(set(a) | set(b)) == 5          # disjoint
    assert pool.num_free == 3
    pool.check_invariants()
    pool.free(a)
    pool.check_invariants()
    assert pool.num_free == 6
    with pytest.raises(RuntimeError):
        pool.free(a)                          # double-free
    with pytest.raises(RuntimeError):
        pool.alloc(7)                         # exhaustion
    assert pool.blocks_for(9) == 3 and pool.blocks_for(8) == 2


def test_scheduler_churn_preserves_pool_invariants():
    """Random admission/eviction churn through the full engine with a pool
    too small to hold all requests at once: every request completes, and the
    pool ends fully free with invariants intact."""
    rng = np.random.default_rng(0)
    # 2 slots x 3 blocks x 8 = room for only 2 mid-size requests at a time
    cfg, eng = _engine(num_slots=2, max_len=24, block_size=8)
    prompts = [rng.integers(1, 90, size=int(rng.integers(1, 12))).tolist()
               for _ in range(9)]
    reqs = [Request(rid=i, prompt=p, max_new=int(rng.integers(1, 8)))
            for i, p in enumerate(prompts)]
    eng.run(reqs)
    for r in reqs:
        assert len(r.tokens) == r.max_new, r.rid
    # equivalence under churn, per request (ragged max_new -> one by one)
    for r in reqs:
        np.testing.assert_array_equal(
            np.asarray(r.tokens),
            eng.generate_ids_static([r.prompt], max_new=r.max_new)[0])


def test_scheduler_respects_pool_capacity_and_frees_on_finish():
    """Admission reserves the full footprint up front (so running requests
    can never OOM mid-flight) while physical blocks map lazily as positions
    are written."""
    pool = KVBlockPool(num_blocks=4, block_size=8)
    sched = Scheduler(2, pool, max_blocks_per_slot=2, policy="fifo")
    for i in range(3):
        sched.submit(Request(rid=i, prompt=[1] * 10, max_new=6))  # 2 blocks
    admitted = sched.admit()
    assert admitted == [0, 1] and pool.num_reserved == 4
    assert not pool.can_reserve(1)            # budget exhausted -> queued
    assert sched.admit() == []
    pool.check_invariants()
    sched.ensure_mapped(0, 9)                 # positions 0..9 -> 2 blocks
    assert pool.num_allocated == 2 and pool.num_reserved == 2
    pool.check_invariants()
    sched.finish(0)                           # frees mapped AND releases
    pool.check_invariants()                   # the unmapped remainder
    assert pool.num_free == 4 and pool.num_reserved == 2
    assert sched.admit() == [0]               # backfills the freed slot
    assert sched.waiting == []


def test_longest_prefill_policy_admits_longest_first():
    pool = KVBlockPool(num_blocks=2, block_size=8)
    sched = Scheduler(1, pool, max_blocks_per_slot=2,
                      policy="longest_prefill")
    sched.submit(Request(rid=0, prompt=[1] * 3, max_new=2))
    sched.submit(Request(rid=1, prompt=[1] * 9, max_new=2))
    sched.submit(Request(rid=2, prompt=[1] * 5, max_new=2))
    sched.admit()
    assert sched.slots[0].req.rid == 1        # longest prompt wins the slot


def test_request_exceeding_slot_capacity_rejected():
    pool = KVBlockPool(num_blocks=4, block_size=8)
    sched = Scheduler(2, pool, max_blocks_per_slot=2)
    with pytest.raises(ValueError):
        sched.submit(Request(rid=0, prompt=[1] * 20, max_new=8))


# ---------------------------------------------------------------------------
# Eviction / EOS / sampling through the scheduler path
# ---------------------------------------------------------------------------

def test_eos_evicts_early_and_prefix_matches():
    cfg, eng = _engine()
    full = eng.generate_ids([[3, 1, 4, 1, 5]], max_new=8)[0]
    eos = int(full[3])
    r = Request(rid=0, prompt=[3, 1, 4, 1, 5], max_new=8, eos_id=eos)
    eng.run([r])
    assert r.tokens[-1] == eos and len(r.tokens) <= 8
    np.testing.assert_array_equal(r.tokens, full[:len(r.tokens)])


class _StubTok:
    """Minimal tokenizer for chat-path tests (no BPE training needed)."""
    pad = 0

    def encode(self, s):
        return [(ord(c) % 90) + 1 for c in s][:6]

    def special_id(self, name):
        return 96

    def decode(self, ids):
        return ",".join(str(i) for i in ids)


def test_chat_threads_temperature():
    """temperature must actually reach the sampler through chat() — the
    historical bug was a chat signature without it, silently sampling at
    1.0.  Near-zero temperature must collapse onto greedy; a hot sample
    (same PRNG seed) must differ."""
    cfg, eng = _engine()
    eng.tok = _StubTok()
    greedy = eng.chat(["hello there"], max_new=8)
    cold = eng.chat(["hello there"], max_new=8, greedy=False,
                    temperature=1e-4)
    assert cold == greedy
    hot = eng.chat(["hello there"], max_new=8, greedy=False, temperature=8.0)
    assert hot != cold


def test_oversized_request_raises_instead_of_hanging():
    """A request whose block need exceeds the whole pool can never be
    admitted — submit must raise, not leave run() spinning forever."""
    cfg, eng = _engine(num_slots=2, max_len=64, block_size=8, num_blocks=4)
    with pytest.raises(ValueError):
        eng.run([Request(rid=0, prompt=[1] * 50, max_new=8)])


def test_empty_prompt_and_zero_max_new_route_to_static_path():
    cfg, eng = _engine()
    out = eng.generate_ids([[], [1, 2]], max_new=4)     # legacy behavior:
    assert out.shape == (2, 4)                          # no exception
    assert eng.generate_ids([[1, 2]], max_new=0).shape == (1, 0)


def test_per_request_sampling_is_schedule_independent():
    """A sampled request's tokens depend on (seed, rid, position) only — not
    on which other requests shared the batch."""
    cfg, eng = _engine()
    alone = Request(rid=7, prompt=[5, 6], max_new=6, greedy=False,
                    temperature=1.3)
    eng.run([alone], seed=11)
    crowd = [Request(rid=i, prompt=[i + 1] * (i + 1), max_new=4,
                     greedy=False) for i in range(5)]
    together = Request(rid=7, prompt=[5, 6], max_new=6, greedy=False,
                       temperature=1.3)
    eng.run(crowd + [together], seed=11)
    assert together.tokens == alone.tokens


# ---------------------------------------------------------------------------
# Model-level paged API: cache writes == full-sequence forward
# ---------------------------------------------------------------------------

def test_paged_cache_writes_match_full_forward():
    """Feeding a prompt token-by-token through decode_step_paged (second
    slot inactive throughout) reproduces the full-sequence forward logits at
    the last position — a bit-level check of the scatter/gather write path
    behind shuffled, non-contiguous physical blocks."""
    import jax.numpy as jnp
    cfg = tiny_cfg("dense")
    m = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(1))
    prompt = [3, 1, 4, 1, 5, 9, 2]
    logits, _ = m.forward(params, {"tokens": jnp.asarray([prompt])})
    ref = np.asarray(logits[0, -1])

    pool = m.init_paged_cache(num_blocks=8, block_size=4)
    table = np.full((2, 4), -1, np.int32)
    table[0, :2] = [3, 6]                   # shuffled physical blocks
    step_logits = None
    for t, tok in enumerate(prompt):
        step_logits, pool = m.decode_step_paged(params, pool, {
            "token": jnp.asarray([[tok], [0]], jnp.int32),
            "position": jnp.asarray([t, -1], jnp.int32),
            "block_table": jnp.asarray(table)})
    np.testing.assert_allclose(np.asarray(step_logits[0, 0]), ref,
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# Quantized KV pools (kv_cache_dtype int8 / fp8 / fp8_e5m2)
# ---------------------------------------------------------------------------

KV_DTYPES = ["int8", "fp8", "fp8_e5m2"]


def _quant_engine(kv, **kw):
    cfg = tiny_cfg("dense", kv_cache_dtype=kv)
    m = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    kw.setdefault("num_slots", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("block_size", 8)
    return cfg, Engine(m, params, **kw)


@pytest.mark.parametrize("kv", KV_DTYPES)
def test_quantized_kv_jnp_matches_pallas_bit_exact(kv):
    """Greedy streams off a quantized pool are identical between the jnp
    dequant fallback and the Pallas dequant-on-load kernels — quantization
    error is in the pool contents, not the reader."""
    outs = {}
    for impl in ("jnp", "pallas"):
        cfg, eng = _quant_engine(kv, attn_impl=impl)
        outs[impl] = eng.generate_ids(RAGGED[:4], max_new=12)
    np.testing.assert_array_equal(outs["jnp"], outs["pallas"])


@pytest.mark.parametrize("kv", KV_DTYPES)
def test_quantized_kv_speculative_matches_continuous_bit_exact(kv):
    """Greedy speculation on a quantized pool is still lossless: the verify
    kernel reads the same narrow blocks the sequential loop wrote, so
    spec_k=0 and spec_k=4 engines emit identical tokens on a ragged
    stream."""
    cfg, base = _quant_engine(kv)
    cfg, spec = _quant_engine(kv, spec_k=4)
    a = base.generate_ids(RAGGED, max_new=13)
    b = spec.generate_ids(RAGGED, max_new=13)
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("kv,floor", [("int8", 0.85), ("fp8", 0.85),
                                      ("fp8_e5m2", 0.5)])
def test_quantized_kv_tracks_full_precision_greedy(kv, floor):
    """Bit-exactness-vs-tolerance: a quantized pool is lossy, and one
    flipped argmax diverges the whole greedy suffix — so the statement is
    aggregate token agreement with the full-precision stream above a
    per-flavor floor (e4m3/int8 nearly exact on the tiny model, e5m2's
    2 mantissa bits noticeably looser), everything else about the
    scheduler path unchanged."""
    cfg, full = _engine()
    want = np.asarray(full.generate_ids(RAGGED, max_new=13))
    cfg, q = _quant_engine(kv)
    got = np.asarray(q.generate_ids(RAGGED, max_new=13))
    assert got.shape == want.shape
    agree = float(np.mean(got == want))
    assert agree >= floor, \
        f"{kv} pool agreement {agree:.2f} vs full precision"


def test_quantized_kv_churn_preserves_pool_invariants():
    """The scheduler-churn test on a quantized pool: a byte-budget pool too
    small for all requests at once, every request completes, and the
    per-request tokens are schedule-independent (equal to a fresh
    quantized engine serving the request alone)."""
    from repro.models.transformer import paged_block_bytes
    rng = np.random.default_rng(0)
    cfg = tiny_cfg("dense", kv_cache_dtype="fp8")
    bpb = paged_block_bytes(cfg, 8)
    cfg2, eng = _quant_engine("fp8", num_slots=2, max_len=24, block_size=8,
                              pool_bytes=6 * bpb)
    assert eng.num_blocks == 6 and eng.bytes_per_block == bpb
    prompts = [rng.integers(1, 90, size=int(rng.integers(1, 12))).tolist()
               for _ in range(9)]
    reqs = [Request(rid=i, prompt=p, max_new=int(rng.integers(1, 8)))
            for i, p in enumerate(prompts)]
    eng.run(reqs)
    for r in reqs:
        assert len(r.tokens) == r.max_new, r.rid
    cfg3, solo = _quant_engine("fp8", num_slots=2, max_len=24, block_size=8)
    for r in reqs:
        np.testing.assert_array_equal(
            np.asarray(r.tokens),
            solo.generate_ids([r.prompt], max_new=r.max_new)[0])


# ---------------------------------------------------------------------------
# Prefix sharing: bit-exactness on/off, warm cache, spec, cache_aware
# ---------------------------------------------------------------------------

TPL = [7, 3, 9, 1, 5, 2, 8, 4] * 3      # 24-token template = 3 blocks @ bs=8
SHARED = [TPL + [50 + i] * (i % 4 + 1) for i in range(6)]


def _run_tokens(eng, prompts, max_new=9, seed=0, **rkw):
    reqs = [Request(rid=i, prompt=list(p), max_new=max_new, **rkw)
            for i, p in enumerate(prompts)]
    stats = eng.run(reqs, seed=seed)
    return [r.tokens for r in reqs], stats


def test_prefix_sharing_greedy_bit_exact():
    """Sharing is scheduling + memory, never math: greedy outputs on a
    shared-template stream are identical with the cache off, cold, and
    warm (the tree persists across run() calls), including COW-forked
    boundary blocks."""
    cfg, off = _engine()
    cfg, on = _engine(prefix_cache=True)
    want, _ = _run_tokens(off, SHARED)
    cold, s1 = _run_tokens(on, SHARED)
    warm, s2 = _run_tokens(on, SHARED)
    assert want == cold == warm
    assert s2["prefix"]["hit_rate"] == 1.0      # warm: every request hits
    assert s2["prefix"]["forked"] > 0           # multi-token tails fork
    assert s2["prefix_skipped_tokens"] > 0      # prefill actually skipped
    assert s2["prefill_tokens"] < s1["prefill_tokens"]


def test_prefix_sharing_speculative_bit_exact():
    """Greedy speculation over shared prefix blocks is still lossless:
    spec_k=4 + warm prefix cache emits the exact no-sharing, no-spec
    stream (rollback never rewinds below the committed prompt, so shared
    blocks are never rewritten)."""
    cfg, base = _engine()
    cfg, spec_on = _engine(spec_k=4, prefix_cache=True)
    want, _ = _run_tokens(base, SHARED, max_new=13)
    cold, _ = _run_tokens(spec_on, SHARED, max_new=13)
    warm, s = _run_tokens(spec_on, SHARED, max_new=13)
    assert want == cold == warm
    assert s["prefix"]["hits"] > 0 and s["drafted"] > 0


def test_sampled_request_unaffected_by_prefix_sharing():
    """Per-request PRNG is keyed (seed, rid, position), so skipping the
    matched prefill must not shift a sampled request's stream."""
    cfg, off = _engine()
    alone = Request(rid=3, prompt=TPL + [50, 51], max_new=6, greedy=False,
                    temperature=1.3)
    off.run([alone], seed=11)
    cfg, on = _engine(prefix_cache=True)
    on.run([Request(rid=9, prompt=TPL + [60], max_new=4)])  # prime cache
    shared = Request(rid=3, prompt=TPL + [50, 51], max_new=6, greedy=False,
                     temperature=1.3)
    on.run([shared], seed=11)
    assert shared.tokens == alone.tokens


def test_cache_aware_admission_prefers_longest_match():
    pool = KVBlockPool(16, 8)
    tree = PrefixTree(8)
    blocks = pool.alloc(3)
    tree.insert(list(TPL), blocks, pool)
    sched = Scheduler(1, pool, max_blocks_per_slot=8, policy="cache_aware",
                      tree=tree)
    sched.submit(Request(rid=0, prompt=[99] * 30, max_new=2))   # no match
    sched.submit(Request(rid=1, prompt=TPL + [50], max_new=2))  # full match
    assert sched.admit() == [0]
    slot = sched.slots[0]
    assert slot.req.rid == 1 and slot.num_shared == 3 and slot.pos == 24
    assert slot.feed == [50]


def test_prefix_cache_lru_bound_respected():
    """--prefix-cache-blocks caps resident cache blocks via LRU."""
    cfg, eng = _engine(prefix_cache=True, prefix_cache_blocks=3)
    _run_tokens(eng, SHARED)
    assert eng._tree.num_blocks <= 3


def test_quantized_pool_bytes_budget_fits_more_blocks():
    """Same pool_bytes, narrower payload -> strictly more blocks, and the
    kv_report the serve CLI prints reflects the quantized layout."""
    budget = 65536
    cfg_b, bf16 = _engine(pool_bytes=budget)
    cfg_q, fp8 = _quant_engine("fp8", pool_bytes=budget)
    assert fp8.bytes_per_block < bf16.bytes_per_block
    assert fp8.num_blocks > bf16.num_blocks
    rep = fp8.kv_report()
    assert rep["kv_cache_dtype"] == "fp8"
    assert rep["kv_pool_dtype"] == "float8_e4m3fn"
    assert rep["pool_bytes"] <= budget
    assert rep["num_blocks"] == fp8.num_blocks
