"""Fault tolerance: schedule DSL, quorum math, elastic rejoin,
crash-consistent resume, comm-sim fault overlay, prefetcher error
surfacing, and graceful serving degradation.

The anchors, in order of strictness:

* masked quorum averaging with an all-live mask is BITWISE the unmasked
  expression (the no-fault path never pays for fault support);
* a K=4 outer round with one dead worker is BITWISE a K=3 round on the
  survivors, pinned at the outer-step level with identical per-row
  deltas (the vmapped inner chunk compiles different reduction blockings
  for different K, so the full-trainer comparison can only be allclose);
* kill -> checkpoint -> --resume continues BITWISE vs an uninterrupted
  run (state, loss history, sync steps), for DDP and DiLoCo.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import tiny_cfg
from repro.checkpoint import (latest_run_checkpoint, list_run_checkpoints,
                              load_run_checkpoint, save_run_checkpoint)
from repro.checkpoint.checkpoint import _atomic_bytes
from repro.configs.base import DiLoCoConfig, OptimizerConfig
from repro.core import DistTrainer, make_strategy
from repro.core import outer_opt
from repro.core.diloco import DiLoCoTrainer
from repro.core.faults import (FaultEvent, FaultSchedule, FleetTracker,
                               SimulatedCrash)
from repro.data.pipeline import Prefetcher
from repro.launch.comm_sim import CommModel, simulate_gossip, \
    simulate_heterogeneous
from repro.models.transformer import build_model, init_params
from repro.serving import KVBlockPool, PrefixTree, Request, Scheduler

OPT = OptimizerConfig(total_steps=100, warmup_steps=0, schedule="constant",
                      learning_rate=0.02, adam_lr=1e-3)


def _setup(k=2, h=4, **dkw):
    cfg = tiny_cfg("dense")
    m = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    dcfg = DiLoCoConfig(num_workers=k, h_inner_steps=h, **dkw)
    return cfg, m, params, dcfg


def _data(cfg, k, step, B=2, S=16):
    key = jax.random.key(1000 + step)
    toks = jax.random.randint(key, (k, B, S), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": (toks + 1) % cfg.vocab_size}


def _assert_tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# Schedule DSL + tracker bookkeeping
# ---------------------------------------------------------------------------

def test_from_spec_parses_every_kind():
    fs = FaultSchedule.from_spec(
        "crash:2@10, rejoin:2@20, slow:1@5x1.5, drop:3@9x2, "
        "corrupt:0@4, kill@30")
    by_kind = {e.kind: e for e in fs.events}
    assert (by_kind["crash"].worker, by_kind["crash"].step) == (2, 10)
    assert (by_kind["rejoin"].worker, by_kind["rejoin"].step) == (2, 20)
    assert by_kind["slow"].factor == 1.5
    assert by_kind["drop"].attempts == 2
    assert by_kind["corrupt"].attempts == 1
    assert (by_kind["kill"].step, by_kind["kill"].worker) == (30, -1)
    assert FaultSchedule.from_spec("").empty and not fs.empty
    # kill is process-level: it never binds the per-worker fault jits
    assert all(e.kind != "kill" for e in fs.worker_events())
    assert len(fs.worker_events()) == 5


def test_schedule_roundtrip_validate_and_seeded_random(tmp_path):
    fs = FaultSchedule.from_spec("crash:2@10,rejoin:2@20,kill@30")
    p = str(tmp_path / "faults.json")
    fs.save(p)
    assert FaultSchedule.load(p).events == fs.events
    assert FaultSchedule.from_spec(p).events == fs.events   # path spelling
    fs.validate(4)
    with pytest.raises(ValueError, match="outside the fleet"):
        fs.validate(2)
    # the seeded draw IS the script: same args, same schedule, anywhere
    a = FaultSchedule.random(8, 40, seed=3, crashes=2, rejoin_after=10)
    b = FaultSchedule.random(8, 40, seed=3, crashes=2, rejoin_after=10)
    assert a.events == b.events
    assert sum(e.kind == "crash" for e in a.events) == 2
    assert sum(e.kind == "rejoin" for e in a.events) == 1


def test_chunk_limit_splits_at_crash_and_kill():
    fs = FaultSchedule.from_spec("crash:1@5,kill@9")
    assert fs.chunk_limit(0) == 4     # chunk must END before the mask flips
    assert fs.chunk_limit(5) == 9     # ...and AT a kill (process dies after)
    assert fs.chunk_limit(10) is None
    tr = FleetTracker(FaultSchedule.from_spec("crash:0@3,rejoin:0@6"), 2)
    live, _ = tr.begin_chunk(0)
    assert live == (True, True)
    live, recs = tr.begin_chunk(3)
    assert live == (False, True)
    assert ("fault", (3, "crash", 0)) in recs


# ---------------------------------------------------------------------------
# Quorum math
# ---------------------------------------------------------------------------

def test_masked_average_all_live_is_bitwise_the_unmasked_mean():
    """ISSUE anchor: masked mean over an all-ones mask == the unmasked
    mean, bitwise, for both plain and drift-aware averaging."""
    delta = {"a": jax.random.normal(jax.random.key(0), (4, 8, 3)),
             "b": jax.random.normal(jax.random.key(1), (4, 5))}
    ones = jnp.ones(4, bool)
    full_fn = jax.jit(
        lambda d, drift: outer_opt._average(
            d, DiLoCoConfig(num_workers=4, drift_aware=drift)),
        static_argnums=1)
    masked_fn = jax.jit(
        lambda d, l, drift: outer_opt._average(
            d, DiLoCoConfig(num_workers=4, drift_aware=drift), live=l),
        static_argnums=2)
    for drift in (False, True):
        _assert_tree_equal(full_fn(delta, drift),
                           masked_fn(delta, ones, drift))


def _noise_row(params, seed, scale=0.01):
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(jax.random.key(seed), len(leaves))
    return jax.tree.unflatten(
        treedef, [l + scale * jax.random.normal(k, l.shape, l.dtype)
                  for l, k in zip(leaves, keys)])


def test_quorum_one_dead_matches_survivor_fleet_bitwise():
    """Structural anchor: a K=4 quorum round with worker 3 dead is the
    K=3 quorum round on the survivors — pinned BITWISE at the outer-step
    level with identical per-row worker params (the masked sum adds a
    literal zero row, which is exact; the dead row passes through
    frozen).  Against PLAIN K=3 DiLoCo the comparison is allclose-tight
    rather than bitwise for a reason unrelated to the quorum math:
    ``jnp.mean`` lowers to ``sum * (1/n)``, and 1/3 is not representable
    — sum/3 lands 1 ulp away (1/4 is exact, which is why the all-live
    K=4 test above IS bitwise)."""
    cfg = tiny_cfg("dense")
    params, _ = init_params(cfg, jax.random.key(0))
    rows = [_noise_row(params, 100 + i) for i in range(4)]

    def with_rows(eng, rs):
        st = eng.init(params)
        return st._replace(worker_params=jax.tree.map(
            lambda *r: jnp.stack(r), *rs))

    eng4 = DiLoCoTrainer(None, OPT, DiLoCoConfig(num_workers=4))
    eng3 = DiLoCoTrainer(None, OPT, DiLoCoConfig(num_workers=3))
    st4, st3 = with_rows(eng4, rows), with_rows(eng3, rows[:3])
    contrib = jnp.array([1, 1, 1, 0], bool)
    new4, _ = jax.jit(eng4.outer_step_quorum)(
        st4, None, contrib, contrib, jnp.zeros(4, bool))
    new3q, _ = jax.jit(eng3.outer_step_quorum)(
        st3, None, jnp.ones(3, bool), jnp.ones(3, bool), jnp.zeros(3, bool))
    _assert_tree_equal(new4.global_params, new3q.global_params)
    # live rows adopt the (identical) new anchor...
    for w in range(3):
        _assert_tree_equal(
            jax.tree.map(lambda x: x[w], new4.worker_params),
            jax.tree.map(lambda x: x[w], new3q.worker_params))
    # ...and the dead row passes through frozen, bit-for-bit
    _assert_tree_equal(
        jax.tree.map(lambda x: x[3], new4.worker_params), rows[3])
    # plain K=3 DiLoCo: identical up to the mean's reciprocal rounding
    new3 = jax.jit(eng3.outer_step)(st3)
    for x, y in zip(jax.tree.leaves(new4.global_params),
                    jax.tree.leaves(new3.global_params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=1e-6, rtol=0)


# ---------------------------------------------------------------------------
# End-to-end fault runs
# ---------------------------------------------------------------------------

def _run_with(dcfg, cfg, m, params, steps, k, **kw):
    strat = make_strategy(dcfg)
    dt = DistTrainer(m.loss, OPT, dcfg, strat)
    state = dt.init(params)
    return dt.run(state, lambda s: _data(cfg, k, s), steps, **kw)


def test_crash_rejoin_end_to_end_records_and_trains():
    cfg, m, params, dcfg = _setup(k=4, h=3, strategy="diloco")
    faults = FaultSchedule.from_spec(
        "slow:3@2x1.5,crash:2@4,drop:1@5,rejoin:2@10")
    state, hist = _run_with(dcfg, cfg, m, params, 12, 4, faults=faults)
    assert hist["sync_steps"] == [2, 5, 8, 11]
    # quorum shrinks at the crash and stays shrunk until the rejoin round
    assert hist["quorum"] == [(2, 4), (5, 3), (8, 3), (11, 3)]
    fault_recs = hist["fault"]
    assert (4, "crash", 2) in fault_recs
    assert (5, "drop_retry", 1) in fault_recs
    assert (2, "slow", 3, 1.5) in fault_recs
    assert (11, "rejoin", 2) in fault_recs
    # rejoin drift metrics logged exactly once, at the rejoin boundary
    (step, worker, norm, cos), = hist["rejoin_drift"]
    assert (step, worker) == (11, 2)
    assert np.isfinite(norm) and np.isfinite(cos)
    assert np.isfinite(hist["loss"]).all()


def test_min_quorum_skips_round_and_anchor_stays_put():
    cfg, m, params, dcfg = _setup(k=2, h=3, strategy="diloco")
    faults = FaultSchedule.from_spec("crash:1@2")
    state, hist = _run_with(dcfg, cfg, m, params, 9, 2, faults=faults,
                            min_quorum=2)
    assert hist["quorum_skip"] == [2, 5, 8]
    assert hist["sync_steps"] == []
    # every round below quorum: the anchor never moves off init
    _assert_tree_equal(state.global_params, params)


def test_drop_retry_keeps_worker_in_and_matches_no_fault_run():
    cfg, m, params, dcfg = _setup(k=2, h=4, strategy="diloco")
    base_state, base_hist = _run_with(dcfg, cfg, m, params, 8, 2)
    # one failed attempt -> codec-aware retry succeeds, worker stays in:
    # full quorum, same math as the no-fault round (allclose-tight — the
    # quorum jit is a different compiled program, so XLA's fusion choices
    # may differ by an ulp; BITWISE no-fault equality is pinned on the
    # empty-schedule path, which keeps the original programs)
    faults = FaultSchedule.from_spec("drop:1@3")
    state, hist = _run_with(dcfg, cfg, m, params, 8, 2, faults=faults)
    assert (3, "drop_retry", 1) in hist["fault"]
    assert hist["quorum"] == [(3, 2), (7, 2)]
    for x, y in zip(jax.tree.leaves(state.global_params),
                    jax.tree.leaves(base_state.global_params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-6,
                                   rtol=0)
    np.testing.assert_allclose(hist["loss"], base_hist["loss"], rtol=1e-4)
    # two failed attempts -> counted out of this round's average
    faults = FaultSchedule.from_spec("corrupt:1@3x2")
    _, hist = _run_with(dcfg, cfg, m, params, 8, 2, faults=faults)
    assert (3, "corrupt_lost", 1) in hist["fault"]
    assert hist["quorum"] == [(3, 1), (7, 2)]


# ---------------------------------------------------------------------------
# Crash-consistent auto-resume (the honesty anchor)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", ["ddp", "diloco"])
def test_kill_checkpoint_resume_is_bitwise(tmp_path, strategy):
    """kill@7 with outer-boundary checkpoints every 6 steps, then
    --resume: the continuation is BITWISE an uninterrupted 12-step run —
    final state, recorded losses, and sync steps.  Kill-only schedules
    never bind the fault jits, so the compiled programs are the
    uninterrupted run's."""
    if strategy == "ddp":
        cfg, m, params, dcfg = _setup(
            k=1, h=1, strategy="ddp", outer_lr=1.0, outer_momentum=0.0,
            nesterov=False)
        k = 1
    else:
        cfg, m, params, dcfg = _setup(k=2, h=3, strategy="diloco")
        k = 2
    base_state, base_hist = _run_with(dcfg, cfg, m, params, 12, k)

    ckpt = str(tmp_path / strategy)
    with pytest.raises(SimulatedCrash, match="after step 7"):
        _run_with(dcfg, cfg, m, params, 12, k,
                  faults=FaultSchedule.from_spec("kill@7"),
                  checkpoint_dir=ckpt, checkpoint_every=6)
    assert [s for s, _ in list_run_checkpoints(ckpt)] == [6]
    state, hist = _run_with(dcfg, cfg, m, params, 12, k,
                            checkpoint_dir=ckpt, checkpoint_every=6,
                            resume=True)
    _assert_tree_equal(state, base_state)
    assert hist["step"] == base_hist["step"]
    np.testing.assert_array_equal(hist["loss"], base_hist["loss"])
    assert hist["sync_steps"] == base_hist["sync_steps"]


def test_torn_checkpoint_falls_back_to_previous(tmp_path):
    d = str(tmp_path)
    s1 = {"w": np.arange(4, dtype=np.float32)}
    s2 = {"w": np.arange(4, dtype=np.float32) * 2}
    save_run_checkpoint(d, 2, s1, history={"loss": [1.0]})
    save_run_checkpoint(d, 4, s2)
    assert [s for s, _ in list_run_checkpoints(d)] == [2, 4]
    # torn write: the newest state file vanished mid-crash -> its
    # manifest is incomplete and the reader degrades to the previous step
    os.remove(os.path.join(d, "ckpt_00000004.state.npz"))
    assert [s for s, _ in list_run_checkpoints(d)] == [2]
    man = latest_run_checkpoint(d)
    assert man["step"] == 2 and man["history"] == {"loss": [1.0]}
    state, _ = load_run_checkpoint(man, {"w": np.zeros(4, np.float32)})
    np.testing.assert_array_equal(state["w"], s1["w"])
    # garbage manifest (torn json): skipped, not fatal
    with open(os.path.join(d, "ckpt_00000006.json"), "w") as f:
        f.write('{"step": 6, "files": {')
    assert [s for s, _ in list_run_checkpoints(d)] == [2]


def test_atomic_write_crash_leaves_old_file_and_no_tmp(tmp_path):
    p = str(tmp_path / "manifest.json")
    _atomic_bytes(p, lambda f: f.write(b"old"))

    def boom(f):
        f.write(b"torn")
        raise RuntimeError("crash mid-write")

    with pytest.raises(RuntimeError, match="mid-write"):
        _atomic_bytes(p, boom)
    with open(p, "rb") as f:
        assert f.read() == b"old"
    assert os.listdir(str(tmp_path)) == ["manifest.json"]


# ---------------------------------------------------------------------------
# Comm-sim fault overlay
# ---------------------------------------------------------------------------

def test_comm_sim_empty_schedule_reduces_to_fault_free():
    """Property: the fault overlay with an empty schedule is the
    identity — dict-exact against the pre-existing simulator output."""
    dcfg = DiLoCoConfig(num_workers=4, h_inner_steps=5, strategy="diloco")
    evs = make_strategy(dcfg).payload_schedule(10_000, 20, dcfg)
    comm = CommModel(bandwidth=1e8, latency=1e-3)
    times = [0.010, 0.012, 0.009, 0.011]
    base = simulate_heterogeneous(evs, 20, times, comm)
    assert simulate_heterogeneous(evs, 20, times, comm,
                                  faults=FaultSchedule()) == base
    assert base["retry_bytes"] == 0.0
    # a crash changes the timeline; a drop pays retry bytes
    crashed = simulate_heterogeneous(
        evs, 20, times, comm, faults=FaultSchedule.from_spec("crash:2@4"))
    assert crashed != base
    dropped = simulate_heterogeneous(
        evs, 20, times, comm, faults=FaultSchedule.from_spec("drop:0@4"))
    assert dropped["retry_bytes"] > 0
    assert dropped["wall_clock_s"] >= base["wall_clock_s"]


def test_comm_sim_gossip_empty_schedule_reduces_to_fault_free():
    dcfg = DiLoCoConfig(num_workers=4, h_inner_steps=5, strategy="gossip")
    rounds = make_strategy(dcfg).gossip_rounds(10_000, 20, dcfg)
    comm = CommModel(bandwidth=1e8, latency=1e-3)
    times = [0.010, 0.012, 0.009, 0.011]
    base = simulate_gossip(rounds, 20, times, comm)
    assert simulate_gossip(rounds, 20, times, comm,
                           faults=FaultSchedule()) == base
    assert base["retry_bytes"] == 0.0
    slowed = simulate_gossip(
        rounds, 20, times, comm,
        faults=FaultSchedule.from_spec("slow:1@2x2.0"))
    assert slowed["wall_clock_s"] > base["wall_clock_s"]


# ---------------------------------------------------------------------------
# Prefetcher error surfacing
# ---------------------------------------------------------------------------

def test_prefetcher_surfaces_original_producer_exception():
    class Boom(RuntimeError):
        pass

    def flaky(step):
        if step == 3:
            raise Boom("bad shard 3")
        return {"x": np.full((2,), step, np.float32)}

    pf = Prefetcher(flaky, 10, depth=2)
    try:
        out = pf.take(0, 3)
        np.testing.assert_array_equal(np.asarray(out["x"])[:, 0], [0, 1, 2])
        with pytest.raises(Boom, match="bad shard 3") as einfo:
            pf.take(3, 2)
        # the ORIGINAL exception object, traceback pointing into data_fn
        assert einfo.value.__cause__ is None
        import traceback
        frames = traceback.extract_tb(einfo.value.__traceback__)
        assert any(f.name == "flaky" for f in frames)
    finally:
        pf.close()


def test_prefetcher_dead_producer_clean_shutdown_error():
    pf = Prefetcher(lambda s: {"x": np.zeros(2, np.float32)}, 8, depth=2)
    pf.take(0, 2)
    # simulate a producer shut down cleanly (no recorded error) while the
    # consumer still wants data: take() must fail loudly, not hang or
    # return garbage
    pf._stop.set()
    pf._thread.join(timeout=5)
    import queue as _q
    while True:
        try:
            pf._q.get_nowait()
        except _q.Empty:
            break
    pf._q.put((None, Prefetcher._DONE))
    with pytest.raises(RuntimeError, match="stopped \\(closed\\)"):
        pf.take(2, 1)


# ---------------------------------------------------------------------------
# Serving graceful degradation
# ---------------------------------------------------------------------------

def test_scheduler_deadline_and_cancel_return_every_resource():
    """Deadline expiry and cancellation are ledger-clean: every KV block,
    budget reservation, and prefix-tree reference comes back exactly as a
    natural completion — pool invariants hold after every phase and the
    pool drains to pristine."""
    bs = 8
    pool = KVBlockPool(32, bs)
    tree = PrefixTree(block_size=bs)
    sched = Scheduler(4, pool, max_blocks_per_slot=8, tree=tree)
    shared = [7] * 20
    reqs = [Request(rid=i, prompt=shared + [i] * 5, max_new=4,
                    deadline_s=(0.5 if i % 2 else None))
            for i in range(8)]
    for r in reqs:
        sched.submit(r)
    admitted = sched.admit(0.0)
    assert len(admitted) == 4
    pool.check_invariants()
    # prefill slot 0 fully and publish its prompt to the prefix cache
    si0 = admitted[0]
    slot0 = sched.slots[si0]
    sched.ensure_mapped(si0, len(slot0.req.prompt) - 1)
    tree.insert(slot0.req.prompt, [b for b in slot0.blocks if b >= 0], pool)
    assert tree.num_blocks == 4     # 3 full chunks + partial tail leaf
    pool.check_invariants()
    # t=1.0: every odd-rid request is past its 0.5s deadline
    evicted = sched.expire(1.0)
    assert sorted(r.rid for _, r in evicted) == [1, 3, 5, 7]
    assert all(r.expired and r.finish_time == 1.0 for _, r in evicted)
    waiting_evictions = [r for si, r in evicted if si is None]
    running_evictions = [r for si, r in evicted if si is not None]
    assert len(waiting_evictions) == 2 and len(running_evictions) == 2
    pool.check_invariants()
    # freed slots re-admit the survivors, who attach the cached prefix
    newly = sched.admit(1.0)
    assert sorted(sched.slots[si].req.rid for si in newly) == [4, 6]
    assert all(sched.slots[si].num_shared == 2 for si in newly)
    assert sched.prefix_hits == 2
    pool.check_invariants()
    # cancel everything still live; unknown rid is a no-op
    for r in reqs:
        sched.cancel(r.rid, now=2.0)
    assert sched.cancel(999) is None
    assert all(s is None for s in sched.slots) and not sched.waiting
    pool.check_invariants()
    # only the tree's references remain; evicting them drains to pristine
    assert pool.num_allocated == tree.num_blocks == 4
    assert pool.num_reserved == 0
    tree.evict(pool, tree.num_blocks)
    pool.check_invariants()
    assert pool.num_allocated == 0 and pool.num_free == 32


def test_engine_expires_past_deadline_requests():
    cfg = tiny_cfg("dense")
    m = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    from repro.serving import Engine
    eng = Engine(m, params, num_slots=2, max_len=64, block_size=8)
    reqs = [Request(rid=0, prompt=[1, 2, 3], max_new=3),
            Request(rid=1, prompt=[4, 5], max_new=3, deadline_s=-1.0)]
    stats = eng.run(reqs, use_time=True)
    assert stats["expired"] == 1
    assert reqs[1].expired and not reqs[1].tokens
    assert not reqs[0].expired and len(reqs[0].tokens) == 3
    # without use_time, deadlines are inert (now is never sampled)
    reqs = [Request(rid=0, prompt=[1, 2, 3], max_new=3, deadline_s=-1.0)]
    stats = eng.run(reqs)
    assert stats["expired"] == 0 and len(reqs[0].tokens) == 3
