"""PrefixTree unit tests: match semantics (full-block walk, boundary COW
fork, the len(prompt)-1 cap), insert dedupe, refcount ownership, and LRU
eviction — pure host-side, no model or device pool needed."""
import pytest

from repro.serving import KVBlockPool, PrefixTree, Request, Scheduler


def _primed(bs=4, num_blocks=16, prompt=None):
    """Pool + tree holding ``prompt``'s blocks (default: 2 full chunks +
    a 2-token boundary leaf)."""
    pool = KVBlockPool(num_blocks, bs)
    tree = PrefixTree(bs)
    prompt = prompt if prompt is not None else list(range(1, 11))  # 10 toks
    blocks = pool.alloc(pool.blocks_for(len(prompt)))
    added = tree.insert(prompt, blocks, pool)
    return pool, tree, prompt, blocks, added


def test_match_on_empty_tree_is_miss():
    tree = PrefixTree(4)
    m = tree.match([1, 2, 3, 4, 5])
    assert not m.hit and m.blocks == [] and m.matched_len == 0
    assert m.fork_src is None


def test_insert_then_match_full_blocks_and_fork():
    pool, tree, prompt, blocks, added = _primed()
    assert added == 3 and tree.num_blocks == 3
    # tree took one reference per node on top of the caller's
    assert all(pool.refcount(b) == 2 for b in blocks)
    m = tree.match(list(prompt) + [99])
    assert m.blocks == blocks[:2]           # 2 full chunks attach directly
    assert m.fork_src == blocks[2]          # boundary leaf -> COW fork
    assert m.matched_len == 10              # 8 full + 2 leaf tokens


def test_match_caps_at_prompt_len_minus_one():
    """At least one token must remain to prefill: matching the WHOLE prompt
    would leave no step to produce the first sample's logits."""
    pool, tree, prompt, blocks, _ = _primed()
    m = tree.match(list(prompt))            # identical prompt
    assert m.matched_len == 9 == len(prompt) - 1
    assert m.blocks == blocks[:2]           # 3rd chunk only partially usable
    assert m.fork_src == blocks[2]
    # exactly one full block of prompt: the cap forbids matching it whole
    p2 = [7, 7, 7, 7]
    b2 = pool.alloc(1)
    tree.insert(p2, b2, pool)
    m2 = tree.match(list(p2))
    assert m2.blocks == [] and m2.fork_src == b2[0] and m2.matched_len == 3


def test_partial_prefix_divergence_stops_the_walk():
    pool, tree, prompt, blocks, _ = _primed()
    q = prompt[:6] + [88, 88, 88, 88]       # diverges inside chunk 2
    m = tree.match(q)
    assert m.blocks == blocks[:1]           # only chunk 1 shared
    assert m.fork_src == blocks[1]          # chunk 2 partially matches (2
    assert m.matched_len == 6               # tokens) -> fork


def test_insert_dedupes_existing_chunks():
    pool, tree, prompt, blocks, _ = _primed()
    dup = pool.alloc(3)
    assert tree.insert(list(prompt), dup, pool) == 0    # nothing new
    assert tree.num_blocks == 3
    assert all(pool.refcount(b) == 1 for b in dup)      # no ref taken
    # a longer prompt sharing the prefix adds only its new tail chunk
    longer = list(prompt[:8]) + [41, 42, 43, 44, 45]
    lb = pool.alloc(4)
    assert tree.insert(longer, lb, pool) == 2           # chunk 3 + leaf
    assert tree.num_blocks == 5


def test_unmapped_block_stops_insert():
    pool, tree, _, _, _ = _primed()
    p = [9, 9, 9, 9, 8, 8, 8, 8]
    b = pool.alloc(1)
    assert tree.insert(p, [b[0], -1], pool) == 1        # stops at the hole
    assert tree.num_blocks == 4


def test_evict_lru_only_when_no_slot_attached():
    pool, tree, prompt, blocks, _ = _primed()
    pool.free(blocks)                       # caller drops its refs: the
    assert pool.num_allocated == 3          # tree now the only owner
    # attach a "slot" to the leaf -> refcount 2 -> not evictable
    pool.incref(blocks[2])
    assert tree.evict(pool, 3) == 0         # leaf pinned; parents have
    assert tree.num_blocks == 3             # children -> nothing evictable
    pool.free([blocks[2]])                  # slot detaches
    assert tree.evict(pool, 1) == 1         # leaf goes (LRU + childless)
    assert tree.num_blocks == 2 and pool.refcount(blocks[2]) == 0
    # evicting the leaf exposed its parent: the rescan loop drains the rest
    assert tree.evict(pool, 5) == 2
    assert tree.num_blocks == 0 and pool.num_free == pool.num_blocks


def test_max_blocks_bound_evicts_lru_on_insert():
    pool = KVBlockPool(16, 4)
    tree = PrefixTree(4, max_blocks=2)
    b1 = pool.alloc(1)
    tree.insert([1, 1, 1, 1], b1, pool)
    pool.free(b1)
    b2 = pool.alloc(1)
    tree.insert([2, 2, 2, 2], b2, pool)
    pool.free(b2)
    assert tree.num_blocks == 2
    tree.match([2, 2, 2, 2, 9])             # touch: chain 2 becomes MRU
    b3 = pool.alloc(1)
    tree.insert([3, 3, 3, 3], b3, pool)     # over the bound: LRU chain 1
    pool.free(b3)                           # is evicted
    assert tree.num_blocks == 2
    assert not tree.match([1, 1, 1, 1, 9]).hit
    assert tree.match([2, 2, 2, 2, 9]).hit


def test_evict_for_frees_until_reservation_fits():
    pool, tree, prompt, blocks, _ = _primed(num_blocks=4)
    pool.free(blocks)                       # tree-only ownership
    assert not pool.can_reserve(3)          # 1 free, 3 cached
    assert tree.evict_for(pool, 3) == 2
    assert pool.can_reserve(3)


def test_scheduler_attaches_shared_and_forks_boundary():
    pool, tree, prompt, blocks, _ = _primed(num_blocks=16)
    sched = Scheduler(2, pool, max_blocks_per_slot=8, tree=tree)
    req = Request(rid=0, prompt=list(prompt) + [99], max_new=5)  # 16 toks
    sched.submit(req)
    assert sched.admit() == [0]
    slot = sched.slots[0]
    assert slot.pos == 10 and slot.num_shared == 2
    assert slot.blocks[:2] == blocks[:2]
    assert slot.feed == [99]                # only the unshared token
    # full budget is 4 blocks; 2 attach shared, 1 went to the COW dst
    assert slot.budget == 2 and slot.reserved == 1
    src, dst = slot.cow
    assert src == blocks[2] and dst not in blocks
    assert pool.refcount(src) == 3          # tree + caller + COW pin
    sched.cow_executed(0)
    assert slot.cow is None and pool.refcount(src) == 2
    # rollback below the shared prefix is structurally impossible, and the
    # refcount ledger backstops it anyway
    with pytest.raises(RuntimeError, match="shared"):
        pool.free([slot.blocks[0]], rereserve=True)
    sched.finish(0)                         # shared stay resident (tree +
    for b in blocks:                        # caller refs), private freed
        assert pool.refcount(b) == 2
    rep = sched.prefix_report()
    assert rep["hits"] == 1 and rep["hit_rate"] == 1.0
    assert rep["matched_tokens"] == 10 and rep["forked"] == 1


def test_admission_pressure_pins_matched_blocks_before_eviction():
    """evict_for during admission must not free the blocks the match just
    returned — a childless matched node is otherwise the LRU victim.  The
    pressure eviction takes the unrelated LRU block and leaves the matched
    pair alone."""
    bs = 4
    pool = KVBlockPool(6, bs)
    tree = PrefixTree(bs)
    junk = pool.alloc(1)                    # unrelated cached chain: the
    tree.insert([9, 9, 9, 9], junk, pool)   # intended (LRU) eviction victim
    pool.free(junk)
    p = [5, 5, 5, 5, 6, 6]                  # 1 full chunk + 2-token leaf
    blocks = pool.alloc(2)
    tree.insert(p, blocks, pool)
    pool.free(blocks)                       # tree-only ownership everywhere
    sched = Scheduler(1, pool, max_blocks_per_slot=5, tree=tree)
    # blocks_for(6+14)=5, 1 shared -> need 4; only 3 free, so admission
    # must evict — and the match's own blocks are childless/LRU-eligible
    # shapes too, so only the pre-eviction pin keeps them alive
    sched.submit(Request(rid=0, prompt=list(p), max_new=14))
    assert sched.admit() == [0]
    slot = sched.slots[0]
    assert slot.blocks[0] == blocks[0] and slot.num_shared == 1
    assert slot.cow is not None and slot.cow[0] == blocks[1]
    # the junk chain was the victim (its freed block may already be
    # re-allocated — e.g. as the COW dst — so check the tree, not refcount)
    assert not tree.match([9, 9, 9, 9, 1]).hit
    assert tree.num_blocks == 2
    pool.check_invariants()


def test_admission_declines_cleanly_when_only_matched_blocks_evictable():
    """Under pressure with nothing evictable but the match's own pinned
    blocks, admission declines and drops its pins — no eviction of the
    matched blocks, no refcount leak, no exception."""
    bs = 4
    pool = KVBlockPool(4, bs)
    tree = PrefixTree(bs)
    p = [5, 5, 5, 5, 6, 6]
    blocks = pool.alloc(2)
    tree.insert(p, blocks, pool)
    pool.free(blocks)                       # 2 cached, 2 free
    sched = Scheduler(1, pool, max_blocks_per_slot=4, tree=tree)
    sched.submit(Request(rid=0, prompt=list(p), max_new=10))  # need 3 > 2
    assert sched.admit() == []
    assert len(sched.waiting) == 1
    assert tree.num_blocks == 2             # match's blocks survived
    assert pool.refcount(blocks[0]) == 1 and pool.refcount(blocks[1]) == 1
    pool.check_invariants()


def test_window_and_tree_are_mutually_exclusive():
    pool = KVBlockPool(8, 4)
    with pytest.raises(ValueError, match="exclusive"):
        Scheduler(2, pool, max_blocks_per_slot=4, window=8,
                  tree=PrefixTree(4))
