"""Serving engine: ragged batches, greedy determinism, scoring."""
import jax
import jax.numpy as jnp
import numpy as np

from helpers import tiny_cfg
from repro.models.transformer import build_model, init_params
from repro.serving import Engine


def _engine():
    cfg = tiny_cfg("dense")
    m = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    return cfg, Engine(m, params)


def test_ragged_left_padding_matches_unpadded():
    """A short prompt inside a ragged batch must generate exactly what it
    would generate alone (pad slots masked by position -1)."""
    cfg, eng = _engine()
    short = [5, 6, 7]
    long = [1, 2, 3, 4, 5, 6, 7, 8, 9, 10]
    together = eng.generate_ids([short, long], max_new=8)
    alone = eng.generate_ids([short], max_new=8)
    np.testing.assert_array_equal(together[0], alone[0])


def test_greedy_deterministic():
    cfg, eng = _engine()
    a = eng.generate_ids([[1, 2, 3]], max_new=6)
    b = eng.generate_ids([[1, 2, 3]], max_new=6)
    np.testing.assert_array_equal(a, b)


def test_greedy_matches_forward_argmax():
    """First generated token == argmax of the forward logits at the last
    prompt position."""
    cfg, eng = _engine()
    prompt = [3, 1, 4, 1, 5]
    out = eng.generate_ids([prompt], max_new=1)
    m = build_model(cfg)
    logits, _ = m.forward(eng.params, {"tokens": jnp.asarray([prompt])})
    assert int(out[0, 0]) == int(jnp.argmax(logits[0, -1]))


def test_score_continuations_ranks_gold_higher_for_trained_pattern():
    """Scoring API sanity: log-probs are finite, shape matches options."""
    cfg, eng = _engine()
    scores = eng.score_continuations([1, 2, 3], [[4], [5], [6, 7]])
    assert scores.shape == (3,)
    assert np.isfinite(scores).all()


def test_sampling_temperature_changes_output():
    cfg, eng = _engine()
    a = eng.generate_ids([[1, 2, 3]], max_new=8, greedy=False, seed=0)
    b = eng.generate_ids([[1, 2, 3]], max_new=8, greedy=False, seed=1)
    assert not np.array_equal(a, b)


def test_temperature_is_forwarded_to_sampler():
    """temperature must actually reach the jitted sampler: near-zero
    temperature collapses sampling onto greedy argmax, and a hot sample
    (same PRNG seed) must differ from the cold one."""
    cfg, eng = _engine()
    prompt = [[1, 2, 3]]
    greedy = eng.generate_ids(prompt, max_new=8)
    cold = eng.generate_ids(prompt, max_new=8, greedy=False,
                            temperature=1e-4, seed=0)
    np.testing.assert_array_equal(cold, greedy)
    hot = eng.generate_ids(prompt, max_new=8, greedy=False,
                           temperature=5.0, seed=0)
    assert not np.array_equal(hot, cold)
