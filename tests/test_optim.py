"""Optimizers: AdamW reference math, Muon orthogonalization, partitioning,
schedules."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import OptimizerConfig
from repro.optim import (adamw, apply_updates, lr_schedule, muon,
                         nanochat_optimizer, newton_schulz, sgd_nesterov)
from repro.optim.combined import partition_label


def test_adamw_matches_numpy_reference():
    opt = adamw(lr=0.1, betas=(0.9, 0.99), eps=1e-8, weight_decay=0.0)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.25])}
    st = opt.init(p)
    upd, st = opt.update(g, st, p, 0)
    m = 0.1 * np.array([0.5, 0.25])
    v = 0.01 * np.array([0.25, 0.0625])
    mhat, vhat = m / 0.1, v / 0.01
    expect = -0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(upd["w"]), expect, rtol=1e-5)


def test_muon_orthogonalizes():
    G = jax.random.normal(jax.random.key(0), (24, 16))
    O = newton_schulz(G, steps=5)
    sv = jnp.linalg.svd(O, compute_uv=False)
    assert float(sv.min()) > 0.5 and float(sv.max()) < 1.5


def test_muon_stacked_params():
    """Muon must orthogonalize each layer of a (L, m, n) stack independently."""
    G = jax.random.normal(jax.random.key(0), (4, 24, 16))
    O = newton_schulz(G)
    single = newton_schulz(G[2])
    np.testing.assert_allclose(np.asarray(O[2]), np.asarray(single),
                               rtol=1e-4, atol=1e-5)


def test_sgd_nesterov_math():
    opt = sgd_nesterov(lr=1.0, momentum=0.5, nesterov=True)
    p = {"w": jnp.zeros(2)}
    g = {"w": jnp.asarray([1.0, 2.0])}
    st = opt.init(p)
    upd, st = opt.update(g, st, p, 0)
    v = np.array([1.0, 2.0])
    expect = -(np.array([1.0, 2.0]) + 0.5 * v)
    np.testing.assert_allclose(np.asarray(upd["w"]), expect)


def test_partition_label_routing():
    from repro.models.transformer import init_params
    from helpers import tiny_cfg
    cfg = tiny_cfg("hybrid")
    params, _ = init_params(cfg, jax.random.key(0))
    labels = jax.tree_util.tree_map_with_path(partition_label, params)
    flat = jax.tree_util.tree_flatten_with_path(labels)[0]
    by = {"muon": [], "adamw": []}
    for path, lab in flat:
        by[lab].append("/".join(str(getattr(p, "key", p)) for p in path))
    assert any("wq" in p for p in by["muon"])
    assert any("table" in p for p in by["adamw"])
    assert any("A_log" in p for p in by["adamw"])
    assert any("conv_w" in p for p in by["adamw"])
    assert not any("wq" in p for p in by["adamw"])


def test_partitioned_state_is_lean():
    """Per-label optimizer state must not allocate for leaves it doesn't own."""
    from repro.models.transformer import init_params
    from helpers import tiny_cfg
    cfg = tiny_cfg("dense")
    params, _ = init_params(cfg, jax.random.key(0))
    opt = nanochat_optimizer(OptimizerConfig())
    st = opt.init(params)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    n_state = sum(x.size for x in jax.tree.leaves(st))
    # muon: 1x matrices; adamw: 2x the rest -> strictly less than 2x params
    assert n_state < 2 * n_params


def test_schedules():
    f = lr_schedule("wsd", 1.0, 100, warmup_steps=10)
    assert float(f(0)) < 0.2
    assert abs(float(f(50)) - 1.0) < 1e-6
    assert float(f(99)) < 0.3
    g = lr_schedule("cosine", 1.0, 100, warmup_steps=0)
    assert float(g(0)) > 0.99
    assert float(g(99)) < 0.05


def test_training_decreases_loss():
    from helpers import tiny_batch, tiny_cfg
    from repro.models.transformer import build_model, init_params
    cfg = tiny_cfg("dense")
    m = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    opt = nanochat_optimizer(OptimizerConfig(
        total_steps=60, warmup_steps=5, schedule="constant",
        learning_rate=0.05, adam_lr=2e-3))
    st = opt.init(params)

    @jax.jit
    def step(params, st, batch, i):
        (loss, _), grads = jax.value_and_grad(m.loss, has_aux=True)(params,
                                                                    batch)
        upd, st = opt.update(grads, st, params, i)
        return apply_updates(params, upd), st, loss

    losses = []
    for i in range(50):
        batch = tiny_batch(cfg, B=8, S=32, key=i)
        params, st, loss = step(params, st, batch, jnp.int32(i))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5
