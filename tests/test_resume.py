"""Checkpoint-resume equivalence: train N steps, checkpoint the FULL DiLoCo
state (worker params + inner optimizer + outer momentum), restore, continue
— must be bit-identical to an uninterrupted run."""
import os
import tempfile

import jax
import numpy as np

from helpers import tiny_cfg
from repro.checkpoint import load_pytree, save_pytree
from repro.configs.base import DiLoCoConfig, OptimizerConfig
from repro.core import DiLoCoTrainer
from repro.models.transformer import build_model, init_params

OPT = OptimizerConfig(total_steps=100, warmup_steps=0, schedule="constant",
                      learning_rate=0.02, adam_lr=1e-3)


def _data(cfg, step):
    key = jax.random.key(500 + step)
    toks = jax.random.randint(key, (2, 4, 16), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": (toks + 1) % cfg.vocab_size}


def test_diloco_resume_bitwise():
    cfg = tiny_cfg("dense")
    m = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    tr = DiLoCoTrainer(m.loss, OPT, DiLoCoConfig(num_workers=2,
                                                 h_inner_steps=3))
    inner, outer = tr.jit_steps()

    def run(state, lo, hi):
        for s in range(lo, hi):
            state, _, _ = inner(state, _data(cfg, s))
            if (s + 1) % 3 == 0:
                state = outer(state)
        return state

    # uninterrupted 12 steps
    ref = run(tr.init(params), 0, 12)

    # interrupted at step 6 with a checkpoint round-trip
    mid = run(tr.init(params), 0, 6)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "state")
        save_pytree(mid, path)
        restored = load_pytree(mid, path)
    resumed = run(restored, 6, 12)

    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
