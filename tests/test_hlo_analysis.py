"""Unit tests for the while-trip-weighted HLO collective parser."""
from repro.launch.hlo_analysis import (parse_computations, _result_bytes,
                                       weighted_collective_stats)

SYNTH = """\
HloModule jit_step

%inner_body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %ar = f32[8,8]{1,0} all-reduce(%x), channel_id=3, replica_groups={{0,1}}, to_apply=%add
  ROOT %t = (s32[], f32[8,8]) tuple(%i, %ar)
}

%inner_cond (p: (s32[], f32[8,8])) -> pred[] {
  %c = s32[] constant(4)
  ROOT %cmp = pred[] compare(%i, %c), direction=LT
}

%outer_body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %w = (s32[], f32[8,8]) while(%tup), condition=%inner_cond, body=%inner_body
  %ag = f32[16,8]{1,0} all-gather(%y), channel_id=4, replica_groups={{0,1}}, dimensions={0}
  ROOT %t2 = (s32[], f32[8,8]) tuple(%i, %z)
}

%outer_cond (p: (s32[], f32[8,8])) -> pred[] {
  %c2 = s32[] constant(3)
  ROOT %cmp2 = pred[] compare(%i, %c2), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %w2 = (s32[], f32[8,8]) while(%tup0), condition=%outer_cond, body=%outer_body
  %ar2 = (f32[8,8], f32[4]) all-reduce(%g1, %g2), channel_id=9, replica_groups={{0,1}}
  ROOT %r = f32[8,8] get-tuple-element(%w2), index=1
}
"""


def test_result_bytes_tuple_types():
    assert _result_bytes(
        "%x = (f32[8,8], f32[4]) all-reduce(%a, %b), replica_groups={}") \
        == 8 * 8 * 4 + 4 * 4
    assert _result_bytes(
        "%x = bf16[16,8]{1,0} all-gather(%a), dimensions={0}") == 16 * 8 * 2


def test_nested_while_weighting():
    entry, colls, edges = parse_computations(SYNTH)
    assert entry == "main"
    stats = weighted_collective_stats(SYNTH)
    # inner all-reduce: 8*8*4 = 256 B, executed 3 (outer) x 4 (inner) = 12x
    # outer all-gather: 16*8*4 = 512 B, executed 3x
    # entry all-reduce: 256 + 16 = 272 B, executed once
    assert stats["bytes_by_kind"]["all-reduce"] == 256 * 12 + 272
    assert stats["bytes_by_kind"]["all-gather"] == 512 * 3
    # wire: all-reduce counts 2x (ring), gather 1x
    assert stats["wire_bytes_per_device"] == 2 * (256 * 12 + 272) + 512 * 3


def test_unreachable_counted_once():
    txt = """\
%orphan (p: f32[4]) -> f32[4] {
  %ar = f32[4]{0} all-reduce(%p), channel_id=1, replica_groups={{0,1}}
}

ENTRY %main (a: f32[4]) -> f32[4] {
  ROOT %r = f32[4] add(%a, %a)
}
"""
    stats = weighted_collective_stats(txt)
    assert stats["bytes_by_kind"]["all-reduce"] == 16


def test_cross_pod_classification():
    from repro.launch.hlo_analysis import _crosses_boundary
    # iota form: 2 groups of 2: {0,1},{2,3} with boundary 2 -> intra only
    assert not _crosses_boundary(
        "all-reduce(%x), replica_groups=[2,2]<=[4]", 2)
    # transposed iota: groups {0,2},{1,3} -> crosses boundary 2
    assert _crosses_boundary(
        "all-reduce(%x), replica_groups=[2,2]<=[2,2]T(1,0)", 2)
    # explicit groups
    assert _crosses_boundary("all-gather(%x), replica_groups={{0,3},{1,2}}", 2)
    assert not _crosses_boundary("all-gather(%x), replica_groups={{0,1},{2,3}}", 2)


def test_weighted_stats_cross_pod_field():
    txt = """\
ENTRY %main (a: f32[4]) -> f32[4] {
  %ar = f32[4]{0} all-reduce(%a), channel_id=1, replica_groups=[1,4]<=[4]
  ROOT %r = f32[4] get-tuple-element(%ar), index=0
}
"""
    stats = weighted_collective_stats(txt, pod_boundary=2)
    assert stats["cross_pod_bytes_per_device"] == 2 * 16  # ring 2x
    stats0 = weighted_collective_stats(txt, pod_boundary=0)
    assert stats0["cross_pod_bytes_per_device"] == 0
