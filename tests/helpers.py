"""Shared tiny configs / batch builders for the test suite."""
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

TINY = {
    "dense": dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                  d_ff=128, vocab_size=97),
    "moe": dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                d_ff=64, vocab_size=97, num_experts=4, num_experts_per_tok=2,
                arch_type="moe"),
    "ssm": dict(num_layers=2, d_model=64, arch_type="ssm", ssm_state_size=16,
                ssm_head_dim=16, ssm_chunk=8, num_heads=4, num_kv_heads=4,
                d_ff=0, vocab_size=97),
    "hybrid": dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                   d_ff=128, vocab_size=97, hybrid=True, ssm_state_size=8,
                   ssm_head_dim=16, ssm_chunk=8, window_pattern=(0, 8)),
    "audio": dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                  d_ff=128, vocab_size=97, is_encoder_decoder=True,
                  num_encoder_layers=2, encoder_seq_len=12,
                  arch_type="audio"),
    "vlm": dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                d_ff=128, vocab_size=97, num_image_tokens=4,
                arch_type="vlm"),
}


def tiny_cfg(kind="dense", **kw) -> ModelConfig:
    d = dict(TINY[kind])
    d.update(kw)
    return ModelConfig(**d)


def tiny_batch(cfg: ModelConfig, B=2, S=16, key=0):
    k = jax.random.key(key)
    toks = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": (toks + 1) % cfg.vocab_size}
    if cfg.num_image_tokens:
        batch["patches"] = jnp.ones((B, cfg.num_image_tokens, cfg.d_model))
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.ones((B, cfg.encoder_seq_len, cfg.d_model))
    return batch
