"""Property test for KVBlockPool's two-level ledger (reservation budget +
lazy mapping + per-block refcounts) under random
reserve/map/truncate/recycle/free/share churn.

The churn interpreter mirrors the Scheduler's use of the pool exactly:
admit reserves a budget, ``ensure_mapped`` draws it down one block at a
time (``alloc(reserved=True)``), window recycling and speculative rollback
return blocks with ``rereserve=True``, finish frees the mapping and
releases the leftover budget.  Prefix-sharing ops ride along: a mapped
block can be inserted into a model "tree" (``incref`` — an extra owner
reference), later admissions attach tree blocks as their leading shared
blocks (one more ``incref`` each, no budget), the tree releases references
(``free`` that only decrefs while other owners remain), and a COW fork
pins its source around a scratch alloc.  As in the real scheduler, shared
blocks are never reclaimed through rollback/recycling (there the guarantee
is structural: ``pos >= matched_len``; here the op generator respects it).
After EVERY op the interpreter asserts:

* ``check_invariants()`` — free ∪ allocated partitions the pool, no
  duplicate free-list entries, reserved ≤ free, refcounts cover exactly
  the allocated set with positive counts;
* the pool-wide reservation equals the sum of per-slot budgets;
* per slot, privately mapped + remaining budget == admitted budget
  (rollback and recycling never leak or mint budget; shared attachments
  are never budgeted);
* allocated == the distinct blocks owned by any slot, scratch, or tree
  reference — no leaks, no premature frees;
* every live block's refcount equals its model owner count exactly.

Runs twice: a seeded-churn version that always runs, and a hypothesis
version (skipped if hypothesis isn't installed) that shrinks failures.
"""
import random

import pytest

from repro.serving.kv_cache import KVBlockPool


class FakeSlot:
    """Duck-typed slot: what truncate() needs, plus the admitted budget."""

    def __init__(self, budget, shared=()):
        self.blocks = list(shared)  # logical -> physical, -1 = unmapped
        self.reserved = budget  # remaining budget
        self.budget = budget    # admitted budget (for the invariant)
        self.num_shared = len(self.blocks)  # leading shared attachments


def _mapped(slot):
    return sum(1 for b in slot.blocks if b >= 0)


def _assert_invariants(pool, slots, scratch, treerefs=()):
    pool.check_invariants()
    assert pool.num_reserved == sum(s.reserved for s in slots), \
        "pool reservation != sum of slot budgets"
    for s in slots:
        assert s.reserved + _mapped(s) - s.num_shared == s.budget, \
            "slot leaked or minted budget"
        assert s.reserved >= 0
    live = set(scratch) | set(treerefs)
    for s in slots:
        live |= {b for b in s.blocks if b >= 0}
    assert pool.num_allocated == len(live), \
        "physical block leaked or freed while owned"
    assert pool.num_free + pool.num_allocated == pool.num_blocks
    for b in live:      # refcount == model owner count, per block
        want = sum(1 for s in slots for blk in s.blocks if blk == b) \
            + scratch.count(b) + list(treerefs).count(b)
        assert pool.refcount(b) == want, \
            f"block {b}: refcount {pool.refcount(b)} != owners {want}"


def churn(ops, num_blocks=12, block_size=4):
    """Interpret (opcode, a, b) triples against a pool + slot set,
    asserting every invariant after every step."""
    return _churn_into(KVBlockPool(num_blocks, block_size), ops)


def _drain(pool, slots, scratch, treerefs):
    """Finish every slot, drop scratch and tree references: the pool must
    come back pristine (the refcount ledger frees each shared block exactly
    when its LAST owner lets go)."""
    for s in list(slots):
        dead = [blk for blk in s.blocks if blk >= 0]
        if dead:
            pool.free(dead)
        pool.release(s.reserved)
    if scratch:
        pool.free(scratch)
    for blk in treerefs:
        pool.free([blk])
    pool.check_invariants()
    assert pool.num_free == pool.num_blocks
    assert pool.num_allocated == 0 and pool.num_reserved == 0


def test_seeded_churn():
    rng = random.Random(1234)
    for _ in range(30):
        n = rng.randrange(1, 300)
        ops = [(rng.randrange(64), rng.randrange(64), rng.randrange(64))
               for _ in range(n)]
        pool, slots, scratch, treerefs = churn(
            ops, num_blocks=rng.randrange(1, 24),
            block_size=rng.choice([1, 2, 4, 8]))
        # drain: finishing everything must return the pool to pristine
        _drain(pool, slots, scratch, treerefs)


def test_churn_on_quantized_byte_budget_pool():
    """The ledger is dtype-agnostic, but a quantized pool at the same byte
    budget holds ~1.8x the blocks of a bf16 pool (narrow payload + f32
    scale sideband): size both from one budget, run the same churn program
    against the larger quantized pool, and check the byte accounting."""
    from helpers import tiny_cfg
    from repro.models.transformer import paged_block_bytes

    bs = 4
    bf16 = paged_block_bytes(tiny_cfg("dense", kv_cache_dtype="bf16"), bs)
    fp8 = paged_block_bytes(tiny_cfg("dense", kv_cache_dtype="fp8"), bs)
    assert fp8 < bf16
    budget = 12 * bf16
    n_bf16, n_fp8 = budget // bf16, budget // fp8
    assert n_fp8 > n_bf16
    rng = random.Random(99)
    ops = [(rng.randrange(64), rng.randrange(64), rng.randrange(64))
           for _ in range(200)]
    for num_blocks, bpb in ((n_bf16, bf16), (n_fp8, fp8)):
        pool = KVBlockPool(num_blocks, bs, bytes_per_block=bpb)
        assert pool.total_bytes == num_blocks * bpb <= budget
        pool, slots, scratch, treerefs = _churn_into(pool, ops)
        _drain(pool, slots, scratch, treerefs)


def _churn_into(pool, ops):
    """churn()'s interpreter against a caller-built pool (byte-budget
    variants); see churn() for the opcode table.  ``treerefs`` models the
    prefix tree's own references: one per inserted block, held until
    "eviction" (only when no slot is attached — ``refcount == 1`` —
    exactly the real ``PrefixTree._evictable`` condition)."""
    slots, scratch, treerefs = [], [], []
    num_blocks, block_size = pool.num_blocks, pool.block_size
    for opcode, a, b in ops:
        op = opcode % 10
        if op == 0:                                   # admit: reserve budget
            budget = a % 5
            if pool.can_reserve(budget):
                pool.reserve(budget)
                slots.append(FakeSlot(budget))
        elif op == 1 and slots:                       # ensure_mapped: 1 block
            s = slots[a % len(slots)]
            if s.reserved > 0:
                s.blocks.append(pool.alloc(1, reserved=True)[0])
                s.reserved -= 1
        elif op == 2 and slots:                       # unmapped hole
            s = slots[a % len(slots)]
            if s.reserved > 0 and len(s.blocks) < num_blocks:
                s.blocks.append(-1)
        elif op == 3 and slots:                       # spec rollback: never
            s = slots[a % len(slots)]                 # below shared prefix
            keep_min = s.num_shared                   # (pos >= matched_len
            for i, blk in enumerate(s.blocks):        # structurally, in the
                if blk >= 0 and pool.refcount(blk) > 1:   # real scheduler)
                    keep_min = max(keep_min, i + 1)
            pos = max(b % (len(s.blocks) * block_size + 1),
                      keep_min * block_size)
            before = s.reserved + _mapped(s)
            pool.truncate(s, pos)
            assert s.reserved + _mapped(s) == before
        elif op == 4 and slots:                       # window recycling
            s = slots[a % len(slots)]                 # (windowed slots never
            mapped_idx = [i for i, blk in enumerate(s.blocks)     # share)
                          if blk >= 0 and i >= s.num_shared
                          and pool.refcount(s.blocks[i]) == 1]
            if mapped_idx:
                j = mapped_idx[b % len(mapped_idx)]
                pool.free([s.blocks[j]], rereserve=True)
                s.blocks[j] = -1
                s.reserved += 1
        elif op == 5 and slots:                       # finish: free + release
            s = slots.pop(a % len(slots))             # (shared attachments
            dead = [blk for blk in s.blocks if blk >= 0]      # just decref)
            if dead:
                pool.free(dead)
            pool.release(s.reserved)
        elif op == 6:                                 # scratch alloc/free
            if scratch and b % 2:
                pool.free([scratch.pop()])
            elif pool.can_allocate(1):
                scratch.extend(pool.alloc(1))
        elif op == 7 and slots:                       # tree insert: the tree
            s = slots[a % len(slots)]                 # takes its own ref on
            tset = set(treerefs)                      # a slot's mapped block
            cands = [blk for blk in s.blocks if blk >= 0 and blk not in tset]
            if cands:
                blk = cands[b % len(cands)]
                pool.incref(blk)
                treerefs.append(blk)
        elif op == 8 and treerefs:                    # admit with shared
            k = 1 + a % min(3, len(treerefs))         # prefix: attach tree
            start = b % len(treerefs)                 # blocks, budget covers
            chosen = [treerefs[(start + j) % len(treerefs)]   # only the tail
                      for j in range(k)]
            budget = b % 4
            if pool.can_reserve(budget):
                pool.reserve(budget)
                for blk in chosen:
                    pool.incref(blk)
                slots.append(FakeSlot(budget, shared=chosen))
        elif op == 9 and treerefs:                    # tree evict / COW fork
            if b % 2:                                 # evict: only when no
                evictable = [blk for blk in treerefs  # slot is attached —
                             if pool.refcount(blk) == 1]  # the _evictable
                if evictable:                             # condition
                    blk = evictable[a % len(evictable)]
                    treerefs.remove(blk)
                    pool.free([blk])
            elif pool.can_allocate(1):                # fork: pin src around
                src = treerefs[a % len(treerefs)]     # the dst alloc, then
                pool.incref(src)                      # unpin (cow_executed)
                dst = pool.alloc(1)
                scratch.append(dst[0])
                pool.free([src])
        _assert_invariants(pool, slots, scratch, treerefs)
    return pool, slots, scratch, treerefs


def test_ledger_raises_on_misuse():
    pool = KVBlockPool(4, 2)
    blocks = pool.alloc(2)
    pool.free(blocks)
    with pytest.raises(RuntimeError, match="double-free"):
        pool.free(blocks[:1])
    with pytest.raises(RuntimeError, match="over-reserve"):
        pool.reserve(5)
    pool.reserve(3)
    with pytest.raises(RuntimeError, match="release"):
        pool.release(4)
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.alloc(2)                   # only 1 unreserved block left


def test_refcount_ledger_raises_on_misuse():
    pool = KVBlockPool(4, 2)
    b = pool.alloc(1)[0]
    with pytest.raises(RuntimeError, match="unallocated"):
        pool.incref(b + 1)              # incref needs a live block
    pool.incref(b)                      # 2 owners
    with pytest.raises(RuntimeError, match="shared"):
        pool.free([b], rereserve=True)  # rollback/recycle never reclaims
    assert pool.refcount(b) == 2        # ...and the failed free mutated
    pool.check_invariants()             # nothing
    pool.free([b])                      # decref: still allocated
    assert pool.refcount(b) == 1 and pool.num_allocated == 1
    pool.free([b])                      # last owner: back on the free list
    assert pool.refcount(b) == 0 and pool.num_free == 4
    with pytest.raises(RuntimeError, match="double-free"):
        pool.free([b])
    c, d = pool.alloc(2)
    with pytest.raises(RuntimeError, match="duplicate"):
        pool.free([c, c])               # one call may not double-count
    pool.free([c, d])
    pool.check_invariants()


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=200, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 63), st.integers(0, 63),
                              st.integers(0, 63)), max_size=120),
           st.integers(1, 24), st.sampled_from([1, 2, 4, 8]))
    def test_hypothesis_churn(ops, num_blocks, block_size):
        churn(ops, num_blocks=num_blocks, block_size=block_size)
except ImportError:  # pragma: no cover - hypothesis is in CI's pip set
    pass
