"""Property test for KVBlockPool's two-level ledger (reservation budget +
lazy mapping) under random reserve/map/truncate/recycle/free churn.

The churn interpreter mirrors the Scheduler's use of the pool exactly:
admit reserves a budget, ``ensure_mapped`` draws it down one block at a
time (``alloc(reserved=True)``), window recycling and speculative rollback
return blocks with ``rereserve=True``, finish frees the mapping and
releases the leftover budget.  After EVERY op it asserts:

* ``check_invariants()`` — free ∪ allocated partitions the pool, no
  duplicate free-list entries, reserved ≤ free;
* the pool-wide reservation equals the sum of per-slot budgets;
* per slot, mapped + remaining budget == admitted budget (rollback and
  recycling never leak or mint budget);
* allocated == all mapped blocks + scratch, i.e. no physical block leaks.

Runs twice: a seeded-churn version that always runs, and a hypothesis
version (skipped if hypothesis isn't installed) that shrinks failures.
"""
import random

import pytest

from repro.serving.kv_cache import KVBlockPool


class FakeSlot:
    """Duck-typed slot: what truncate() needs, plus the admitted budget."""

    def __init__(self, budget):
        self.blocks = []        # logical -> physical, -1 = unmapped
        self.reserved = budget  # remaining budget
        self.budget = budget    # admitted budget (for the invariant)


def _mapped(slot):
    return sum(1 for b in slot.blocks if b >= 0)


def _assert_invariants(pool, slots, scratch):
    pool.check_invariants()
    assert pool.num_reserved == sum(s.reserved for s in slots), \
        "pool reservation != sum of slot budgets"
    for s in slots:
        assert s.reserved + _mapped(s) == s.budget, \
            "slot leaked or minted budget"
        assert s.reserved >= 0
    assert pool.num_allocated == sum(_mapped(s) for s in slots) + \
        len(scratch), "physical block leaked or double-mapped"
    assert pool.num_free + pool.num_allocated == pool.num_blocks


def churn(ops, num_blocks=12, block_size=4):
    """Interpret (opcode, a, b) triples against a pool + slot set,
    asserting every invariant after every step."""
    return _churn_into(KVBlockPool(num_blocks, block_size), ops)


def test_seeded_churn():
    rng = random.Random(1234)
    for _ in range(30):
        n = rng.randrange(1, 300)
        ops = [(rng.randrange(64), rng.randrange(64), rng.randrange(64))
               for _ in range(n)]
        pool, slots, scratch = churn(ops,
                                     num_blocks=rng.randrange(1, 24),
                                     block_size=rng.choice([1, 2, 4, 8]))
        # drain: finishing everything must return the pool to pristine
        for s in list(slots):
            dead = [blk for blk in s.blocks if blk >= 0]
            if dead:
                pool.free(dead)
            pool.release(s.reserved)
        if scratch:
            pool.free(scratch)
        pool.check_invariants()
        assert pool.num_free == pool.num_blocks
        assert pool.num_allocated == 0 and pool.num_reserved == 0


def test_churn_on_quantized_byte_budget_pool():
    """The ledger is dtype-agnostic, but a quantized pool at the same byte
    budget holds ~1.8x the blocks of a bf16 pool (narrow payload + f32
    scale sideband): size both from one budget, run the same churn program
    against the larger quantized pool, and check the byte accounting."""
    from helpers import tiny_cfg
    from repro.models.transformer import paged_block_bytes

    bs = 4
    bf16 = paged_block_bytes(tiny_cfg("dense", kv_cache_dtype="bf16"), bs)
    fp8 = paged_block_bytes(tiny_cfg("dense", kv_cache_dtype="fp8"), bs)
    assert fp8 < bf16
    budget = 12 * bf16
    n_bf16, n_fp8 = budget // bf16, budget // fp8
    assert n_fp8 > n_bf16
    rng = random.Random(99)
    ops = [(rng.randrange(64), rng.randrange(64), rng.randrange(64))
           for _ in range(200)]
    for num_blocks, bpb in ((n_bf16, bf16), (n_fp8, fp8)):
        pool = KVBlockPool(num_blocks, bs, bytes_per_block=bpb)
        assert pool.total_bytes == num_blocks * bpb <= budget
        pool, slots, scratch = _churn_into(pool, ops)
        for s in list(slots):
            dead = [blk for blk in s.blocks if blk >= 0]
            if dead:
                pool.free(dead)
            pool.release(s.reserved)
        if scratch:
            pool.free(scratch)
        pool.check_invariants()
        assert pool.num_free == pool.num_blocks


def _churn_into(pool, ops):
    """churn()'s interpreter against a caller-built pool (byte-budget
    variants); see churn() for the opcode table."""
    slots, scratch = [], []
    num_blocks, block_size = pool.num_blocks, pool.block_size
    for opcode, a, b in ops:
        op = opcode % 7
        if op == 0:                                   # admit: reserve budget
            budget = a % 5
            if pool.can_reserve(budget):
                pool.reserve(budget)
                slots.append(FakeSlot(budget))
        elif op == 1 and slots:                       # ensure_mapped: 1 block
            s = slots[a % len(slots)]
            if s.reserved > 0:
                s.blocks.append(pool.alloc(1, reserved=True)[0])
                s.reserved -= 1
        elif op == 2 and slots:                       # unmapped hole
            s = slots[a % len(slots)]
            if s.reserved > 0 and len(s.blocks) < num_blocks:
                s.blocks.append(-1)
        elif op == 3 and slots:                       # spec rollback
            s = slots[a % len(slots)]
            pos = b % (len(s.blocks) * block_size + 1)
            before = s.reserved + _mapped(s)
            pool.truncate(s, pos)
            assert s.reserved + _mapped(s) == before
        elif op == 4 and slots:                       # window recycling
            s = slots[a % len(slots)]
            mapped_idx = [i for i, blk in enumerate(s.blocks) if blk >= 0]
            if mapped_idx:
                j = mapped_idx[b % len(mapped_idx)]
                pool.free([s.blocks[j]], rereserve=True)
                s.blocks[j] = -1
                s.reserved += 1
        elif op == 5 and slots:                       # finish: free + release
            s = slots.pop(a % len(slots))
            dead = [blk for blk in s.blocks if blk >= 0]
            if dead:
                pool.free(dead)
            pool.release(s.reserved)
        elif op == 6:                                 # scratch alloc/free
            if scratch and b % 2:
                pool.free([scratch.pop()])
            elif pool.can_allocate(1):
                scratch.extend(pool.alloc(1))
        _assert_invariants(pool, slots, scratch)
    return pool, slots, scratch


def test_ledger_raises_on_misuse():
    pool = KVBlockPool(4, 2)
    blocks = pool.alloc(2)
    pool.free(blocks)
    with pytest.raises(RuntimeError, match="double-free"):
        pool.free(blocks[:1])
    with pytest.raises(RuntimeError, match="over-reserve"):
        pool.reserve(5)
    pool.reserve(3)
    with pytest.raises(RuntimeError, match="release"):
        pool.release(4)
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.alloc(2)                   # only 1 unreserved block left


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=200, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 63), st.integers(0, 63),
                              st.integers(0, 63)), max_size=120),
           st.integers(1, 24), st.sampled_from([1, 2, 4, 8]))
    def test_hypothesis_churn(ops, num_blocks, block_size):
        churn(ops, num_blocks=num_blocks, block_size=block_size)
except ImportError:  # pragma: no cover - hypothesis is in CI's pip set
    pass
