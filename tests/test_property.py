"""Property-based tests (hypothesis) on system invariants.

``hypothesis`` is an optional test dependency (declared in pyproject.toml's
``test`` extra); when absent the whole module degrades to a skip instead of
breaking collection for the rest of the suite.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.outer_opt import dequantize_delta, quantize_delta
from repro.configs.base import DiLoCoConfig
from repro.core.outer_opt import average_deltas
from repro.core.sync import AsyncGossipSync, DiLoCoSync
from repro.core.transport import BF16Cast, Fp8Codec, Int8Symmetric
from repro.launch.comm_sim import (CommModel, simulate_gossip,
                                   simulate_heterogeneous, simulate_schedule)
from repro.models.layers import softmax_cross_entropy
from repro.optim import newton_schulz
from repro.optim.schedule import lr_schedule

SETTINGS = dict(max_examples=20, deadline=None)


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 4), st.integers(2, 24))
def test_int8_quantization_error_bound(seed, k, n):
    """|dequant(quant(x)) - x| <= amax/254 per tensor (symmetric int8)."""
    x = np.asarray(jax.random.normal(jax.random.key(seed), (k, n, n)))
    payload, scales = quantize_delta({"w": jnp.asarray(x)}, "int8")
    back = np.asarray(dequantize_delta(payload, scales)["w"])
    for i in range(k):
        amax = np.abs(x[i]).max()
        assert np.abs(back[i] - x[i]).max() <= amax / 254 + 1e-9


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 4), st.integers(2, 24))
def test_int8_codec_roundtrip_error_bound(seed, k, n):
    """Codec-level statement of the int8 bound: |dec(enc(x)) - x| is at
    most half a quantization step (amax/254) per worker row, and the
    error-feedback residual equals the round-trip error exactly."""
    codec = Int8Symmetric(use_kernel=False)   # oracle path: shapes vary
    x = np.asarray(jax.random.normal(jax.random.key(seed), (k, n)))
    res0 = {"w": jnp.zeros((k, n))}
    payload, new_res = codec.encode({"w": jnp.asarray(x)}, res0)
    back = np.asarray(codec.decode(payload)["w"])
    for i in range(k):
        amax = np.abs(x[i]).max()
        assert np.abs(back[i] - x[i]).max() <= amax / 254 + 1e-9
    np.testing.assert_allclose(np.asarray(new_res["w"]), x - back,
                               atol=1e-7)


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 4), st.integers(2, 24),
       st.sampled_from(["e4m3", "e5m2"]))
def test_fp8_codec_roundtrip_error_bound(seed, k, n, flavor):
    """Per element: |dec(enc(x)) - x| <= |x| * half-ulp(flavor) + scale
    (half-ulp 2^-4 for e4m3's 3 mantissa bits, 2^-3 for e5m2's 2; the
    scale term covers the subnormal region), and the error-feedback
    residual equals the round-trip error exactly."""
    codec = Fp8Codec(use_kernel=False, flavor=flavor)
    qmax, rel = (448.0, 2.0 ** -4) if flavor == "e4m3" else \
        (57344.0, 2.0 ** -3)
    x = np.asarray(jax.random.normal(jax.random.key(seed), (k, n)))
    res0 = {"w": jnp.zeros((k, n))}
    payload, new_res = codec.encode({"w": jnp.asarray(x)}, res0)
    assert np.asarray(payload.data["w"]).dtype.itemsize == 1
    back = np.asarray(codec.decode(payload)["w"])
    for i in range(k):
        s = max(np.abs(x[i]).max(), 1e-12) / qmax
        assert (np.abs(back[i] - x[i]) <= np.abs(x[i]) * rel + s).all()
    np.testing.assert_allclose(np.asarray(new_res["w"]), x - back,
                               atol=1e-7)


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 16))
def test_bf16_codec_exact_on_representable(seed, n):
    """bf16 cast is the identity on values that already fit in bf16 (f32
    values rounded to bf16 up front round-trip bit-exactly)."""
    codec = BF16Cast()
    x = jax.random.normal(jax.random.key(seed), (2, n))
    x = x.astype(jnp.bfloat16).astype(jnp.float32)   # representable by construction
    back = codec.decode(codec.encode({"w": x})[0])["w"]
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 6))
def test_drift_aware_average_is_convex_combination(seed, k):
    """Drift-aware averaging output stays inside the per-coordinate
    [min, max] envelope of the worker deltas (convexity)."""
    x = np.asarray(jax.random.normal(jax.random.key(seed), (k, 5)))
    avg = np.asarray(average_deltas(
        {"w": jnp.asarray(x)}, DiLoCoConfig(num_workers=k, drift_aware=True))["w"])
    assert (avg <= x.max(axis=0) + 1e-6).all()
    assert (avg >= x.min(axis=0) - 1e-6).all()


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1))
def test_cross_entropy_matches_numpy(seed):
    key = jax.random.key(seed)
    logits = jax.random.normal(key, (2, 5, 11))
    labels = jax.random.randint(jax.random.key(seed + 1), (2, 5), 0, 11)
    got = float(softmax_cross_entropy(logits, labels))
    l = np.asarray(logits, np.float64)
    p = np.exp(l - l.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    want = float(np.mean(-np.log(
        np.take_along_axis(p, np.asarray(labels)[..., None], -1)[..., 0])))
    assert abs(got - want) < 1e-4


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1))
def test_cross_entropy_ignores_masked_labels(seed):
    logits = jax.random.normal(jax.random.key(seed), (1, 6, 7))
    labels = jnp.asarray([[1, 2, -1, -1, 3, 4]])
    full = softmax_cross_entropy(logits, labels)
    sub = softmax_cross_entropy(
        logits[:, jnp.asarray([0, 1, 4, 5])], labels[:, jnp.asarray([0, 1, 4, 5])])
    assert abs(float(full) - float(sub)) < 1e-5


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 32), st.integers(2, 32))
def test_newton_schulz_bounded_singular_values(seed, m, n):
    G = jax.random.normal(jax.random.key(seed), (m, n)) + 1e-3
    O = newton_schulz(G)
    sv = np.asarray(jnp.linalg.svd(O, compute_uv=False))
    assert sv.max() < 1.6
    assert np.isfinite(np.asarray(O)).all()


@settings(**SETTINGS)
@given(st.sampled_from(["wsd", "cosine", "constant"]),
       st.integers(1, 50), st.integers(50, 500))
def test_lr_schedule_positive_and_bounded(kind, warm, total):
    f = lr_schedule(kind, 1.0, total, warmup_steps=warm)
    for s in range(0, total, max(total // 10, 1)):
        v = float(f(s))
        assert 0.0 <= v <= 1.0 + 1e-6


@settings(**SETTINGS)
@given(st.integers(2, 6), st.integers(4, 30), st.floats(0.001, 0.1),
       st.integers(1_000, 1_000_000))
def test_heterogeneous_equal_clocks_matches_schedule(h, steps, t, n):
    """With identical per-worker step times and staleness 0 the
    heterogeneous simulator reduces exactly to the single-timeline one —
    every per-worker link replays the same transfers."""
    dcfg = DiLoCoConfig(num_workers=4, h_inner_steps=h)
    evs = DiLoCoSync().payload_schedule(n, steps, dcfg)
    comm = CommModel(bandwidth=1e6, latency=1e-3)
    a = simulate_schedule(evs, steps, t, comm)
    b = simulate_heterogeneous(evs, steps, [t] * 4, comm)
    assert b["wall_clock_s"] == pytest.approx(a["wall_clock_s"], rel=1e-9)
    assert b["stall_s"] == pytest.approx(a["stall_s"], rel=1e-9, abs=1e-12)
    assert b["total_bytes"] == a["total_bytes"]


@settings(**SETTINGS)
@given(st.integers(2, 6), st.integers(6, 30),
       st.lists(st.floats(0.005, 0.05), min_size=2, max_size=5),
       st.integers(10_000, 1_000_000))
def test_heterogeneous_wall_monotone_in_staleness(h, steps, times, n):
    """A larger staleness window can only delay blocking further — modeled
    wall clock is non-increasing in staleness_steps for any fleet."""
    dcfg = DiLoCoConfig(num_workers=len(times), h_inner_steps=h)
    evs = DiLoCoSync().payload_schedule(n, steps, dcfg)
    comm = CommModel(bandwidth=1e6, latency=1e-3)
    walls = [simulate_heterogeneous(evs, steps, times, comm,
                                    staleness_steps=s)["wall_clock_s"]
             for s in range(6)]
    assert all(a >= b - 1e-12 for a, b in zip(walls, walls[1:]))


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 16), st.integers(2, 6), st.integers(6, 30),
       st.integers(0, 3),
       st.lists(st.floats(0.005, 0.05), min_size=2, max_size=5))
def test_gossip_wall_monotone_in_staleness(seed, h, steps, jitter, times):
    """Same invariant for the per-pair gossip simulator, over jittered
    per-worker publish schedules."""
    k = len(times)
    dcfg = DiLoCoConfig(num_workers=k, h_inner_steps=h)
    strat = AsyncGossipSync(jitter=jitter, staleness_bound=2, seed=seed)
    rounds = strat.gossip_rounds(500_000, steps, dcfg)
    comm = CommModel(bandwidth=1e6, latency=1e-3)
    walls = [simulate_gossip(rounds, steps, times, comm,
                             staleness_steps=s)["wall_clock_s"]
             for s in range(6)]
    assert all(a >= b - 1e-12 for a, b in zip(walls, walls[1:]))


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 8))
def test_ring_cache_insert_keeps_newest(seed, cap):
    """After inserting N > cap tokens one at a time, the cache holds exactly
    the last `cap` positions."""
    from repro.models.attention import _cache_insert
    import jax.numpy as jnp
    cache = {"k": jnp.zeros((1, cap, 1, 2)), "v": jnp.zeros((1, cap, 1, 2)),
             "pos": jnp.full((1, cap), -1, jnp.int32),
             "idx": jnp.zeros((), jnp.int32)}
    n = cap + 3
    for t in range(n):
        cache = _cache_insert(
            cache, jnp.ones((1, 1, 1, 2)) * t, jnp.ones((1, 1, 1, 2)) * t,
            jnp.asarray([[t]], jnp.int32))
    got = sorted(np.asarray(cache["pos"][0]).tolist())
    assert got == list(range(n - cap, n))
