"""Gossip outer sync (ISSUE 8): the honesty anchors — K=2 gossip is
bit-exact DiLoCo, the full topology IS the DiLoCo mean, async with
jitter=0 and staleness_bound=0 is the synchronous barrier — plus the
topology schedule, payload accounting, and the per-pair simulator."""
import dataclasses

import jax
import numpy as np
import pytest

from helpers import tiny_cfg
from repro.configs.base import DiLoCoConfig, OptimizerConfig
from repro.core import (AsyncGossipSync, DiLoCoSync, DistTrainer, GossipSync,
                        gossip_peers, strategy_names)
from repro.core.sync import (_GossipRunner, _gossip_payload_bytes,
                             hop_bytes_per_worker)
from repro.core.transport import make_codec
from repro.launch.comm_sim import CommModel, simulate_gossip
from repro.models.transformer import build_model, init_params

OPT = OptimizerConfig(total_steps=100, warmup_steps=0, schedule="constant",
                      learning_rate=0.02, adam_lr=1e-3)


def _setup(k=2, h=4, **dkw):
    # smaller than helpers.TINY: every test here runs two full training
    # arms, and the equivalences are about the outer loop, not the model
    cfg = tiny_cfg("dense", num_layers=1, d_model=32, num_heads=2,
                   num_kv_heads=1, d_ff=64)
    m = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    dcfg = DiLoCoConfig(num_workers=k, h_inner_steps=h, **dkw)
    return cfg, m, params, dcfg


def _data(cfg, k, step, B=4, S=16):
    key = jax.random.key(1000 + step)
    toks = jax.random.randint(key, (k, B, S), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": (toks + 1) % cfg.vocab_size}


def _run(m, params, dcfg, strategy, cfg, steps, k):
    dt = DistTrainer(m.loss, OPT, dcfg, strategy)
    state = dt.init(params)
    return dt.run(state, lambda s: _data(cfg, k, s), steps)


def _assert_bitwise(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _assert_close(a, b, atol):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=atol,
                                   rtol=0)


# ---------------------------------------------------------------------------
# Equivalence anchors
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topology", ["ring", "random"])
def test_gossip_k2_bitexact_diloco(topology):
    """With two workers the one gossip pair IS the fleet, so any topology
    is the DiLoCo mean — bit-for-bit (structural: K=2 binds the DiLoCo
    runner itself)."""
    cfg, m, params, dcfg = _setup(k=2, h=4)
    a_state, a_hist = _run(m, params, dcfg, DiLoCoSync(), cfg, 12, k=2)
    b_state, b_hist = _run(m, params, dcfg, GossipSync(topology=topology),
                           cfg, 12, k=2)
    _assert_bitwise(a_state.global_params, b_state.global_params)
    _assert_bitwise(a_state.worker_params, b_state.worker_params)
    assert a_hist["sync_steps"] == b_hist["sync_steps"] == [3, 7, 11]
    np.testing.assert_array_equal(a_hist["loss"], b_hist["loss"])


def test_gossip_full_topology_bitexact_diloco():
    """topology='full' averages ALL workers — definitionally DiLoCo, and
    bound to the DiLoCo runner so the match is bitwise at any K."""
    cfg, m, params, dcfg = _setup(k=4, h=4)
    a_state, _ = _run(m, params, dcfg, DiLoCoSync(), cfg, 8, k=4)
    b_state, b_hist = _run(m, params, dcfg, GossipSync(topology="full"),
                           cfg, 8, k=4)
    _assert_bitwise(a_state.global_params, b_state.global_params)
    _assert_bitwise(a_state.worker_params, b_state.worker_params)
    assert b_hist["sync_steps"] == [3, 7]


def test_async_zero_jitter_zero_bound_bitexact_gossip():
    """jitter=0 + staleness_bound=0: every worker is co-due every H with
    staleness 0, so async gossip IS the synchronous barrier — bitwise,
    including the per-worker (step, worker, peer, staleness) records."""
    cfg, m, params, dcfg = _setup(k=4, h=4)
    a_state, a_hist = _run(m, params, dcfg, GossipSync(), cfg, 12, k=4)
    b_state, b_hist = _run(m, params, dcfg, AsyncGossipSync(), cfg, 12, k=4)
    _assert_bitwise(a_state.global_params, b_state.global_params)
    _assert_bitwise(a_state.worker_params, b_state.worker_params)
    assert a_hist["gossip_syncs"] == b_hist["gossip_syncs"]
    assert all(s == 0 for *_, s in b_hist["gossip_syncs"])
    np.testing.assert_array_equal(a_hist["loss"], b_hist["loss"])


def test_async_k2_bitexact_diloco():
    """K=2 with equal clocks and bound 0 delegates to the DiLoCo runner,
    same as the synchronous strategy."""
    cfg, m, params, dcfg = _setup(k=2, h=4)
    a_state, _ = _run(m, params, dcfg, DiLoCoSync(), cfg, 8, k=2)
    b_state, _ = _run(m, params, dcfg, AsyncGossipSync(), cfg, 8, k=2)
    _assert_bitwise(a_state.global_params, b_state.global_params)
    _assert_bitwise(a_state.worker_params, b_state.worker_params)


def test_trailing_partial_round_finalize_parity():
    """A run ending mid-window flushes one trailing round in finalize on
    both the sync and async paths — same final state, same extra sync."""
    cfg, m, params, dcfg = _setup(k=4, h=4)
    a_state, a_hist = _run(m, params, dcfg, GossipSync(), cfg, 10, k=4)
    b_state, b_hist = _run(m, params, dcfg, AsyncGossipSync(), cfg, 10, k=4)
    assert a_hist["sync_steps"] == b_hist["sync_steps"] == [3, 7, 9]
    _assert_bitwise(a_state.global_params, b_state.global_params)
    _assert_bitwise(a_state.worker_params, b_state.worker_params)


def test_int8_wire_sync_matches_async():
    """The codec transport (error feedback included) rides under both
    gossip paths identically."""
    cfg, m, params, dcfg = _setup(k=4, h=4, delta_dtype="int8")
    a_state, _ = _run(m, params, dcfg, GossipSync(), cfg, 8, k=4)
    b_state, _ = _run(m, params, dcfg, AsyncGossipSync(), cfg, 8, k=4)
    _assert_bitwise(a_state.global_params, b_state.global_params)
    _assert_bitwise(a_state.worker_params, b_state.worker_params)


class _RawPairGossip(GossipSync):
    """Bypass the K=2 structural delegation: always bind the pair runner,
    so the pair math itself gets compared against DiLoCo."""

    def bind(self, engine, params, donate=True):
        h = self.h or engine.cfg.h_inner_steps
        return _GossipRunner(engine, params, h, self.topology, self.seed,
                             donate)


def test_raw_pair_math_matches_diloco_k2():
    """The actual pair module at K=2 — pair-averaged anchors, momentum
    and deltas over two identical-anchor rows — computes the DiLoCo mean
    up to FMA-contraction rounding (the structural delegation exists
    because bitwise across separately-compiled modules is a compiler
    lottery, not because the math differs)."""
    cfg, m, params, dcfg = _setup(k=2, h=4)
    a_state, _ = _run(m, params, dcfg, DiLoCoSync(), cfg, 8, k=2)
    b_state, _ = _run(m, params, dcfg, _RawPairGossip(), cfg, 8, k=2)
    _assert_close(a_state.global_params, b_state.global_params, atol=1e-5)
    _assert_close(a_state.worker_params, b_state.worker_params, atol=1e-5)


def test_async_jittered_trains_and_records_staleness():
    """Desynchronized clocks + bounded staleness still train: losses stay
    finite, every due worker leaves a (step, worker, peer, staleness)
    record, and observed staleness is -1 (never-published) or >= 0."""
    cfg, m, params, dcfg = _setup(k=4, h=4)
    strat = AsyncGossipSync(jitter=2, staleness_bound=3, seed=7)
    state, hist = _run(m, params, dcfg, strat, cfg, 13, k=4)
    assert np.isfinite(hist["loss"]).all()
    recs = hist["gossip_syncs"]
    assert recs, "jittered run produced no gossip applies"
    for step, w, p, s in recs:
        assert 0 <= w < 4 and 0 <= p < 4
        assert s == -1 or s >= 0
    # finalize flushed workers whose period does not divide the run length
    assert {w for _, w, _, _ in recs} == set(range(4))


def test_gossip_random_topology_trains():
    cfg, m, params, dcfg = _setup(k=4, h=4)
    state, hist = _run(m, params, dcfg, GossipSync(topology="random", seed=3),
                       cfg, 12, k=4)
    assert np.isfinite(hist["loss"]).all()
    assert hist["sync_steps"] == [3, 7, 11]


# ---------------------------------------------------------------------------
# Topology schedule
# ---------------------------------------------------------------------------

def test_gossip_peers_involution_and_determinism():
    for topology in ("ring", "random"):
        for k in (2, 4, 8):
            for r in range(6):
                peers = gossip_peers(k, r, topology, seed=5)
                assert sorted(peers) == list(range(k))
                assert all(peers[peers[i]] == i for i in range(k))
        # keyed by (seed, round): same args, same matching
        assert (gossip_peers(8, 3, topology, seed=5)
                == gossip_peers(8, 3, topology, seed=5))
    # different rounds actually rotate the ring matching
    assert gossip_peers(4, 0, "ring") != gossip_peers(4, 1, "ring")


def test_gossip_peers_odd_k_self_pairs_one_worker():
    """An odd fleet leaves exactly one worker self-paired (a solo outer
    step) each round."""
    for r in range(4):
        peers = gossip_peers(5, r, "ring", seed=0)
        assert sum(1 for i, p in enumerate(peers) if p == i) == 1
        assert all(peers[peers[i]] == i for i in range(5))


def test_gossip_peers_full_and_unknown():
    assert gossip_peers(8, 0, "full") is None
    with pytest.raises(ValueError, match="topology"):
        gossip_peers(8, 0, "torus")


def test_runners_reject_bad_args():
    cfg, m, params, dcfg = _setup(k=4, h=4)

    def bind(strategy):
        dt = DistTrainer(m.loss, OPT, dcfg, strategy)
        dt.run(dt.init(params), lambda s: _data(cfg, 4, s), 2)

    with pytest.raises(ValueError, match="topology"):
        bind(GossipSync(topology="torus"))
    with pytest.raises(ValueError, match="full"):
        bind(AsyncGossipSync(topology="full"))
    with pytest.raises(ValueError, match="jitter"):
        bind(AsyncGossipSync(jitter=-1))
    with pytest.raises(ValueError, match="staleness_bound"):
        bind(AsyncGossipSync(staleness_bound=-1))


def test_registry_has_gossip_strategies():
    names = strategy_names()
    assert "gossip" in names and "async_gossip" in names


# ---------------------------------------------------------------------------
# Payload accounting + per-pair simulator
# ---------------------------------------------------------------------------

def test_hop_bytes_per_worker_collectives():
    assert hop_bytes_per_worker(100, 8, "gather") == 700
    assert hop_bytes_per_worker(100, 8, "reduce") == 175
    assert hop_bytes_per_worker(100, 8, "peer") == 100
    assert hop_bytes_per_worker(100, 1, "gather") == 100
    assert hop_bytes_per_worker(100, 1, "reduce") == 100
    with pytest.raises(ValueError, match="collective"):
        hop_bytes_per_worker(100, 8, "broadcast")


def test_gossip_payload_flat_in_k_and_carries_outer_state():
    """One publication = codec'd delta + f32 anchors + f32 momentum
    (12n for the f32 codec), flat in fleet size; 'full' ships the
    (K-1)-row gather of codec-only deltas like DiLoCo."""
    n, steps = 1000, 20
    codec = make_codec("float32")
    assert _gossip_payload_bytes(codec, n) == 4 * n + 8 * n
    for k in (2, 8, 64):
        dcfg = DiLoCoConfig(num_workers=k, h_inner_steps=10)
        ev = GossipSync().payload_schedule(n, steps, dcfg)
        assert [e.bytes_per_worker for e in ev] == [12 * n, 12 * n]
        full = GossipSync(topology="full").payload_schedule(n, steps, dcfg)
        assert all(e.bytes_per_worker == (k - 1) * 4 * n for e in full)
    # int8 wire: 1 byte/param + 4-byte scale per leaf row on the delta,
    # anchors/momentum still f32 — strictly between 8n and 12n
    b8 = _gossip_payload_bytes(make_codec("int8"), n)
    assert 8 * n < b8 < 12 * n


def test_gossip_rounds_pair_deps():
    dcfg = DiLoCoConfig(num_workers=4, h_inner_steps=4)
    rounds = GossipSync().gossip_rounds(1000, 12, dcfg)
    assert [r.emit_steps for r in rounds] == [(3,) * 4, (7,) * 4, (11,) * 4]
    for r, rnd in enumerate(rounds):
        peers = gossip_peers(4, r, "ring", 0)
        for w in range(4):
            assert rnd.deps[w] == ((peers[w], rnd.emit_steps[w]),)
    full = GossipSync(topology="full").gossip_rounds(1000, 12, dcfg)
    assert all(len(rnd.deps[w]) == 3 for rnd in full for w in range(4))


def test_async_gossip_rounds_match_runner_schedule():
    """The simulator replay emits exactly when the runner's per-worker
    clocks fire, and a dropped (stale/never-published) contribution has
    no pair dep."""
    dcfg = DiLoCoConfig(num_workers=4, h_inner_steps=4)
    strat = AsyncGossipSync(jitter=2, staleness_bound=1, seed=7)
    rounds = strat.gossip_rounds(1000, 13, dcfg)
    periods = strat._periods(4, 4)
    fired = sorted((s, w) for rnd in rounds
                   for w, s in enumerate(rnd.emit_steps) if s >= 0)
    want = sorted((s, w) for w in range(4) for s in range(13)
                  if (s + 1) % periods[w] == 0)
    assert fired == want
    for rnd in rounds:
        for w in range(4):
            assert len(rnd.deps[w]) <= 1


def test_simulate_gossip_pair_barrier_beats_fleet_barrier():
    """Same emits, same bytes: blocking on ONE peer is never slower than
    blocking on all K-1 — the reason gossip tolerates stragglers."""
    comm = CommModel(bandwidth=1e6, latency=1e-3)
    dcfg = DiLoCoConfig(num_workers=4, h_inner_steps=4)
    ring = GossipSync().gossip_rounds(100_000, 16, dcfg)
    fleet = [dataclasses.replace(
        rnd, deps=tuple(tuple((j, rnd.emit_steps[j]) for j in range(4)
                              if j != w) for w in range(4)))
        for rnd in ring]
    times = [0.01, 0.01, 0.012, 0.02]
    r_pair = simulate_gossip(ring, 16, times, comm)
    r_fleet = simulate_gossip(fleet, 16, times, comm)
    assert r_pair["wall_clock_s"] <= r_fleet["wall_clock_s"]
    assert r_pair["total_bytes"] == r_fleet["total_bytes"]


def test_simulate_gossip_staleness_window_monotone():
    """A larger staleness window can only hide more of the transfer:
    modeled wall clock is non-increasing in staleness_steps."""
    comm = CommModel(bandwidth=1e6, latency=1e-3)
    dcfg = DiLoCoConfig(num_workers=4, h_inner_steps=4)
    rounds = GossipSync().gossip_rounds(500_000, 16, dcfg)
    times = [0.01, 0.01, 0.015, 0.03]
    walls = [simulate_gossip(rounds, 16, times, comm,
                             staleness_steps=s)["wall_clock_s"]
             for s in (0, 1, 2, 4, 8)]
    assert all(a >= b - 1e-12 for a, b in zip(walls, walls[1:]))
    assert walls[0] > walls[-1]  # the window actually bought something
