"""DiLoCo algorithm invariants — the paper's core mechanism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import tiny_cfg
from repro.configs.base import DiLoCoConfig, OptimizerConfig
from repro.core import (AdaptiveH, DDPTrainer, DiLoCoTrainer, drift,
                        run_ddp, run_diloco)
from repro.core.outer_opt import (average_deltas, dequantize_delta,
                                  outer_update, init_outer_state,
                                  quantize_delta)
from repro.models.transformer import build_model, init_params

OPT = OptimizerConfig(total_steps=100, warmup_steps=0, schedule="constant",
                      learning_rate=0.02, adam_lr=1e-3)


def _setup(k=4, h=5):
    cfg = tiny_cfg("dense")
    m = build_model(cfg)
    params, _ = init_params(cfg, jax.random.key(0))
    dcfg = DiLoCoConfig(num_workers=k, h_inner_steps=h)
    tr = DiLoCoTrainer(m.loss, OPT, dcfg)
    return cfg, m, params, tr


def _worker_data(cfg, k, step, B=4, S=16):
    key = jax.random.key(1000 + step)
    toks = jax.random.randint(key, (k, B, S), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": (toks + 1) % cfg.vocab_size}


def test_workers_stay_synced_with_identical_data():
    """Same data on every worker -> workers remain bit-identical."""
    cfg, m, params, tr = _setup(k=3)
    state = tr.init(params)
    one = _worker_data(cfg, 1, 0)
    same = jax.tree.map(lambda x: jnp.broadcast_to(x, (3,) + x.shape[1:]), one)
    inner, outer = tr.jit_steps()
    for _ in range(3):
        state, loss, _ = inner(state, same)
    wp = state.worker_params
    for leaf in jax.tree.leaves(wp):
        assert bool(jnp.all(leaf[0] == leaf[1])) and bool(
            jnp.all(leaf[1] == leaf[2]))


def test_workers_diverge_with_different_data_and_resync():
    cfg, m, params, tr = _setup(k=3)
    state = tr.init(params)
    inner, outer = tr.jit_steps()
    for step in range(3):
        state, _, _ = inner(state, _worker_data(cfg, 3, step))
    # divergence
    leaf = jax.tree.leaves(state.worker_params)[2]
    assert float(jnp.max(jnp.abs(leaf[0] - leaf[1]))) > 0
    # outer sync re-broadcasts
    state = outer(state)
    for leaf in jax.tree.leaves(state.worker_params):
        assert bool(jnp.all(leaf[0] == leaf[1]))
    for g, w in zip(jax.tree.leaves(state.global_params),
                    jax.tree.leaves(state.worker_params)):
        assert bool(jnp.all(g == w[0]))


def test_outer_update_math():
    """theta' = theta + eta*(mu*v' + delta_avg) with v' = mu*v + delta_avg
    (Nesterov); checked against a hand-rolled numpy implementation."""
    cfg = DiLoCoConfig(num_workers=2, outer_lr=0.8, outer_momentum=0.9)
    params = {"w": jnp.asarray([[1.0, 2.0]])}
    state = init_outer_state(params)
    stacked = {"w": jnp.asarray([[[0.1, 0.2]], [[0.3, 0.4]]])}  # deltas (K=2)
    avg = average_deltas(stacked, cfg)
    new, st = outer_update(params, avg, state, cfg)
    d = np.array([0.2, 0.3])
    v = 0.9 * 0.0 + d
    expect = np.array([1.0, 2.0]) + 0.8 * (d + 0.9 * v)
    np.testing.assert_allclose(np.asarray(new["w"][0]), expect, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(st.v["w"][0]), v, rtol=1e-6)


def test_diloco_h1_eta1_mu0_equals_delta_averaging():
    """H=1, eta=1, mu=0 reduces the outer step to plain parameter-delta
    averaging: theta_{t+1} = mean_i theta_i."""
    cfg, m, params, _ = _setup()
    dcfg = DiLoCoConfig(num_workers=2, h_inner_steps=1, outer_lr=1.0,
                        outer_momentum=0.0, nesterov=False)
    tr = DiLoCoTrainer(m.loss, OPT, dcfg)
    state = tr.init(params)
    batches = _worker_data(cfg, 2, 0)
    inner, outer = tr.jit_steps()
    state, _, _ = inner(state, batches)
    manual_mean = jax.tree.map(lambda w: jnp.mean(w, axis=0),
                               state.worker_params)
    state = outer(state)
    for a, b in zip(jax.tree.leaves(manual_mean),
                    jax.tree.leaves(state.global_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_delta_quantization_roundtrip():
    delta = {"w": jax.random.normal(jax.random.key(0), (2, 8, 8)) * 0.01}
    for dt, tol in [("float32", 0.0), ("bfloat16", 1e-4), ("int8", 2e-4)]:
        payload, scales = quantize_delta(delta, dt)
        back = dequantize_delta(payload, scales)
        err = float(jnp.max(jnp.abs(back["w"] - delta["w"])))
        assert err <= tol, (dt, err)
    payload, scales = quantize_delta(delta, "int8")
    assert payload["w"].dtype == jnp.int8


def test_drift_aware_weights_sum_preserved():
    """Drift-aware averaging is a convex combination: with identical deltas
    it must equal the plain mean."""
    delta = {"w": jnp.ones((4, 3, 3)) * 0.5}
    plain = average_deltas(delta, DiLoCoConfig(num_workers=4))
    da = average_deltas(delta, DiLoCoConfig(num_workers=4, drift_aware=True))
    np.testing.assert_allclose(np.asarray(plain["w"]), np.asarray(da["w"]),
                               rtol=1e-6)


def test_comm_accounting_h_ratio():
    cfg, m, params, tr = _setup()
    assert tr.bytes_per_sync(params) == tr.ddp_bytes_per_step(params)
    tr8 = DiLoCoTrainer(m.loss, OPT,
                        DiLoCoConfig(num_workers=4, delta_dtype="int8"))
    assert tr8.bytes_per_sync(params) * 4 == tr.ddp_bytes_per_step(params)


def test_run_diloco_converges_and_syncs():
    cfg, m, params, tr = _setup(k=2, h=4)
    state = tr.init(params)
    state, hist = run_diloco(tr, state, lambda s: _worker_data(cfg, 2, s), 12)
    assert len(hist["sync_steps"]) == 3
    assert hist["loss"][-1] < hist["loss"][0]


@pytest.mark.slow
def test_hybrid_handoff_ddp_continues():
    """DiLoCo-pretrained global params must be a valid DDP starting point
    (the paper's Hybrid configuration)."""
    cfg, m, params, tr = _setup(k=2, h=3)
    state = tr.init(params)
    state, _ = run_diloco(tr, state, lambda s: _worker_data(cfg, 2, s), 6)
    ddp = DDPTrainer(m.loss, OPT)
    dstate = ddp.init(state.global_params)
    merged = lambda s: jax.tree.map(
        lambda x: x.reshape((-1,) + x.shape[2:]), _worker_data(cfg, 2, s))
    dstate, hist = run_ddp(ddp, dstate, merged, 6)
    assert np.isfinite(hist["loss"]).all()


def test_adaptive_h_grows_when_flat_shrinks_when_steep():
    hs = AdaptiveH(h0=20, h_min=5, h_max=100, window=8, hi=5e-3, lo=5e-4)
    for i in range(60):
        hs.should_sync(i, i % 50, 1.0)        # perfectly flat loss
    assert hs.current_h > 20
    hs2 = AdaptiveH(h0=20, h_min=5, h_max=100, window=8, hi=5e-3, lo=5e-4)
    for i in range(60):
        hs2.should_sync(i, 50, 5.0 - 0.1 * i)  # steep descent
    assert hs2.current_h < 20


def test_drift_metrics():
    cfg, m, params, tr = _setup(k=3)
    state = tr.init(params)
    inner, _ = tr.jit_steps()
    for step in range(3):
        state, _, _ = inner(state, _worker_data(cfg, 3, step))
    d = drift.param_drift(state.worker_params, state.global_params)
    assert float(d["delta_norm_mean"]) > 0
    assert -1.0 <= float(d["pairwise_cos"]) <= 1.0
    X = jax.random.normal(jax.random.key(0), (32, 8))
    assert abs(float(drift.linear_cka(X, X)) - 1.0) < 1e-5
    assert float(drift.linear_cka(
        X, jax.random.normal(jax.random.key(1), (32, 8)))) < 0.9
