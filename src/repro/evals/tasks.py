"""Task evaluations mirroring the paper's Table 1 columns.

| paper        | ours                                     | metric          |
|--------------|------------------------------------------|-----------------|
| MMLU / ARC   | fact multiple-choice (``mc_accuracy``)    | option-logprob  |
| GSM8K        | arithmetic completion (``arith_exact``)   | exact match     |
| HumanEval    | pattern continuation (``pattern_exact``)  | exact match     |
| ChatCORE     | mean of the three chat-format tasks       | composite       |
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.data.tokenizer import BPETokenizer
from repro.serving.engine import Engine


def mc_accuracy(engine: Engine, tok: BPETokenizer, items: List[dict]) -> float:
    rows, spans = [], []
    for it in items:
        prompt = tok.encode(it["prompt"])
        start = len(rows)
        rows.extend((prompt, tok.encode(o + " ")) for o in it["options"])
        spans.append((start, len(rows), it["answer"]))
    scores = engine.score_continuations_batch(rows)   # one jitted forward
    correct = sum(int(int(np.argmax(scores[a:b])) == ans)
                  for a, b, ans in spans)
    return correct / max(len(items), 1)


def _gen_exact(engine: Engine, tok: BPETokenizer, items: List[dict],
               max_new: int = 12) -> float:
    """Generative exact-match evals go through the same scheduler path that
    serves traffic: one request per item, with EOS-based early eviction so
    finished items free their slots for queued ones (``Engine.generate``
    falls back to static buckets for ssm/hybrid archs)."""
    prompts = [tok.encode(it["prompt"]) for it in items]
    rows = engine.generate(prompts, max_new=max_new, greedy=True,
                           eos_id=tok.special_id("<|assistant_end|>"))
    correct = 0
    for row, it in zip(rows, items):
        text = tok.decode(list(row))
        if text.strip().startswith(it["answer"]):
            correct += 1
    return correct / max(len(items), 1)


def arith_exact(engine: Engine, tok: BPETokenizer, items: List[dict]) -> float:
    return _gen_exact(engine, tok, items, max_new=8)


def pattern_exact(engine: Engine, tok: BPETokenizer, items: List[dict]) -> float:
    return _gen_exact(engine, tok, items, max_new=8)


def chat_suite(engine: Engine, tok: BPETokenizer, suites: Dict[str, List[dict]]
               ) -> Dict[str, float]:
    """Run the full Table-1 analogue.  suites keys: mc / mc_heldout / arith /
    pattern (any subset)."""
    out: Dict[str, float] = {}
    if "mc" in suites:
        out["mc"] = mc_accuracy(engine, tok, suites["mc"])
    if "mc_heldout" in suites:
        out["mc_heldout"] = mc_accuracy(engine, tok, suites["mc_heldout"])
    if "arith" in suites:
        out["arith"] = arith_exact(engine, tok, suites["arith"])
    if "pattern" in suites:
        out["pattern"] = pattern_exact(engine, tok, suites["pattern"])
    core_keys = [k for k in ("mc", "arith", "pattern") if k in out]
    if core_keys:
        out["chatcore"] = float(np.mean([out[k] for k in core_keys]))
    return out
