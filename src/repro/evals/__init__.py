from repro.evals.core_eval import heldout_metrics
from repro.evals.tasks import (arith_exact, chat_suite, mc_accuracy,
                               pattern_exact)

__all__ = ["heldout_metrics", "mc_accuracy", "arith_exact", "pattern_exact",
           "chat_suite"]
