"""CORE-proxy evaluation: held-out loss / bits-per-token on the pretrain
distribution.

nanochat's CORE metric is a normalized composite over 22 public benchmarks —
not reproducible offline.  Our proxy keeps the role it plays in the paper
(base-stage quality signal, higher = better) as ``core = exp(-heldout_ce)``,
the per-token prediction probability, plus raw CE and bits-per-token.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.data.pipeline import PackedDataset
from repro.models.transformer import ModelAPI


def heldout_metrics(model: ModelAPI = None, params=None,
                    ds: PackedDataset = None, batches: int = 8,
                    batch_size: int = 16, seed: int = 4242,
                    engine=None) -> Dict[str, float]:
    """Pass ``engine`` (the serving ``Engine`` used for the generative task
    evals) to score the exact params being served — eval and serving then
    share one model/params stack instead of drifting apart."""
    if engine is not None:
        model, params = engine.model, engine.params
    if model is None or params is None:
        raise TypeError(
            "heldout_metrics: pass model+params or engine=")
    if ds is None:
        raise TypeError("heldout_metrics: ds is required")
    loss_fn = jax.jit(model.loss)
    tot, n = 0.0, 0
    for i in range(batches):
        b = ds.batch(10_000_000 + i, batch_size, seed=seed)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        loss, _ = loss_fn(params, b)
        tot += float(loss)
        n += 1
    ce = tot / max(n, 1)
    return {"heldout_ce": ce,
            "bits_per_token": ce / math.log(2),
            "core_proxy": math.exp(-ce)}
