"""internvl2-26b  [arXiv:2404.16821]
VLM, 48L internlm2-20b language backbone: d_model=6144, 48 heads (GQA kv=8),
d_ff=16384, vocab=92553.  The InternViT-6B vision tower + MLP projector are
STUBBED: input_specs provides 256 projected patch embeddings per image at
d_model, prepended to the text sequence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    arch_type="vlm",
    source="arXiv:2404.16821 (InternVL2-26B, InternLM2-20B backbone)",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    num_image_tokens=256,
    mlp_activation="swiglu",
    rope_theta=1000000.0,
    tie_embeddings=False,
)
