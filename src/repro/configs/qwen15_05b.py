"""qwen1.5-0.5b  [hf:Qwen/Qwen1.5-0.5B]
dense, 24L, d_model=1024, 16 heads (MHA: kv=16), d_ff=2816, vocab=151936,
QKV bias, tied embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    arch_type="dense",
    source="hf:Qwen/Qwen1.5-0.5B",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    mlp_activation="swiglu",
    rope_theta=1000000.0,
    tie_embeddings=True,
)
