"""Configuration schema for the repro framework.

A ``ModelConfig`` fully describes one architecture (the paper's nanochat d20
model or one of the ten assigned architectures).  A ``ShapeConfig`` describes
one input shape (train_4k / prefill_32k / decode_32k / long_500k).  A
``DiLoCoConfig`` describes the paper's algorithm hyper-parameters, and
``TrainConfig`` bundles everything a launcher needs.

Everything is a frozen dataclass so configs hash and can be closed over by
jitted functions safely.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class ModelConfig:
    # identity ------------------------------------------------------------
    name: str = "model"
    arch_type: str = "dense"        # dense | moe | ssm | hybrid | audio | vlm
    source: str = ""                 # citation for the config values

    # trunk ----------------------------------------------------------------
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0                # 0 -> d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 512

    # attention ------------------------------------------------------------
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    # sliding window: 0 = full attention.  ``window_pattern`` gives a cycle of
    # per-layer windows (0 entries = global); empty -> uniform ``window``.
    window: int = 0
    window_pattern: Tuple[int, ...] = ()
    logit_soft_cap: float = 0.0

    # mlp -------------------------------------------------------------------
    mlp_activation: str = "swiglu"   # swiglu | relu2 | gelu
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = True

    # moe --------------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # ssm (mamba-2 / SSD) -----------------------------------------------------
    ssm_state_size: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2              # d_inner = expand * d_model
    ssm_conv_width: int = 4
    ssm_chunk: int = 128

    # hybrid (hymba): parallel attention + SSM heads in every layer ----------
    hybrid: bool = False

    # encoder-decoder (seamless-m4t) ------------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq_len: int = 1024      # stubbed frontend: #frame embeddings

    # vlm (internvl2): patch embeddings prepended to the text sequence -------
    num_image_tokens: int = 0        # 0 -> pure text

    # vocab padding: embeddings/logits are padded to a multiple so the vocab
    # dim shards cleanly over the tensor-parallel axis (labels never hit the
    # pad ids; softmax learns to push them down).  1 = no padding (tests).
    vocab_pad_multiple: int = 1

    # numerics ----------------------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "float32"   # dry-run overrides to bfloat16
    kv_cache_dtype: str = ""         # "" = compute dtype; bf16 = narrow cast;
                                     # int8 | fp8 | fp8_e5m2 = quantized paged
                                     # pool with per-token-per-head scales
    fp8_matmul: bool = False         # fp8 per-tile QK^T matmuls in the
                                     # attention kernels (TPU; CPU/interpret
                                     # falls back to the full-precision path)
    remat: bool = True
    use_scan: bool = True
    use_pallas: bool = False         # reference jnp path by default (CPU)
    z_loss: float = 0.0
    loss_chunk: int = 0              # >0: chunked CE (never materializes the
                                     # full (B,S,V) logits) — see §Perf

    # -------------------------------------------------------------------------
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    def padded_vocab(self) -> int:
        m = max(self.vocab_pad_multiple, 1)
        return ((self.vocab_size + m - 1) // m) * m

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # parameter count estimate (for roofline MODEL_FLOPS = 6*N*D) -------------
    def param_count(self, active_only: bool = False) -> int:
        D = self.d_model
        hd = self.resolved_head_dim()
        n_q = self.num_heads * hd
        n_kv = self.num_kv_heads * hd
        attn = D * n_q + 2 * D * n_kv + n_q * D
        if self.qkv_bias:
            attn += n_q + 2 * n_kv
        if self.mlp_activation == "swiglu":
            mlp_dense = 3 * D * self.d_ff
        else:
            mlp_dense = 2 * D * self.d_ff
        if self.num_experts:
            e = self.num_experts_per_tok if active_only else self.num_experts
            e += self.num_shared_experts
            mlp = e * mlp_dense + D * self.num_experts   # + router
        else:
            mlp = mlp_dense
        ssm = 0
        if self.ssm_state_size:
            d_in = self.ssm_expand * D if not self.hybrid else n_q
            nh = d_in // self.ssm_head_dim
            # in_proj (z,x,B,C,dt) + conv + out_proj + A,D,dt_bias + gated norm
            conv_dim = d_in + 2 * self.ssm_state_size
            ssm = (D * (2 * d_in + 2 * self.ssm_state_size + nh)
                   + conv_dim * self.ssm_conv_width + d_in * D + 3 * nh + d_in)
        per_layer = 2 * D  # norms
        if self.hybrid:
            per_layer += attn + mlp + ssm
        elif self.ssm_state_size and self.arch_type == "ssm":
            per_layer = 2 * D + ssm  # attention-free; d_ff==0
        else:
            per_layer += attn + mlp
        total = self.num_layers * per_layer
        if self.is_encoder_decoder:
            # encoder layers (self-attn + mlp) + decoder cross-attn
            enc = self.num_encoder_layers * (attn + mlp_dense + 2 * D)
            cross = self.num_layers * (attn + D)
            total += enc + cross
        emb = self.vocab_size * D
        total += emb if self.tie_embeddings else 2 * emb
        total += D  # final norm
        return int(total)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"
    # decode shapes attend one fresh token against a seq_len KV cache
    sub_quadratic_required: bool = False


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode", sub_quadratic_required=True)

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclass(frozen=True)
class DiLoCoConfig:
    """Hyper-parameters from the paper (§3)."""
    num_workers: int = 8
    h_inner_steps: int = 100          # H=100 base pretraining
    h_mid_sft: int = 30               # H=30 mid-training / SFT
    outer_lr: float = 0.8             # eta_outer
    outer_momentum: float = 0.9       # mu_outer (Nesterov)
    nesterov: bool = True
    # --- beyond-paper knobs ------------------------------------------------
    delta_dtype: str = "float32"      # float32 | bfloat16 | int8 | fp8 |
                                      # fp8_e5m2: the outer sync's wire
                                      # codec (core.transport)
    error_feedback: bool = True       # lossy codecs carry a per-worker
                                      # residual so quantization noise
                                      # cannot bias the outer optimizer
    grad_compress: str = "none"       # none | int8 | fp8 | fp8_e5m2: DDP-side
                                      # per-step update compression — routes
                                      # the everystep exchange through the
                                      # same codec stack (ddp_compressed)
    drift_aware: bool = False         # drift-weighted averaging (paper §5 future work)
    adaptive_h: bool = False          # adaptive H schedule (paper §5 future work)
    h_min: int = 10
    h_max: int = 200
    # --- sync-strategy runtime (repro.core.sync / DistTrainer) -------------
    strategy: str = "diloco"          # ddp | diloco | streaming | overlapped
                                      # | pipelined (DiLoCoX-style fragments)
                                      # | gossip | async_gossip (NoLoCo-style
                                      # peer averaging, no all-reduce)
    num_fragments: int = 4            # streaming/pipelined: F fragments
    sync_delay: int = 0               # overlapped/pipelined: steps between
                                      # delta capture and outer application
    h_jitter: int = 0                 # overlapped: max per-worker straggler
                                      # jitter (inner steps) on delta capture;
                                      # async_gossip: max per-worker period
                                      # jitter (worker i syncs every H+j_i)
    sync_seed: int = 0                # seeds the per-worker straggler jitter
                                      # draws and the gossip topology schedule
                                      # (reproducible runs)
    topology: str = "ring"            # gossip peer schedule: ring | random
                                      # matching | full (= the DiLoCo mean)
    staleness_bound: int = 0          # async_gossip: drop peer contributions
                                      # staler than this many inner steps
                                      # (0 = synchronous apply)


@dataclass(frozen=True)
class OptimizerConfig:
    """nanochat's optimizer split: Muon for matrices, AdamW for the rest."""
    learning_rate: float = 0.02       # muon lr
    adam_lr: float = 3e-4
    weight_decay: float = 0.0
    adam_betas: Tuple[float, float] = (0.9, 0.95)
    adam_eps: float = 1e-10
    muon_momentum: float = 0.95
    muon_ns_steps: int = 5
    grad_clip: float = 1.0
    fused_adamw: bool = False         # fused Pallas AdamW update kernel
                                      # (repro.kernels.fused_adamw): same
                                      # update math, ulp-level agreement
    warmup_steps: int = 32
    schedule: str = "wsd"             # wsd | cosine | constant
    total_steps: int = 1000
    final_lr_frac: float = 0.0


@dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig = field(default_factory=ModelConfig)
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    diloco: DiLoCoConfig = field(default_factory=DiLoCoConfig)
    shape: ShapeConfig = TRAIN_4K
    method: str = "diloco"            # diloco | ddp
    seed: int = 0
