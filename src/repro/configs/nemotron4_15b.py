"""nemotron-4-15b  [arXiv:2402.16819]
dense, 32L, d_model=6144, 48 heads (GQA kv=8), d_ff=24576, vocab=256000,
squared-ReLU MLP (no gating)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    arch_type="dense",
    source="arXiv:2402.16819 (Nemotron-4 15B)",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256000,
    mlp_activation="relu2",
    rope_theta=10000.0,
    tie_embeddings=False,
)
