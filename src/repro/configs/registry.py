"""Architecture registry: ``--arch <id>`` ids -> ModelConfig, reduced smoke
variants, and ShapeDtypeStruct input specs per (arch × input shape).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

_MODULES: Dict[str, str] = {
    "nanochat-d20": "repro.configs.nanochat_d20",
    "qwen1.5-0.5b": "repro.configs.qwen15_05b",
    "mamba2-1.3b": "repro.configs.mamba2_13b",
    "command-r-plus-104b": "repro.configs.command_r_plus_104b",
    "nemotron-4-15b": "repro.configs.nemotron4_15b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "internvl2-26b": "repro.configs.internvl2_26b",
    "hymba-1.5b": "repro.configs.hymba_15b",
    "mistral-large-123b": "repro.configs.mistral_large_123b",
}

ARCH_IDS = [k for k in _MODULES if k != "nanochat-d20"]   # the 10 assigned
ALL_IDS = list(_MODULES)

# Sliding window applied when a full-attention arch runs long_500k decode
# (framework-provided sub-quadratic variant; see DESIGN.md §4).
LONG_CONTEXT_WINDOW = 8192


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(_MODULES[arch_id])
    return mod.CONFIG


def _scale_heads(n: int, target: int) -> int:
    return max(1, min(n, target))


def get_reduced(arch_id: str) -> ModelConfig:
    """Reduced variant of the same family: 2 layers, d_model<=256, <=4
    experts — runs a CPU forward/train step in the smoke tests."""
    c = get_config(arch_id)
    hd = 32
    heads = min(c.num_heads, 4)
    kv = max(1, min(c.num_kv_heads, heads))
    if c.num_heads % c.num_kv_heads == 0:
        kv = max(1, heads // max(1, c.num_heads // c.num_kv_heads))
    d_model = heads * hd * 2          # keep d_model != heads*hd to catch bugs
    red = dataclasses.replace(
        c,
        num_layers=2,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=hd,
        d_ff=0 if c.d_ff == 0 else 4 * d_model,
        vocab_size=512,
        num_experts=min(c.num_experts, 4),
        num_experts_per_tok=min(c.num_experts_per_tok, 2),
        ssm_state_size=min(c.ssm_state_size, 32),
        ssm_head_dim=16 if c.ssm_state_size else c.ssm_head_dim,
        ssm_chunk=32,
        num_encoder_layers=2 if c.is_encoder_decoder else 0,
        encoder_seq_len=32 if c.is_encoder_decoder else c.encoder_seq_len,
        num_image_tokens=16 if c.num_image_tokens else 0,
        window=min(c.window, 32) if c.window else 0,
        window_pattern=tuple(min(w, 32) if w else 0
                             for w in c.window_pattern[:2]) if c.window_pattern else (),
        rope_theta=10000.0,
    )
    return red


def long_context_variant(cfg: ModelConfig) -> ModelConfig:
    """Sub-quadratic decode variant for long_500k: SSM/hybrid unchanged;
    attention archs get a sliding window (ring-buffer KV cache)."""
    if cfg.arch_type == "ssm":
        return cfg
    if cfg.window and cfg.window <= LONG_CONTEXT_WINDOW:
        return cfg
    if cfg.window_pattern:
        pat = tuple(w if w else LONG_CONTEXT_WINDOW for w in cfg.window_pattern)
        return cfg.with_(window_pattern=pat)
    return cfg.with_(window=LONG_CONTEXT_WINDOW, window_pattern=())


def decode_cache_capacity(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """KV capacity the serve_step sees: full seq_len, or the SWA window for
    ring-buffer decode on long contexts."""
    if shape.sub_quadratic_required and cfg.arch_type != "ssm":
        ws = [cfg.window] if cfg.window else []
        if cfg.window_pattern:
            ws = [w if w else LONG_CONTEXT_WINDOW for w in cfg.window_pattern]
        w = max(ws) if ws else LONG_CONTEXT_WINDOW
        return min(shape.seq_len, max(w, 128))
    return shape.seq_len


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                batch_override: Optional[int] = None) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input — shardable,
    weak-type-correct, no device allocation."""
    B = batch_override or shape.global_batch
    S = shape.seq_len
    i32 = jnp.int32

    if shape.kind in ("train", "prefill"):
        s_text = S
        specs: Dict[str, jax.ShapeDtypeStruct] = {}
        if cfg.num_image_tokens:
            s_text = S - cfg.num_image_tokens
            specs["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16
                if cfg.compute_dtype == "bfloat16" else jnp.float32)
        if cfg.is_encoder_decoder:
            specs["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16
                if cfg.compute_dtype == "bfloat16" else jnp.float32)
        specs["tokens"] = jax.ShapeDtypeStruct((B, s_text), i32)
        specs["labels"] = jax.ShapeDtypeStruct((B, s_text), i32)
        return specs

    # decode: one new token against a seq_len-context cache
    return {"token": jax.ShapeDtypeStruct((B, 1), i32),
            "position": jax.ShapeDtypeStruct((B,), i32)}


def shape_by_name(name: str) -> ShapeConfig:
    return SHAPES[name]
