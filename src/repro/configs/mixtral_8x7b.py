"""mixtral-8x7b  [arXiv:2401.04088]
MoE, 32L, d_model=4096, 32 heads (GQA kv=8), d_ff=14336, vocab=32000,
8 experts top-2, sliding-window attention (W=4096)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    arch_type="moe",
    source="arXiv:2401.04088 (Mixtral of Experts)",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    num_experts=8,
    num_experts_per_tok=2,
    window=4096,
    mlp_activation="swiglu",
    rope_theta=1000000.0,
    tie_embeddings=False,
)
