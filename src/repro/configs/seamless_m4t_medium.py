"""seamless-m4t-medium  [arXiv:2308.11596]
audio encoder-decoder, 12L (12 enc + 12 dec), d_model=1024, 16 heads (kv=16),
d_ff=4096, vocab=256206, LayerNorm.  The speech frontend (mel + conformer
conv) is STUBBED: input_specs provides precomputed frame embeddings
(B, 1024, d_model); the transformer backbone here consumes them.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    arch_type="audio",
    source="arXiv:2308.11596 (SeamlessM4T medium)",
    num_layers=12,
    num_encoder_layers=12,
    is_encoder_decoder=True,
    encoder_seq_len=1024,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    mlp_activation="gelu",
    norm="layernorm",
    tie_embeddings=True,
)
