"""mistral-large-123b  [hf:mistralai/Mistral-Large-Instruct-2407]
dense, 88L, d_model=12288, 96 heads (GQA kv=8), d_ff=28672, vocab=32768."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    arch_type="dense",
    source="hf:mistralai/Mistral-Large-Instruct-2407",
    num_layers=88,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=32768,
    mlp_activation="swiglu",
    rope_theta=1000000.0,
    tie_embeddings=False,
)
