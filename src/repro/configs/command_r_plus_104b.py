"""command-r-plus-104b  [hf:CohereForAI/c4ai-command-r-v01 family]
dense, 64L, d_model=12288, 96 heads (GQA kv=8), d_ff=33792, vocab=256000,
no biases, tied embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    arch_type="dense",
    source="hf:CohereForAI/c4ai-command-r-plus",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab_size=256000,
    qkv_bias=False,
    mlp_activation="swiglu",
    rope_theta=75000000.0,
    tie_embeddings=True,
)
