"""hymba-1.5b  [arXiv:2411.13676]
hybrid-head: every layer runs attention heads and SSM (mamba) heads in
PARALLEL on the same input, outputs normalized and mixed.  32L,
d_model=1600, 25 heads of 64 (GQA kv=5), d_ff=5504, vocab=32001,
ssm_state=16.  Sliding window (1024) everywhere except 3 global-attention
layers (first / middle / last).  Meta-tokens are simplified away (DESIGN.md).
"""
from repro.configs.base import ModelConfig

_L = 32
_WINDOWS = tuple(0 if i in (0, _L // 2, _L - 1) else 1024 for i in range(_L))

CONFIG = ModelConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    source="arXiv:2411.13676 (Hymba-1.5B)",
    num_layers=_L,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    hybrid=True,
    ssm_state_size=16,
    ssm_head_dim=64,
    ssm_conv_width=4,
    ssm_chunk=128,
    window_pattern=_WINDOWS,
    mlp_activation="swiglu",
    tie_embeddings=True,
)
