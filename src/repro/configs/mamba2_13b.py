"""mamba2-1.3b  [arXiv:2405.21060]
SSM (attention-free), 48L, d_model=2048, SSD state=128, head_dim=64,
expand=2 (d_inner=4096, 64 SSD heads), vocab=50280."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    arch_type="ssm",
    source="arXiv:2405.21060 (Mamba-2 1.3B)",
    num_layers=48,
    d_model=2048,
    num_heads=32,          # unused by SSM blocks; kept for API uniformity
    num_kv_heads=32,
    d_ff=0,                # attention-free, no MLP (per assignment spec)
    vocab_size=50280,
    ssm_state_size=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=128,
    tie_embeddings=True,
)
