"""llama4-scout-17b-a16e  [hf:meta-llama/Llama-4-Scout-17B-16E]
MoE, 48L, d_model=5120, 40 heads (GQA kv=8), d_ff=8192 (per expert),
vocab=202048, 16 experts top-1 + 1 shared expert, chunked local attention
(iRoPE: 3 local : 1 global) modeled as window_pattern (8192,8192,8192,0).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    arch_type="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    num_experts=16,
    num_experts_per_tok=1,
    num_shared_experts=1,
    window_pattern=(8192, 8192, 8192, 0),
    mlp_activation="swiglu",
    rope_theta=500000.0,
    tie_embeddings=False,
)
