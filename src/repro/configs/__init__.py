from repro.configs.base import (DECODE_32K, LONG_500K, PREFILL_32K, SHAPES,
                                TRAIN_4K, DiLoCoConfig, ModelConfig,
                                OptimizerConfig, ShapeConfig, TrainConfig)
from repro.configs.registry import (ALL_IDS, ARCH_IDS, decode_cache_capacity,
                                    get_config, get_reduced, input_specs,
                                    long_context_variant, shape_by_name)

__all__ = ["ModelConfig", "ShapeConfig", "DiLoCoConfig", "OptimizerConfig",
           "TrainConfig", "SHAPES", "TRAIN_4K", "PREFILL_32K", "DECODE_32K",
           "LONG_500K", "ARCH_IDS", "ALL_IDS", "get_config", "get_reduced",
           "input_specs", "long_context_variant", "decode_cache_capacity",
           "shape_by_name"]
