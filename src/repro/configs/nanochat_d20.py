"""nanochat d20 — the paper's own reference model (~550M params, 20 layers).

[github.com/karpathy/nanochat — depth-20 config: d_model = 64*depth = 1280,
 10 heads of 128, MLP 4x, vocab 2^16, rotary, untied embeddings]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nanochat-d20",
    arch_type="dense",
    source="github:karpathy/nanochat (d20 speedrun config)",
    num_layers=20,
    d_model=1280,
    num_heads=10,
    num_kv_heads=10,
    head_dim=128,
    d_ff=5120,
    vocab_size=65536,
    mlp_activation="swiglu",
    rope_theta=10000.0,
    tie_embeddings=False,
)
