"""Byte-pair-encoding tokenizer, trained from scratch — nanochat ships a Rust
BPE; this is the same algorithm in pure Python/numpy (our corpora are small).

Byte-level: the base alphabet is the 256 byte values; merges are learned
greedily by pair frequency.  Special tokens follow nanochat's chat schema
(<|bos|>, <|user_start|> … <|assistant_end|>) so the mid-training/SFT stages
can format dialogues exactly like the paper's pipeline.
"""
from __future__ import annotations

import json
from collections import Counter
from typing import Dict, Iterable, List, Optional, Tuple

SPECIAL_TOKENS = [
    "<|bos|>", "<|user_start|>", "<|user_end|>",
    "<|assistant_start|>", "<|assistant_end|>", "<|pad|>",
]


class BPETokenizer:
    def __init__(self, merges: List[Tuple[int, int]],
                 special_tokens: Optional[List[str]] = None):
        self.merges = merges
        self.special = special_tokens or list(SPECIAL_TOKENS)
        self._rank: Dict[Tuple[int, int], int] = {
            tuple(m): i for i, m in enumerate(merges)}
        self._special_base = 256 + len(merges)
        self._special_ids = {s: self._special_base + i
                             for i, s in enumerate(self.special)}

    # -- vocab ----------------------------------------------------------------
    @property
    def vocab_size(self) -> int:
        return 256 + len(self.merges) + len(self.special)

    def special_id(self, tok: str) -> int:
        return self._special_ids[tok]

    @property
    def bos(self) -> int:
        return self._special_ids["<|bos|>"]

    @property
    def pad(self) -> int:
        return self._special_ids["<|pad|>"]

    # -- train ------------------------------------------------------------------
    @classmethod
    def train(cls, texts: Iterable[str], vocab_size: int,
              special_tokens: Optional[List[str]] = None) -> "BPETokenizer":
        special = special_tokens or list(SPECIAL_TOKENS)
        n_merges = vocab_size - 256 - len(special)
        assert n_merges >= 0, "vocab_size too small"
        # work on word chunks (whitespace-split) to keep pair counting cheap
        words = Counter()
        for t in texts:
            for w in t.split(" "):
                words[tuple((w + " ").encode("utf-8"))] += 1
        merges: List[Tuple[int, int]] = []
        seqs = {w: list(w) for w in words}
        for merge_i in range(n_merges):
            pairs: Counter = Counter()
            for w, cnt in words.items():
                s = seqs[w]
                for a, b in zip(s, s[1:]):
                    pairs[(a, b)] += cnt
            if not pairs:
                break
            (a, b), freq = pairs.most_common(1)[0]
            if freq < 2:
                break
            new_id = 256 + merge_i
            merges.append((a, b))
            for w in words:
                s = seqs[w]
                out, i = [], 0
                while i < len(s):
                    if i + 1 < len(s) and s[i] == a and s[i + 1] == b:
                        out.append(new_id)
                        i += 2
                    else:
                        out.append(s[i])
                        i += 1
                seqs[w] = out
        return cls(merges, special)

    # -- encode/decode ------------------------------------------------------------
    def _encode_chunk(self, data: bytes) -> List[int]:
        s = list(data)
        while len(s) >= 2:
            best, best_rank = None, None
            for i, pair in enumerate(zip(s, s[1:])):
                r = self._rank.get(pair)
                if r is not None and (best_rank is None or r < best_rank):
                    best, best_rank = i, r
            if best is None:
                break
            new_id = 256 + best_rank
            s = s[:best] + [new_id] + s[best + 2:]
        return s

    def encode(self, text: str, add_bos: bool = False) -> List[int]:
        ids: List[int] = [self.bos] if add_bos else []
        # split out special tokens first
        rest = [text]
        for sp in self.special:
            nxt = []
            for part in rest:
                if isinstance(part, int):
                    nxt.append(part)
                    continue
                pieces = part.split(sp)
                for j, piece in enumerate(pieces):
                    if j:
                        nxt.append(self._special_ids[sp])
                    if piece:
                        nxt.append(piece)
            rest = nxt
        for part in rest:
            if isinstance(part, int):
                ids.append(part)
            else:
                for w in part.split(" "):
                    ids.extend(self._encode_chunk((w + " ").encode("utf-8")))
        return ids

    def decode(self, ids: List[int]) -> str:
        # expand merges
        table: List[bytes] = [bytes([i]) for i in range(256)]
        for a, b in self.merges:
            table.append(table[a] + table[b])
        out = b""
        for i in ids:
            if i >= self._special_base:
                out += self.special[i - self._special_base].encode("utf-8")
            elif i < len(table):
                out += table[i]
        return out.decode("utf-8", errors="replace")

    # -- persistence -----------------------------------------------------------------
    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"merges": self.merges, "special": self.special}, f)

    @classmethod
    def load(cls, path: str) -> "BPETokenizer":
        with open(path) as f:
            d = json.load(f)
        return cls([tuple(m) for m in d["merges"]], d["special"])
