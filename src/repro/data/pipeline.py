"""Token pipeline: packing, deterministic batch sampling, per-worker sharding.

DiLoCo semantics require each worker to consume a *disjoint* data stream (the
paper shards FineWeb-Edu across the 8 GPUs).  ``worker_batches`` dedicates a
non-overlapping region of the packed token stream per worker and samples from
it with a step-seeded PRNG, so runs are exactly reproducible and DDP-vs-DiLoCo
comparisons consume identical token budgets.

``Prefetcher`` feeds the chunked ``DistTrainer`` hot path: a background
thread runs ``data_fn`` (host RNG + gather + tokenise + stacking) ahead
of the training loop, so batch assembly overlaps device compute instead
of serialising with it.  Batches are pure functions of the step index,
so running ahead is trivially correct.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.data.tokenizer import BPETokenizer


@dataclasses.dataclass
class PackedDataset:
    tokens: np.ndarray            # (N,) int32 contiguous packed stream
    seq_len: int

    @classmethod
    def from_texts(cls, texts: List[str], tok: BPETokenizer, seq_len: int,
                   add_bos: bool = True) -> "PackedDataset":
        ids: List[int] = []
        for t in texts:
            ids.extend(tok.encode(t, add_bos=add_bos))
        arr = np.asarray(ids, np.int32)
        need = seq_len + 1
        if len(arr) < 2 * need:  # make sampling well-defined on tiny corpora
            reps = int(np.ceil(2 * need / max(len(arr), 1)))
            arr = np.tile(arr, reps)
        return cls(arr, seq_len)

    @property
    def num_tokens(self) -> int:
        return int(self.tokens.size)

    def _sample(self, rng: np.random.Generator, batch: int,
                lo: int, hi: int) -> Dict[str, np.ndarray]:
        need = self.seq_len + 1
        hi = max(hi - need, lo + 1)
        starts = rng.integers(lo, hi, size=batch)
        chunk = np.stack([self.tokens[s:s + need] for s in starts])
        return {"tokens": chunk[:, :-1].astype(np.int32),
                "labels": chunk[:, 1:].astype(np.int32)}

    def batch(self, step: int, batch: int, seed: int = 0
              ) -> Dict[str, np.ndarray]:
        """Merged (DDP) batch."""
        rng = np.random.default_rng((seed, step))
        return self._sample(rng, batch, 0, self.num_tokens)

    def worker_batches(self, step: int, num_workers: int, per_worker: int,
                       seed: int = 0) -> Dict[str, np.ndarray]:
        """(K, B, S) stacked batches from disjoint per-worker shards."""
        shard = self.num_tokens // num_workers
        outs = []
        for w in range(num_workers):
            rng = np.random.default_rng((seed, step, w))
            outs.append(self._sample(rng, per_worker,
                                     w * shard, (w + 1) * shard))
        return {k: np.stack([o[k] for o in outs]) for k in outs[0]}


def build_tokenizer(texts: List[str], vocab_size: int) -> BPETokenizer:
    return BPETokenizer.train(texts, vocab_size)


# ---------------------------------------------------------------------------
# Async prefetch for the chunked training loop
# ---------------------------------------------------------------------------

def stack_batches(batches: List):
    """Stack per-step batch pytrees into one chunk with a leading T dim.

    Host (numpy) leaves are stacked on the host and shipped in ONE
    ``device_put`` per chunk — per-item ``jnp.stack`` would pay a
    device dispatch per step, which is exactly the overhead the chunked
    loop exists to remove.  Device-resident leaves stack on device.
    """
    import jax
    import jax.numpy as jnp

    def stack(*xs):
        if all(isinstance(x, np.ndarray) for x in xs):
            return np.stack(xs)
        return jnp.stack(xs)

    return jax.device_put(jax.tree.map(stack, *batches))


class Prefetcher:
    """Double-buffered async batch source for ``DistTrainer``'s chunked loop.

    A daemon thread produces ``data_fn(step)`` for steps ``0..num_steps-1``
    in order and parks each host batch in a bounded queue ``depth`` steps
    ahead of the consumer, so batch assembly (RNG, gather, tokenise)
    overlaps device compute.  ``take(start, n)`` pops the next ``n``
    consecutive batches and stacks them into one (T, ...) chunk shipped
    with a single ``device_put`` (``stack_batches``); the loop consumes
    steps strictly in order, so the queue IS the schedule.  Producer
    exceptions surface on the consuming thread at the next ``take``.
    """

    _DONE = object()

    def __init__(self, data_fn: Callable[[int], Dict], num_steps: int,
                 depth: int = 8):
        self.data_fn = data_fn
        self.num_steps = num_steps
        self._q: queue.Queue = queue.Queue(maxsize=max(int(depth), 1))
        self._stop = threading.Event()
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _produce(self):
        try:
            for step in range(self.num_steps):
                item = (step, self.data_fn(step))
                while not self._stop.is_set():
                    try:
                        self._q.put(item, timeout=0.1)
                        break
                    except queue.Full:
                        continue
                if self._stop.is_set():
                    return
        except BaseException as e:  # surfaced by the consumer's next take()
            self._err = e
            self._q.put((None, self._DONE))

    def take(self, start: int, n: int):
        """Stacked device chunk for steps ``start .. start + n - 1``."""
        out = []
        for i in range(n):
            step, batch = self._q.get()
            if batch is self._DONE:
                raise RuntimeError("prefetcher data_fn failed") from self._err
            if step != start + i:
                raise RuntimeError(
                    f"prefetcher consumed out of order: wanted {start + i}, "
                    f"queue held {step} (take() must walk steps 0..N-1)")
            out.append(batch)
        return stack_batches(out)

    def close(self):
        self._stop.set()
        while True:     # unblock a producer parked on a full queue
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=5)
