"""Token pipeline: packing, deterministic batch sampling, per-worker sharding.

DiLoCo semantics require each worker to consume a *disjoint* data stream (the
paper shards FineWeb-Edu across the 8 GPUs).  ``worker_batches`` dedicates a
non-overlapping region of the packed token stream per worker and samples from
it with a step-seeded PRNG, so runs are exactly reproducible and DDP-vs-DiLoCo
comparisons consume identical token budgets.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.data.tokenizer import BPETokenizer


@dataclasses.dataclass
class PackedDataset:
    tokens: np.ndarray            # (N,) int32 contiguous packed stream
    seq_len: int

    @classmethod
    def from_texts(cls, texts: List[str], tok: BPETokenizer, seq_len: int,
                   add_bos: bool = True) -> "PackedDataset":
        ids: List[int] = []
        for t in texts:
            ids.extend(tok.encode(t, add_bos=add_bos))
        arr = np.asarray(ids, np.int32)
        need = seq_len + 1
        if len(arr) < 2 * need:  # make sampling well-defined on tiny corpora
            reps = int(np.ceil(2 * need / max(len(arr), 1)))
            arr = np.tile(arr, reps)
        return cls(arr, seq_len)

    @property
    def num_tokens(self) -> int:
        return int(self.tokens.size)

    def _sample(self, rng: np.random.Generator, batch: int,
                lo: int, hi: int) -> Dict[str, np.ndarray]:
        need = self.seq_len + 1
        hi = max(hi - need, lo + 1)
        starts = rng.integers(lo, hi, size=batch)
        chunk = np.stack([self.tokens[s:s + need] for s in starts])
        return {"tokens": chunk[:, :-1].astype(np.int32),
                "labels": chunk[:, 1:].astype(np.int32)}

    def batch(self, step: int, batch: int, seed: int = 0
              ) -> Dict[str, np.ndarray]:
        """Merged (DDP) batch."""
        rng = np.random.default_rng((seed, step))
        return self._sample(rng, batch, 0, self.num_tokens)

    def worker_batches(self, step: int, num_workers: int, per_worker: int,
                       seed: int = 0) -> Dict[str, np.ndarray]:
        """(K, B, S) stacked batches from disjoint per-worker shards."""
        shard = self.num_tokens // num_workers
        outs = []
        for w in range(num_workers):
            rng = np.random.default_rng((seed, step, w))
            outs.append(self._sample(rng, per_worker,
                                     w * shard, (w + 1) * shard))
        return {k: np.stack([o[k] for o in outs]) for k in outs[0]}


def build_tokenizer(texts: List[str], vocab_size: int) -> BPETokenizer:
    return BPETokenizer.train(texts, vocab_size)
