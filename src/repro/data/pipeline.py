"""Token pipeline: packing, deterministic batch sampling, per-worker sharding.

DiLoCo semantics require each worker to consume a *disjoint* data stream (the
paper shards FineWeb-Edu across the 8 GPUs).  ``worker_batches`` dedicates a
non-overlapping region of the packed token stream per worker and samples from
it with a step-seeded PRNG, so runs are exactly reproducible and DDP-vs-DiLoCo
comparisons consume identical token budgets.

``Prefetcher`` feeds the chunked ``DistTrainer`` hot path: a background
thread runs ``data_fn`` (host RNG + gather + tokenise + stacking) ahead
of the training loop, so batch assembly overlaps device compute instead
of serialising with it.  Batches are pure functions of the step index,
so running ahead is trivially correct.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.data.tokenizer import BPETokenizer


@dataclasses.dataclass
class PackedDataset:
    tokens: np.ndarray            # (N,) int32 contiguous packed stream
    seq_len: int

    @classmethod
    def from_texts(cls, texts: List[str], tok: BPETokenizer, seq_len: int,
                   add_bos: bool = True) -> "PackedDataset":
        ids: List[int] = []
        for t in texts:
            ids.extend(tok.encode(t, add_bos=add_bos))
        arr = np.asarray(ids, np.int32)
        need = seq_len + 1
        if len(arr) < 2 * need:  # make sampling well-defined on tiny corpora
            reps = int(np.ceil(2 * need / max(len(arr), 1)))
            arr = np.tile(arr, reps)
        return cls(arr, seq_len)

    @property
    def num_tokens(self) -> int:
        return int(self.tokens.size)

    def _sample(self, rng: np.random.Generator, batch: int,
                lo: int, hi: int) -> Dict[str, np.ndarray]:
        need = self.seq_len + 1
        hi = max(hi - need, lo + 1)
        starts = rng.integers(lo, hi, size=batch)
        chunk = np.stack([self.tokens[s:s + need] for s in starts])
        return {"tokens": chunk[:, :-1].astype(np.int32),
                "labels": chunk[:, 1:].astype(np.int32)}

    def batch(self, step: int, batch: int, seed: int = 0
              ) -> Dict[str, np.ndarray]:
        """Merged (DDP) batch."""
        rng = np.random.default_rng((seed, step))
        return self._sample(rng, batch, 0, self.num_tokens)

    def worker_batches(self, step: int, num_workers: int, per_worker: int,
                       seed: int = 0) -> Dict[str, np.ndarray]:
        """(K, B, S) stacked batches from disjoint per-worker shards."""
        shard = self.num_tokens // num_workers
        outs = []
        for w in range(num_workers):
            rng = np.random.default_rng((seed, step, w))
            outs.append(self._sample(rng, per_worker,
                                     w * shard, (w + 1) * shard))
        return {k: np.stack([o[k] for o in outs]) for k in outs[0]}


def build_tokenizer(texts: List[str], vocab_size: int) -> BPETokenizer:
    return BPETokenizer.train(texts, vocab_size)


# ---------------------------------------------------------------------------
# Async prefetch for the chunked training loop
# ---------------------------------------------------------------------------

def stack_batches(batches: List):
    """Stack per-step batch pytrees into one chunk with a leading T dim.

    Host (numpy) leaves are stacked on the host and shipped in ONE
    ``device_put`` per chunk — per-item ``jnp.stack`` would pay a
    device dispatch per step, which is exactly the overhead the chunked
    loop exists to remove.  Device-resident leaves stack on device.
    """
    import jax
    import jax.numpy as jnp

    def stack(*xs):
        if all(isinstance(x, np.ndarray) for x in xs):
            return np.stack(xs)
        return jnp.stack(xs)

    return jax.device_put(jax.tree.map(stack, *batches))


class Prefetcher:
    """Double-buffered async batch source for ``DistTrainer``'s chunked loop.

    A daemon thread produces ``data_fn(step)`` for steps ``0..num_steps-1``
    in order and parks each host batch in a bounded queue ``depth`` steps
    ahead of the consumer, so batch assembly (RNG, gather, tokenise)
    overlaps device compute.  ``take(start, n)`` pops the next ``n``
    consecutive batches and stacks them into one (T, ...) chunk shipped
    with a single ``device_put`` (``stack_batches``); the loop consumes
    steps strictly in order, so the queue IS the schedule.  Producer
    exceptions surface on the consuming thread at the next ``take``.
    """

    _DONE = object()

    def __init__(self, data_fn: Callable[[int], Dict], num_steps: int,
                 depth: int = 8, start: int = 0):
        self.data_fn = data_fn
        self.num_steps = num_steps
        self.start = int(start)     # resume cursor: produce start..N-1
        self._q: queue.Queue = queue.Queue(maxsize=max(int(depth), 1))
        self._stop = threading.Event()
        self._err: Optional[BaseException] = None
        self._pending: List = []        # items popped by prime(), unconsumed
        self._primed = None             # (start, n, box) of an async chunk
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _produce(self):
        try:
            for step in range(self.start, self.num_steps):
                item = (step, self.data_fn(step))
                while not self._stop.is_set():
                    try:
                        self._q.put(item, timeout=0.1)
                        break
                    except queue.Full:
                        continue
                if self._stop.is_set():
                    return
        except BaseException as e:  # surfaced by the consumer's next take()
            self._err = e
            self._q.put((None, self._DONE))

    def _next_item(self):
        """Next (step, batch) in order: primed leftovers first, then the
        producer queue."""
        if self._pending:
            return self._pending.pop(0)
        return self._q.get()

    def prime(self, start: int, n: int) -> None:
        """Start assembling the chunk for steps ``start .. start + n - 1``
        on a background thread (pop + host-stack + ``device_put``), so
        chunk assembly overlaps whatever the caller does next — in
        ``DistTrainer`` that is the outer-sync jit at the chunk boundary,
        whose latency the next ``take`` would otherwise serialize behind.

        Purely an optimization: ``take`` consumes a primed chunk when the
        bounds match exactly and falls back to the raw items otherwise
        (e.g. a sync runner whose next event moved), so priming can never
        change what ``take`` returns."""
        n = min(n, self.num_steps - start)
        if self._primed is not None or n <= 0:
            return
        box = {"done": threading.Event()}

        def work():
            try:
                raw = []
                for _ in range(n):
                    item = self._next_item()
                    raw.append(item)
                    if item[1] is self._DONE:
                        break            # producer died: nothing follows
                box["raw"] = raw
                if len(raw) == n and not any(b is self._DONE
                                             for _, b in raw):
                    box["chunk"] = stack_batches([b for _, b in raw])
            except BaseException as e:   # surfaces at the matching take()
                box["err"] = e
            box["done"].set()

        self._primed = (start, n, box)
        threading.Thread(target=work, daemon=True).start()

    def take(self, start: int, n: int):
        """Stacked device chunk for steps ``start .. start + n - 1``."""
        if self._primed is not None:
            pstart, pn, box = self._primed
            self._primed = None
            box["done"].wait()
            if "err" in box:
                raise box["err"]
            if pstart == start and pn == n and "chunk" in box:
                self._check_order(box["raw"], start)
                return box["chunk"]
            # bounds moved (or producer died mid-chunk): keep the raw
            # items and fall through to the synchronous path
            self._pending = box["raw"] + self._pending
        out = []
        for i in range(n):
            step, batch = self._next_item()
            if batch is self._DONE:
                if self._err is not None:
                    # re-raise the producer's ORIGINAL exception object —
                    # its traceback still points into data_fn, not at this
                    # queue pop (wrapping it in a RuntimeError buried the
                    # actual failure two `__cause__` hops deep)
                    raise self._err
                # no recorded error: the producer was shut down cleanly
                # (close() drained it) while a consumer still wanted data
                raise RuntimeError(
                    "prefetcher producer stopped (closed) before step "
                    f"{start + i}")
            if step != start + i:
                raise RuntimeError(
                    f"prefetcher consumed out of order: wanted {start + i}, "
                    f"queue held {step} (take() must walk steps 0..N-1)")
            out.append(batch)
        return stack_batches(out)

    @staticmethod
    def _check_order(raw, start: int) -> None:
        for i, (step, batch) in enumerate(raw):
            if batch is not Prefetcher._DONE and step != start + i:
                raise RuntimeError(
                    f"prefetcher consumed out of order: wanted {start + i}, "
                    f"queue held {step} (take() must walk steps 0..N-1)")

    def close(self):
        self._stop.set()
        while True:     # unblock a producer parked on a full queue
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        if self._primed is not None:
            # wake a prime worker parked on the now-drained queue (it exits
            # at the first _DONE it pops) so it can't outlive the run
            # holding a chunk of batches
            _, _, box = self._primed
            self._primed = None
            self._q.put((None, self._DONE))
            box["done"].wait(timeout=5)
        self._pending.clear()
        self._thread.join(timeout=5)
