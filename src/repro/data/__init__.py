from repro.data.tokenizer import BPETokenizer, SPECIAL_TOKENS
from repro.data.pipeline import (PackedDataset, Prefetcher, build_tokenizer,
                                 stack_batches)
from repro.data import synthetic

__all__ = ["BPETokenizer", "SPECIAL_TOKENS", "PackedDataset", "Prefetcher",
           "build_tokenizer", "stack_batches", "synthetic"]
