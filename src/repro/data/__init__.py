from repro.data.tokenizer import BPETokenizer, SPECIAL_TOKENS
from repro.data.pipeline import PackedDataset, build_tokenizer
from repro.data import synthetic

__all__ = ["BPETokenizer", "SPECIAL_TOKENS", "PackedDataset",
           "build_tokenizer", "synthetic"]
