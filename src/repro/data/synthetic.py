"""Synthetic three-stage corpora mirroring nanochat's data pipeline.

The container is offline, so FineWeb-Edu / SmolTalk / GSM8K are replaced by a
seeded synthetic world with the same *structure*:

* pretrain   — declarative factual sentences + arithmetic statements + word
               patterns, Zipf-weighted filler vocabulary (FineWeb-Edu proxy);
* dialogue   — the same knowledge re-rendered in nanochat's chat schema
               (<|user_start|>…<|assistant_end|>) (SmolTalk proxy, the
               paper's mid-training stage);
* sft        — cleaner instruction/answer pairs, arithmetic-heavy (ARC/GSM8K
               SFT proxy).

Evaluation draws from the SAME world (held-out entities / operand ranges), so
"MMLU-like" fact lookup, "GSM8K-like" arithmetic and "HumanEval-like" pattern
completion measure genuine knowledge transfer across stages — which is what
the paper's Table 1 tracks.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Sequence, Tuple

ATTRIBUTES = ["color", "size", "shape", "sound", "taste"]
VALUES = {
    "color": ["red", "blue", "green", "gold", "black"],
    "size": ["tiny", "small", "large", "huge", "giant"],
    "shape": ["round", "square", "flat", "long", "curved"],
    "sound": ["quiet", "loud", "soft", "sharp", "deep"],
    "taste": ["sweet", "sour", "salty", "bitter", "plain"],
}
FILLER = ["the", "a", "is", "of", "and", "it", "that", "very", "quite",
          "really", "also", "so", "now", "then", "here", "there"]
PATTERN_WORDS = ["ka", "lo", "mi", "zu", "re"]


@dataclasses.dataclass
class World:
    """A fixed fact table: entity -> attribute -> value."""
    n_entities: int
    facts: Dict[str, Dict[str, str]]
    entities: List[str]

    @classmethod
    def make(cls, n_entities: int = 40, seed: int = 1234) -> "World":
        rng = random.Random(seed)
        entities = [f"ent{i}" for i in range(n_entities)]
        facts = {e: {a: rng.choice(VALUES[a]) for a in ATTRIBUTES}
                 for e in entities}
        return cls(n_entities, facts, entities)

    def train_entities(self) -> List[str]:
        return self.entities[: int(0.8 * self.n_entities)]

    def eval_entities(self) -> List[str]:
        return self.entities[int(0.8 * self.n_entities):]


# ---------------------------------------------------------------------------
# Sentence generators
# ---------------------------------------------------------------------------

def _fact_sentence(world: World, rng: random.Random, ents: Sequence[str]) -> str:
    e = rng.choice(list(ents))
    a = rng.choice(ATTRIBUTES)
    v = world.facts[e][a]
    forms = [
        f"the {a} of {e} is {v} .",
        f"{e} has a {v} {a} .",
        f"everyone knows the {a} of {e} is {v} .",
    ]
    return rng.choice(forms)


def _arith_sentence(rng: random.Random, hard: bool = False) -> str:
    hi = 99 if hard else 49
    a, b = rng.randint(0, hi), rng.randint(0, hi)
    op = rng.choice(["+", "-", "*"])
    if op == "+":
        r = a + b
    elif op == "-":
        a, b = max(a, b), min(a, b)
        r = a - b
    else:
        a, b = rng.randint(0, 12), rng.randint(0, 12)
        r = a * b
    return f"{a} {op} {b} = {r} ."


def _pattern_sentence(rng: random.Random) -> str:
    w1, w2 = rng.sample(PATTERN_WORDS, 2)
    n = rng.randint(2, 4)
    return " ".join([w1, w2] * n) + " ."


def _filler_sentence(rng: random.Random) -> str:
    n = rng.randint(3, 8)
    return " ".join(rng.choices(FILLER, k=n)) + " ."


def gen_pretrain_texts(world: World, n: int, seed: int = 0) -> List[str]:
    rng = random.Random(seed)
    ents = world.train_entities()
    out = []
    for _ in range(n):
        r = rng.random()
        if r < 0.45:
            out.append(_fact_sentence(world, rng, ents))
        elif r < 0.7:
            out.append(_arith_sentence(rng))
        elif r < 0.85:
            out.append(_pattern_sentence(rng))
        else:
            out.append(_filler_sentence(rng))
    return out


# ---------------------------------------------------------------------------
# Chat / instruction stages
# ---------------------------------------------------------------------------

def _chat(q: str, a: str) -> str:
    return (f"<|user_start|>{q}<|user_end|>"
            f"<|assistant_start|>{a}<|assistant_end|>")


def _qa_pair(world: World, rng: random.Random, ents: Sequence[str]
             ) -> Tuple[str, str]:
    r = rng.random()
    if r < 0.5:
        e = rng.choice(list(ents))
        a = rng.choice(ATTRIBUTES)
        return (f"what is the {a} of {e} ?", f"the {a} of {e} is {world.facts[e][a]} .")
    if r < 0.85:
        s = _arith_sentence(rng)
        lhs, res = s.rstrip(" .").split(" = ")
        return (f"compute {lhs} .", f"{lhs} = {res} .")
    w1, w2 = rng.sample(PATTERN_WORDS, 2)
    return (f"continue the pattern {w1} {w2} {w1} {w2} .",
            f"{w1} {w2} {w1} {w2} .")


def gen_dialogue_texts(world: World, n: int, seed: int = 1) -> List[str]:
    """Mid-training stage: multi-turn dialogues (SmolTalk proxy)."""
    rng = random.Random(seed)
    ents = world.train_entities()
    out = []
    for _ in range(n):
        turns = rng.randint(1, 3)
        convo = "<|bos|>"
        for _ in range(turns):
            q, a = _qa_pair(world, rng, ents)
            convo += _chat(q, a)
        out.append(convo)
    return out


def gen_sft_texts(world: World, n: int, seed: int = 2) -> List[str]:
    """SFT stage: single-turn, arithmetic/fact heavy, clean answers."""
    rng = random.Random(seed)
    ents = world.train_entities()
    out = []
    for _ in range(n):
        q, a = _qa_pair(world, rng, ents)
        out.append("<|bos|>" + _chat(q, a))
    return out


# ---------------------------------------------------------------------------
# Eval item generators (consumed by repro.evals.tasks)
# ---------------------------------------------------------------------------

def gen_mc_eval(world: World, n: int, seed: int = 7,
                heldout: bool = False) -> List[dict]:
    """MMLU-like multiple choice on world facts."""
    rng = random.Random(seed)
    ents = world.eval_entities() if heldout else world.train_entities()
    items = []
    for _ in range(n):
        e = rng.choice(list(ents))
        a = rng.choice(ATTRIBUTES)
        gold = world.facts[e][a]
        opts = [v for v in VALUES[a] if v != gold]
        rng.shuffle(opts)
        options = opts[:3] + [gold]
        rng.shuffle(options)
        items.append({
            "prompt": f"<|user_start|>what is the {a} of {e} ?<|user_end|>"
                      f"<|assistant_start|>the {a} of {e} is ",
            "options": options,
            "answer": options.index(gold),
        })
    return items


def gen_arith_eval(n: int, seed: int = 8) -> List[dict]:
    """GSM8K-like: exact-match arithmetic completion."""
    rng = random.Random(seed)
    items = []
    for _ in range(n):
        s = _arith_sentence(rng)
        lhs, res = s.rstrip(" .").split(" = ")
        items.append({
            "prompt": f"<|user_start|>compute {lhs} .<|user_end|>"
                      f"<|assistant_start|>{lhs} = ",
            "answer": res,
        })
    return items


def gen_pattern_eval(n: int, seed: int = 9) -> List[dict]:
    """HumanEval-like: deterministic continuation exact-match."""
    rng = random.Random(seed)
    items = []
    for _ in range(n):
        w1, w2 = rng.sample(PATTERN_WORDS, 2)
        items.append({
            "prompt": f"<|user_start|>continue the pattern {w1} {w2} {w1} {w2} ."
                      f"<|user_end|><|assistant_start|>",
            "answer": f"{w1} {w2}",
        })
    return items
