"""Jitted public wrapper around the flash attention Pallas kernel.

``interpret`` defaults to *backend-selected* via ``repro.kernels.common``:
the kernel body runs under the Pallas interpreter on CPU hosts (same
arithmetic, Python-speed — what the correctness sweeps use) and compiles
through Mosaic on TPU.  ``REPRO_PALLAS_INTERPRET=0|1`` force-overrides.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.common import resolve_interpret
from repro.kernels.flash_attention.kernel import flash_attention_fwd


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret", "fp8"))
def _flash_attention(q, k, v, *, causal, window, bq, bk, interpret, fp8):
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               bq=bq, bk=bk, interpret=interpret, fp8=fp8)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, bq: int = 128,
                    bk: int = 128, interpret: Optional[bool] = None,
                    fp8: bool = False):
    """q: (B, H, Sq, D); k/v: (B, KV, Sk, D) grouped-query.  ``fp8`` runs
    the QK^T contraction on per-row fp8 tiles (see kernel.py)."""
    interpret = resolve_interpret(interpret)
    return _flash_attention(q, k, v, causal=causal, window=window,
                            bq=bq, bk=bk, interpret=interpret, fp8=fp8)
