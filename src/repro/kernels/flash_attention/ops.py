"""Jitted public wrapper around the flash attention Pallas kernel.

On TPU hardware set ``interpret=False``; on this CPU container the kernel
body executes in interpret mode (same arithmetic, Python-speed) which is what
the correctness sweeps use.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.flash_attention.kernel import flash_attention_fwd


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, bq: int = 128,
                    bk: int = 128, interpret: bool = True):
    """q: (B, H, Sq, D); k/v: (B, KV, Sk, D) grouped-query.  See kernel.py."""
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               bq=bq, bk=bk, interpret=interpret)
