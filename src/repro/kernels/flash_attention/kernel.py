"""Block-wise flash attention — Pallas TPU kernel.

TPU-native adaptation of the FlashAttention recurrence:

* grid = (batch, q_heads, Sq/BQ, Sk/BK), minor-most axis is the KV block, so
  the online-softmax running state (m, l, acc) lives in VMEM scratch across
  the sequential KV sweep and never touches HBM;
* GQA without materializing repeated KV: the K/V BlockSpec index_map sends
  query head ``h`` to KV head ``h // group`` — the MXU sees the same tile
  from VMEM for all heads in a group;
* causal + sliding-window masking via block-local iota against absolute
  positions; fully-masked KV blocks are skipped with ``pl.when`` so SWA
  prefill does O(S·W) work;
* block shapes default to (BQ, BK) = (128, 128) with head_dim padded to a
  lane multiple — MXU-aligned (128×128 systolic array).

Validated on CPU with ``interpret=True`` against ``ref.reference_attention``.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import qk_dot_fp8

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: Optional[int],
                 bq: int, bk: int, n_k_blocks: int,
                 fp8: bool = False, narrow_dot: bool = False):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * bq
    k_start = ik * bk
    q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # whole-block skip test (static shapes; dynamic predicate)
    relevant = jnp.asarray(True)
    if causal:
        relevant = jnp.asarray(k_start <= q_start + bq - 1)
    if window is not None:
        # newest key in block must be within window of the oldest query
        relevant = jnp.logical_and(
            relevant, k_start + bk - 1 >= q_start - window + 1)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, d)
        if fp8:     # per-row fp8 tiles; narrow MXU contraction on TPU
            s = qk_dot_fp8(q, k, narrow_dot=narrow_dot) * scale
        else:
            s = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale  # (bq, bk)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, q_pos - k_pos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == n_k_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: Optional[int] = None,
                        bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                        interpret: bool = True,
                        fp8: bool = False) -> jax.Array:
    """q: (B, H, Sq, D); k/v: (B, KV, Sk, D) with H % KV == 0.
    Returns (B, H, Sq, D).

    ``fp8=True`` runs the QK^T contraction on per-row fp8_e4m3 tiles with
    per-tile amax scales (``common.qk_dot_fp8``) — the narrow-dtype MXU
    dot only when compiling (interpret mode keeps the quantization but
    contracts in f32, since the interpreter has no fp8 matmul units).
    The PV matmul stays f32: P is a softmax output in [0, 1] whose
    dynamic range fp8 would waste."""
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    assert H % KV == 0
    group = H // KV
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    n_q, n_k = Sq // bq, Sk // bk
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, n_k_blocks=n_k, fp8=fp8,
        narrow_dot=fp8 and not interpret)

    return pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max m
            pltpu.VMEM((bq, 1), jnp.float32),    # running denom l
            pltpu.VMEM((bq, D), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
