"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math
from typing import Optional

import jax.numpy as jnp


def reference_attention(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None) -> jnp.ndarray:
    """q: (B, H, Sq, D); k/v: (B, KV, Sk, D).  Materialized-scores oracle."""
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    group = H // KV
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def reference_attention_fp8(q, k, v, *, causal: bool = True,
                            window: Optional[int] = None) -> jnp.ndarray:
    """Oracle for the ``fp8=True`` kernel path: quantize every (position,
    head) row of Q and K to fp8_e4m3 with a per-row amax scale over the
    head dim (the kernel's per-tile granularity — tiles slice rows, never
    split them), dequantize, and run the plain oracle.  The kernel factors
    the scales out of the dot instead of materializing the wide rows;
    the value is identical up to f32 reassociation."""
    from repro.kernels.quantize import reference_quantize_axis

    def dq(x):
        xq, s = reference_quantize_axis(x, axis=-1, dtype="fp8_e4m3")
        return (xq.astype(jnp.float32) * s).astype(x.dtype)

    return reference_attention(dq(q), dq(k), v, causal=causal, window=window)
