from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import (reference_attention,
                                               reference_attention_fp8)

__all__ = ["flash_attention", "reference_attention",
           "reference_attention_fp8"]
