from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import reference_attention

__all__ = ["flash_attention", "reference_attention"]
