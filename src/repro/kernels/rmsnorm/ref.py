"""Pure-jnp oracle for the fused RMSNorm kernel."""
import jax
import jax.numpy as jnp


def reference_rmsnorm(x, scale, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def reference_rmsnorm_residual(x, residual, scale, eps: float = 1e-5):
    s = x.astype(jnp.float32) + residual.astype(jnp.float32)
    return reference_rmsnorm(s, scale, eps), s.astype(x.dtype)
