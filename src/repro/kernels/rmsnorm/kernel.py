"""Fused RMSNorm (+ optional residual add) — Pallas TPU kernel.

Row-tiled: grid over row blocks, each block (BR, d) resident in VMEM; the
reduction, rsqrt, scale multiply and residual add fuse into one HBM
read/write pass (unfused XLA does norm + mul + add as separate HLOs unless
the fusion heuristics fire; the kernel makes it structural).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BR = 256


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * s_ref[...].astype(jnp.float32)[None, :]).astype(o_ref.dtype)


def _rmsnorm_residual_kernel(x_ref, r_ref, s_ref, o_ref, res_ref, *,
                             eps: float):
    x = x_ref[...].astype(jnp.float32) + r_ref[...].astype(jnp.float32)
    res_ref[...] = x.astype(res_ref.dtype)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * s_ref[...].astype(jnp.float32)[None, :]).astype(o_ref.dtype)


def rmsnorm_fwd(x, scale, *, eps: float = 1e-5, br: int = DEFAULT_BR,
                interpret: bool = True):
    """x: (R, d) rows; scale: (d,)."""
    R, d = x.shape
    br = min(br, R)
    assert R % br == 0, (R, br)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(R // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, d), x.dtype),
        interpret=interpret,
    )(x, scale)


def rmsnorm_residual_fwd(x, residual, scale, *, eps: float = 1e-5,
                         br: int = DEFAULT_BR, interpret: bool = True):
    """Fused (x + residual) -> RMSNorm.  Returns (normed, new_residual)."""
    R, d = x.shape
    br = min(br, R)
    assert R % br == 0, (R, br)
    return pl.pallas_call(
        functools.partial(_rmsnorm_residual_kernel, eps=eps),
        grid=(R // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                   pl.BlockSpec((br, d), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((R, d), x.dtype),
                   jax.ShapeDtypeStruct((R, d), x.dtype)],
        interpret=interpret,
    )(x, residual, scale)
