from repro.kernels.rmsnorm.ops import rmsnorm, rmsnorm_residual
from repro.kernels.rmsnorm.ref import (reference_rmsnorm,
                                       reference_rmsnorm_residual)

__all__ = ["rmsnorm", "rmsnorm_residual", "reference_rmsnorm",
           "reference_rmsnorm_residual"]
