"""Jitted wrappers for the fused RMSNorm kernel (reshape any leading dims)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rmsnorm.kernel import rmsnorm_fwd, rmsnorm_residual_fwd


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-5, interpret: bool = True):
    shape = x.shape
    R = 1
    for s in shape[:-1]:
        R *= s
    br = R
    for cand in (256, 128, 64, 32, 16, 8, 4, 2, 1):
        if R % cand == 0:
            br = cand
            break
    out = rmsnorm_fwd(x.reshape(R, shape[-1]), scale, eps=eps, br=br,
                      interpret=interpret)
    return out.reshape(shape)


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm_residual(x, residual, scale, *, eps: float = 1e-5,
                     interpret: bool = True):
    shape = x.shape
    R = 1
    for s in shape[:-1]:
        R *= s
    br = R
    for cand in (256, 128, 64, 32, 16, 8, 4, 2, 1):
        if R % cand == 0:
            br = cand
            break
    o, r = rmsnorm_residual_fwd(x.reshape(R, shape[-1]),
                                residual.reshape(R, shape[-1]), scale,
                                eps=eps, br=br, interpret=interpret)
    return o.reshape(shape), r.reshape(shape)
