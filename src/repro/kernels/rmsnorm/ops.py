"""Jitted wrappers for the fused RMSNorm kernel (reshape any leading dims).

``interpret`` defaults to *backend-selected* via ``repro.kernels.common``:
interpret on CPU hosts, compiled on TPU, ``REPRO_PALLAS_INTERPRET=0|1``
force-overrides.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.common import resolve_interpret
from repro.kernels.rmsnorm.kernel import rmsnorm_fwd, rmsnorm_residual_fwd


def _row_block(shape) -> int:
    """Largest power-of-two row tile (<= 256) dividing the row count."""
    R = 1
    for s in shape[:-1]:
        R *= s
    for cand in (256, 128, 64, 32, 16, 8, 4, 2, 1):
        if R % cand == 0:
            return cand
    return R


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def _rmsnorm(x, scale, *, eps, interpret):
    shape = x.shape
    R = 1
    for s in shape[:-1]:
        R *= s
    out = rmsnorm_fwd(x.reshape(R, shape[-1]), scale, eps=eps,
                      br=_row_block(shape), interpret=interpret)
    return out.reshape(shape)


def rmsnorm(x, scale, *, eps: float = 1e-5,
            interpret: Optional[bool] = None):
    interpret = resolve_interpret(interpret)
    return _rmsnorm(x, scale, eps=eps, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def _rmsnorm_residual(x, residual, scale, *, eps, interpret):
    shape = x.shape
    R = 1
    for s in shape[:-1]:
        R *= s
    o, r = rmsnorm_residual_fwd(x.reshape(R, shape[-1]),
                                residual.reshape(R, shape[-1]), scale,
                                eps=eps, br=_row_block(shape),
                                interpret=interpret)
    return o.reshape(shape), r.reshape(shape)


def rmsnorm_residual(x, residual, scale, *, eps: float = 1e-5,
                     interpret: Optional[bool] = None):
    interpret = resolve_interpret(interpret)
    return _rmsnorm_residual(x, residual, scale, eps=eps,
                             interpret=interpret)
