"""Pure-jnp oracle for the SSD kernel — delegates to the model's reference
implementation so the kernel, the model path, and the decode recurrence are
all pinned to the same math."""
from repro.models.ssm import ssd_chunked


def reference_ssd(x, dt, A, Bm, Cm, D, chunk: int = 128):
    return ssd_chunked(x, dt, A, Bm, Cm, D, chunk)
