from repro.kernels.ssd.ops import ssd
from repro.kernels.ssd.ref import reference_ssd

__all__ = ["ssd", "reference_ssd"]
