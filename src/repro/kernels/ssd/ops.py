"""Jitted wrapper for the SSD Pallas kernel (pads S to a chunk multiple)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd.kernel import ssd_fwd


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, A, Bm, Cm, D, *, chunk: int = 128, interpret: bool = True):
    """Pads to a chunk multiple with dt=0 (decay 1, zero input — a no-op for
    the recurrence), runs the kernel, strips padding."""
    S = x.shape[1]
    Q = min(chunk, S) if S % chunk else chunk
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    y, h = ssd_fwd(x, dt, A, Bm, Cm, D, chunk=Q, interpret=interpret)
    return y[:, :S], h
