"""Jitted wrapper for the SSD Pallas kernel (pads S to a chunk multiple).

``interpret`` defaults to *backend-selected* via ``repro.kernels.common``:
interpret on CPU hosts, compiled on TPU, ``REPRO_PALLAS_INTERPRET=0|1``
force-overrides.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import resolve_interpret
from repro.kernels.ssd.kernel import ssd_fwd


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _ssd(x, dt, A, Bm, Cm, D, *, chunk, interpret):
    """Pads to a chunk multiple with dt=0 (decay 1, zero input — a no-op for
    the recurrence), runs the kernel, strips padding."""
    S = x.shape[1]
    Q = min(chunk, S) if S % chunk else chunk
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    y, h = ssd_fwd(x, dt, A, Bm, Cm, D, chunk=Q, interpret=interpret)
    return y[:, :S], h


def ssd(x, dt, A, Bm, Cm, D, *, chunk: int = 128,
        interpret: Optional[bool] = None):
    interpret = resolve_interpret(interpret)
    return _ssd(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=interpret)
