"""Mamba-2 SSD chunk scan — Pallas TPU kernel.

TPU adaptation of the SSD algorithm (GPU original uses a parallel chunk scan
with shared-memory staging):

* grid = (batch, n_chunks) with the chunk axis minor-most — TPU grids execute
  sequentially, so the inter-chunk recurrent state h (H, N, P) lives in a
  VMEM scratch buffer across the whole sweep and is re-zeroed when the batch
  index changes.  The state never round-trips to HBM (the GPU version
  materializes per-chunk states); HBM traffic is exactly one read of
  x/dt/B/C and one write of y.
* the intra-chunk term is a masked (Q×Q) decay-weighted attention computed
  on the MXU via dot_general; Q defaults to 128 to match the systolic array.
* everything is computed in f32 regardless of input dtype (SSM recurrences
  are exp-of-sums — bf16 drifts).

Validated in interpret mode against ``repro.models.ssm.ssd_chunked`` (which
is itself the model's reference path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref,
                y_ref, hout_ref, h_scr, *, Q: int, n_chunks: int):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _reset():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)          # (Q, H, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q, H)
    A = a_ref[...].astype(jnp.float32)        # (H,)
    Bm = b_ref[0].astype(jnp.float32)         # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)         # (Q, N)
    D = d_ref[...].astype(jnp.float32)        # (H,)

    dA = dt * A[None, :]                      # (Q, H) <= 0
    cum = jnp.cumsum(dA, axis=0)              # (Q, H)
    xbar = x * dt[..., None]                  # (Q, H, P)

    # intra-chunk quadratic term
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    L = jnp.exp(cum[:, None, :] - cum[None, :, :])                # (Q, Q, H)
    tril = (jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
            >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1))
    M = jnp.where(tril[:, :, None], CB[:, :, None] * L, 0.0)      # (Q, Q, H)
    y_intra = jnp.einsum("qkh,khp->qhp", M, xbar,
                         preferred_element_type=jnp.float32)

    # inter-chunk contribution from carried state
    h_prev = h_scr[...]                                           # (H, N, P)
    y_inter = jnp.einsum("qn,qh,hnp->qhp", Cm, jnp.exp(cum), h_prev,
                         preferred_element_type=jnp.float32)

    y = y_intra + y_inter + x * D[None, :, None]
    y_ref[0] = y.astype(y_ref.dtype)

    # state update: h = decay_total * h_prev + sum_k decay_to_end B_k xbar_k
    decay_end = jnp.exp(cum[-1:, :] - cum)                        # (Q, H)
    S_c = jnp.einsum("kn,kh,khp->hnp", Bm, decay_end, xbar,
                     preferred_element_type=jnp.float32)
    h_new = jnp.exp(cum[-1])[:, None, None] * h_prev + S_c
    h_scr[...] = h_new

    @pl.when(c_idx == n_chunks - 1)
    def _emit_state():
        hout_ref[0] = h_new.astype(hout_ref.dtype)


def ssd_fwd(x, dt, A, Bm, Cm, D, *, chunk: int = 128,
            interpret: bool = True):
    """x: (B,S,H,P); dt: (B,S,H); A,D: (H,); Bm/Cm: (B,S,N).
    S must be divisible by chunk (ops.py pads).  Returns (y, final_state)."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk

    kernel = functools.partial(_ssd_kernel, Q=chunk, n_chunks=n_chunks)
    y, h = pl.pallas_call(
        kernel,
        grid=(Bsz, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, chunk, H), lambda b, c: (b, c, 0)),
            pl.BlockSpec((H,), lambda b, c: (0,)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((H,), lambda b, c: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, H, N, P), lambda b, c: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bsz, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((H, N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm, D)
    return y, h
