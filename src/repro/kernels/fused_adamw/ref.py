"""Pure-jnp oracle for the fused AdamW update kernel.

These are the semantics of record — exactly the per-leaf math of
``repro.optim.adamw`` (same ops on the same f32 intermediates).  The
Pallas kernel must match this oracle to within XLA's shape-dependent
FMA-contraction noise (~1-2 ulp; the kernel computes on flattened
(1, M) views and a ``pallas_call`` is a fusion barrier, so bit-identical
rounding across both programs is not guaranteed on CPU).  Bias
corrections ``bc1 = 1 - b1**t`` / ``bc2 = 1 - b2**t``
are computed by the caller (they are per-step scalars shared by every
leaf) and divided through inside, mirroring the unfused path.
"""
from __future__ import annotations

import jax.numpy as jnp


def reference_fused_adamw(p, g, m, v, lr, bc1, bc2, *, b1: float, b2: float,
                          eps: float, wd: float):
    """One AdamW step on a single leaf.

    ``p``/``g`` in any float dtype (cast to f32 like the unfused path),
    ``m``/``v`` f32 moments, ``lr``/``bc1``/``bc2`` f32 scalars (may be
    traced — schedules and bias corrections are step-dependent).  Returns
    ``(update, new_m, new_v)`` — the update is applied by the caller via
    ``apply_updates`` so the ``Optimizer`` contract is unchanged.
    """
    g = g.astype(jnp.float32)
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * jnp.square(g)
    mhat = m / bc1
    vhat = v / bc2
    u = -lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p.astype(jnp.float32))
    return u, m, v
