"""Fused AdamW update Pallas kernel for the inner-loop hot path.

One column-tiled elementwise pass computes BOTH moment updates, the bias
corrections, weight decay, and the scaled parameter update:

    m' = b1 m + (1-b1) g
    v' = b2 v + (1-b2) g^2
    u  = -lr (m'/bc1 / (sqrt(v'/bc2) + eps) + wd p)

Unfused XLA materialises m', v', mhat, vhat, and the decay term as
separate HBM round-trips (the optimizer runs per-leaf inside a vmapped
scan body, where fusion across the tree is not guaranteed); the kernel
makes the fusion structural: p/g/m/v stream through VMEM once and three
outputs (u, m', v') stream back.

Per-step scalars (lr from the schedule, bc1/bc2 bias corrections) arrive
as one (1, 3) f32 operand replicated to every tile — they are traced
values, not compile-time constants, so retraces never depend on the step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128          # TPU lane width: flattened leaves pad to a multiple


def _fused_adamw_kernel(p_ref, g_ref, m_ref, v_ref, s_ref,
                        u_ref, nm_ref, nv_ref, *, b1, b2, eps, wd):
    g = g_ref[...].astype(jnp.float32)
    m = b1 * m_ref[...] + (1 - b1) * g
    v = b2 * v_ref[...] + (1 - b2) * jnp.square(g)
    lr, bc1, bc2 = s_ref[0, 0], s_ref[0, 1], s_ref[0, 2]
    mhat = m / bc1
    vhat = v / bc2
    u_ref[...] = -lr * (mhat / (jnp.sqrt(vhat) + eps)
                        + wd * p_ref[...].astype(jnp.float32))
    nm_ref[...] = m
    nv_ref[...] = v


def fused_adamw_fwd(p, g, m, v, scal, *, b1: float, b2: float, eps: float,
                    wd: float, bc: int = 0, interpret: bool = True):
    """p, g: (1, M) any float dtype; m, v: (1, M) f32; scal: (1, 3) f32
    ``[lr, bc1, bc2]``; M % LANE == 0.  Returns ``(u, new_m, new_v)`` all
    f32 (1, M).
    """
    _, M = p.shape
    assert M % LANE == 0, p.shape
    if not bc:
        bc = M
        for cand in (65536, 32768, 16384, 8192, 4096, 2048, 1024, 512, 256,
                     LANE):
            if M % cand == 0:
                bc = cand
                break
    f32 = jax.ShapeDtypeStruct((1, M), jnp.float32)
    return pl.pallas_call(
        functools.partial(_fused_adamw_kernel, b1=b1, b2=b2, eps=eps, wd=wd),
        grid=(M // bc,),
        in_specs=[pl.BlockSpec((1, bc), lambda j: (0, j)),
                  pl.BlockSpec((1, bc), lambda j: (0, j)),
                  pl.BlockSpec((1, bc), lambda j: (0, j)),
                  pl.BlockSpec((1, bc), lambda j: (0, j)),
                  pl.BlockSpec((1, 3), lambda j: (0, 0))],
        out_specs=[pl.BlockSpec((1, bc), lambda j: (0, j)),
                   pl.BlockSpec((1, bc), lambda j: (0, j)),
                   pl.BlockSpec((1, bc), lambda j: (0, j))],
        out_shape=[f32, f32, f32],
        interpret=interpret,
    )(p, g, m, v, scal)
