"""Jitted wrapper for the fused AdamW kernel: arbitrary leaf shapes in,
flattened LANE-padded (1, M) kernel views inside.

``interpret`` defaults to *backend-selected* via
``repro.kernels.common``: interpret on CPU hosts (Mosaic cannot
compile), compiled on TPU, force-overridable via
``REPRO_PALLAS_INTERPRET=0|1``.

Zero padding is invisible to the update: padded lanes carry g=m=v=p=0, so
m'=v'=0 and u = -lr*(0/(0+eps) + 0) = 0, and they are sliced away anyway.
0-sized sentinel leaves (the partitioned optimizer masks leaves it does
not own to ``(0,)``) short-circuit to the oracle — a Pallas grid cannot
be empty.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import (default_interpret, pallas_mode,
                                  resolve_interpret)
from repro.kernels.fused_adamw.kernel import LANE, fused_adamw_fwd
from repro.kernels.fused_adamw.ref import reference_fused_adamw

__all__ = ["fused_adamw_update", "default_interpret", "pallas_mode"]


def _flatten_pad(x, dtype=None) -> jax.Array:
    flat = x.reshape(1, -1)
    if dtype is not None:
        flat = flat.astype(dtype)
    pad = (-flat.shape[1]) % LANE
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    return flat


@functools.partial(jax.jit,
                   static_argnames=("b1", "b2", "eps", "wd", "interpret"))
def _fused_update(p, g, m, v, lr, bc1, bc2, *, b1, b2, eps, wd, interpret):
    if p.size == 0:
        return reference_fused_adamw(p, g, m, v, lr, bc1, bc2,
                                     b1=b1, b2=b2, eps=eps, wd=wd)
    scal = jnp.stack([jnp.asarray(lr, jnp.float32),
                      jnp.asarray(bc1, jnp.float32),
                      jnp.asarray(bc2, jnp.float32)]).reshape(1, 3)
    u, nm, nv = fused_adamw_fwd(
        _flatten_pad(p), _flatten_pad(g), _flatten_pad(m, jnp.float32),
        _flatten_pad(v, jnp.float32), scal,
        b1=b1, b2=b2, eps=eps, wd=wd, interpret=interpret)
    n = p.size
    unflat = lambda x: x[0, :n].reshape(p.shape)
    return unflat(u), unflat(nm), unflat(nv)


def fused_adamw_update(p, g, m, v, lr, bc1, bc2, *, b1: float, b2: float,
                       eps: float, wd: float,
                       interpret: Optional[bool] = None):
    """One fused AdamW step on a single leaf of any shape/float dtype.

    ``lr``/``bc1``/``bc2`` are (possibly traced) f32 scalars — the
    schedule value and bias corrections ``1 - b**t``.  Returns
    ``(update, new_m, new_v)`` shaped like the jnp oracle
    (``ref.reference_fused_adamw``): same ops in the same order as the
    unfused ``repro.optim.adamw`` math, agreeing to within ~1-2 ulp of
    FMA-contraction noise (see ``ref.py``).
    """
    interpret = resolve_interpret(interpret)
    return _fused_update(p, g, m, v, lr, bc1, bc2, b1=b1, b2=b2, eps=eps,
                         wd=wd, interpret=interpret)
