from repro.kernels.fused_adamw.ops import fused_adamw_update
from repro.kernels.fused_adamw.ref import reference_fused_adamw

__all__ = ["fused_adamw_update", "reference_fused_adamw"]
