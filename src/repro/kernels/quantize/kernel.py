"""Fused symmetric-int8 quantization kernels for the outer-sync transport.

Two kernels back the ``Int8Symmetric`` codec (``repro.core.transport``):

* ``quantize_ef_fwd`` — fused quantize + error-feedback residual update.
  One grid program per worker row: computes the per-tensor (per-worker)
  amax scale, the clipped/rounded int8 payload, AND the new residual
  ``e - q*scale`` in a single VMEM-resident pass, where ``e = delta +
  residual`` is the error-compensated delta.  Unfused XLA does this as
  abs/max/div/round/clip/convert/mul/sub over separate HBM round-trips;
  the kernel makes the fusion structural.
* ``dequantize_fwd`` — int8 payload × per-row scale -> f32, column-tiled.

Rows are whole (1, M) blocks so the amax reduction needs no cross-program
pass; production-scale tensors would tile columns with a two-phase amax
reduction, which we trade away for simplicity (the deltas this repo syncs
fit VMEM comfortably at the reduced configs; real fleets shard the K rows
over pods first, see ``launch/dryrun_lib.dryrun_outer_step``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128          # TPU lane width: flattened payloads pad to a multiple
SCALE_EPS = 1e-12   # matches the jnp oracle: scale = max(amax, eps) / 127


def _quantize_ef_kernel(x_ref, r_ref, q_ref, nr_ref, s_ref):
    e = x_ref[...].astype(jnp.float32) + r_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(e))
    scale = jnp.maximum(amax, SCALE_EPS) / 127.0
    q = jnp.clip(jnp.round(e / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    nr_ref[...] = e - q * scale
    s_ref[...] = jnp.full((1, 1), scale, jnp.float32)


def _dequantize_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[0, 0]


def quantize_ef_fwd(x, residual, *, interpret: bool = True):
    """x, residual: (K, M) f32 with M % LANE == 0.

    Returns ``(q, new_residual, scale)``: int8 (K, M), f32 (K, M), and the
    per-row f32 scales (K, 1).
    """
    K, M = x.shape
    assert M % LANE == 0, (K, M)
    return pl.pallas_call(
        _quantize_ef_kernel,
        grid=(K,),
        in_specs=[pl.BlockSpec((1, M), lambda i: (i, 0)),
                  pl.BlockSpec((1, M), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, M), lambda i: (i, 0)),
                   pl.BlockSpec((1, M), lambda i: (i, 0)),
                   pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((K, M), jnp.int8),
                   jax.ShapeDtypeStruct((K, M), jnp.float32),
                   jax.ShapeDtypeStruct((K, 1), jnp.float32)],
        interpret=interpret,
    )(x, residual)


def dequantize_fwd(q, scale, *, bc: int = 0, interpret: bool = True):
    """q: (K, M) int8, scale: (K, 1) f32 -> f32 (K, M)."""
    K, M = q.shape
    assert M % LANE == 0, (K, M)
    if not bc:
        bc = M
        for cand in (65536, 32768, 16384, 8192, 4096, 2048, 1024, 512, 256,
                     LANE):
            if M % cand == 0:
                bc = cand
                break
    return pl.pallas_call(
        functools.partial(_dequantize_kernel),
        grid=(K, M // bc),
        in_specs=[pl.BlockSpec((1, bc), lambda i, j: (i, j)),
                  pl.BlockSpec((1, 1), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((1, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((K, M), jnp.float32),
        interpret=interpret,
    )(q, scale)
