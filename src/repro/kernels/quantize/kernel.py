"""Fused symmetric quantization kernels for the outer-sync transport and
the quantized paged-KV cache.

One parameterized kernel pair backs every quantized wire/pool in the repo
(``Int8Symmetric`` / ``Fp8Codec`` in ``repro.core.transport``, the fp8/int8
KV pools in ``serving``):

* ``quantize_ef_fwd`` — fused quantize + error-feedback residual update,
  parameterized over the target dtype and the scale granularity.  Each
  grid program computes the amax scale of ITS block, the clipped (and,
  for int targets, rounded) narrow payload, AND the new residual
  ``e - q*scale`` in a single VMEM-resident pass, where ``e = delta +
  residual`` is the error-compensated delta.  Unfused XLA does this as
  abs/max/div/round/clip/convert/mul/sub over separate HBM round-trips;
  the kernel makes the fusion structural.
* ``dequantize_fwd`` — narrow payload × per-block scale -> f32, tiled to
  match whichever granularity produced the scales.

Supported target dtypes × scale granularities (``QMAX`` is the symmetric
clip bound; scale = max(amax, eps) / QMAX):

    dtype      QMAX     payload        granularity
    int8       127      round+clip     per-tensor row (tile=M) or per-tile
    fp8_e4m3   448      clip+RNE cast  per-tensor row (tile=M) or per-tile
    fp8_e5m2   57344    clip+RNE cast  per-tensor row (tile=M) or per-tile

Per-tensor rows are whole (1, M) blocks so the amax reduction needs no
cross-program pass; per-tile runs grid (K, M//tile) with one scale per
(row, tile).  fp8 targets clip to ±QMAX *before* the cast: e4m3fn has no
inf encoding, so an unclipped overflow would become NaN on the wire.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128          # TPU lane width: flattened payloads pad to a multiple
SCALE_EPS = 1e-12   # matches the jnp oracle: scale = max(amax, eps) / QMAX

# symmetric clip bound per target dtype (the finfo/iinfo max of each)
QMAX = {"int8": 127.0, "fp8_e4m3": 448.0, "fp8_e5m2": 57344.0}
QDTYPES = ("int8", "fp8_e4m3", "fp8_e5m2")


def target_dtype(dtype: str):
    """jnp dtype for a quantize target name (raises on unknown names)."""
    if dtype == "int8":
        return jnp.int8
    if dtype == "fp8_e4m3":
        return jnp.float8_e4m3fn
    if dtype == "fp8_e5m2":
        return jnp.float8_e5m2
    raise ValueError(f"unknown quantize target {dtype!r}; "
                     f"expected one of {QDTYPES}")


def _quantize_ef_kernel(x_ref, r_ref, q_ref, nr_ref, s_ref, *, dtype: str):
    e = x_ref[...].astype(jnp.float32) + r_ref[...].astype(jnp.float32)
    qmax = QMAX[dtype]
    amax = jnp.max(jnp.abs(e))
    scale = jnp.maximum(amax, SCALE_EPS) / qmax
    y = e / scale
    if dtype == "int8":
        y = jnp.round(y)
    q = jnp.clip(y, -qmax, qmax).astype(q_ref.dtype)
    q_ref[...] = q
    nr_ref[...] = e - q.astype(jnp.float32) * scale
    s_ref[...] = jnp.full((1, 1), scale, jnp.float32)


def _dequantize_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[0, 0]


def quantize_ef_fwd(x, residual, *, dtype: str = "int8", tile: int = 0,
                    interpret: bool = True):
    """x, residual: (K, M) f32 with M % LANE == 0.

    ``tile`` selects the scale granularity: 0 (the default) is per-tensor
    (one scale per worker row, tile = M); otherwise one scale per
    ``tile``-wide column block (M % tile == 0, tile % LANE == 0).

    Returns ``(q, new_residual, scale)``: the narrow payload (K, M), the
    f32 residual (K, M), and the f32 scales (K, M // tile).
    """
    K, M = x.shape
    assert M % LANE == 0, (K, M)
    if not tile:
        tile = M
    assert M % tile == 0 and tile % LANE == 0, (M, tile)
    n_t = M // tile
    return pl.pallas_call(
        functools.partial(_quantize_ef_kernel, dtype=dtype),
        grid=(K, n_t),
        in_specs=[pl.BlockSpec((1, tile), lambda i, j: (i, j)),
                  pl.BlockSpec((1, tile), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((1, tile), lambda i, j: (i, j)),
                   pl.BlockSpec((1, tile), lambda i, j: (i, j)),
                   pl.BlockSpec((1, 1), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((K, M), target_dtype(dtype)),
                   jax.ShapeDtypeStruct((K, M), jnp.float32),
                   jax.ShapeDtypeStruct((K, n_t), jnp.float32)],
        interpret=interpret,
    )(x, residual)


def dequantize_fwd(q, scale, *, bc: int = 0, interpret: bool = True):
    """q: (K, M) narrow payload, scale: (K, S) f32 with M % S == 0 ->
    f32 (K, M).  S == 1 is the per-tensor layout; S > 1 per-tile (the
    column-block width is M // S)."""
    K, M = q.shape
    S = scale.shape[1]
    assert M % LANE == 0 and M % S == 0, (K, M, S)
    if S > 1:
        bc = M // S              # tile width is dictated by the scales
    elif not bc:
        bc = M
        for cand in (65536, 32768, 16384, 8192, 4096, 2048, 1024, 512, 256,
                     LANE):
            if M % cand == 0:
                bc = cand
                break
    return pl.pallas_call(
        _dequantize_kernel,
        grid=(K, M // bc),
        in_specs=[pl.BlockSpec((1, bc), lambda i, j: (i, j)),
                  pl.BlockSpec((1, 1),
                               (lambda i, j: (i, j)) if S > 1 else
                               (lambda i, j: (i, 0)))],
        out_specs=pl.BlockSpec((1, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((K, M), jnp.float32),
        interpret=interpret,
    )(q, scale)
