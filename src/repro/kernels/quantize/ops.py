"""Jitted wrappers for the quantize kernels: arbitrary leaf shapes in,
flattened LANE-padded (K, M) kernel views inside.

Public surface (all parameterized over ``dtype`` in ``kernel.QDTYPES``):

* ``quantize_ef(x, residual, dtype=, tile=)`` — fused quantize +
  error-feedback residual; ``tile=0`` is per-tensor-per-row scales,
  ``tile>0`` one scale per ``tile`` flattened elements;
* ``dequantize(q, scale)`` — the inverse; granularity is inferred from
  the scale shape.

Degenerate leaves are handled here, NOT in the kernels: scalar (0-d)
params run through a (1, 1) view and 0-size sentinel leaves skip the
kernel entirely (both mirror the ``ref`` oracles bit-for-bit), so codecs
can map over any parameter pytree.

``interpret`` defaults to *backend-selected* via
``repro.kernels.common``: interpret on CPU hosts (Mosaic cannot
compile), compiled on TPU, force-overridable via
``REPRO_PALLAS_INTERPRET=0|1``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.common import (default_interpret, pallas_mode,
                                  resolve_interpret)
from repro.kernels.quantize.kernel import (LANE, dequantize_fwd,
                                           quantize_ef_fwd, target_dtype)

__all__ = ["quantize_ef", "dequantize", "default_interpret", "pallas_mode"]


def _flatten_pad(x, multiple: int = LANE) -> Tuple[jax.Array, int]:
    """(K, ...) -> (K, M) with M padded to a ``multiple`` multiple.

    Zero padding is invisible to the kernel: padded lanes contribute 0 to
    the amax, quantize to 0, and leave a 0 residual.
    """
    k = x.shape[0]
    flat = x.reshape(k, -1)
    pad = (-flat.shape[1]) % multiple
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    return flat, x.size // k


@functools.partial(jax.jit, static_argnames=("dtype", "tile", "interpret"))
def _quantize_ef(x, residual, *, dtype: str, tile: int, interpret: bool):
    xf, m = _flatten_pad(x.astype(jnp.float32), multiple=tile or LANE)
    rf = (jnp.zeros_like(xf) if residual is None
          else _flatten_pad(residual.astype(jnp.float32),
                            multiple=tile or LANE)[0])
    q, nr, s = quantize_ef_fwd(xf, rf, dtype=dtype, tile=tile,
                               interpret=interpret)
    shape = x.shape
    q = q[:, :m].reshape(shape)
    nr = nr[:, :m].reshape(shape)
    if not tile:
        s = s.reshape((shape[0],) + (1,) * (len(shape) - 1))
    return q, nr, s


def quantize_ef(x, residual=None, *, dtype: str = "int8", tile: int = 0,
                interpret: Optional[bool] = None):
    """Fused per-worker-row symmetric quantize + residual update.

    ``x``: (K, ...) delta; ``residual``: matching error-feedback carry (or
    None for plain quantization); ``dtype``: int8 / fp8_e4m3 / fp8_e5m2.
    ``tile=0`` (per-tensor) returns results shaped like the jnp oracle
    (``ref.reference_quantize_ef``); ``tile>0`` returns per-tile scales
    ``(K, padded_M // tile)`` over the flattened, zero-padded row layout.
    """
    interpret = resolve_interpret(interpret)
    if x.ndim == 0:                      # scalar param: quantize elementwise
        q, nr, s = _quantize_ef(
            x.reshape(1, 1),
            None if residual is None else residual.reshape(1, 1),
            dtype=dtype, tile=tile, interpret=interpret)
        return q.reshape(()), nr.reshape(()), s.reshape(())
    if x.size == 0:                      # 0-size sentinel leaf: no kernel
        from repro.kernels.quantize.ref import reference_quantize_ef
        return reference_quantize_ef(x, residual, dtype=dtype)
    return _quantize_ef(x, residual, dtype=dtype, tile=tile,
                        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def _dequantize(q, scale, *, tile: int, interpret: bool):
    k = q.shape[0]
    qf, m = _flatten_pad(q, multiple=tile or LANE)
    s = scale.reshape(k, 1) if not tile else scale.reshape(k, -1)
    out = dequantize_fwd(qf, s, interpret=interpret)
    return out[:, :m].reshape(q.shape)


def dequantize(q, scale, *, tile: int = 0,
               interpret: Optional[bool] = None):
    """Narrow (K, ...) payload x scales -> f32 delta.  ``tile`` must match
    the granularity ``quantize_ef`` ran with: 0 for per-tensor rows
    (scales of size K), else the per-tile width (scales
    ``(K, padded_M // tile)``)."""
    interpret = resolve_interpret(interpret)
    if q.ndim == 0:
        return _dequantize(q.reshape(1, 1), scale.reshape(1, 1),
                           tile=0, interpret=interpret).reshape(())
    if q.size == 0:
        return q.astype(jnp.float32)
    return _dequantize(q, scale, tile=tile, interpret=interpret)
