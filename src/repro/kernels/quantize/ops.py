"""Jitted wrappers for the quantize kernels: arbitrary leaf shapes in,
flattened LANE-padded (K, M) kernel views inside.

``interpret`` defaults to *backend-selected* via
``repro.kernels.common``: interpret on CPU hosts (Mosaic cannot
compile), compiled on TPU, force-overridable via
``REPRO_PALLAS_INTERPRET=0|1``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.common import (default_interpret, pallas_mode,
                                  resolve_interpret)
from repro.kernels.quantize.kernel import (LANE, dequantize_fwd,
                                           quantize_ef_fwd)

__all__ = ["quantize_ef", "dequantize", "default_interpret", "pallas_mode"]


def _flatten_pad(x) -> Tuple[jax.Array, int]:
    """(K, ...) -> (K, M) with M padded to a LANE multiple.

    Zero padding is invisible to the kernel: padded lanes contribute 0 to
    the amax, quantize to 0, and leave a 0 residual.
    """
    k = x.shape[0]
    flat = x.reshape(k, -1)
    pad = (-flat.shape[1]) % LANE
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    return flat, x.size // k


@functools.partial(jax.jit, static_argnames=("interpret",))
def _quantize_ef(x, residual, *, interpret: bool):
    xf, m = _flatten_pad(x.astype(jnp.float32))
    rf = (jnp.zeros_like(xf) if residual is None
          else _flatten_pad(residual.astype(jnp.float32))[0])
    q, nr, s = quantize_ef_fwd(xf, rf, interpret=interpret)
    shape = x.shape
    q = q[:, :m].reshape(shape)
    nr = nr[:, :m].reshape(shape)
    s = s.reshape((shape[0],) + (1,) * (len(shape) - 1))
    return q, nr, s


def quantize_ef(x, residual=None, *, interpret: Optional[bool] = None):
    """Fused per-worker-row symmetric int8 quantize + residual update.

    ``x``: (K, ...) delta; ``residual``: matching error-feedback carry (or
    None for plain quantization).  Returns ``(q, new_residual, scale)``
    shaped like the jnp oracle (``ref.reference_quantize_ef``).
    """
    interpret = resolve_interpret(interpret)
    return _quantize_ef(x, residual, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _dequantize(q, scale, *, interpret: bool):
    qf, m = _flatten_pad(q)
    out = dequantize_fwd(qf, scale.reshape(q.shape[0], 1),
                         interpret=interpret)
    return out[:, :m].reshape(q.shape)


def dequantize(q, scale, *, interpret: Optional[bool] = None):
    """int8 (K, ...) payload x per-row scale -> f32 delta."""
    interpret = resolve_interpret(interpret)
    return _dequantize(q, scale, interpret=interpret)
