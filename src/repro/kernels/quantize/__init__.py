from repro.kernels.quantize.kernel import QDTYPES, QMAX, target_dtype
from repro.kernels.quantize.ops import dequantize, quantize_ef
from repro.kernels.quantize.ref import (fast_dequant_cast,
                                        reference_dequantize,
                                        reference_quantize_axis,
                                        reference_quantize_ef)

__all__ = ["quantize_ef", "dequantize", "reference_quantize_ef",
           "reference_quantize_axis", "reference_dequantize",
           "fast_dequant_cast", "QDTYPES", "QMAX", "target_dtype"]
