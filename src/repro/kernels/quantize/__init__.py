from repro.kernels.quantize.ops import dequantize, quantize_ef
from repro.kernels.quantize.ref import (reference_dequantize,
                                        reference_quantize_ef)

__all__ = ["quantize_ef", "dequantize", "reference_quantize_ef",
           "reference_dequantize"]
