"""Pure-jnp oracle for the fused quantize+error-feedback kernels.

These are the semantics of record: the Pallas kernels must match them
bit-for-bit (same round/clip ops on the same f32 intermediates), and the
transport codecs fall back to them wherever a Pallas call is undesirable
(sharded multi-pod lowering, property tests over many shapes).
"""
from __future__ import annotations

import jax.numpy as jnp

SCALE_EPS = 1e-12


def reference_quantize_ef(x, residual=None):
    """Per-row symmetric int8 quantization with error feedback.

    ``x``: (K, ...) f32 — one row per worker; scales reduce over every
    non-leading axis (per-tensor-per-worker).  Returns ``(q, new_residual,
    scale)`` with ``scale`` keepdims-shaped ``(K, 1, ..., 1)``.
    """
    e = x.astype(jnp.float32)
    if residual is not None:
        e = e + residual.astype(jnp.float32)
    axes = tuple(range(1, e.ndim))
    amax = jnp.max(jnp.abs(e), axis=axes, keepdims=True) if axes else \
        jnp.abs(e)
    scale = jnp.maximum(amax, SCALE_EPS) / 127.0
    q = jnp.clip(jnp.round(e / scale), -127, 127).astype(jnp.int8)
    new_residual = e - q.astype(jnp.float32) * scale
    return q, new_residual, scale


def reference_dequantize(q, scale):
    return q.astype(jnp.float32) * scale
