"""Pure-jnp oracles for the fused quantize+error-feedback kernels.

These are the semantics of record: the Pallas kernels must match them
bit-for-bit (same clip/round/cast ops on the same f32 intermediates), and
the transport codecs fall back to them wherever a Pallas call is
undesirable (sharded multi-pod lowering, property tests over many shapes).

The dtype × granularity matrix mirrors ``kernel.QMAX``:

* ``reference_quantize_ef``   — per-tensor-per-worker scales (reduce over
  every non-leading axis), int8 / fp8_e4m3 / fp8_e5m2 targets, fused
  error-feedback residual;
* ``reference_quantize_axis`` — per-tile scales (reduce over ONE axis,
  keepdims), the oracle for the per-tile kernel path and the primitive
  the quantized KV pool quantizes heads with;
* ``reference_dequantize``    — payload × broadcastable scale -> f32.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.quantize.kernel import QMAX, target_dtype

SCALE_EPS = 1e-12


def _narrow(e, scale, dtype: str):
    """Shared clip(+round for int targets) + cast; fp8 clips BEFORE the
    cast because e4m3fn saturates to NaN, not inf."""
    qmax = QMAX[dtype]
    y = e / scale
    if dtype == "int8":
        y = jnp.round(y)
    return jnp.clip(y, -qmax, qmax).astype(target_dtype(dtype))


def reference_quantize_ef(x, residual=None, dtype: str = "int8"):
    """Per-row symmetric quantization with error feedback.

    ``x``: (K, ...) f32 — one row per worker; scales reduce over every
    non-leading axis (per-tensor-per-worker).  Returns ``(q, new_residual,
    scale)`` with ``scale`` keepdims-shaped ``(K, 1, ..., 1)``.  Scalar
    (0-d) leaves quantize elementwise; 0-size sentinel leaves pass through
    with unit scales.
    """
    e = x.astype(jnp.float32)
    if residual is not None:
        e = e + residual.astype(jnp.float32)
    axes = tuple(range(1, e.ndim))
    if e.size == 0:
        # 0-size sentinel leaf: nothing to scale — unit scales keep the
        # keepdims shape contract and decode back to the same empty leaf
        scale = jnp.ones(e.shape[:1] + (1,) * len(axes), jnp.float32)
        return e.astype(target_dtype(dtype)), e, scale
    amax = jnp.max(jnp.abs(e), axis=axes, keepdims=True) if axes else \
        jnp.abs(e)
    scale = jnp.maximum(amax, SCALE_EPS) / QMAX[dtype]
    q = _narrow(e, scale, dtype)
    new_residual = e - q.astype(jnp.float32) * scale
    return q, new_residual, scale


def reference_quantize_axis(x, axis: int = -1, dtype: str = "fp8_e4m3"):
    """Per-tile symmetric quantization: one amax scale per slice along
    ``axis`` (keepdims).  No error feedback — this is the oracle for the
    per-tile kernel path and the KV-pool append primitive (axis = head
    dim -> per-token-per-head scales).  Returns ``(q, scale)``.
    """
    e = x.astype(jnp.float32)
    if e.size == 0:
        shape = list(e.shape)
        shape[axis] = 1
        return e.astype(target_dtype(dtype)), jnp.ones(shape, jnp.float32)
    amax = jnp.max(jnp.abs(e), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, SCALE_EPS) / QMAX[dtype]
    return _narrow(e, scale, dtype), scale


def reference_dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def fast_dequant_cast(q):
    """Narrow payload -> f32, bitwise-identical to ``astype(float32)``.

    fp8 -> f32 on CPU XLA lowers to per-element software emulation, which
    dominates the dequant-on-load hot path; a 1-byte payload has only 256
    bit patterns, so the convert is a table gather instead.  int8 and
    wider payloads keep the plain cast (already a vectorized convert)."""
    import jax

    if q.dtype in (jnp.float8_e4m3fn, jnp.float8_e5m2):
        table = jnp.arange(256, dtype=jnp.uint8).view(q.dtype).astype(
            jnp.float32)
        return table[jax.lax.bitcast_convert_type(q, jnp.uint8)]
    return q.astype(jnp.float32)
