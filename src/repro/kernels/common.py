"""Shared interpret-mode resolution for every Pallas kernel package,
plus the fp8 per-tile QK^T contraction the attention kernels share.

One override point for the whole kernel suite: ``interpret`` defaults to
*backend-selected* — the Pallas interpreter is only used on CPU hosts
(where Mosaic cannot compile); on TPU the kernels compile.
``REPRO_PALLAS_INTERPRET=0|1`` force-overrides the selection, and
``pallas_mode()`` reports the resolved mode so benchmarks can record
which path actually ran.

Every ``kernels/<name>/ops.py`` must resolve ``interpret`` through this
module (enforced by the ``kernel-contract`` lint pass in
``repro.tools.lint``) instead of keeping a private copy or hardcoding a
default — a hardcoded ``interpret=True`` silently runs the Python-speed
interpreter on TPU.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["default_interpret", "pallas_mode", "resolve_interpret",
           "qk_dot_fp8", "FP8_QMAX"]

FP8_QMAX = 448.0        # float8_e4m3fn saturation (matches quantize.QMAX)


def qk_dot_fp8(q, k, *, narrow_dot: bool):
    """fp8 per-tile QK^T for attention kernel bodies: quantize each ROW of
    the f32 ``q`` (rows, D) and ``k`` (cols, D) tiles to fp8_e4m3 with its
    own amax scale, contract over D, and rescale by the outer product of
    the row scales (scales factor out of the dot exactly).

    ``narrow_dot=True`` feeds the narrow tiles straight to the MXU
    (``preferred_element_type=f32`` accumulate) — the TPU fast path;
    ``narrow_dot=False`` (CPU / Pallas interpreter, where fp8 matmul units
    don't exist) upcasts the already-quantized tiles and contracts in f32:
    identical quantization numerics, full-precision multiply.  Returns
    (rows, cols) f32 scores.
    """
    dims = (((1,), (1,)), ((), ()))
    qs = jnp.maximum(jnp.max(jnp.abs(q), axis=1, keepdims=True),
                     1e-12) / FP8_QMAX
    ks = jnp.maximum(jnp.max(jnp.abs(k), axis=1, keepdims=True),
                     1e-12) / FP8_QMAX
    q8 = jnp.clip(q / qs, -FP8_QMAX, FP8_QMAX).astype(jnp.float8_e4m3fn)
    k8 = jnp.clip(k / ks, -FP8_QMAX, FP8_QMAX).astype(jnp.float8_e4m3fn)
    if not narrow_dot:
        q8, k8 = q8.astype(jnp.float32), k8.astype(jnp.float32)
    s = jax.lax.dot_general(q8, k8, dims,
                            preferred_element_type=jnp.float32)
    return s * qs * ks[:, 0][None, :]


def default_interpret() -> bool:
    """Interpret only where Mosaic can't compile (CPU), unless overridden."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() == "cpu"


def pallas_mode() -> str:
    """'interpret' or 'compiled' — what the kernels will actually run as."""
    return "interpret" if default_interpret() else "compiled"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """``None`` -> the backend-selected default; explicit bools pass through."""
    return default_interpret() if interpret is None else bool(interpret)
