"""Shared interpret-mode resolution for every Pallas kernel package.

One override point for the whole kernel suite: ``interpret`` defaults to
*backend-selected* — the Pallas interpreter is only used on CPU hosts
(where Mosaic cannot compile); on TPU the kernels compile.
``REPRO_PALLAS_INTERPRET=0|1`` force-overrides the selection, and
``pallas_mode()`` reports the resolved mode so benchmarks can record
which path actually ran.

Every ``kernels/<name>/ops.py`` must resolve ``interpret`` through this
module (enforced by the ``kernel-contract`` lint pass in
``repro.tools.lint``) instead of keeping a private copy or hardcoding a
default — a hardcoded ``interpret=True`` silently runs the Python-speed
interpreter on TPU.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

__all__ = ["default_interpret", "pallas_mode", "resolve_interpret"]


def default_interpret() -> bool:
    """Interpret only where Mosaic can't compile (CPU), unless overridden."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() == "cpu"


def pallas_mode() -> str:
    """'interpret' or 'compiled' — what the kernels will actually run as."""
    return "interpret" if default_interpret() else "compiled"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """``None`` -> the backend-selected default; explicit bools pass through."""
    return default_interpret() if interpret is None else bool(interpret)
