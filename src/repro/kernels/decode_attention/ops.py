"""Jitted wrappers for the decode attention Pallas kernels.

``interpret`` defaults to *backend-selected* via
``repro.kernels.common``: the Pallas interpreter is only used on CPU
hosts (where Mosaic cannot compile); on TPU the kernels compile.
``REPRO_PALLAS_INTERPRET=0|1`` force-overrides the selection, and
``pallas_mode()`` reports the resolved mode so benchmarks can record
which path actually ran.  (``default_interpret``/``pallas_mode`` are
re-exported here for backward compatibility — ``repro.kernels.common``
is the canonical home.)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.common import (default_interpret, pallas_mode,
                                  resolve_interpret)
from repro.kernels.decode_attention.kernel import (
    decode_attention_fwd, paged_decode_attention_dequant_fwd,
    paged_decode_attention_fwd, paged_verify_attention_dequant_fwd,
    paged_verify_attention_fwd)

__all__ = ["decode_attention", "paged_decode_attention",
           "paged_decode_attention_dequant", "paged_verify_attention",
           "paged_verify_attention_dequant", "default_interpret",
           "pallas_mode"]


@functools.partial(jax.jit, static_argnames=("window", "bk", "interpret"))
def _decode_attention(q, k, v, pos, q_pos, *, window, bk, interpret):
    return decode_attention_fwd(q, k, v, pos, q_pos, window=window, bk=bk,
                                interpret=interpret)


def decode_attention(q, k, v, pos, q_pos, *, window: int = 0, bk: int = 256,
                     interpret: Optional[bool] = None):
    interpret = resolve_interpret(interpret)
    return _decode_attention(q, k, v, pos, q_pos, window=window, bk=bk,
                             interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "interpret", "fp8"))
def _paged_decode_attention(q, k_pool, v_pool, block_tables, q_pos, *,
                            window, interpret, fp8):
    return paged_decode_attention_fwd(q, k_pool, v_pool, block_tables, q_pos,
                                      window=window, interpret=interpret,
                                      fp8=fp8)


def paged_decode_attention(q, k_pool, v_pool, block_tables, q_pos, *,
                           window: int = 0,
                           interpret: Optional[bool] = None,
                           fp8: bool = False):
    """Block-table-indexed decode attention (see kernel.py for shapes).
    ``fp8`` runs QK^T on per-row fp8 tiles (``ModelConfig.fp8_matmul``)."""
    interpret = resolve_interpret(interpret)
    return _paged_decode_attention(q, k_pool, v_pool, block_tables, q_pos,
                                   window=window, interpret=interpret,
                                   fp8=fp8)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def _paged_decode_attention_dequant(q, k_pool, v_pool, k_scale, v_scale,
                                    block_tables, q_pos, *, window,
                                    interpret):
    return paged_decode_attention_dequant_fwd(
        q, k_pool, v_pool, k_scale, v_scale, block_tables, q_pos,
        window=window, interpret=interpret)


def paged_decode_attention_dequant(q, k_pool, v_pool, k_scale, v_scale,
                                   block_tables, q_pos, *, window: int = 0,
                                   interpret: Optional[bool] = None):
    """Quantized-pool paged decode attention: narrow K/V payload plus
    (NB, bs, KV) f32 scales, dequantized on load (see kernel.py)."""
    interpret = resolve_interpret(interpret)
    return _paged_decode_attention_dequant(
        q, k_pool, v_pool, k_scale, v_scale, block_tables, q_pos,
        window=window, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def _paged_verify_attention_dequant(q, k_pool, v_pool, k_scale, v_scale,
                                    block_tables, start_pos, n_tokens, *,
                                    window, interpret):
    return paged_verify_attention_dequant_fwd(
        q, k_pool, v_pool, k_scale, v_scale, block_tables, start_pos,
        n_tokens, window=window, interpret=interpret)


def paged_verify_attention_dequant(q, k_pool, v_pool, k_scale, v_scale,
                                   block_tables, start_pos, n_tokens, *,
                                   window: int = 0,
                                   interpret: Optional[bool] = None):
    """Quantized-pool multi-query paged decode attention — the speculative-
    verification variant with dequant-on-load (see kernel.py)."""
    interpret = resolve_interpret(interpret)
    return _paged_verify_attention_dequant(
        q, k_pool, v_pool, k_scale, v_scale, block_tables, start_pos,
        n_tokens, window=window, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "interpret", "fp8"))
def _paged_verify_attention(q, k_pool, v_pool, block_tables, start_pos,
                            n_tokens, *, window, interpret, fp8):
    return paged_verify_attention_fwd(q, k_pool, v_pool, block_tables,
                                      start_pos, n_tokens, window=window,
                                      interpret=interpret, fp8=fp8)


def paged_verify_attention(q, k_pool, v_pool, block_tables, start_pos,
                           n_tokens, *, window: int = 0,
                           interpret: Optional[bool] = None,
                           fp8: bool = False):
    """Multi-query-per-slot paged decode attention — the speculative-
    verification variant (see kernel.py for shapes).  ``fp8`` runs QK^T
    on per-row fp8 tiles (``ModelConfig.fp8_matmul``)."""
    interpret = resolve_interpret(interpret)
    return _paged_verify_attention(q, k_pool, v_pool, block_tables,
                                   start_pos, n_tokens, window=window,
                                   interpret=interpret, fp8=fp8)
