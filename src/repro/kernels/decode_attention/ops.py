"""Jitted wrapper for the decode attention Pallas kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attention.kernel import decode_attention_fwd


@functools.partial(jax.jit, static_argnames=("window", "bk", "interpret"))
def decode_attention(q, k, v, pos, q_pos, *, window: int = 0, bk: int = 256,
                     interpret: bool = True):
    return decode_attention_fwd(q, k, v, pos, q_pos, window=window, bk=bk,
                                interpret=interpret)
