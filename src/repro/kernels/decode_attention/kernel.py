"""Single-token GQA decode attention — Pallas TPU kernel (the serving
hot-spot: one query against a long KV cache).

Differences from the prefill flash kernel:

* Sq = 1: the query tile is a (G, D) block (all heads of one KV group),
  so the MXU contraction is (G, D) x (D, BK) — head-dim contraction keeps
  the systolic array busy even with a single token;
* the cache may be a ring buffer: validity comes from an explicit per-slot
  ``pos`` array (−1 = empty, else absolute position), with causal +
  sliding-window predicates evaluated against the query's position —
  layout-free, so prefill-then-wrap caches need no compaction;
* grid = (B, KV, S/BK): the KV-block sweep is minor-most, so the online
  softmax state (m, l, acc) lives in VMEM scratch across the sweep.

Validated in interpret mode against ``ref.reference_decode_attention``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import qk_dot_fp8

NEG_INF = -1e30
DEFAULT_BK = 256


def _qk(q, k, *, fp8: bool, narrow_dot: bool):
    """The QK^T contraction every kernel body below shares: f32 dot, or
    the per-row fp8 tile path (``common.qk_dot_fp8``) behind ``fp8``."""
    if fp8:
        return qk_dot_fp8(q, k, narrow_dot=narrow_dot)
    return jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)


def _decode_kernel(qpos_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float, window: int,
                   bk: int, n_k: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)               # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)               # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)               # (bk, D)
    pos = pos_ref[0]                                  # (bk,) int32
    q_pos = qpos_ref[0]                               # scalar int32

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    ok = (pos >= 0) & (pos <= q_pos)
    if window > 0:
        ok &= (q_pos - pos) < window
    s = jnp.where(ok[None, :], s, NEG_INF)            # (G, bk)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == n_k - 1)
    def _fin():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def _paged_decode_kernel(tab_ref, qpos_ref, q_ref, k_ref, v_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, scale: float, window: int,
                         bs: int, n_b: int, fp8: bool = False,
                         narrow_dot: bool = False):
    s_idx = pl.program_id(0)
    ib = pl.program_id(2)

    @pl.when(ib == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)               # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)            # (bs, D)
    v = v_ref[0, :, 0].astype(jnp.float32)            # (bs, D)
    q_pos = qpos_ref[s_idx]                           # scalar int32
    mapped = tab_ref[s_idx, ib] >= 0                  # −1 = unmapped block

    s = _qk(q, k, fp8=fp8, narrow_dot=narrow_dot) * scale
    # blocks hold contiguous positions: logical position = ib*bs + lane
    k_pos = ib * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)[0]
    ok = (k_pos <= q_pos) & mapped
    if window > 0:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok[None, :], s, NEG_INF)            # (G, bs)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ib == n_b - 1)
    def _fin():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def _paged_decode_dequant_kernel(tab_ref, qpos_ref, q_ref, k_ref, v_ref,
                                 ks_ref, vs_ref, o_ref, m_scr, l_scr,
                                 acc_scr, *, scale: float, window: int,
                                 bs: int, n_b: int):
    """Quantized-pool variant of ``_paged_decode_kernel``: the K/V tiles
    arrive in the pool's narrow dtype (int8 / fp8) and are dequantized
    on load with the per-token-per-head scale tiles riding the same
    block-table index map — the wide cache never exists in VMEM either."""
    s_idx = pl.program_id(0)
    ib = pl.program_id(2)

    @pl.when(ib == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)               # (G, D)
    ks = ks_ref[0, :, 0]                              # (bs,) f32
    vs = vs_ref[0, :, 0]
    k = k_ref[0, :, 0].astype(jnp.float32) * ks[:, None]   # (bs, D)
    v = v_ref[0, :, 0].astype(jnp.float32) * vs[:, None]
    q_pos = qpos_ref[s_idx]                           # scalar int32
    mapped = tab_ref[s_idx, ib] >= 0                  # −1 = unmapped block

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    k_pos = ib * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)[0]
    ok = (k_pos <= q_pos) & mapped
    if window > 0:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok[None, :], s, NEG_INF)            # (G, bs)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ib == n_b - 1)
    def _fin():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def _paged_verify_dequant_kernel(tab_ref, start_ref, ntok_ref, q_ref, k_ref,
                                 v_ref, ks_ref, vs_ref, o_ref, m_scr, l_scr,
                                 acc_scr, *, scale: float, window: int,
                                 bs: int, n_b: int, T: int, G: int):
    """Quantized-pool variant of ``_paged_verify_kernel`` (see
    ``_paged_decode_dequant_kernel`` for the dequant-on-load contract)."""
    s_idx = pl.program_id(0)
    ib = pl.program_id(2)

    @pl.when(ib == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0].astype(jnp.float32).reshape(T * G, -1)   # (T*G, D)
    ks = ks_ref[0, :, 0]                              # (bs,) f32
    vs = vs_ref[0, :, 0]
    k = k_ref[0, :, 0].astype(jnp.float32) * ks[:, None]   # (bs, D)
    v = v_ref[0, :, 0].astype(jnp.float32) * vs[:, None]
    start = start_ref[s_idx]                          # scalar int32
    n_tok = ntok_ref[s_idx]                           # scalar int32
    mapped = tab_ref[s_idx, ib] >= 0                  # −1 = unmapped block

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    row_t = jax.lax.broadcasted_iota(jnp.int32, (T * G, 1), 0) // G
    q_pos = start + row_t                             # (T*G, 1)
    valid = (start >= 0) & (row_t < n_tok)
    k_pos = ib * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    ok = valid & mapped & (k_pos <= q_pos)
    if window > 0:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok, s, NEG_INF)                     # (T*G, bs)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ib == n_b - 1)
    def _fin():
        o_ref[0, :, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                          ).reshape(T, G, -1).astype(o_ref.dtype)


def _paged_verify_kernel(tab_ref, start_ref, ntok_ref, q_ref, k_ref, v_ref,
                         o_ref, m_scr, l_scr, acc_scr, *, scale: float,
                         window: int, bs: int, n_b: int, T: int, G: int,
                         fp8: bool = False, narrow_dot: bool = False):
    """Multi-query-per-slot variant: the q tile holds T query tokens per
    slot (speculative verification / multi-token prefill), occupying
    contiguous positions ``start .. start + n - 1``.  Rows are (T, G)
    flattened to (T*G, D) so the MXU contraction stays a single dot; the
    causal predicate is evaluated per row group against the row's own
    position ``start + t``."""
    s_idx = pl.program_id(0)
    ib = pl.program_id(2)

    @pl.when(ib == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0].astype(jnp.float32).reshape(T * G, -1)   # (T*G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)            # (bs, D)
    v = v_ref[0, :, 0].astype(jnp.float32)            # (bs, D)
    start = start_ref[s_idx]                          # scalar int32
    n_tok = ntok_ref[s_idx]                           # scalar int32
    mapped = tab_ref[s_idx, ib] >= 0                  # −1 = unmapped block

    s = _qk(q, k, fp8=fp8, narrow_dot=narrow_dot) * scale
    # row r of the flattened tile is query token t = r // G at absolute
    # position start + t; tokens beyond n_tok are padding (fully masked)
    row_t = jax.lax.broadcasted_iota(jnp.int32, (T * G, 1), 0) // G
    q_pos = start + row_t                             # (T*G, 1)
    valid = (start >= 0) & (row_t < n_tok)
    k_pos = ib * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    ok = valid & mapped & (k_pos <= q_pos)
    if window > 0:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok, s, NEG_INF)                     # (T*G, bs)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ib == n_b - 1)
    def _fin():
        o_ref[0, :, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                          ).reshape(T, G, -1).astype(o_ref.dtype)


def paged_verify_attention_fwd(q, k_pool, v_pool, block_tables, start_pos,
                               n_tokens, *, window: int = 0,
                               interpret: bool = True, fp8: bool = False):
    """Multi-query block-table-indexed decode attention (speculative
    verification): each slot attends with T query tokens at contiguous
    positions ``start_pos[s] + t`` (t < ``n_tokens[s]``; the rest are
    padding whose rows come back garbage the caller must ignore).

    q: (S, T, KV, G, D); k_pool/v_pool: (NB, bs, KV, D); block_tables:
    (S, MB) int32 (−1 = unmapped); start_pos: (S,) int32 (−1 = inactive
    slot); n_tokens: (S,) int32 live query tokens per slot.  The fresh K/V
    for all T tokens must already be scattered into the pool — causality
    among them is purely positional, exactly like the single-query kernel.
    Returns (S, T, KV, G, D)."""
    S, T, KV, G, D = q.shape
    NB, bs = k_pool.shape[:2]
    MB = block_tables.shape[1]
    scale = 1.0 / math.sqrt(D)
    kernel = functools.partial(_paged_verify_kernel, scale=scale,
                               window=window, bs=bs, n_b=MB, T=T, G=G,
                               fp8=fp8, narrow_dot=fp8 and not interpret)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(S, KV, MB),
        in_specs=[
            pl.BlockSpec((1, T, 1, G, D),
                         lambda s, h, ib, tab, st, nt: (s, 0, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda s, h, ib, tab, st, nt:
                         (jnp.maximum(tab[s, ib], 0), 0, h, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda s, h, ib, tab, st, nt:
                         (jnp.maximum(tab[s, ib], 0), 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, T, 1, G, D),
                               lambda s, h, ib, tab, st, nt: (s, 0, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((T * G, 1), jnp.float32),
            pltpu.VMEM((T * G, 1), jnp.float32),
            pltpu.VMEM((T * G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S, T, KV, G, D), q.dtype),
        interpret=interpret,
    )(block_tables, start_pos, n_tokens, q, k_pool, v_pool)


def paged_verify_attention_dequant_fwd(q, k_pool, v_pool, k_scale, v_scale,
                                       block_tables, start_pos, n_tokens, *,
                                       window: int = 0,
                                       interpret: bool = True):
    """Quantized-pool multi-query paged decode attention: ``k_pool`` /
    ``v_pool`` hold the narrow payload (int8 / fp8) and ``k_scale`` /
    ``v_scale`` the (NB, bs, KV) f32 per-token-per-head amax scales;
    tiles are dequantized on load inside the kernel.  Shapes otherwise
    as ``paged_verify_attention_fwd``."""
    S, T, KV, G, D = q.shape
    NB, bs = k_pool.shape[:2]
    MB = block_tables.shape[1]
    scale = 1.0 / math.sqrt(D)
    kernel = functools.partial(_paged_verify_dequant_kernel, scale=scale,
                               window=window, bs=bs, n_b=MB, T=T, G=G)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(S, KV, MB),
        in_specs=[
            pl.BlockSpec((1, T, 1, G, D),
                         lambda s, h, ib, tab, st, nt: (s, 0, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda s, h, ib, tab, st, nt:
                         (jnp.maximum(tab[s, ib], 0), 0, h, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda s, h, ib, tab, st, nt:
                         (jnp.maximum(tab[s, ib], 0), 0, h, 0)),
            pl.BlockSpec((1, bs, 1),
                         lambda s, h, ib, tab, st, nt:
                         (jnp.maximum(tab[s, ib], 0), 0, h)),
            pl.BlockSpec((1, bs, 1),
                         lambda s, h, ib, tab, st, nt:
                         (jnp.maximum(tab[s, ib], 0), 0, h)),
        ],
        out_specs=pl.BlockSpec((1, T, 1, G, D),
                               lambda s, h, ib, tab, st, nt: (s, 0, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((T * G, 1), jnp.float32),
            pltpu.VMEM((T * G, 1), jnp.float32),
            pltpu.VMEM((T * G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S, T, KV, G, D), q.dtype),
        interpret=interpret,
    )(block_tables, start_pos, n_tokens, q, k_pool, v_pool,
      k_scale, v_scale)


def paged_decode_attention_dequant_fwd(q, k_pool, v_pool, k_scale, v_scale,
                                       block_tables, q_pos, *,
                                       window: int = 0,
                                       interpret: bool = True):
    """Quantized-pool single-token paged decode attention (see
    ``paged_verify_attention_dequant_fwd`` for the scale contract).
    Shapes otherwise as ``paged_decode_attention_fwd``."""
    S, KV, G, D = q.shape
    NB, bs = k_pool.shape[:2]
    MB = block_tables.shape[1]
    scale = 1.0 / math.sqrt(D)
    kernel = functools.partial(_paged_decode_dequant_kernel, scale=scale,
                               window=window, bs=bs, n_b=MB)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(S, KV, MB),
        in_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda s, h, ib, tab, qp: (s, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda s, h, ib, tab, qp:
                         (jnp.maximum(tab[s, ib], 0), 0, h, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda s, h, ib, tab, qp:
                         (jnp.maximum(tab[s, ib], 0), 0, h, 0)),
            pl.BlockSpec((1, bs, 1),
                         lambda s, h, ib, tab, qp:
                         (jnp.maximum(tab[s, ib], 0), 0, h)),
            pl.BlockSpec((1, bs, 1),
                         lambda s, h, ib, tab, qp:
                         (jnp.maximum(tab[s, ib], 0), 0, h)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda s, h, ib, tab, qp: (s, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S, KV, G, D), q.dtype),
        interpret=interpret,
    )(block_tables, q_pos, q, k_pool, v_pool, k_scale, v_scale)


def paged_decode_attention_fwd(q, k_pool, v_pool, block_tables, q_pos, *,
                               window: int = 0, interpret: bool = True,
                               fp8: bool = False):
    """Block-table-indexed decode attention over a shared paged KV pool.

    q: (S, KV, G, D) one token per active slot; k_pool/v_pool: (NB, bs, KV, D)
    fixed-size physical blocks; block_tables: (S, MB) int32 — logical block j
    of slot s lives in physical block ``block_tables[s, j]`` (−1 = unmapped);
    q_pos: (S,) int32 absolute query positions (−1 = inactive slot).

    The block table is a scalar-prefetch operand, so the per-(slot, block)
    pool tile is DMA'd straight from the physical block the table names — the
    gather never materializes a per-slot contiguous cache.  Validity is
    positional (blocks hold contiguous positions), so stale pool contents
    beyond ``q_pos`` and unmapped table slots are masked, never read into the
    softmax.  Returns (S, KV, G, D)."""
    S, KV, G, D = q.shape
    NB, bs = k_pool.shape[:2]
    MB = block_tables.shape[1]
    scale = 1.0 / math.sqrt(D)
    kernel = functools.partial(_paged_decode_kernel, scale=scale,
                               window=window, bs=bs, n_b=MB,
                               fp8=fp8, narrow_dot=fp8 and not interpret)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(S, KV, MB),
        in_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda s, h, ib, tab, qp: (s, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda s, h, ib, tab, qp:
                         (jnp.maximum(tab[s, ib], 0), 0, h, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda s, h, ib, tab, qp:
                         (jnp.maximum(tab[s, ib], 0), 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda s, h, ib, tab, qp: (s, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S, KV, G, D), q.dtype),
        interpret=interpret,
    )(block_tables, q_pos, q, k_pool, v_pool)


def decode_attention_fwd(q, k, v, pos, q_pos, *, window: int = 0,
                         bk: int = DEFAULT_BK, interpret: bool = True):
    """q: (B, KV, G, D) one token per request, grouped query heads;
    k/v: (B, KV, S, D) cache; pos: (B, S) int32 slot positions (−1 empty);
    q_pos: (B,) int32 absolute query positions.  Returns (B, KV, G, D)."""
    B, KV, G, D = q.shape
    S = k.shape[2]
    bk = min(bk, S)
    assert S % bk == 0, (S, bk)
    n_k = S // bk
    scale = 1.0 / math.sqrt(D)
    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               bk=bk, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(B, KV, n_k),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ik: (b,)),            # q_pos
            pl.BlockSpec((1, 1, G, D), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, bk), lambda b, h, ik: (b, ik)),       # pos
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(q_pos, q, k, v, pos)
