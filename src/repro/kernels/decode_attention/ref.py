"""Pure-jnp oracle for the decode attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def _fp8_rows(x):
    """Quantize-dequantize each row of ``x`` (..., D) through fp8_e4m3
    with a per-row amax scale — the oracle-side view of the kernels'
    ``fp8=True`` QK^T tiles (scales factor out of the dot exactly)."""
    from repro.kernels.quantize import reference_quantize_axis
    xq, s = reference_quantize_axis(x.astype(jnp.float32), axis=-1,
                                    dtype="fp8_e4m3")
    return (xq.astype(jnp.float32) * s).astype(x.dtype)


def reference_decode_attention(q, k, v, pos, q_pos, window: int = 0):
    """q: (B,KV,G,D); k/v: (B,KV,S,D); pos: (B,S); q_pos: (B,)."""
    D = q.shape[-1]
    s = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    ok = (pos >= 0) & (pos <= q_pos[:, None])
    if window > 0:
        ok &= (q_pos[:, None] - pos) < window
    s = jnp.where(ok[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgs,bhsd->bhgd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def reference_paged_verify_attention(q, k_pool, v_pool, block_tables,
                                     start_pos, n_tokens, window: int = 0):
    """Multi-query paged variant (speculative verification): slot s attends
    with T query tokens at contiguous positions ``start_pos[s] + t``; tokens
    with ``t >= n_tokens[s]`` (and whole slots with ``start_pos[s] < 0``)
    are padding whose rows are garbage the caller must ignore.

    q: (S,T,KV,G,D); k_pool/v_pool: (NB,bs,KV,D); block_tables: (S,MB);
    start_pos/n_tokens: (S,) int32.  Returns (S,T,KV,G,D)."""
    S, T, KV, G, D = q.shape
    NB, bs = k_pool.shape[:2]
    MB = block_tables.shape[1]
    safe = jnp.maximum(block_tables, 0)
    k = k_pool[safe].reshape(S, MB * bs, KV, D)
    v = v_pool[safe].reshape(S, MB * bs, KV, D)
    q_pos = start_pos[:, None] + jnp.arange(T)[None, :]        # (S, T)
    valid = (start_pos[:, None] >= 0) & (jnp.arange(T)[None, :]
                                         < n_tokens[:, None])
    k_pos = jnp.arange(MB * bs)[None, None, :]                 # (1, 1, L)
    ok = ((k_pos <= q_pos[:, :, None]) & valid[:, :, None]
          & jnp.repeat(block_tables >= 0, bs, axis=1)[:, None, :])
    if window > 0:
        ok &= (q_pos[:, :, None] - k_pos) < window
    s = jnp.einsum("bthgd,bshd->bhgts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    s = jnp.where(ok[:, None, None, :, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgts,bshd->bthgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def reference_paged_decode_attention_fp8(q, k_pool, v_pool, block_tables,
                                         q_pos, window: int = 0):
    """Oracle for the single-token paged kernel's ``fp8=True`` QK^T path:
    Q rows and pooled K rows pass through per-row fp8 quantization before
    the plain oracle; V is untouched (the PV matmul stays f32)."""
    return reference_paged_decode_attention(
        _fp8_rows(q), _fp8_rows(k_pool), v_pool, block_tables, q_pos,
        window=window)


def reference_paged_verify_attention_fp8(q, k_pool, v_pool, block_tables,
                                         start_pos, n_tokens,
                                         window: int = 0):
    """Oracle for the multi-query paged kernel's ``fp8=True`` QK^T path
    (see ``reference_paged_decode_attention_fp8``)."""
    return reference_paged_verify_attention(
        _fp8_rows(q), _fp8_rows(k_pool), v_pool, block_tables, start_pos,
        n_tokens, window=window)


def reference_paged_verify_attention_dequant(q, k_pool, v_pool, k_scale,
                                             v_scale, block_tables, start_pos,
                                             n_tokens, window: int = 0):
    """Quantized-pool oracle: dequantize the narrow (int8 / fp8) pool with
    its (NB, bs, KV) f32 per-token-per-head scales, then run the plain
    multi-query oracle.  The Pallas kernel fuses the dequant into the tile
    load; this materializes the wide pool instead — same math."""
    k = k_pool.astype(jnp.float32) * k_scale[..., None]
    v = v_pool.astype(jnp.float32) * v_scale[..., None]
    return reference_paged_verify_attention(
        q, k.astype(q.dtype), v.astype(q.dtype), block_tables, start_pos,
        n_tokens, window=window)


def reference_paged_decode_attention_dequant(q, k_pool, v_pool, k_scale,
                                             v_scale, block_tables, q_pos,
                                             window: int = 0):
    """Quantized-pool oracle for the single-token paged kernel (see
    ``reference_paged_verify_attention_dequant`` for the scale contract)."""
    k = k_pool.astype(jnp.float32) * k_scale[..., None]
    v = v_pool.astype(jnp.float32) * v_scale[..., None]
    return reference_paged_decode_attention(
        q, k.astype(q.dtype), v.astype(q.dtype), block_tables, q_pos,
        window=window)


def reference_paged_decode_attention(q, k_pool, v_pool, block_tables, q_pos,
                                     window: int = 0):
    """Paged variant: the KV cache is a shared pool of fixed-size blocks and
    each sequence maps logical block j to physical block ``block_tables[s,j]``
    (−1 = unmapped).  Blocks hold contiguous positions, so logical slot i of
    sequence s carries position i; validity is purely positional:
    ``i <= q_pos[s]`` (and inside the sliding window, when one is set).

    q: (S,KV,G,D); k_pool/v_pool: (NB,bs,KV,D); block_tables: (S,MB) int32;
    q_pos: (S,) int32 (−1 = inactive slot).  Returns (S,KV,G,D)."""
    S, KV, G, D = q.shape
    NB, bs = k_pool.shape[:2]
    MB = block_tables.shape[1]
    safe = jnp.maximum(block_tables, 0)                    # (S, MB)
    k = k_pool[safe].reshape(S, MB * bs, KV, D)            # (S, L, KV, D)
    v = v_pool[safe].reshape(S, MB * bs, KV, D)
    k_pos = jnp.arange(MB * bs)[None, :]                   # logical positions
    ok = (k_pos <= q_pos[:, None]) & jnp.repeat(block_tables >= 0, bs, axis=1)
    if window > 0:
        ok &= (q_pos[:, None] - k_pos) < window
    s = jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    s = jnp.where(ok[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgs,bshd->bhgd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
