"""Pure-jnp oracle for the decode attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def reference_decode_attention(q, k, v, pos, q_pos, window: int = 0):
    """q: (B,KV,G,D); k/v: (B,KV,S,D); pos: (B,S); q_pos: (B,)."""
    D = q.shape[-1]
    s = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    ok = (pos >= 0) & (pos <= q_pos[:, None])
    if window > 0:
        ok &= (q_pos[:, None] - pos) < window
    s = jnp.where(ok[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgs,bhsd->bhgd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
