from repro.kernels.decode_attention.ops import (decode_attention,
                                                default_interpret,
                                                paged_decode_attention,
                                                paged_verify_attention,
                                                pallas_mode)
from repro.kernels.decode_attention.ref import (
    reference_decode_attention, reference_paged_decode_attention,
    reference_paged_verify_attention)

__all__ = ["decode_attention", "paged_decode_attention",
           "paged_verify_attention", "reference_decode_attention",
           "reference_paged_decode_attention",
           "reference_paged_verify_attention",
           "default_interpret", "pallas_mode"]
