from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import reference_decode_attention

__all__ = ["decode_attention", "reference_decode_attention"]
