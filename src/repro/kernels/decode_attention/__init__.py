from repro.kernels.decode_attention.ops import (
    decode_attention, default_interpret, paged_decode_attention,
    paged_decode_attention_dequant, paged_verify_attention,
    paged_verify_attention_dequant, pallas_mode)
from repro.kernels.decode_attention.ref import (
    reference_decode_attention, reference_paged_decode_attention,
    reference_paged_decode_attention_dequant,
    reference_paged_decode_attention_fp8,
    reference_paged_verify_attention,
    reference_paged_verify_attention_dequant,
    reference_paged_verify_attention_fp8)

__all__ = ["decode_attention", "paged_decode_attention",
           "paged_decode_attention_dequant", "paged_verify_attention",
           "paged_verify_attention_dequant", "reference_decode_attention",
           "reference_paged_decode_attention",
           "reference_paged_decode_attention_dequant",
           "reference_paged_decode_attention_fp8",
           "reference_paged_verify_attention",
           "reference_paged_verify_attention_dequant",
           "reference_paged_verify_attention_fp8",
           "default_interpret", "pallas_mode"]
