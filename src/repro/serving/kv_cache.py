"""Paged KV-cache block pool — the host-side allocator behind the
continuous-batching engine.

The device side is a pool of ``num_blocks`` fixed-size KV blocks per layer
(see ``repro.models.transformer.init_paged_cache``); this module owns the
*mapping*: which physical blocks belong to which request, which are free,
and the padded per-slot block tables the jitted step consumes.  Blocks hold
contiguous positions (logical position i of a request lives at offset
``i % block_size`` of its ``i // block_size``-th block), so device-side
validity is purely positional and the allocator never has to touch device
memory to recycle a block — stale contents are masked by the position gate
until overwritten.

Two-level accounting: admission **reserves** a block *budget* up front (so
a running request can never hit a mid-flight pool OOM) while physical
blocks are **mapped** lazily as positions are written.  This split is what
makes rollback and recycling cheap:

* ``truncate(slot, pos)`` — speculative-decode rollback: physical blocks
  wholly beyond ``pos`` return to the free list but their budget stays
  with the slot (the positions will be re-fed with accepted tokens);
* sliding-window recycling (``Scheduler.recycle_window``) frees blocks
  that fell out of the attention window the same way — and because a
  windowed slot's *budget* only covers the live window (not the full
  prompt+gen span), admission capacity for windowed archs scales with the
  window, not the sequence length.

Prefix sharing adds a per-block **refcount ledger**: a block attached by
several owners (the prefix tree plus any number of slots serving the same
prompt prefix) carries one reference per owner, ``free`` drops one
reference, and the block only returns to the free list at refcount 0.
``free(rereserve=True)`` on a still-shared block raises — speculative
rollback and window recycling re-credit a slot's private budget, and a
shared block was never part of it, so reclaiming one is structurally a
bug, not a policy choice.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np


class KVBlockPool:
    """Fixed-size block allocator (free-list) with a reservation ledger and
    per-block refcounts.  Raises on double-alloc / double-free /
    over-reserve / shared-block reclaim so scheduler bugs surface as
    exceptions, not silent KV corruption."""

    def __init__(self, num_blocks: int, block_size: int,
                 bytes_per_block: int = 0):
        assert num_blocks > 0 and block_size > 0
        self.num_blocks = num_blocks
        self.block_size = block_size
        # device-side cost of one block across all layers (payload + scale
        # planes for quantized pools — see transformer.paged_block_bytes);
        # 0 = unknown.  Pure metadata: capacity reports denominate in bytes,
        # admission stays block-granular.
        self.bytes_per_block = bytes_per_block
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._allocated: set = set()
        self._refcount: Dict[int, int] = {}  # allocated block -> owners
        self._reserved = 0          # budgeted-but-unmapped blocks

    # -- queries ------------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_allocated(self) -> int:
        return len(self._allocated)

    @property
    def num_reserved(self) -> int:
        return self._reserved

    @property
    def total_bytes(self) -> int:
        """Device bytes the whole pool costs (0 when untracked)."""
        return self.num_blocks * self.bytes_per_block

    @property
    def num_shared(self) -> int:
        """Blocks with more than one owner (prefix-cache sharing)."""
        return sum(1 for c in self._refcount.values() if c > 1)

    def refcount(self, block: int) -> int:
        """Owner count of an allocated block (0 for free blocks)."""
        return self._refcount.get(block, 0)

    def blocks_for(self, num_tokens: int) -> int:
        """Blocks needed to hold ``num_tokens`` cache entries."""
        return -(-max(num_tokens, 0) // self.block_size)

    def can_allocate(self, n: int) -> bool:
        """Whether n blocks can be allocated OUTSIDE any reservation."""
        return n <= len(self._free) - self._reserved

    can_reserve = can_allocate      # same ledger: unreserved free blocks

    # -- reservation (admission-time budget) --------------------------------
    def reserve(self, n: int) -> None:
        if not self.can_reserve(n):
            raise RuntimeError(
                f"KV pool over-reserve: want {n} blocks, "
                f"{len(self._free) - self._reserved} unreserved free")
        self._reserved += n

    def release(self, n: int) -> None:
        if n > self._reserved:
            raise RuntimeError(f"release {n} > reserved {self._reserved}")
        self._reserved -= n

    # -- alloc / free -------------------------------------------------------
    def alloc(self, n: int, *, reserved: bool = False) -> List[int]:
        """Pop n physical blocks.  ``reserved=True`` draws them down from
        an existing reservation (always succeeds while the reservation
        invariant ``reserved <= free`` holds); ``reserved=False`` may only
        take unreserved blocks."""
        avail = len(self._free) if reserved else \
            len(self._free) - self._reserved
        if n > avail:
            raise RuntimeError(
                f"KV pool exhausted: want {n} blocks, {avail} "
                f"{'reserved-' if reserved else 'unreserved '}free")
        if reserved:
            self._reserved -= n
        out = [self._free.pop() for _ in range(n)]
        self._allocated.update(out)
        for b in out:
            self._refcount[b] = 1
        return out

    def incref(self, block: int) -> None:
        """Add an owner to an allocated block (prefix-cache attachment:
        the tree on insert, a slot on admission)."""
        if block not in self._allocated:
            raise RuntimeError(f"incref on unallocated block {block}")
        self._refcount[block] += 1

    def free(self, blocks: Sequence[int], *, rereserve: bool = False) -> None:
        """Drop one reference per block; blocks reaching refcount 0 return
        to the free list.  ``rereserve=True`` re-credits their budget
        (rollback/recycling: the slot keeps the right to map replacements)
        and therefore REFUSES still-shared blocks: a shared prefix block
        was never part of any slot's private budget, so reclaiming one
        through rollback/recycling is a scheduler bug."""
        if len(set(blocks)) != len(blocks):
            raise RuntimeError(f"duplicate blocks in free: {list(blocks)}")
        for b in blocks:      # validate before mutating anything
            if b not in self._allocated:
                raise RuntimeError(f"double-free / foreign block {b}")
            if rereserve and self._refcount[b] > 1:
                raise RuntimeError(
                    f"rereserve-free of shared block {b} "
                    f"(refcount {self._refcount[b]})")
        for b in blocks:
            if self._refcount[b] > 1:
                self._refcount[b] -= 1
                continue
            del self._refcount[b]
            self._allocated.remove(b)
            self._free.append(b)
        if rereserve:
            self._reserved += len(blocks)

    # -- speculative-decode rollback ----------------------------------------
    def truncate(self, slot, pos: int) -> int:
        """Roll a slot's mapping back to ``pos`` committed tokens: physical
        blocks wholly beyond the committed prefix (logical index >=
        ``blocks_for(pos)``) return to the free list, their budget going
        back to the slot (``slot.reserved``) so the positions can be
        re-mapped when real tokens arrive.  ``slot`` is duck-typed: it
        needs ``blocks`` (logical->physical list, −1 = unmapped) and a
        ``reserved`` counter.  Stale device contents need no touch — the
        position gate masks them until overwritten.  Returns the number of
        blocks reclaimed."""
        keep = self.blocks_for(pos)
        dead = [b for b in slot.blocks[keep:] if b >= 0]
        if dead:
            self.free(dead, rereserve=True)     # pool-wide ledger
            slot.reserved += len(dead)          # the slot's share of it
        del slot.blocks[keep:]
        return len(dead)

    def check_invariants(self) -> None:
        """free ∪ allocated must partition [0, num_blocks) exactly, the
        reservation ledger must be covered by free blocks, and the refcount
        ledger must cover exactly the allocated set with positive counts."""
        free = set(self._free)
        if len(free) != len(self._free):
            raise AssertionError("duplicate block on the free list")
        if free & self._allocated:
            raise AssertionError(
                f"blocks both free and allocated: {free & self._allocated}")
        if free | self._allocated != set(range(self.num_blocks)):
            raise AssertionError("leaked or out-of-range blocks")
        if not 0 <= self._reserved <= len(self._free):
            raise AssertionError(
                f"reservation ledger broken: {self._reserved} reserved, "
                f"{len(self._free)} free")
        if set(self._refcount) != self._allocated:
            raise AssertionError(
                "refcount ledger out of sync with the allocated set: "
                f"{set(self._refcount) ^ self._allocated}")
        bad = {b: c for b, c in self._refcount.items() if c < 1}
        if bad:
            raise AssertionError(f"non-positive refcounts: {bad}")


def pad_block_table(blocks: Sequence[int], max_blocks: int) -> np.ndarray:
    """(max_blocks,) int32 table row; −1 marks unmapped logical blocks."""
    assert len(blocks) <= max_blocks, (len(blocks), max_blocks)
    row = np.full((max_blocks,), -1, np.int32)
    row[:len(blocks)] = blocks
    return row
