"""Paged KV-cache block pool — the host-side allocator behind the
continuous-batching engine.

The device side is a pool of ``num_blocks`` fixed-size KV blocks per layer
(see ``repro.models.transformer.init_paged_cache``); this module owns the
*mapping*: which physical blocks belong to which request, which are free,
and the padded per-slot block tables the jitted step consumes.  Blocks hold
contiguous positions (logical position i of a request lives at offset
``i % block_size`` of its ``i // block_size``-th block), so device-side
validity is purely positional and the allocator never has to touch device
memory to recycle a block — stale contents are masked by the position gate
until overwritten.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np


class KVBlockPool:
    """Fixed-size block allocator (free-list).  Raises on double-alloc /
    double-free so scheduler bugs surface as exceptions, not silent KV
    corruption."""

    def __init__(self, num_blocks: int, block_size: int):
        assert num_blocks > 0 and block_size > 0
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._allocated: set = set()

    # -- queries ------------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_allocated(self) -> int:
        return len(self._allocated)

    def blocks_for(self, num_tokens: int) -> int:
        """Blocks needed to hold ``num_tokens`` cache entries."""
        return -(-max(num_tokens, 0) // self.block_size)

    def can_allocate(self, n: int) -> bool:
        return n <= len(self._free)

    # -- alloc / free -------------------------------------------------------
    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"KV pool exhausted: want {n} blocks, {len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        self._allocated.update(out)
        return out

    def free(self, blocks: Sequence[int]) -> None:
        for b in blocks:
            if b not in self._allocated:
                raise RuntimeError(f"double-free / foreign block {b}")
            self._allocated.remove(b)
            self._free.append(b)

    def check_invariants(self) -> None:
        """free ∪ allocated must partition [0, num_blocks) exactly."""
        free = set(self._free)
        if len(free) != len(self._free):
            raise AssertionError("duplicate block on the free list")
        if free & self._allocated:
            raise AssertionError(
                f"blocks both free and allocated: {free & self._allocated}")
        if free | self._allocated != set(range(self.num_blocks)):
            raise AssertionError("leaked or out-of-range blocks")


def pad_block_table(blocks: Sequence[int], max_blocks: int) -> np.ndarray:
    """(max_blocks,) int32 table row; −1 marks unmapped logical blocks."""
    assert len(blocks) <= max_blocks, (len(blocks), max_blocks)
    row = np.full((max_blocks,), -1, np.int32)
    row[:len(blocks)] = blocks
    return row
