from repro.serving.engine import Engine

__all__ = ["Engine"]
