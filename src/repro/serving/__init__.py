from repro.serving.drafter import propose as draft_propose
from repro.serving.engine import Engine
from repro.serving.kv_cache import KVBlockPool, pad_block_table
from repro.serving.prefix_tree import PrefixTree
from repro.serving.scheduler import Request, Scheduler

__all__ = ["Engine", "KVBlockPool", "PrefixTree", "Request", "Scheduler",
           "pad_block_table", "draft_propose"]
