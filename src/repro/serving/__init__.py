from repro.serving.drafter import propose as draft_propose
from repro.serving.engine import Engine
from repro.serving.kv_cache import KVBlockPool, pad_block_table
from repro.serving.scheduler import Request, Scheduler

__all__ = ["Engine", "KVBlockPool", "Request", "Scheduler",
           "pad_block_table", "draft_propose"]
