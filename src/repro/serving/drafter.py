"""Prompt-lookup / n-gram drafter for speculative decoding.

A zero-parameter host-side proposer (the "self-drafting" in self-drafting
slots): the draft for a slot is whatever followed the most recent earlier
occurrence of the slot's current suffix n-gram in its OWN token history
(prompt + generated so far).  No extra model, no device work — the cost is
a numpy sliding-window match over a few hundred ints, amortized against a
full model forward.  This is the prompt-lookup decoding trick
(transformers' ``prompt_lookup_num_tokens``): extremely effective on
extraction/summarization-style traffic and on the repetitive tails greedy
decoding produces, and harmless (drafts are simply rejected) elsewhere.

The drafter is intentionally *deterministic*: a slot's proposal is a pure
function of its own history, so speculative sampling keyed by
``(seed, rid, position)`` stays schedule-independent — which requests
shared the batch can never change another request's tokens.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np


def _match_once(arr: np.ndarray, k: int, max_n: int, min_n: int
                ) -> List[int]:
    """One suffix-n-gram lookup over ``arr``; up to ``k`` continuation
    tokens from the most recent earlier occurrence, [] on miss."""
    H = len(arr)
    for n in range(min(max_n, H - 1), min_n - 1, -1):
        suffix = arr[H - n:]
        # windows [i, i+n) over everything before the suffix's last token,
        # so a match always has at least one continuation token
        win = np.lib.stride_tricks.sliding_window_view(arr[:H - 1], n)
        hits = np.nonzero((win == suffix).all(axis=1))[0]
        if hits.size:
            i = int(hits[-1])                 # most recent occurrence
            cont = arr[i + n:i + n + k]
            if cont.size:
                return [int(t) for t in cont]
    return []


def propose(history: Sequence[int], k: int, max_n: int = 3,
            min_n: int = 1) -> List[int]:
    """Draft up to ``k`` tokens continuing ``history``.

    Matches the longest suffix n-gram (``max_n`` down to ``min_n``) against
    the rest of the history; on a hit, proposes the tokens that followed
    the MOST RECENT earlier occurrence.  When the match lands near the end
    of the history the continuation truncates, so matching re-runs on the
    extended sequence until the budget fills or a lookup misses — on a
    periodic tail (the common greedy regime) this unrolls the loop to the
    full ``k`` instead of stopping at the period.  Returns [] when nothing
    matches (the engine then falls back to plain one-token decoding for
    the round)."""
    H = len(history)
    if k <= 0 or H < min_n + 1:
        return []
    arr = np.asarray(history, dtype=np.int64)
    out: List[int] = []
    while len(out) < k:
        cont = _match_once(arr, k - len(out), max_n, min_n)
        if not cont:
            break
        out.extend(cont)
        arr = np.concatenate([arr, np.asarray(cont, np.int64)])
    return out
