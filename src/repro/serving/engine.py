"""Continuous-batching inference engine (nanochat ships a small engine + web
UI; this is the JAX equivalent, built on the models' paged decode path).

Layered design:

* ``repro.serving.kv_cache``  — paged KV-block pool (host allocator; the
  device pool lives in ``models.transformer.init_paged_cache``);
* ``repro.serving.scheduler`` — admission / eviction over a fixed slot set
  (FIFO or longest-prefill-first), block-budget reservation + lazy mapping;
* ``repro.serving.drafter``   — zero-cost prompt-lookup n-gram drafter;
* this module              — the persistent decode loop: ONE jitted step over
  the whole slot set, compiled once, with position-gated masking so slots at
  different generation depths coexist.

Two persistent-step shapes, selected by ``spec_k``:

**spec_k == 0 (sequential scan step).**  Each call scans ``prefill_chunk``
token-steps: every slot either consumes its *scripted* pending tokens (the
prompt, fed in chunks — chunked prefill) or chains on its own samples, so
prefill and decode tokens coexist in the same batched step and the pool
round-trip + dispatch cost is amortized over ``num_slots × prefill_chunk``
token-slots.

**spec_k > 0 (speculative verify step).**  The draft→verify→rollback
contract:

* **draft** — per round, each decoding slot's host-side n-gram drafter
  (``drafter.propose``) proposes up to ``spec_k`` tokens continuing the
  slot's own history; the script row is ``[carry, d_1 .. d_m]`` padded to
  ``spec_k + 1`` and masked per slot (``n_feed``), so arbitrary mixes of
  prefilling / drafting / draft-less slots hit the SAME jitted step —
  prefill chunks are just scripts with no drafts;
* **verify** — ONE multi-token forward (``verify_step_paged`` →
  ``paged_verify_attention``) scores all ``spec_k + 1`` positions of every
  slot simultaneously: the same position-gated masking chunked prefill
  relies on makes causality among the fresh tokens purely positional,
  so one model traversal replaces ``m + 1`` sequential ones;
* **accept** — greedy slots accept the longest draft prefix matching the
  argmax chain (bit-exact vs the non-speculative engine by construction:
  every emitted token is the target model's own next token given the
  accepted prefix).  Sampling slots run rejection sampling against the
  deterministic drafter (q = point mass): accept ``d`` with probability
  ``p(d)``, else emit a sample from the residual ``p`` with ``d`` zeroed
  and renormalized — provably the target softmax distribution, with all
  draws keyed by ``(seed, rid, position)`` so tokens stay
  schedule-independent;
* **rollback** — rejected suffixes need no device work (the position gate
  masks cache entries beyond the committed position until overwritten);
  the host rewinds ``slot.pos`` and ``KVBlockPool.truncate`` reclaims
  whole blocks past the committed prefix, re-crediting the slot's
  reservation so the positions re-map when real tokens arrive.

The legacy static-bucket path (LEFT-padded batch, one ``lax.scan`` compile
per ``(batch, lengths)`` bucket) is kept as ``generate_ids_static`` — it is
the reference for the greedy-equivalence tests and the baseline arm of
``benchmarks/serving_bench.py``.  ``generate_ids`` / ``chat`` are thin
wrappers that route through the scheduler whenever the architecture supports
the paged cache.

Note on SSM/hybrid archs: the paged cache is position-gated — stale block
contents are *masked*, not cleared, which is only sound when every read is
gated on the token's absolute position (attention).  An SSM recurrence
updates its O(1) state unconditionally, so a freed-and-reused slot would
leak state across requests — and for the same reason speculative decoding
cannot roll an SSM back: rejecting a draft suffix would need the recurrent
state *before* the rejected tokens, which the unconditional update has
already destroyed.  ssm/hybrid (and encoder-decoder) archs therefore fall
back to the static-bucket path, where ragged batches should use same-length
prompts (documented limitation; the paper's nanochat model is dense
attention).

Quantized KV pools (``cfg.kv_cache_dtype`` int8 / fp8 / fp8_e5m2) store the
paged pool in a 1-byte payload plus f32 per-token-per-head scales —
roughly half the bytes of a bf16 pool per block — so a byte-budget engine
(``pool_bytes``) fits proportionally more blocks and admits more
concurrent requests in the same device budget.  Quantize-on-scatter /
dequant-on-load happens inside ``models.attention.paged_decode_attention``;
the speculative and sequential loops are unchanged and stay bit-exact with
each other under greedy decoding (both read the same quantized pool).  The
SSM/hybrid static fallback ignores ``kv_cache_dtype``: its ring-buffer
cache is not paged, and the recurrent state cannot be position-gated.

Uniform sliding-window archs additionally recycle KV blocks per slot: once
every position in a block falls ``window`` behind the committed position it
can never be attended again, so the block returns to the pool mid-request
(the block-table entry goes to −1, which both kernels and the jnp path mask)
and admission budgets cover only the live window — capacity scales with the
window, not prompt+max_new.

Prefix sharing (``prefix_cache=True``) puts a radix tree
(``serving.prefix_tree``) over the pool: requests whose prompts share a
block-aligned prefix share the physical blocks holding it.  What is
shared: *full* blocks of prompt tokens only — written once at the
original prefill and never rewritten, because generated tokens land at
positions ≥ the prompt length and speculative rollback never rewinds
below the committed prompt, so ``truncate`` structurally cannot touch a
shared block (and the pool's refcount ledger raises if it ever tried).
The COW boundary rule: a partially matched block (the match ends
mid-block) is copy-on-write — the new request gets a private block from
its own budget, the engine copies the source block's device contents
before the slot's first step, and positions beyond the matched length
are ordinary stale garbage masked by the position gate until
overwritten.  The matched prefix skips prefill entirely: the slot starts
at ``pos = matched_len`` with its block table pointing at the shared
blocks — the position-gated paged kernels need no device-side change —
and only the tail is chunk-prefilled.  The tree and the host block pool
persist across ``run()`` calls (the device pool already does), so a warm
cache keeps paying off; LRU eviction (``prefix_cache_blocks``) bounds
its residency, and admission evicts LRU cache blocks under pool
pressure before refusing a request.  Sliding-window recycling frees
prompt blocks mid-request — incompatible with sharers attaching them —
so windowed archs bypass the cache (``prefix_cache`` is ignored).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# The ONLY device->host fetch point for the step loops below.  Each step
# makes one batched fetch per jitted call; the host-sync-in-hot-path lint
# pass allowlists this name, so new fetches must route through it (or argue
# their case with an explicit suppression).
_fetch = np.asarray

from repro.data.tokenizer import BPETokenizer
from repro.models.transformer import (ModelAPI, paged_block_bytes,
                                      paged_cache_supported)
from repro.serving import drafter as drafter_mod
from repro.serving.kv_cache import KVBlockPool, pad_block_table
from repro.serving.scheduler import Request, Scheduler


def _left_pad(prompts: Sequence[Sequence[int]], pad_id: int
              ) -> Tuple[np.ndarray, np.ndarray]:
    tp = max(len(p) for p in prompts)
    out = np.full((len(prompts), tp), pad_id, np.int32)
    lens = np.zeros((len(prompts),), np.int32)
    for i, p in enumerate(prompts):
        out[i, tp - len(p):] = p
        lens[i] = len(p)
    return out, lens


@dataclasses.dataclass
class Engine:
    model: ModelAPI
    params: object
    tok: Optional[BPETokenizer] = None
    max_len: int = 256                 # per-request prompt+gen capacity
                                       # (pool bytes scale with it; requests
                                       # beyond it fall back to the static
                                       # path, which is unbounded)
    num_slots: int = 8                 # concurrent sequences in the step
    block_size: int = 16               # KV tokens per pool block
    num_blocks: Optional[int] = None   # pool size; default fits all slots
    pool_bytes: Optional[int] = None   # byte budget for the pool instead:
                                       # num_blocks = bytes // block cost, so
                                       # a quantized kv_cache_dtype fits
                                       # proportionally more blocks (and thus
                                       # admits more requests) in the SAME
                                       # device budget
    prefill_chunk: int = 8             # token-steps per scan-step call
    spec_k: int = 0                    # speculative draft length; 0 = the
                                       # sequential scan step (no drafting)
    draft_ngram: int = 3               # longest suffix n-gram the prompt-
                                       # lookup drafter matches on
    policy: str = "fifo"               # admission: fifo | longest_prefill
                                       # | cache_aware
    attn_impl: Optional[str] = None    # None=auto: pallas kernel off-CPU
    prefix_cache: bool = False         # share prompt-prefix KV blocks via
                                       # a radix tree (dense archs only;
                                       # windowed archs bypass it)
    prefix_cache_blocks: Optional[int] = None   # LRU bound on resident
                                       # cache blocks (None = pool-bounded)

    def __post_init__(self):
        self._gen_fn = jax.jit(self._generate_scan,
                               static_argnames=("max_new", "greedy"))
        self.continuous = paged_cache_supported(self.model.cfg)
        if not self.continuous:
            return
        self._mb = -(-self.max_len // self.block_size)   # blocks per slot
        self.bytes_per_block = paged_block_bytes(self.model.cfg,
                                                 self.block_size)
        if self.num_blocks is None:
            if self.pool_bytes is not None:
                self.num_blocks = max(
                    self.pool_bytes // self.bytes_per_block, 1)
            else:
                self.num_blocks = self.num_slots * self._mb
        self.capacity = self._mb * self.block_size
        self._pool = None       # device pool allocated lazily on first run()
                                # so score-/static-only engines don't hold
                                # num_blocks x block_size KV slots per layer
        cfg = self.model.cfg
        # uniform sliding window -> per-slot block recycling is sound (every
        # layer shares the same window; heterogeneous window_pattern pools
        # must keep blocks alive for the largest window, incl. global=0)
        self._recycle_w = int(cfg.window) \
            if (cfg.window and not cfg.window_pattern) else 0
        # prefix cache: tree + host pool persist across run() calls (the
        # device pool already does), so shared blocks stay warm between
        # streams; window recycling frees prompt blocks mid-request, which
        # would yank them out from under sharers -> windowed archs bypass
        self._tree = None
        self._host_pool = None
        if self.prefix_cache and not self._recycle_w:
            from repro.serving.prefix_tree import PrefixTree
            self._tree = PrefixTree(self.block_size,
                                    self.prefix_cache_blocks or 0)

            def copy_block(pool, src, dst):
                # COW boundary fork: duplicate one physical block across
                # every pool leaf (payload + quantized scale planes all
                # index blocks on axis 1); src/dst are traced scalars, so
                # the jit compiles once for any block pair
                return jax.tree_util.tree_map(
                    lambda a: a.at[:, dst].set(a[:, src]), pool)

            self._copy_fn = jax.jit(copy_block, donate_argnums=(0,))
        if self.attn_impl is None:
            self.attn_impl = ("pallas" if jax.default_backend() == "tpu"
                              else "jnp")
        impl = self.attn_impl if self.attn_impl == "pallas" else None
        model = self.model
        T = self.prefill_chunk
        W = self.spec_k + 1

        def step(params, pool, script, n_script, start_pos, table, temps,
                 greedy, base_key, rids):
            """T token-steps over the whole slot set.  script: (S, T) pending
            tokens (prompt chunk, or the carry token for decoding slots);
            n_script: (S,) how many are scripted — beyond that a slot chains
            on its own samples; start_pos: (S,) first write position (−1 =
            inactive).  Returns (pool, samples (S, T)) where samples[:, t]
            is the token sampled after feeding token t."""
            active = start_pos >= 0

            def body(carry, t):
                pool, prev = carry
                tok = jnp.where(t < n_script, script[:, t], prev)
                pos = jnp.where(active, start_pos + t, -1)
                logits, pool = model.decode_step_paged(
                    params, pool, {"token": tok[:, None], "position": pos,
                                   "block_table": table}, impl=impl)
                logits = logits[:, 0].astype(jnp.float32)    # (S, V)
                greedy_tok = jnp.argmax(logits, axis=-1)
                # per-request PRNG stream: key = f(seed, rid, position) — the
                # sample for a given position is deterministic no matter how
                # requests were scheduled around it
                keys = jax.vmap(lambda r, q: jax.random.fold_in(
                    jax.random.fold_in(base_key, r), q))(rids, pos)
                temp = jnp.maximum(jnp.where(greedy, 1.0, temps), 1e-6)
                sampled = jax.vmap(jax.random.categorical)(
                    keys, logits / temp[:, None])
                nxt = jnp.where(greedy, greedy_tok, sampled).astype(jnp.int32)
                return (pool, nxt), nxt

            (pool, _), samples = jax.lax.scan(
                body, (pool, jnp.zeros(script.shape[:1], jnp.int32)),
                jnp.arange(T))
            return pool, samples.T                           # (S, T)

        # the pool is donated: each round consumes the previous round's
        # buffers in place (the engine never reads a superseded pool), which
        # drops a pool-sized copy per call
        self._step_fn = jax.jit(step, donate_argnums=(1,))

        def verify(params, pool, script, start_pos, n_feed, table, temps,
                   greedy, base_key, rids):
            """One speculative round: ALL W = spec_k+1 scripted positions of
            every slot scored in ONE forward.  script: (S, W) = [carry,
            draft_1..draft_m] for decoding slots / a prompt chunk for
            prefilling ones; n_feed: (S,) live tokens (the rest is padding,
            masked); start_pos: (S,) first write position (−1 = inactive).
            Returns (pool, greedy_tok, sampled, accept, resid) all (S, W):
            per fed position t — the argmax token, a plain categorical
            sample, whether rejection sampling accepts the NEXT scripted
            token (u < p(script[t+1])), and a sample from the residual
            distribution (p with that draft zeroed, renormalized)."""
            t_idx = jnp.arange(W)[None, :]
            live = (start_pos[:, None] >= 0) & (t_idx < n_feed[:, None])
            pos = jnp.where(live, start_pos[:, None] + t_idx, -1)
            logits, pool = model.verify_step_paged(
                params, pool, {"tokens": script, "positions": pos,
                               "block_table": table}, impl=impl)
            logits = logits.astype(jnp.float32)              # (S, W, V)
            greedy_tok = jnp.argmax(logits, axis=-1)
            keys = jax.vmap(jax.vmap(
                lambda r, q: jax.random.fold_in(
                    jax.random.fold_in(base_key, r), q),
                in_axes=(None, 0)))(rids, pos)               # (S, W) keys
            temp = jnp.maximum(jnp.where(greedy, 1.0, temps), 1e-6)
            scaled = logits / temp[:, None, None]
            sampled = jax.vmap(jax.vmap(jax.random.categorical))(keys, scaled)
            probs = jax.nn.softmax(scaled, axis=-1)
            # rejection sampling vs the DETERMINISTIC drafter (q = point
            # mass on the draft token): accept with prob p(draft); the
            # residual is exactly p minus that mass, renormalized — together
            # they reproduce the target softmax distribution
            nxt = jnp.roll(script, -1, axis=1)               # draft at t+1
            p_draft = jnp.take_along_axis(probs, nxt[..., None],
                                          axis=-1)[..., 0]
            u = jax.vmap(jax.vmap(lambda k: jax.random.uniform(
                jax.random.fold_in(k, 1))))(keys)
            accept = u < p_draft
            resid_logits = jnp.where(
                jax.nn.one_hot(nxt, scaled.shape[-1], dtype=bool),
                -jnp.inf, scaled)
            rkeys = jax.vmap(jax.vmap(
                lambda k: jax.random.fold_in(k, 2)))(keys)
            resid = jax.vmap(jax.vmap(jax.random.categorical))(rkeys,
                                                               resid_logits)
            return (pool, greedy_tok.astype(jnp.int32),
                    sampled.astype(jnp.int32), accept,
                    resid.astype(jnp.int32))

        self._verify_fn = jax.jit(verify, donate_argnums=(1,))

        def verify_greedy(params, pool, script, start_pos, n_feed, table):
            """Greedy-only verify round: argmax chain, no sampling machinery
            (softmax / categorical / residual draws are dead weight when
            every active slot is greedy — the common serving regime the
            drafter targets).  Returns (pool, greedy_tok (S, W))."""
            t_idx = jnp.arange(W)[None, :]
            live = (start_pos[:, None] >= 0) & (t_idx < n_feed[:, None])
            pos = jnp.where(live, start_pos[:, None] + t_idx, -1)
            logits, pool = model.verify_step_paged(
                params, pool, {"tokens": script, "positions": pos,
                               "block_table": table}, impl=impl)
            return pool, jnp.argmax(logits.astype(jnp.float32),
                                    axis=-1).astype(jnp.int32)

        self._verify_greedy_fn = jax.jit(verify_greedy, donate_argnums=(1,))

    # ======================================================================
    # Continuous decode loop (the scheduler path)
    # ======================================================================

    def _make_sched(self, round_tokens: int) -> Scheduler:
        if self._tree is not None:
            # persistent host pool: at run end every slot has finished, so
            # only the tree's refcounts survive — exactly the resident
            # prefix cache the next run's admissions match against
            if self._host_pool is None:
                self._host_pool = KVBlockPool(
                    self.num_blocks, self.block_size,
                    bytes_per_block=self.bytes_per_block)
            pool = self._host_pool
        else:
            pool = KVBlockPool(self.num_blocks, self.block_size,
                               bytes_per_block=self.bytes_per_block)
        sched = Scheduler(self.num_slots, pool, self._mb, self.policy,
                          window=self._recycle_w, tree=self._tree)
        sched.chunk_tokens = round_tokens
        return sched

    def kv_report(self) -> Dict[str, object]:
        """Static KV-pool facts for serving reports: the storage format
        ``cfg.kv_cache_dtype`` resolved to, and what the pool costs."""
        from repro.models.attention import kv_pool_dtype
        cfg = self.model.cfg
        return {
            "kv_cache_dtype": cfg.kv_cache_dtype or "compute",
            "kv_pool_dtype": str(kv_pool_dtype(cfg)),
            "block_size": self.block_size,
            "num_blocks": self.num_blocks,
            "bytes_per_block": self.bytes_per_block,
            "pool_bytes": self.num_blocks * self.bytes_per_block,
        }

    def _prep_round(self, sched: Scheduler, act: List[int],
                    tables: np.ndarray, round_tokens,
                    stats: Dict[str, float]) -> None:
        """Recycle dead window blocks, lazily map the blocks this round
        writes (``round_tokens``: int, or a per-slot (S,) array), and
        refresh the padded block tables where the mapping changed."""
        for si in act:
            slot = sched.slots[si]
            n = int(round_tokens[si]) if isinstance(round_tokens, np.ndarray)\
                else int(round_tokens)
            recycled = sched.recycle_window(si)
            stats["recycled_blocks"] += recycled
            if sched.ensure_mapped(si, slot.pos + n - 1) or recycled:
                # stale table entries for truncated logical blocks beyond
                # the round's live range are positionally masked, so the
                # rebuild can wait until the mapping actually changes
                tables[si] = pad_block_table(slot.blocks, self._mb)
                self._tdirty = True

    def _expire_due(self, sched: Scheduler, now_v: float, use_time: bool,
                    tables: np.ndarray, stats: Dict[str, float]) -> None:
        """Evict requests past their deadline (graceful degradation).
        Only meaningful under ``use_time`` — without it ``now`` is inf and
        every deadline would fire spuriously."""
        if not use_time:
            return
        for si, req in sched.expire(now_v):
            stats["expired"] += 1
            if si is not None:      # running slot freed: clear its table row
                tables[si] = -1
                self._tdirty = True

    def _attach_new(self, sched: Scheduler, newly: List[int], pool,
                    tables: np.ndarray, stats: Dict[str, float]):
        """Post-admission hook: execute pending copy-on-write boundary
        forks (ONE jitted block copy per fork, scalar-traced indices — no
        retrace across block pairs), account skipped prefix tokens, and
        build the table rows for prefix-attached slots (their mapping
        exists before ``ensure_mapped`` ever runs)."""
        for si in newly:
            slot = sched.slots[si]
            if slot.pos:        # admission matched a cached prefix
                stats["prefix_skipped_tokens"] += slot.pos
            if slot.cow is not None:
                src, dst = slot.cow
                pool = self._copy_fn(pool, jnp.asarray(src, jnp.int32),
                                     jnp.asarray(dst, jnp.int32))
                sched.cow_executed(si)
            if slot.blocks:
                tables[si] = pad_block_table(slot.blocks, self._mb)
                self._tdirty = True
        return pool

    def run(self, requests: Sequence[Request], *, seed: int = 0,
            use_time: bool = False) -> Dict[str, float]:
        """Drive the continuous loop until every request finished.  Mutates
        each ``Request`` in place (``tokens``, admit/finish times, draft
        counters) and returns aggregate stats.  ``use_time`` honors
        ``Request.arrival`` (seconds relative to the call) against the wall
        clock; otherwise all requests are immediately admissible."""
        assert self.continuous, "continuous path unsupported for this arch"
        if self.spec_k > 0:
            return self._run_spec(requests, seed=seed, use_time=use_time)
        S, MB, T = self.num_slots, self._mb, self.prefill_chunk
        sched = self._make_sched(T)
        for r in requests:
            assert r.max_new >= 1, "max_new must be >= 1"
            sched.submit(r)
        base_key = jax.random.key(seed)
        pool = self._pool if self._pool is not None else \
            self.model.init_paged_cache(self.num_blocks, self.block_size)
        self._pool = None       # donated below: never reuse a stale handle
        tables = np.full((S, MB), -1, np.int32)
        self._tdirty = True
        tables_dev = jnp.asarray(tables)
        stats = {"step_calls": 0, "prefill_tokens": 0, "generated": 0,
                 "token_slots": 0, "recycled_blocks": 0,
                 "prefix_skipped_tokens": 0, "expired": 0}
        t0 = time.perf_counter()
        now = (lambda: time.perf_counter() - t0) if use_time else \
            (lambda: float("inf"))

        while sched.has_work():
            self._expire_due(sched, now(), use_time, tables, stats)
            newly = sched.admit(now())
            act = sched.active_slots()
            if not act:
                time.sleep(5e-4)        # idle: waiting on future arrivals
                continue
            pool = self._attach_new(sched, newly, pool, tables, stats)
            self._prep_round(sched, act, tables, T, stats)

            # -- build the scripted chunk for every active slot ------------
            script = np.zeros((S, T), np.int32)
            n_script = np.zeros((S,), np.int32)
            start = np.full((S,), -1, np.int32)
            temps = np.ones((S,), np.float32)
            greedy = np.ones((S,), bool)
            rids = np.zeros((S,), np.int32)
            for si in act:
                slot = sched.slots[si]
                n = min(T, len(slot.feed))
                script[si, :n] = slot.feed[:n]
                n_script[si] = n
                start[si] = slot.pos
                temps[si] = slot.req.temperature
                greedy[si] = slot.req.greedy
                rids[si] = slot.req.rid

            if self._tdirty:    # device tables re-upload only on change
                tables_dev = jnp.asarray(tables)
                self._tdirty = False
            pool, samples = self._step_fn(
                self.params, pool, jnp.asarray(script),
                jnp.asarray(n_script), jnp.asarray(start),
                tables_dev, jnp.asarray(temps),
                jnp.asarray(greedy), base_key, jnp.asarray(rids))
            samples = _fetch(samples)
            stats["step_calls"] += 1
            stats["token_slots"] += len(act) * T

            # -- consume: scripted tokens advance, the rest are samples ----
            for si in act:
                slot = sched.slots[si]
                n = int(n_script[si])
                slot.pos += T
                exhausted = n == len(slot.feed)
                del slot.feed[:n]
                stats["prefill_tokens"] += max(n - (1 if slot.generated
                                                    else 0), 0)
                if not exhausted:
                    continue            # still mid-prompt: nothing sampled
                if slot.generated == 0:
                    # prompt fully written this round: its blocks enter the
                    # prefix tree NOW (before any emit can finish the slot
                    # and drop its references) so later arrivals share them
                    sched.register_prefix(si)
                done = False
                for tok in samples[si, n - 1:]:
                    done = self._emit(sched, si, int(tok), stats, now,
                                      use_time, tables)
                    if done:
                        break
                if not done:            # carry the last sample into the
                    slot.feed = [slot.req.tokens[-1]]   # next chunk
        self._pool = pool
        stats["wall"] = time.perf_counter() - t0
        stats.update(sched.capacity_report())
        return stats

    # ------------------------------------------------------------------
    # Speculative loop (spec_k > 0): draft -> verify -> accept -> rollback
    # ------------------------------------------------------------------

    def _run_spec(self, requests: Sequence[Request], *, seed: int = 0,
                  use_time: bool = False) -> Dict[str, float]:
        S, MB, W = self.num_slots, self._mb, self.spec_k + 1
        sched = self._make_sched(W)
        for r in requests:
            assert r.max_new >= 1, "max_new must be >= 1"
            sched.submit(r)
        base_key = jax.random.key(seed)
        pool = self._pool if self._pool is not None else \
            self.model.init_paged_cache(self.num_blocks, self.block_size)
        self._pool = None       # donated below: never reuse a stale handle
        tables = np.full((S, MB), -1, np.int32)
        self._tdirty = True
        tables_dev = jnp.asarray(tables)
        stats = {"step_calls": 0, "prefill_tokens": 0, "generated": 0,
                 "token_slots": 0, "recycled_blocks": 0, "drafted": 0,
                 "accepted": 0, "rolled_back": 0,
                 "prefix_skipped_tokens": 0, "expired": 0}
        t0 = time.perf_counter()
        now = (lambda: time.perf_counter() - t0) if use_time else \
            (lambda: float("inf"))

        while sched.has_work():
            self._expire_due(sched, now(), use_time, tables, stats)
            newly = sched.admit(now())
            act = sched.active_slots()
            if not act:
                time.sleep(5e-4)
                continue
            pool = self._attach_new(sched, newly, pool, tables, stats)

            # -- draft: build [carry, d_1..d_m] / prompt-chunk scripts -----
            script = np.zeros((S, W), np.int32)
            n_feed = np.zeros((S,), np.int32)
            start = np.full((S,), -1, np.int32)
            temps = np.ones((S,), np.float32)
            greedy = np.ones((S,), bool)
            rids = np.zeros((S,), np.int32)
            n_draft = np.zeros((S,), np.int32)
            for si in act:
                slot = sched.slots[si]
                if len(slot.feed) > 1:          # prefill chunk: no drafts
                    n = min(W, len(slot.feed))
                    script[si, :n] = slot.feed[:n]
                else:                           # decode: carry + drafts
                    room = min(self.spec_k,
                               slot.req.max_new - slot.generated - 1)
                    drafts = drafter_mod.propose(slot.history, room,
                                                 max_n=self.draft_ngram) \
                        if room > 0 else []
                    n_draft[si] = len(drafts)
                    n = 1 + len(drafts)
                    script[si, :n] = slot.feed + drafts
                n_feed[si] = n
                start[si] = slot.pos
                temps[si] = slot.req.temperature
                greedy[si] = slot.req.greedy
                rids[si] = slot.req.rid
            self._prep_round(sched, act, tables, n_feed, stats)

            # -- verify: one forward over every scripted position ----------
            if self._tdirty:    # device tables re-upload only on change
                tables_dev = jnp.asarray(tables)
                self._tdirty = False
            all_greedy = all(greedy[si] for si in act)
            if all_greedy:
                pool, g_tok = self._verify_greedy_fn(
                    self.params, pool, jnp.asarray(script),
                    jnp.asarray(start), jnp.asarray(n_feed),
                    tables_dev)
                g_tok = _fetch(g_tok)
                s_tok = acc = resid = g_tok      # unread on greedy slots
            else:
                pool, g_tok, s_tok, acc, resid = self._verify_fn(
                    self.params, pool, jnp.asarray(script),
                    jnp.asarray(start), jnp.asarray(n_feed),
                    tables_dev, jnp.asarray(temps),
                    jnp.asarray(greedy), base_key, jnp.asarray(rids))
                g_tok, s_tok = _fetch(g_tok), _fetch(s_tok)
                acc, resid = _fetch(acc), _fetch(resid)
            stats["step_calls"] += 1
            stats["token_slots"] += len(act) * W

            # -- accept / rollback -----------------------------------------
            for si in act:
                slot = sched.slots[si]
                n = int(n_feed[si])
                if n_draft[si] == 0 and len(slot.feed) > 1:
                    # prefill round: n prompt tokens written
                    slot.pos += n
                    exhausted = n == len(slot.feed)
                    del slot.feed[:n]
                    stats["prefill_tokens"] += n if not slot.generated else 0
                    if not exhausted:
                        continue
                    if slot.generated == 0:
                        sched.register_prefix(si)   # prompt fully written
                    # first sample comes from the last prompt position
                    tok = int(g_tok[si, n - 1] if slot.req.greedy
                              else s_tok[si, n - 1])
                    if self._emit(sched, si, tok, stats, now, use_time,
                                  tables):
                        continue
                    slot.feed = [slot.req.tokens[-1]]
                    continue

                # decode round: carry at start, m drafts behind it
                if slot.generated == 0:
                    # single-token feed (1-token prompt tail): the carry
                    # token completed the prompt in this round's step
                    sched.register_prefix(si)
                m = int(n_draft[si])
                is_greedy = slot.req.greedy
                a = 0                   # accepted drafts (committed writes)
                done = False
                for i in range(m):
                    d = int(script[si, i + 1])
                    ok = (d == int(g_tok[si, i])) if is_greedy \
                        else bool(acc[si, i])
                    if ok:
                        a += 1
                        done = self._emit(sched, si, d, stats, now,
                                          use_time, tables)
                        if done:
                            break
                    else:               # emit the target's own token
                        done = self._emit(
                            sched, si,
                            int(g_tok[si, i]) if is_greedy
                            else int(resid[si, i]),
                            stats, now, use_time, tables)
                        break
                else:
                    if not done:        # every draft accepted: bonus token
                        done = self._emit(
                            sched, si,
                            int(g_tok[si, m]) if is_greedy
                            else int(s_tok[si, m]),
                            stats, now, use_time, tables)
                stats["drafted"] += m
                stats["accepted"] += a
                slot.req.drafted += m
                slot.req.accepted += a
                if done:
                    continue            # finish() already ran inside _emit
                # commit carry + a accepted drafts; roll back the rest
                slot.pos = int(start[si]) + 1 + a
                if a < m:
                    stats["rolled_back"] += m - a
                    sched.pool.truncate(slot, slot.pos)
                slot.feed = [slot.req.tokens[-1]]
        self._pool = pool
        stats["wall"] = time.perf_counter() - t0
        stats["accept_rate"] = (stats["accepted"] / stats["drafted"]
                                if stats["drafted"] else float("nan"))
        stats.update(sched.capacity_report())
        return stats

    def _emit(self, sched: Scheduler, si: int, tok: int, stats, now,
              use_time: bool, tables: np.ndarray) -> bool:
        """Append one generated token; finish the slot on EOS/max_new.
        Returns True when the slot finished."""
        slot = sched.slots[si]
        slot.generated += 1
        slot.req.tokens.append(tok)
        stats["generated"] += 1
        if slot.generated == 1:
            slot.req.first_token_time = now() if use_time else 0.0
        if slot.generated >= slot.req.max_new or tok == slot.req.eos_id:
            sched.finish(si, now() if use_time else 0.0)
            tables[si] = -1
            self._tdirty = True
            return True
        return False

    # ======================================================================
    # Legacy static-bucket path (reference + ssm/hybrid fallback)
    # ======================================================================

    def _generate_scan(self, params, tokens, lens, key, temperature, *,
                       max_new: int, greedy: bool):
        B, Tp = tokens.shape
        cache = self.model.init_cache(B, Tp + max_new)

        def prefill_body(carry, t):
            cache = carry
            pos = t - (Tp - lens)                       # (B,) may be negative
            logits, cache = self.model.decode_step(
                params, cache, {"token": tokens[:, t][:, None],
                                "position": jnp.maximum(pos, -1)})
            return cache, logits[:, 0]

        cache, all_logits = jax.lax.scan(prefill_body, cache, jnp.arange(Tp))
        last_logits = all_logits[-1]                    # (B, V)

        def gen_body(carry, t):
            cache, logits, key = carry
            key, sub = jax.random.split(key)
            if greedy:
                nxt = jnp.argmax(logits, axis=-1)
            else:
                nxt = jax.random.categorical(sub, logits / temperature)
            pos = lens + t                               # (B,)
            logits, cache = self.model.decode_step(
                params, cache, {"token": nxt[:, None].astype(jnp.int32),
                                "position": pos})
            return (cache, logits[:, 0], key), nxt

        (_, _, _), toks = jax.lax.scan(
            gen_body, (cache, last_logits, key), jnp.arange(max_new))
        return toks.T                                    # (B, max_new)

    def generate_ids_static(self, prompts: Sequence[Sequence[int]],
                            max_new: int = 16, greedy: bool = True,
                            temperature: float = 1.0,
                            seed: int = 0) -> np.ndarray:
        """The static-bucket path: one compile per (batch, lengths) bucket,
        the whole batch stalls until its longest request finishes."""
        pad = self.tok.pad if self.tok else 0
        tokens, lens = _left_pad(prompts, pad)
        out = self._gen_fn(self.params, jnp.asarray(tokens), jnp.asarray(lens),
                           jax.random.key(seed),
                           jnp.asarray(temperature, jnp.float32),
                           max_new=max_new, greedy=greedy)
        return np.asarray(out)

    # ======================================================================
    # Public API (wrappers over the scheduler)
    # ======================================================================

    def _fits(self, prompts: Sequence[Sequence[int]], max_new: int) -> bool:
        """Whether the scheduler path can serve this batch; anything it
        can't (empty prompts, max_new < 1, over-capacity requests — per-slot
        OR whole-pool — or an unsupported arch) routes to the static path
        instead."""
        return (self.continuous and max_new >= 1
                and all(1 <= len(p) and len(p) + max_new <= self.capacity
                        and -(-(len(p) + max_new) // self.block_size)
                        <= self.num_blocks
                        for p in prompts))

    def generate(self, prompts: Sequence[Sequence[int]], max_new: int = 16,
                 greedy: bool = True, temperature: float = 1.0,
                 seed: int = 0, eos_id: Optional[int] = None
                 ) -> List[List[int]]:
        """Ragged generation: the scheduler path when the batch fits (EOS
        evicts early, freeing the slot for queued requests), the static
        bucket otherwise (trimmed to match).  Rows include the EOS token
        when one was produced."""
        if self._fits(prompts, max_new):
            reqs = [Request(rid=i, prompt=list(p), max_new=max_new,
                            temperature=temperature, greedy=greedy,
                            eos_id=eos_id)
                    for i, p in enumerate(prompts)]
            self.run(reqs, seed=seed)
            return [r.tokens for r in reqs]
        rows = [list(r) for r in self.generate_ids_static(
            prompts, max_new=max_new, greedy=greedy,
            temperature=temperature, seed=seed)]
        if eos_id is not None:
            rows = [row[:row.index(eos_id) + 1] if eos_id in row else row
                    for row in rows]
        return rows

    def generate_ids(self, prompts: Sequence[Sequence[int]],
                     max_new: int = 16, greedy: bool = True,
                     temperature: float = 1.0, seed: int = 0) -> np.ndarray:
        return np.asarray(self.generate(prompts, max_new=max_new,
                                        greedy=greedy,
                                        temperature=temperature, seed=seed),
                          np.int32)

    def chat(self, prompts: List[str], max_new: int = 32,
             greedy: bool = True, temperature: float = 1.0) -> List[str]:
        assert self.tok is not None
        ids = [self.tok.encode(p) for p in prompts]
        stop = self.tok.special_id("<|assistant_end|>")
        rows = self.generate(ids, max_new=max_new, greedy=greedy,
                             temperature=temperature, eos_id=stop)
        texts = []
        for row in rows:
            if stop in row:
                row = row[:row.index(stop)]
            texts.append(self.tok.decode(list(row)))
        return texts

    # -- scoring (used by the MC eval) ----------------------------------------
    def _score_batch(self, params, tokens, cont_mask):
        """tokens: (B, T); cont_mask: (B, T) — 1 where position t's *target*
        (t+1) belongs to the continuation.  Returns (B,) sum logprob."""
        logits, _ = self.model.forward(params, {"tokens": tokens})
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        tgt = jnp.roll(tokens, -1, axis=1)
        gold = jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
        return jnp.sum(gold * cont_mask, axis=1)

    def score_continuations_batch(self, rows) -> np.ndarray:
        """rows: list of (prompt_ids, option_ids).  One jitted forward for
        the whole batch (padded to a shared length bucket)."""
        if not hasattr(self, "_score_jit"):
            self._score_jit = jax.jit(self._score_batch)
        pad = self.tok.pad if self.tok else 0
        tmax = max(len(p) + len(o) for p, o in rows)
        tmax = -(-tmax // 16) * 16  # bucket to 16 to bound recompiles
        toks = np.full((len(rows), tmax), pad, np.int32)
        mask = np.zeros((len(rows), tmax), np.float32)
        for i, (p, o) in enumerate(rows):
            full = list(p) + list(o)
            toks[i, :len(full)] = full
            mask[i, len(p) - 1:len(full) - 1] = 1.0
        out = self._score_jit(self.params, jnp.asarray(toks),
                              jnp.asarray(mask))
        return np.asarray(out)

    def score_continuations(self, prompt_ids: Sequence[int],
                            options_ids: Sequence[Sequence[int]]) -> np.ndarray:
        return self.score_continuations_batch(
            [(prompt_ids, o) for o in options_ids])
