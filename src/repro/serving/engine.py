"""Continuous-batching inference engine (nanochat ships a small engine + web
UI; this is the JAX equivalent, built on the models' paged decode path).

Layered design:

* ``repro.serving.kv_cache``  — paged KV-block pool (host allocator; the
  device pool lives in ``models.transformer.init_paged_cache``);
* ``repro.serving.scheduler`` — admission / eviction over a fixed slot set
  (FIFO or longest-prefill-first);
* this module              — the persistent decode loop: ONE jitted step over
  the whole slot set, compiled once, with position-gated masking so slots at
  different generation depths coexist.  Each call scans ``prefill_chunk``
  token-steps: every slot either consumes its *scripted* pending tokens (the
  prompt, fed in chunks of at most ``prefill_chunk`` per call — chunked
  prefill, so a long prompt shares steps with running decodes instead of
  stalling them) or chains on its own samples, so prefill and decode tokens
  coexist in the same batched step and the pool round-trip + dispatch cost
  is amortized over ``num_slots × prefill_chunk`` token-slots.

The legacy static-bucket path (LEFT-padded batch, one ``lax.scan`` compile
per ``(batch, lengths)`` bucket) is kept as ``generate_ids_static`` — it is
the reference for the greedy-equivalence tests and the baseline arm of
``benchmarks/serving_bench.py``.  ``generate_ids`` / ``chat`` are thin
wrappers that route through the scheduler whenever the architecture supports
the paged cache.

Note on SSM/hybrid archs: the paged cache is position-gated — stale block
contents are *masked*, not cleared, which is only sound when every read is
gated on the token's absolute position (attention).  An SSM recurrence
updates its O(1) state unconditionally, so a freed-and-reused slot would
leak state across requests; ssm/hybrid (and encoder-decoder) archs therefore
fall back to the static-bucket path, where ragged batches should use
same-length prompts (documented limitation; the paper's nanochat model is
dense attention).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import BPETokenizer
from repro.models.transformer import ModelAPI, paged_cache_supported
from repro.serving.kv_cache import KVBlockPool, pad_block_table
from repro.serving.scheduler import Request, Scheduler


def _left_pad(prompts: Sequence[Sequence[int]], pad_id: int
              ) -> Tuple[np.ndarray, np.ndarray]:
    tp = max(len(p) for p in prompts)
    out = np.full((len(prompts), tp), pad_id, np.int32)
    lens = np.zeros((len(prompts),), np.int32)
    for i, p in enumerate(prompts):
        out[i, tp - len(p):] = p
        lens[i] = len(p)
    return out, lens


@dataclasses.dataclass
class Engine:
    model: ModelAPI
    params: object
    tok: Optional[BPETokenizer] = None
    max_len: int = 256                 # per-request prompt+gen capacity
                                       # (pool bytes scale with it; requests
                                       # beyond it fall back to the static
                                       # path, which is unbounded)
    num_slots: int = 8                 # concurrent sequences in the step
    block_size: int = 16               # KV tokens per pool block
    num_blocks: Optional[int] = None   # pool size; default fits all slots
    prefill_chunk: int = 8             # token-steps per persistent-step call
    policy: str = "fifo"               # admission: fifo | longest_prefill
    attn_impl: Optional[str] = None    # None=auto: pallas kernel off-CPU

    def __post_init__(self):
        self._gen_fn = jax.jit(self._generate_scan,
                               static_argnames=("max_new", "greedy"))
        self.continuous = paged_cache_supported(self.model.cfg)
        if not self.continuous:
            return
        self._mb = -(-self.max_len // self.block_size)   # blocks per slot
        if self.num_blocks is None:
            self.num_blocks = self.num_slots * self._mb
        self.capacity = self._mb * self.block_size
        self._pool = None       # device pool allocated lazily on first run()
                                # so score-/static-only engines don't hold
                                # num_blocks x block_size KV slots per layer
        if self.attn_impl is None:
            self.attn_impl = ("pallas" if jax.default_backend() == "tpu"
                              else "jnp")
        impl = self.attn_impl if self.attn_impl == "pallas" else None
        model = self.model
        T = self.prefill_chunk

        def step(params, pool, script, n_script, start_pos, table, temps,
                 greedy, base_key, rids):
            """T token-steps over the whole slot set.  script: (S, T) pending
            tokens (prompt chunk, or the carry token for decoding slots);
            n_script: (S,) how many are scripted — beyond that a slot chains
            on its own samples; start_pos: (S,) first write position (−1 =
            inactive).  Returns (pool, samples (S, T)) where samples[:, t]
            is the token sampled after feeding token t."""
            active = start_pos >= 0

            def body(carry, t):
                pool, prev = carry
                tok = jnp.where(t < n_script, script[:, t], prev)
                pos = jnp.where(active, start_pos + t, -1)
                logits, pool = model.decode_step_paged(
                    params, pool, {"token": tok[:, None], "position": pos,
                                   "block_table": table}, impl=impl)
                logits = logits[:, 0].astype(jnp.float32)    # (S, V)
                greedy_tok = jnp.argmax(logits, axis=-1)
                # per-request PRNG stream: key = f(seed, rid, position) — the
                # sample for a given position is deterministic no matter how
                # requests were scheduled around it
                keys = jax.vmap(lambda r, q: jax.random.fold_in(
                    jax.random.fold_in(base_key, r), q))(rids, pos)
                temp = jnp.maximum(jnp.where(greedy, 1.0, temps), 1e-6)
                sampled = jax.vmap(jax.random.categorical)(
                    keys, logits / temp[:, None])
                nxt = jnp.where(greedy, greedy_tok, sampled).astype(jnp.int32)
                return (pool, nxt), nxt

            (pool, _), samples = jax.lax.scan(
                body, (pool, jnp.zeros(script.shape[:1], jnp.int32)),
                jnp.arange(T))
            return pool, samples.T                           # (S, T)

        self._step_fn = jax.jit(step)

    # ======================================================================
    # Continuous decode loop (the scheduler path)
    # ======================================================================

    def run(self, requests: Sequence[Request], *, seed: int = 0,
            use_time: bool = False) -> Dict[str, float]:
        """Drive the continuous loop until every request finished.  Mutates
        each ``Request`` in place (``tokens``, admit/finish times) and
        returns aggregate stats.  ``use_time`` honors ``Request.arrival``
        (seconds relative to the call) against the wall clock; otherwise all
        requests are immediately admissible."""
        assert self.continuous, "continuous path unsupported for this arch"
        S, MB, T = self.num_slots, self._mb, self.prefill_chunk
        sched = Scheduler(S, KVBlockPool(self.num_blocks, self.block_size),
                          MB, self.policy)
        for r in requests:
            assert r.max_new >= 1, "max_new must be >= 1"
            sched.submit(r)
        base_key = jax.random.key(seed)
        if self._pool is None:
            self._pool = self.model.init_paged_cache(self.num_blocks,
                                                     self.block_size)
        pool = self._pool
        tables = np.full((S, MB), -1, np.int32)
        stats = {"step_calls": 0, "prefill_tokens": 0, "generated": 0,
                 "token_slots": 0}
        t0 = time.perf_counter()
        now = (lambda: time.perf_counter() - t0) if use_time else \
            (lambda: float("inf"))

        while sched.has_work():
            for si in sched.admit(now()):
                tables[si] = pad_block_table(sched.slots[si].blocks, MB)
            act = sched.active_slots()
            if not act:
                time.sleep(5e-4)        # idle: waiting on future arrivals
                continue

            # -- build the scripted chunk for every active slot ------------
            script = np.zeros((S, T), np.int32)
            n_script = np.zeros((S,), np.int32)
            start = np.full((S,), -1, np.int32)
            temps = np.ones((S,), np.float32)
            greedy = np.ones((S,), bool)
            rids = np.zeros((S,), np.int32)
            for si in act:
                slot = sched.slots[si]
                n = min(T, len(slot.feed))
                script[si, :n] = slot.feed[:n]
                n_script[si] = n
                start[si] = slot.pos
                temps[si] = slot.req.temperature
                greedy[si] = slot.req.greedy
                rids[si] = slot.req.rid

            pool, samples = self._step_fn(
                self.params, pool, jnp.asarray(script),
                jnp.asarray(n_script), jnp.asarray(start),
                jnp.asarray(tables), jnp.asarray(temps),
                jnp.asarray(greedy), base_key, jnp.asarray(rids))
            samples = np.asarray(samples)
            stats["step_calls"] += 1
            stats["token_slots"] += len(act) * T

            # -- consume: scripted tokens advance, the rest are samples ----
            for si in act:
                slot = sched.slots[si]
                n = int(n_script[si])
                slot.pos += T
                exhausted = n == len(slot.feed)
                del slot.feed[:n]
                stats["prefill_tokens"] += max(n - (1 if slot.generated
                                                    else 0), 0)
                if not exhausted:
                    continue            # still mid-prompt: nothing sampled
                done = False
                for tok in samples[si, n - 1:]:
                    tok = int(tok)
                    slot.generated += 1
                    slot.req.tokens.append(tok)
                    stats["generated"] += 1
                    if (slot.generated >= slot.req.max_new
                            or tok == slot.req.eos_id):
                        done = True
                        break
                if done:
                    sched.finish(si, now() if use_time else 0.0)
                    tables[si] = -1
                else:                   # carry the last sample into the
                    slot.feed = [slot.req.tokens[-1]]   # next chunk
        self._pool = pool
        stats["wall"] = time.perf_counter() - t0
        return stats

    # ======================================================================
    # Legacy static-bucket path (reference + ssm/hybrid fallback)
    # ======================================================================

    def _generate_scan(self, params, tokens, lens, key, temperature, *,
                       max_new: int, greedy: bool):
        B, Tp = tokens.shape
        cache = self.model.init_cache(B, Tp + max_new)

        def prefill_body(carry, t):
            cache = carry
            pos = t - (Tp - lens)                       # (B,) may be negative
            logits, cache = self.model.decode_step(
                params, cache, {"token": tokens[:, t][:, None],
                                "position": jnp.maximum(pos, -1)})
            return cache, logits[:, 0]

        cache, all_logits = jax.lax.scan(prefill_body, cache, jnp.arange(Tp))
        last_logits = all_logits[-1]                    # (B, V)

        def gen_body(carry, t):
            cache, logits, key = carry
            key, sub = jax.random.split(key)
            if greedy:
                nxt = jnp.argmax(logits, axis=-1)
            else:
                nxt = jax.random.categorical(sub, logits / temperature)
            pos = lens + t                               # (B,)
            logits, cache = self.model.decode_step(
                params, cache, {"token": nxt[:, None].astype(jnp.int32),
                                "position": pos})
            return (cache, logits[:, 0], key), nxt

        (_, _, _), toks = jax.lax.scan(
            gen_body, (cache, last_logits, key), jnp.arange(max_new))
        return toks.T                                    # (B, max_new)

    def generate_ids_static(self, prompts: Sequence[Sequence[int]],
                            max_new: int = 16, greedy: bool = True,
                            temperature: float = 1.0,
                            seed: int = 0) -> np.ndarray:
        """The static-bucket path: one compile per (batch, lengths) bucket,
        the whole batch stalls until its longest request finishes."""
        pad = self.tok.pad if self.tok else 0
        tokens, lens = _left_pad(prompts, pad)
        out = self._gen_fn(self.params, jnp.asarray(tokens), jnp.asarray(lens),
                           jax.random.key(seed),
                           jnp.asarray(temperature, jnp.float32),
                           max_new=max_new, greedy=greedy)
        return np.asarray(out)

    # ======================================================================
    # Public API (wrappers over the scheduler)
    # ======================================================================

    def _fits(self, prompts: Sequence[Sequence[int]], max_new: int) -> bool:
        """Whether the scheduler path can serve this batch; anything it
        can't (empty prompts, max_new < 1, over-capacity requests — per-slot
        OR whole-pool — or an unsupported arch) routes to the static path
        instead."""
        return (self.continuous and max_new >= 1
                and all(1 <= len(p) and len(p) + max_new <= self.capacity
                        and -(-(len(p) + max_new) // self.block_size)
                        <= self.num_blocks
                        for p in prompts))

    def generate(self, prompts: Sequence[Sequence[int]], max_new: int = 16,
                 greedy: bool = True, temperature: float = 1.0,
                 seed: int = 0, eos_id: Optional[int] = None
                 ) -> List[List[int]]:
        """Ragged generation: the scheduler path when the batch fits (EOS
        evicts early, freeing the slot for queued requests), the static
        bucket otherwise (trimmed to match).  Rows include the EOS token
        when one was produced."""
        if self._fits(prompts, max_new):
            reqs = [Request(rid=i, prompt=list(p), max_new=max_new,
                            temperature=temperature, greedy=greedy,
                            eos_id=eos_id)
                    for i, p in enumerate(prompts)]
            self.run(reqs, seed=seed)
            return [r.tokens for r in reqs]
        rows = [list(r) for r in self.generate_ids_static(
            prompts, max_new=max_new, greedy=greedy,
            temperature=temperature, seed=seed)]
        if eos_id is not None:
            rows = [row[:row.index(eos_id) + 1] if eos_id in row else row
                    for row in rows]
        return rows

    def generate_ids(self, prompts: Sequence[Sequence[int]],
                     max_new: int = 16, greedy: bool = True,
                     temperature: float = 1.0, seed: int = 0) -> np.ndarray:
        return np.asarray(self.generate(prompts, max_new=max_new,
                                        greedy=greedy,
                                        temperature=temperature, seed=seed),
                          np.int32)

    def chat(self, prompts: List[str], max_new: int = 32,
             greedy: bool = True, temperature: float = 1.0) -> List[str]:
        assert self.tok is not None
        ids = [self.tok.encode(p) for p in prompts]
        stop = self.tok.special_id("<|assistant_end|>")
        rows = self.generate(ids, max_new=max_new, greedy=greedy,
                             temperature=temperature, eos_id=stop)
        texts = []
        for row in rows:
            if stop in row:
                row = row[:row.index(stop)]
            texts.append(self.tok.decode(list(row)))
        return texts

    # -- scoring (used by the MC eval) ----------------------------------------
    def _score_batch(self, params, tokens, cont_mask):
        """tokens: (B, T); cont_mask: (B, T) — 1 where position t's *target*
        (t+1) belongs to the continuation.  Returns (B,) sum logprob."""
        logits, _ = self.model.forward(params, {"tokens": tokens})
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        tgt = jnp.roll(tokens, -1, axis=1)
        gold = jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
        return jnp.sum(gold * cont_mask, axis=1)

    def score_continuations_batch(self, rows) -> np.ndarray:
        """rows: list of (prompt_ids, option_ids).  One jitted forward for
        the whole batch (padded to a shared length bucket)."""
        if not hasattr(self, "_score_jit"):
            self._score_jit = jax.jit(self._score_batch)
        pad = self.tok.pad if self.tok else 0
        tmax = max(len(p) + len(o) for p, o in rows)
        tmax = -(-tmax // 16) * 16  # bucket to 16 to bound recompiles
        toks = np.full((len(rows), tmax), pad, np.int32)
        mask = np.zeros((len(rows), tmax), np.float32)
        for i, (p, o) in enumerate(rows):
            full = list(p) + list(o)
            toks[i, :len(full)] = full
            mask[i, len(p) - 1:len(full) - 1] = 1.0
        out = self._score_jit(self.params, jnp.asarray(toks),
                              jnp.asarray(mask))
        return np.asarray(out)

    def score_continuations(self, prompt_ids: Sequence[int],
                            options_ids: Sequence[Sequence[int]]) -> np.ndarray:
        return self.score_continuations_batch(
            [(prompt_ids, o) for o in options_ids])
