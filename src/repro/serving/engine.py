"""Batched KV-cache inference engine (nanochat ships a small engine + web UI;
this is the JAX equivalent, built on the models' decode_step).

Prompts are LEFT-padded to a common length; padded slots are inserted into
the cache with position −1, which the attention mask treats as invalid, so
ragged batches decode correctly.  Both the prefill (teacher-forced) and the
generation loop are single ``lax.scan``s — one compile per (batch, lengths)
bucket.

Note: SSM/hybrid state updates are not position-gated, so ragged batches
should use same-length prompts for those archs (documented limitation; the
paper's nanochat model is dense attention).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import BPETokenizer
from repro.models.transformer import ModelAPI


def _left_pad(prompts: Sequence[Sequence[int]], pad_id: int
              ) -> Tuple[np.ndarray, np.ndarray]:
    tp = max(len(p) for p in prompts)
    out = np.full((len(prompts), tp), pad_id, np.int32)
    lens = np.zeros((len(prompts),), np.int32)
    for i, p in enumerate(prompts):
        out[i, tp - len(p):] = p
        lens[i] = len(p)
    return out, lens


@dataclasses.dataclass
class Engine:
    model: ModelAPI
    params: object
    tok: Optional[BPETokenizer] = None
    max_len: int = 512

    def __post_init__(self):
        self._gen_fn = jax.jit(self._generate_scan,
                               static_argnames=("max_new", "greedy"))

    # -- core scan ------------------------------------------------------------
    def _generate_scan(self, params, tokens, lens, key, temperature, *,
                       max_new: int, greedy: bool):
        B, Tp = tokens.shape
        cache = self.model.init_cache(B, Tp + max_new)

        def prefill_body(carry, t):
            cache = carry
            pos = t - (Tp - lens)                       # (B,) may be negative
            logits, cache = self.model.decode_step(
                params, cache, {"token": tokens[:, t][:, None],
                                "position": jnp.maximum(pos, -1)})
            return cache, logits[:, 0]

        cache, all_logits = jax.lax.scan(prefill_body, cache, jnp.arange(Tp))
        last_logits = all_logits[-1]                    # (B, V)

        def gen_body(carry, t):
            cache, logits, key = carry
            key, sub = jax.random.split(key)
            if greedy:
                nxt = jnp.argmax(logits, axis=-1)
            else:
                nxt = jax.random.categorical(sub, logits / temperature)
            pos = lens + t                               # (B,)
            logits, cache = self.model.decode_step(
                params, cache, {"token": nxt[:, None].astype(jnp.int32),
                                "position": pos})
            return (cache, logits[:, 0], key), nxt

        (_, _, _), toks = jax.lax.scan(
            gen_body, (cache, last_logits, key), jnp.arange(max_new))
        return toks.T                                    # (B, max_new)

    # -- public API -------------------------------------------------------------
    def generate_ids(self, prompts: Sequence[Sequence[int]], max_new: int = 16,
                     greedy: bool = True, temperature: float = 1.0,
                     seed: int = 0) -> np.ndarray:
        pad = self.tok.pad if self.tok else 0
        tokens, lens = _left_pad(prompts, pad)
        out = self._gen_fn(self.params, jnp.asarray(tokens), jnp.asarray(lens),
                           jax.random.key(seed),
                           jnp.asarray(temperature, jnp.float32),
                           max_new=max_new, greedy=greedy)
        return np.asarray(out)

    def chat(self, prompts: List[str], max_new: int = 32,
             greedy: bool = True) -> List[str]:
        assert self.tok is not None
        ids = [self.tok.encode(p) for p in prompts]
        out = self.generate_ids(ids, max_new=max_new, greedy=greedy)
        stop = self.tok.special_id("<|assistant_end|>")
        texts = []
        for row in out:
            row = list(row)
            if stop in row:
                row = row[:row.index(stop)]
            texts.append(self.tok.decode(row))
        return texts

    # -- scoring (used by the MC eval) ----------------------------------------
    def _score_batch(self, params, tokens, cont_mask):
        """tokens: (B, T); cont_mask: (B, T) — 1 where position t's *target*
        (t+1) belongs to the continuation.  Returns (B,) sum logprob."""
        logits, _ = self.model.forward(params, {"tokens": tokens})
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        tgt = jnp.roll(tokens, -1, axis=1)
        gold = jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
        return jnp.sum(gold * cont_mask, axis=1)

    def score_continuations_batch(self, rows) -> np.ndarray:
        """rows: list of (prompt_ids, option_ids).  One jitted forward for
        the whole batch (padded to a shared length bucket)."""
        if not hasattr(self, "_score_jit"):
            self._score_jit = jax.jit(self._score_batch)
        pad = self.tok.pad if self.tok else 0
        tmax = max(len(p) + len(o) for p, o in rows)
        tmax = -(-tmax // 16) * 16  # bucket to 16 to bound recompiles
        toks = np.full((len(rows), tmax), pad, np.int32)
        mask = np.zeros((len(rows), tmax), np.float32)
        for i, (p, o) in enumerate(rows):
            full = list(p) + list(o)
            toks[i, :len(full)] = full
            mask[i, len(p) - 1:len(full) - 1] = 1.0
        out = self._score_jit(self.params, jnp.asarray(toks),
                              jnp.asarray(mask))
        return np.asarray(out)

    def score_continuations(self, prompt_ids: Sequence[int],
                            options_ids: Sequence[Sequence[int]]) -> np.ndarray:
        return self.score_continuations_batch(
            [(prompt_ids, o) for o in options_ids])
