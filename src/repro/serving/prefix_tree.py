"""Radix tree over token sequences mapping shared prompt prefixes to
refcounted physical KV blocks — the prefix cache behind cache-aware
admission.

Granularity is one node per KV block: a node's ``key`` is the
``block_size``-token chunk (shorter for a boundary leaf) whose KV lives in
``node.block``.  Chat-style traffic repeats the same system-prompt /
template prefix across requests, so the tree turns those identical leading
chunks into ONE physical block each: a request whose prompt walks matched
full-block nodes attaches those blocks at admission (refcount bumped per
attachment), reserves budget only for its unshared tail, and starts
decoding at ``pos = matched_len`` — the prefill compute and the pool bytes
for the shared prefix are both skipped.

Sharing rules:

* **full blocks are shared in place** — every position in the block is
  prompt prefix, written once at the original prefill and never rewritten
  (generated tokens land at positions ≥ prompt length, speculative
  rollback never rewinds below the committed prompt), so concurrent
  readers are safe;
* **the boundary partial block is copy-on-write** — a block whose key is a
  strict prefix of its tokens (or a full block matched only partially)
  also holds positions the new request must write, so the match returns a
  *fork*: the scheduler allocates a private block from the request's own
  budget and the engine copies the source block's device contents before
  the first step.  Positions beyond the fork's valid length are stale
  garbage masked by the position gate until overwritten, exactly like any
  freshly mapped block;
* a match never covers the whole prompt — at least one token is left to
  prefill so the step produces the logits the first sampled token comes
  from (``matched_len <= len(prompt) - 1``).

Ownership: the tree holds ONE pool reference per node
(``KVBlockPool.incref`` on insert); each attached slot holds its own.
``evict`` only removes childless nodes whose refcount is exactly the
tree's own (no slot attached), LRU-first by a logical access clock, so a
block is returned to the free list precisely when the last owner lets go.
``max_blocks`` bounds how many blocks the cache may keep resident;
admission-pressure eviction (``Scheduler.admit``) shrinks it further when
a waiting request's tail budget doesn't fit.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.serving.kv_cache import KVBlockPool


@dataclasses.dataclass
class _Node:
    key: Tuple[int, ...]            # the block's token chunk
    block: int                      # physical block id (tree holds 1 ref)
    parent: Optional["_Node"]
    children: Dict[Tuple[int, ...], "_Node"] = \
        dataclasses.field(default_factory=dict)
    last_use: int = 0

    @property
    def full(self) -> bool:
        return self.parent is not None and len(self.key) > 0


@dataclasses.dataclass
class Match:
    """Result of a prefix lookup.  ``blocks`` are full shared blocks to
    attach (refcounts NOT yet bumped — admission does that); ``fork_src``
    is the boundary block to copy-on-write (None = clean block boundary),
    valid for the first ``matched_len - block_size * len(blocks)``
    positions of the forked block."""
    blocks: List[int]
    matched_len: int
    fork_src: Optional[int] = None

    @property
    def hit(self) -> bool:
        return self.matched_len > 0


class PrefixTree:
    def __init__(self, block_size: int, max_blocks: int = 0):
        """``max_blocks``: LRU bound on resident cache blocks (0 = only
        bounded by the pool itself)."""
        assert block_size > 0
        self.block_size = block_size
        self.max_blocks = int(max_blocks)
        self.root = _Node(key=(), block=-1, parent=None)
        self._clock = 0
        self._nodes = 0
        # observability (reset by the scheduler per run if desired)
        self.hits = 0
        self.misses = 0
        self.matched_tokens = 0
        self.inserted_blocks = 0
        self.evicted_blocks = 0

    # -- queries ------------------------------------------------------------
    @property
    def num_blocks(self) -> int:
        """Blocks currently resident in the cache (== tree nodes)."""
        return self._nodes

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def match(self, prompt: List[int], *, touch: bool = True) -> Match:
        """Longest shared prefix of ``prompt``, capped at
        ``len(prompt) - 1`` tokens.  ``touch=False`` is a side-effect-free
        dry run (used by the ``cache_aware`` admission policy to rank
        waiting requests without perturbing LRU order)."""
        bs = self.block_size
        limit = len(prompt) - 1
        node, blocks, matched = self.root, [], 0
        while matched + bs <= limit:
            child = node.children.get(tuple(prompt[matched:matched + bs]))
            if child is None:
                break
            blocks.append(child.block)
            matched += bs
            node = child
            if touch:
                child.last_use = self._tick()
        # boundary: the longest child whose key prefix-matches the
        # remaining tokens gives a copy-on-write fork
        fork_src, fork_len = None, 0
        remaining = prompt[matched:limit]
        for child in node.children.values():
            n = 0
            for a, b in zip(child.key, remaining):
                if a != b:
                    break
                n += 1
            if n > fork_len:
                fork_src, fork_len = child, n
        if fork_src is not None and touch:
            fork_src.last_use = self._tick()
        return Match(blocks=blocks, matched_len=matched + fork_len,
                     fork_src=fork_src.block if fork_src else None)

    # -- insertion ----------------------------------------------------------
    def insert(self, prompt: List[int], blocks: List[int],
               pool: KVBlockPool) -> int:
        """Register a prefilled prompt's blocks: full chunks become full
        nodes, a non-aligned tail becomes a partial leaf.  Blocks already
        represented (a concurrent request prefilled the same prefix) are
        left in place — the tree keeps ONE block per chunk.  New nodes take
        their own pool reference.  Returns the number of blocks newly
        inserted."""
        bs = self.block_size
        node, added, i = self.root, 0, 0
        while (i + 1) * bs <= len(prompt):
            chunk = tuple(prompt[i * bs:(i + 1) * bs])
            child = node.children.get(chunk)
            if child is None:
                if blocks[i] < 0:       # unmapped (windowed/partial prefill)
                    return added
                child = _Node(key=chunk, block=blocks[i], parent=node)
                pool.incref(blocks[i])
                node.children[chunk] = child
                self._nodes += 1
                added += 1
            child.last_use = self._tick()
            node = child
            i += 1
        tail = tuple(prompt[i * bs:])
        if tail and i < len(blocks) and blocks[i] >= 0 \
                and tail not in node.children:
            leaf = _Node(key=tail, block=blocks[i], parent=node)
            pool.incref(blocks[i])
            node.children[tail] = leaf
            leaf.last_use = self._tick()
            self._nodes += 1
            added += 1
        self.inserted_blocks += added
        if self.max_blocks:
            self.evict(pool, max(self._nodes - self.max_blocks, 0))
        return added

    # -- eviction -----------------------------------------------------------
    def _evictable(self, pool: KVBlockPool) -> List[_Node]:
        """Childless nodes no slot is attached to (refcount == the tree's
        own), LRU-first."""
        out = []
        stack = [self.root]
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            if n.parent is not None and not n.children \
                    and pool.refcount(n.block) == 1:
                out.append(n)
        out.sort(key=lambda n: n.last_use)
        return out

    def evict(self, pool: KVBlockPool, n: int) -> int:
        """Drop up to ``n`` LRU leaves, freeing their blocks.  Evicting a
        leaf can expose its parent; the scan repeats until ``n`` blocks
        went or nothing is evictable.  Returns blocks actually freed."""
        freed = 0
        while freed < n:
            leaves = self._evictable(pool)
            if not leaves:
                break
            for leaf in leaves[:n - freed]:
                pool.free([leaf.block])
                del leaf.parent.children[leaf.key]
                self._nodes -= 1
                freed += 1
        self.evicted_blocks += freed
        return freed

    def evict_for(self, pool: KVBlockPool, need: int) -> int:
        """Admission-pressure eviction: free LRU cache blocks until the
        pool can reserve ``need`` blocks (or nothing is evictable).
        Returns blocks freed."""
        freed = 0
        while not pool.can_reserve(need) and self.evict(pool, 1):
            freed += 1
        return freed

    def report(self) -> dict:
        lookups = self.hits + self.misses
        return {
            "shared_blocks": self._nodes,
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / lookups if lookups else 0.0,
            "matched_tokens": self.matched_tokens,
            "inserted_blocks": self.inserted_blocks,
            "evicted_blocks": self.evicted_blocks,
        }
