"""Request scheduler for the continuous-batching engine.

Owns the waiting queue, the fixed slot set, and the block-pool bookkeeping:

* **admission** — a waiting request enters a free slot once its arrival time
  has passed and the pool can *reserve* its full block budget
  (``ceil((len(prompt) + max_new) / block_size)`` blocks for dense archs —
  reserved up front so a running request can never hit a mid-flight pool
  OOM; for uniform sliding-window archs the budget only covers the live
  window, since out-of-window blocks are recycled, so admission capacity
  scales with the window, not the sequence length);
* **lazy mapping** — physical blocks are drawn down from the reservation as
  positions are actually written (``ensure_mapped``), which is what lets
  speculative rollback (``KVBlockPool.truncate``) and window recycling
  return blocks without breaking the no-OOM guarantee;
* **eviction** — finished slots (EOS or ``max_new`` reached) free their
  mapped blocks and release the rest of their budget immediately, so the
  next waiting request backfills the slot while the remaining slots keep
  decoding;
* **policies** — ``fifo`` admits in arrival order; ``longest_prefill`` admits
  the longest waiting prompt first (front-loads heavy prefills so they
  overlap with many short decodes instead of serializing at the tail).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.serving.kv_cache import KVBlockPool

POLICIES = ("fifo", "longest_prefill")


@dataclasses.dataclass
class Request:
    """One generation request.  ``tokens`` is filled by the engine."""
    rid: int
    prompt: List[int]
    max_new: int = 16
    temperature: float = 1.0
    greedy: bool = True
    eos_id: Optional[int] = None
    arrival: float = 0.0
    # -- engine-filled ------------------------------------------------------
    tokens: List[int] = dataclasses.field(default_factory=list)
    admit_time: Optional[float] = None
    finish_time: Optional[float] = None
    drafted: int = 0        # speculative: draft tokens proposed for this req
    accepted: int = 0       # speculative: draft tokens verified-accepted

    @property
    def total_tokens(self) -> int:
        return len(self.prompt) + self.max_new

    @property
    def accept_rate(self) -> float:
        return self.accepted / self.drafted if self.drafted else float("nan")


@dataclasses.dataclass
class Slot:
    """Per-slot decode state.  ``pos`` is the next cache position to write
    (== committed tokens; speculative rollback rewinds it).  ``feed`` holds
    the tokens still to be fed through the persistent step: the prompt at
    admission (consumed in chunks — chunked prefill), then the single carry
    token once the slot is sampling; the first sampled token therefore
    comes out of the same jitted step as every other one.  ``blocks`` maps
    logical block index -> physical block id (−1 = unmapped: not yet
    written, rolled back, or recycled out of the window); ``reserved`` is
    the slot's remaining block budget (unmapped blocks it may still draw
    from the pool)."""
    req: Request
    blocks: List[int] = dataclasses.field(default_factory=list)
    reserved: int = 0
    feed: List[int] = dataclasses.field(default_factory=list)
    pos: int = 0
    generated: int = 0

    @property
    def in_prefill(self) -> bool:
        return self.generated == 0 and len(self.feed) > 1

    @property
    def history(self) -> List[int]:
        """Token history the drafter may match against: the prompt plus
        everything generated so far."""
        return self.req.prompt + self.req.tokens


class Scheduler:
    def __init__(self, num_slots: int, pool: KVBlockPool,
                 max_blocks_per_slot: int, policy: str = "fifo",
                 window: Optional[int] = None):
        """``window``: uniform sliding-window size in tokens (None/0 = full
        attention).  When set, per-request budgets cover only the live
        window span (+ one in-flight chunk, supplied per-request via
        ``chunk_tokens`` below) and ``recycle_window`` frees dead blocks."""
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; one of {POLICIES}")
        self.pool = pool
        self.policy = policy
        self.max_blocks_per_slot = max_blocks_per_slot
        self.window = int(window) if window else 0
        self.chunk_tokens = 1       # engine sets: max tokens fed per round
        self.waiting: List[Request] = []
        self.slots: List[Optional[Slot]] = [None] * num_slots
        self.peak_admitted = 0      # max simultaneously-occupied slots seen
        self.total_admitted = 0     # requests admitted over the run

    # -- queries ------------------------------------------------------------
    @property
    def num_slots(self) -> int:
        return len(self.slots)

    def has_work(self) -> bool:
        return bool(self.waiting) or any(s is not None for s in self.slots)

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def budget_for(self, req: Request) -> int:
        """Block budget reserved at admission.  Dense: the full
        prompt+max_new footprint.  Windowed: the largest number of blocks
        simultaneously mapped — the window span plus the chunk being
        written, which can straddle two extra partial blocks."""
        need = self.pool.blocks_for(req.total_tokens)
        if self.window:
            live = self.pool.blocks_for(self.window + self.chunk_tokens) + 2
            need = min(need, live)
        return need

    # -- submission / admission --------------------------------------------
    def submit(self, req: Request) -> None:
        cap = self.max_blocks_per_slot * self.pool.block_size
        if req.total_tokens > cap:
            raise ValueError(
                f"request {req.rid}: {req.total_tokens} tokens exceeds the "
                f"per-slot capacity {cap}")
        need = self.budget_for(req)
        if need > self.pool.num_blocks:
            # would never admit -> the engine loop would spin forever
            raise ValueError(
                f"request {req.rid}: needs {need} blocks but the pool only "
                f"has {self.pool.num_blocks}")
        if not req.prompt:
            raise ValueError(f"request {req.rid}: empty prompt")
        self.waiting.append(req)

    def _pick(self, now: float) -> Optional[int]:
        ready = [i for i, r in enumerate(self.waiting) if r.arrival <= now]
        if not ready:
            return None
        if self.policy == "longest_prefill":
            return max(ready, key=lambda i: (len(self.waiting[i].prompt),
                                             -i))
        return ready[0]

    def admit(self, now: float = float("inf")) -> List[int]:
        """Admit as many ready requests as slots + block budget allow;
        returns the newly filled slot indices.  Admission only reserves —
        physical blocks are mapped lazily by ``ensure_mapped``."""
        newly: List[int] = []
        free_slots = [i for i, s in enumerate(self.slots) if s is None]
        while free_slots and self.waiting:
            pick = self._pick(now)
            if pick is None:
                break
            req = self.waiting[pick]
            need = self.budget_for(req)
            if not self.pool.can_reserve(need):
                break                       # head-of-line blocks until frees
            self.waiting.pop(pick)
            si = free_slots.pop(0)
            self.pool.reserve(need)
            slot = Slot(req=req, reserved=need, feed=list(req.prompt))
            slot.req.admit_time = now if now != float("inf") else 0.0
            self.slots[si] = slot
            newly.append(si)
        if newly:
            self.total_admitted += len(newly)
            self.peak_admitted = max(
                self.peak_admitted,
                sum(s is not None for s in self.slots))
        return newly

    def capacity_report(self) -> dict:
        """Bytes-denominated capacity snapshot: how much device memory the
        pool costs, what one admitted request's budget costs, and the
        admission high-water mark.  ``bytes_per_block`` = 0 when the pool
        was built without byte metadata."""
        bpb = self.pool.bytes_per_block
        return {
            "num_blocks": self.pool.num_blocks,
            "block_size": self.pool.block_size,
            "bytes_per_block": bpb,
            "pool_bytes": self.pool.total_bytes,
            "peak_admitted": self.peak_admitted,
            "total_admitted": self.total_admitted,
        }

    # -- lazy mapping / recycling -------------------------------------------
    def ensure_mapped(self, si: int, upto_pos: int) -> bool:
        """Map physical blocks for every logical block covering positions
        ``[0, upto_pos]`` that is still unmapped, drawing from the slot's
        reservation (capped by it: positions beyond the budgeted footprint
        stay unmapped and device writes there are dropped — never
        corrupted).  Returns True if the mapping changed."""
        slot = self.slots[si]
        need = min(self.pool.blocks_for(upto_pos + 1),
                   self.max_blocks_per_slot)
        changed = False
        if need > len(slot.blocks):
            slot.blocks.extend([-1] * (need - len(slot.blocks)))
        lo = 0
        if self.window:     # blocks below the window floor stay dead
            lo = max(0, (slot.pos - self.window + 1) // self.pool.block_size)
        for j in range(lo, need):
            if slot.blocks[j] < 0 and slot.reserved > 0:
                slot.blocks[j] = self.pool.alloc(1, reserved=True)[0]
                slot.reserved -= 1
                changed = True
        return changed

    def recycle_window(self, si: int) -> int:
        """Free mapped blocks that fell wholly out of the attention window
        (every key position <= pos − window can never be attended by a
        future query, since committed ``pos`` is monotone).  Budget returns
        to the slot, keeping its live-window mapping rights.  Returns the
        number of blocks recycled."""
        if not self.window:
            return 0
        slot = self.slots[si]
        bs = self.pool.block_size
        dead_upto = min(len(slot.blocks),
                        max(0, (slot.pos - self.window + 1) // bs))
        n = 0
        for j in range(dead_upto):
            if slot.blocks[j] >= 0:
                self.pool.free([slot.blocks[j]], rereserve=True)
                slot.blocks[j] = -1
                slot.reserved += 1
                n += 1
        return n

    # -- eviction -----------------------------------------------------------
    def finish(self, si: int, now: float = 0.0) -> Request:
        slot = self.slots[si]
        assert slot is not None, f"finish on empty slot {si}"
        mapped = [b for b in slot.blocks if b >= 0]
        if mapped:
            self.pool.free(mapped)
        self.pool.release(slot.reserved)
        self.slots[si] = None
        slot.req.finish_time = now
        return slot.req
