"""Request scheduler for the continuous-batching engine.

Owns the waiting queue, the fixed slot set, and the block-pool bookkeeping:

* **admission** — a waiting request enters a free slot once its arrival time
  has passed and the pool can *reserve* its full block budget
  (``ceil((len(prompt) + max_new) / block_size)`` blocks for dense archs —
  reserved up front so a running request can never hit a mid-flight pool
  OOM; for uniform sliding-window archs the budget only covers the live
  window, since out-of-window blocks are recycled, so admission capacity
  scales with the window, not the sequence length);
* **lazy mapping** — physical blocks are drawn down from the reservation as
  positions are actually written (``ensure_mapped``), which is what lets
  speculative rollback (``KVBlockPool.truncate``) and window recycling
  return blocks without breaking the no-OOM guarantee;
* **eviction** — finished slots (EOS or ``max_new`` reached) free their
  mapped blocks and release the rest of their budget immediately, so the
  next waiting request backfills the slot while the remaining slots keep
  decoding;
* **policies** — ``fifo`` admits in arrival order; ``longest_prefill`` admits
  the longest waiting prompt first (front-loads heavy prefills so they
  overlap with many short decodes instead of serializing at the tail);
  ``cache_aware`` prefers the waiting request with the longest
  prefix-cache match (its tail budget is the smallest and its prefill the
  cheapest, so hits drain the queue fastest);
* **prefix sharing** — with a ``PrefixTree`` attached, admission matches
  each request's prompt against cached block-aligned prefixes: matched
  full blocks attach to the slot directly (one ``incref`` per attachment,
  no budget reserved, no prefill compute — the slot starts at
  ``pos = matched_len``), a partially matched boundary block becomes a
  copy-on-write fork (``Slot.cow``: the engine copies the source block's
  device contents into a private block drawn from the slot's own budget),
  and only the *unshared tail* reserves budget.  ``finish`` drops the
  slot's references — private blocks return to the free list, shared
  prefix blocks stay resident under the tree's own reference until LRU
  eviction (``PrefixTree.evict``) or admission pressure
  (``PrefixTree.evict_for``) lets them go.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.serving.kv_cache import KVBlockPool
from repro.serving.prefix_tree import Match, PrefixTree

POLICIES = ("fifo", "longest_prefill", "cache_aware")


@dataclasses.dataclass
class Request:
    """One generation request.  ``tokens`` is filled by the engine."""
    rid: int
    prompt: List[int]
    max_new: int = 16
    temperature: float = 1.0
    greedy: bool = True
    eos_id: Optional[int] = None
    arrival: float = 0.0
    deadline_s: Optional[float] = None  # latency SLO: the request expires
                                        # once now > arrival + deadline_s
                                        # (waiting OR running) — see
                                        # ``Scheduler.expire``
    # -- engine-filled ------------------------------------------------------
    tokens: List[int] = dataclasses.field(default_factory=list)
    admit_time: Optional[float] = None
    first_token_time: Optional[float] = None    # wall time of token #1
    finish_time: Optional[float] = None
    expired: bool = False   # evicted at its deadline (tokens may be partial)
    drafted: int = 0        # speculative: draft tokens proposed for this req
    accepted: int = 0       # speculative: draft tokens verified-accepted

    def past_deadline(self, now: float) -> bool:
        return (self.deadline_s is not None
                and now > self.arrival + self.deadline_s)

    @property
    def total_tokens(self) -> int:
        return len(self.prompt) + self.max_new

    @property
    def ttft(self) -> float:
        """Time-to-first-token (seconds from arrival) — the latency
        prefix sharing actually moves: a cache hit skips the matched
        prefill outright."""
        if self.first_token_time is None:
            return float("nan")
        return self.first_token_time - self.arrival

    @property
    def accept_rate(self) -> float:
        return self.accepted / self.drafted if self.drafted else float("nan")


@dataclasses.dataclass
class Slot:
    """Per-slot decode state.  ``pos`` is the next cache position to write
    (== committed tokens; speculative rollback rewinds it).  ``feed`` holds
    the tokens still to be fed through the persistent step: the prompt at
    admission (consumed in chunks — chunked prefill), then the single carry
    token once the slot is sampling; the first sampled token therefore
    comes out of the same jitted step as every other one.  ``blocks`` maps
    logical block index -> physical block id (−1 = unmapped: not yet
    written, rolled back, or recycled out of the window); ``reserved`` is
    the slot's remaining block budget (unmapped blocks it may still draw
    from the pool)."""
    req: Request
    blocks: List[int] = dataclasses.field(default_factory=list)
    reserved: int = 0
    feed: List[int] = dataclasses.field(default_factory=list)
    pos: int = 0
    generated: int = 0
    budget: int = 0         # blocks reserved at admission (private tail)
    num_shared: int = 0     # leading prefix-cache blocks (not budgeted;
                            # slot holds one pool reference each)
    cow: Optional[Tuple[int, int]] = None   # (src, dst) boundary-block
                            # copy the engine must run before the first
                            # step; src is pinned until then

    @property
    def in_prefill(self) -> bool:
        return self.generated == 0 and len(self.feed) > 1

    @property
    def history(self) -> List[int]:
        """Token history the drafter may match against: the prompt plus
        everything generated so far."""
        return self.req.prompt + self.req.tokens


class Scheduler:
    def __init__(self, num_slots: int, pool: KVBlockPool,
                 max_blocks_per_slot: int, policy: str = "fifo",
                 window: Optional[int] = None,
                 tree: Optional[PrefixTree] = None):
        """``window``: uniform sliding-window size in tokens (None/0 = full
        attention).  When set, per-request budgets cover only the live
        window span (+ one in-flight chunk, supplied per-request via
        ``chunk_tokens`` below) and ``recycle_window`` frees dead blocks.
        ``tree``: prefix cache; mutually exclusive with ``window`` (window
        recycling frees prompt blocks mid-request, which would yank them
        out from under later sharers — windowed archs bypass the cache)."""
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; one of {POLICIES}")
        if tree is not None and window:
            raise ValueError("prefix cache and sliding-window recycling "
                             "are mutually exclusive")
        self.pool = pool
        self.policy = policy
        self.max_blocks_per_slot = max_blocks_per_slot
        self.window = int(window) if window else 0
        self.tree = tree
        self.chunk_tokens = 1       # engine sets: max tokens fed per round
        self.waiting: List[Request] = []
        self.slots: List[Optional[Slot]] = [None] * num_slots
        self.peak_admitted = 0      # max simultaneously-occupied slots seen
        self.total_admitted = 0     # requests admitted over the run
        # per-run prefix-sharing counters (the tree's own counters are
        # cumulative across runs on a persistent engine)
        self.prefix_hits = 0
        self.prefix_misses = 0
        self.prefix_matched_tokens = 0
        self.prefix_prompt_tokens = 0
        self.prefix_shared_attached = 0     # full blocks attached shared
        self.prefix_forked = 0              # boundary blocks COW-forked

    # -- queries ------------------------------------------------------------
    @property
    def num_slots(self) -> int:
        return len(self.slots)

    def has_work(self) -> bool:
        return bool(self.waiting) or any(s is not None for s in self.slots)

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def budget_for(self, req: Request) -> int:
        """Block budget reserved at admission.  Dense: the full
        prompt+max_new footprint.  Windowed: the largest number of blocks
        simultaneously mapped — the window span plus the chunk being
        written, which can straddle two extra partial blocks."""
        need = self.pool.blocks_for(req.total_tokens)
        if self.window:
            live = self.pool.blocks_for(self.window + self.chunk_tokens) + 2
            need = min(need, live)
        return need

    # -- submission / admission --------------------------------------------
    def submit(self, req: Request) -> None:
        cap = self.max_blocks_per_slot * self.pool.block_size
        if req.total_tokens > cap:
            raise ValueError(
                f"request {req.rid}: {req.total_tokens} tokens exceeds the "
                f"per-slot capacity {cap}")
        need = self.budget_for(req)
        if need > self.pool.num_blocks:
            # would never admit -> the engine loop would spin forever
            raise ValueError(
                f"request {req.rid}: needs {need} blocks but the pool only "
                f"has {self.pool.num_blocks}")
        if not req.prompt:
            raise ValueError(f"request {req.rid}: empty prompt")
        self.waiting.append(req)

    def _ranked(self, now: float) -> List[int]:
        """Ready waiting-queue indices in admission-preference order."""
        ready = [i for i, r in enumerate(self.waiting) if r.arrival <= now]
        if self.policy == "longest_prefill":
            ready.sort(key=lambda i: (-len(self.waiting[i].prompt), i))
        elif self.policy == "cache_aware" and self.tree is not None:
            # longest cached prefix first: smallest tail budget, cheapest
            # prefill (dry-run match — no LRU perturbation)
            ready.sort(key=lambda i: (-self.tree.match(
                self.waiting[i].prompt, touch=False).matched_len, i))
        return ready

    def _try_admit(self, pick: int, free_slots: List[int],
                   now: float) -> Optional[int]:
        """Admit waiting[pick] if its unshared-tail budget fits (evicting
        LRU prefix-cache blocks under pressure); returns the slot index or
        None.  Matched prefix blocks attach shared (refcount bumped, no
        budget); a partially matched boundary block is COW-forked from the
        slot's own budget, its source pinned until the engine copies."""
        req = self.waiting[pick]
        m = self.tree.match(req.prompt) if self.tree is not None \
            else Match(blocks=[], matched_len=0)
        # pin every matched block BEFORE eviction runs: a childless matched
        # node (or the fork source) is otherwise fair game for the very
        # evict_for below, and would come back freed — or reallocated to
        # someone else.  The pins become the slot's own references on
        # success; on failure they are dropped.
        pinned = list(m.blocks)
        if m.fork_src is not None:
            pinned.append(m.fork_src)
        for b in pinned:
            self.pool.incref(b)
        need = self.budget_for(req) - len(m.blocks)
        if not self.pool.can_reserve(need):
            if self.tree is None \
                    or not self.tree.evict_for(self.pool, need) \
                    or not self.pool.can_reserve(need):
                if pinned:
                    self.pool.free(pinned)
                return None
        self.waiting.pop(pick)
        si = free_slots.pop(0)
        self.pool.reserve(need)
        slot = Slot(req=req, reserved=need, budget=need,
                    feed=list(req.prompt[m.matched_len:]),
                    pos=m.matched_len)
        slot.blocks = list(m.blocks)
        slot.num_shared = len(m.blocks)
        if m.fork_src is not None:
            # source stays pinned until the engine runs the device copy
            # (cow_executed); a later admission in this same admit() call
            # could otherwise evict it mid-flight
            dst = self.pool.alloc(1, reserved=True)[0]
            slot.reserved -= 1
            slot.blocks.append(dst)
            slot.cow = (m.fork_src, dst)
            self.prefix_forked += 1
        if self.tree is not None:
            self.prefix_hits += m.hit
            self.prefix_misses += not m.hit
            self.prefix_matched_tokens += m.matched_len
            self.prefix_prompt_tokens += len(req.prompt)
            self.prefix_shared_attached += len(m.blocks)
            self.tree.hits += m.hit
            self.tree.misses += not m.hit
            self.tree.matched_tokens += m.matched_len
        slot.req.admit_time = now if now != float("inf") else 0.0
        self.slots[si] = slot
        return si

    def admit(self, now: float = float("inf")) -> List[int]:
        """Admit as many ready requests as slots + block budget allow;
        returns the newly filled slot indices.  Admission only reserves —
        physical blocks are mapped lazily by ``ensure_mapped`` (matched
        prefix blocks attach immediately; see ``_try_admit``).  ``fifo``
        keeps head-of-line semantics: the oldest ready request blocks the
        queue until its budget fits.  The other policies scan the ready
        queue in preference order, so one over-budget request parked at
        the front cannot starve smaller ones that would fit now."""
        newly: List[int] = []
        free_slots = [i for i, s in enumerate(self.slots) if s is None]
        while free_slots and self.waiting:
            cands = self._ranked(now)
            if self.policy == "fifo":
                cands = cands[:1]           # documented head-of-line
            admitted = None
            for pick in cands:
                admitted = self._try_admit(pick, free_slots, now)
                if admitted is not None:
                    break
            if admitted is None:
                break
            newly.append(admitted)
        if newly:
            self.total_admitted += len(newly)
            self.peak_admitted = max(
                self.peak_admitted,
                sum(s is not None for s in self.slots))
        return newly

    def capacity_report(self) -> dict:
        """Bytes-denominated capacity snapshot: how much device memory the
        pool costs, what one admitted request's budget costs, and the
        admission high-water mark.  ``bytes_per_block`` = 0 when the pool
        was built without byte metadata."""
        bpb = self.pool.bytes_per_block
        out = {
            "num_blocks": self.pool.num_blocks,
            "block_size": self.pool.block_size,
            "bytes_per_block": bpb,
            "pool_bytes": self.pool.total_bytes,
            "peak_admitted": self.peak_admitted,
            "total_admitted": self.total_admitted,
        }
        if self.tree is not None:
            out["prefix"] = self.prefix_report()
        return out

    def prefix_report(self) -> dict:
        """Per-run prefix-sharing stats: hit rate over admitted requests,
        matched-token fraction, blocks attached shared / forked, and the
        pool bytes sharing saved (budget NOT reserved thanks to attached
        blocks).  ``tree`` holds the cumulative cross-run counters."""
        lookups = self.prefix_hits + self.prefix_misses
        return {
            "hits": self.prefix_hits,
            "misses": self.prefix_misses,
            "hit_rate": self.prefix_hits / lookups if lookups else 0.0,
            "matched_tokens": self.prefix_matched_tokens,
            "prompt_tokens": self.prefix_prompt_tokens,
            "matched_frac": (self.prefix_matched_tokens
                             / self.prefix_prompt_tokens
                             if self.prefix_prompt_tokens else 0.0),
            "shared_attached": self.prefix_shared_attached,
            "forked": self.prefix_forked,
            "bytes_saved": (self.prefix_shared_attached
                            * self.pool.bytes_per_block),
            "resident_blocks": self.tree.num_blocks
            if self.tree is not None else 0,
        }

    # -- prefix registration / copy-on-write --------------------------------
    def register_prefix(self, si: int) -> int:
        """Insert a slot's freshly prefilled prompt blocks into the prefix
        tree (the engine calls this the moment the prompt is fully
        written, so later arrivals in the same run can already share).
        The tree takes its own reference per new node; the slot keeps its
        own until ``finish``.  Returns blocks newly inserted."""
        if self.tree is None:
            return 0
        slot = self.slots[si]
        return self.tree.insert(slot.req.prompt, slot.blocks, self.pool)

    def cow_executed(self, si: int) -> None:
        """The engine finished the boundary-block device copy: unpin the
        source (admission pinned it so same-round eviction could not free
        it mid-copy)."""
        slot = self.slots[si]
        assert slot.cow is not None, f"no pending COW on slot {si}"
        self.pool.free([slot.cow[0]])
        slot.cow = None

    # -- lazy mapping / recycling -------------------------------------------
    def ensure_mapped(self, si: int, upto_pos: int) -> bool:
        """Map physical blocks for every logical block covering positions
        ``[0, upto_pos]`` that is still unmapped, drawing from the slot's
        reservation (capped by it: positions beyond the budgeted footprint
        stay unmapped and device writes there are dropped — never
        corrupted).  Returns True if the mapping changed."""
        slot = self.slots[si]
        need = min(self.pool.blocks_for(upto_pos + 1),
                   self.max_blocks_per_slot)
        changed = False
        if need > len(slot.blocks):
            slot.blocks.extend([-1] * (need - len(slot.blocks)))
        lo = 0
        if self.window:     # blocks below the window floor stay dead
            lo = max(0, (slot.pos - self.window + 1) // self.pool.block_size)
        for j in range(lo, need):
            if slot.blocks[j] < 0 and slot.reserved > 0:
                slot.blocks[j] = self.pool.alloc(1, reserved=True)[0]
                slot.reserved -= 1
                changed = True
        return changed

    def recycle_window(self, si: int) -> int:
        """Free mapped blocks that fell wholly out of the attention window
        (every key position <= pos − window can never be attended by a
        future query, since committed ``pos`` is monotone).  Budget returns
        to the slot, keeping its live-window mapping rights.  Returns the
        number of blocks recycled."""
        if not self.window:
            return 0
        slot = self.slots[si]
        bs = self.pool.block_size
        dead_upto = min(len(slot.blocks),
                        max(0, (slot.pos - self.window + 1) // bs))
        n = 0
        for j in range(dead_upto):
            if slot.blocks[j] >= 0:
                self.pool.free([slot.blocks[j]], rereserve=True)
                slot.blocks[j] = -1
                slot.reserved += 1
                n += 1
        return n

    # -- graceful degradation -----------------------------------------------
    def expire(self, now: float) -> List[Tuple[Optional[int], Request]]:
        """Evict every request past its ``deadline_s`` — graceful
        degradation under overload: a request that can no longer meet its
        SLO stops consuming capacity instead of starving those that can.

        Waiting requests simply leave the queue (they hold no resources).
        Running slots go through ``finish``, which returns every KV block,
        COW pin, budget reservation, and prefix-tree reference exactly as
        a natural completion would — the ledger sees no difference.
        Returns ``(slot_index | None, request)`` pairs (None = was still
        waiting) so the engine can clear the freed slots' block tables.
        """
        out: List[Tuple[Optional[int], Request]] = []
        keep: List[Request] = []
        for r in self.waiting:
            if r.past_deadline(now):
                r.expired = True
                r.finish_time = now
                out.append((None, r))
            else:
                keep.append(r)
        self.waiting = keep
        for si, slot in enumerate(self.slots):
            if slot is not None and slot.req.past_deadline(now):
                slot.req.expired = True
                out.append((si, self.finish(si, now)))
        return out

    def cancel(self, rid: int, now: float = 0.0) -> Optional[Request]:
        """Withdraw one request by id, waiting or running; same clean
        teardown as ``expire``.  Returns it, or None if unknown/finished.
        Callers driving an engine loop must clear the slot's block-table
        row when the returned request had been running."""
        for i, r in enumerate(self.waiting):
            if r.rid == rid:
                r.expired = True
                r.finish_time = now
                return self.waiting.pop(i)
        for si, slot in enumerate(self.slots):
            if slot is not None and slot.req.rid == rid:
                slot.req.expired = True
                return self.finish(si, now)
        return None

    # -- eviction -----------------------------------------------------------
    def finish(self, si: int, now: float = 0.0) -> Request:
        """Release the slot: every mapped block drops the slot's reference
        — private blocks return to the free list, shared prefix blocks
        stay resident under the tree's reference — and the leftover budget
        is released.  A never-executed COW pin (a request that finished
        before its first step, which the engine's flow does not produce)
        is dropped too, so the ledger stays leak-free regardless."""
        slot = self.slots[si]
        assert slot is not None, f"finish on empty slot {si}"
        if slot.cow is not None:
            self.pool.free([slot.cow[0]])
            slot.cow = None
        mapped = [b for b in slot.blocks if b >= 0]
        if mapped:
            self.pool.free(mapped)
        self.pool.release(slot.reserved)
        self.slots[si] = None
        if self.tree is not None and self.tree.max_blocks:
            # insert enforces the LRU bound too, but blocks attached to
            # live slots are unevictable then — re-check now that this
            # slot's references are gone
            self.tree.evict(self.pool, max(
                self.tree.num_blocks - self.tree.max_blocks, 0))
        slot.req.finish_time = now
        return slot.req
