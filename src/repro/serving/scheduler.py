"""Request scheduler for the continuous-batching engine.

Owns the waiting queue, the fixed slot set, and the block-pool bookkeeping:

* **admission** — a waiting request enters a free slot once its arrival time
  has passed and the pool can hold its full footprint
  (``ceil((len(prompt) + max_new) / block_size)`` blocks, reserved up front so
  a running request can never hit a mid-flight pool OOM);
* **eviction** — finished slots (EOS or ``max_new`` reached) free their
  blocks immediately, so the next waiting request backfills the slot while
  the remaining slots keep decoding;
* **policies** — ``fifo`` admits in arrival order; ``longest_prefill`` admits
  the longest waiting prompt first (front-loads heavy prefills so they
  overlap with many short decodes instead of serializing at the tail).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.serving.kv_cache import KVBlockPool

POLICIES = ("fifo", "longest_prefill")


@dataclasses.dataclass
class Request:
    """One generation request.  ``tokens`` is filled by the engine."""
    rid: int
    prompt: List[int]
    max_new: int = 16
    temperature: float = 1.0
    greedy: bool = True
    eos_id: Optional[int] = None
    arrival: float = 0.0
    # -- engine-filled ------------------------------------------------------
    tokens: List[int] = dataclasses.field(default_factory=list)
    admit_time: Optional[float] = None
    finish_time: Optional[float] = None

    @property
    def total_tokens(self) -> int:
        return len(self.prompt) + self.max_new


@dataclasses.dataclass
class Slot:
    """Per-slot decode state.  ``pos`` is the next cache position to write
    (== tokens already written).  ``feed`` holds the tokens still to be fed
    through the persistent step: the prompt at admission (consumed in
    chunks — chunked prefill), then the single carry token once the slot is
    sampling; the first sampled token therefore comes out of the same jitted
    step as every other one."""
    req: Request
    blocks: List[int]
    feed: List[int] = dataclasses.field(default_factory=list)
    pos: int = 0
    generated: int = 0

    @property
    def in_prefill(self) -> bool:
        return self.generated == 0 and len(self.feed) > 1


class Scheduler:
    def __init__(self, num_slots: int, pool: KVBlockPool,
                 max_blocks_per_slot: int, policy: str = "fifo"):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; one of {POLICIES}")
        self.pool = pool
        self.policy = policy
        self.max_blocks_per_slot = max_blocks_per_slot
        self.waiting: List[Request] = []
        self.slots: List[Optional[Slot]] = [None] * num_slots

    # -- queries ------------------------------------------------------------
    @property
    def num_slots(self) -> int:
        return len(self.slots)

    def has_work(self) -> bool:
        return bool(self.waiting) or any(s is not None for s in self.slots)

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    # -- submission / admission --------------------------------------------
    def submit(self, req: Request) -> None:
        cap = self.max_blocks_per_slot * self.pool.block_size
        if req.total_tokens > cap:
            raise ValueError(
                f"request {req.rid}: {req.total_tokens} tokens exceeds the "
                f"per-slot capacity {cap}")
        need = self.pool.blocks_for(req.total_tokens)
        if need > self.pool.num_blocks:
            # would never admit -> the engine loop would spin forever
            raise ValueError(
                f"request {req.rid}: needs {need} blocks but the pool only "
                f"has {self.pool.num_blocks}")
        if not req.prompt:
            raise ValueError(f"request {req.rid}: empty prompt")
        self.waiting.append(req)

    def _pick(self, now: float) -> Optional[int]:
        ready = [i for i, r in enumerate(self.waiting) if r.arrival <= now]
        if not ready:
            return None
        if self.policy == "longest_prefill":
            return max(ready, key=lambda i: (len(self.waiting[i].prompt),
                                             -i))
        return ready[0]

    def admit(self, now: float = float("inf")) -> List[int]:
        """Admit as many ready requests as slots + blocks allow; returns the
        newly filled slot indices."""
        newly: List[int] = []
        free_slots = [i for i, s in enumerate(self.slots) if s is None]
        while free_slots and self.waiting:
            pick = self._pick(now)
            if pick is None:
                break
            req = self.waiting[pick]
            need = self.pool.blocks_for(req.total_tokens)
            if not self.pool.can_allocate(need):
                break                       # head-of-line blocks until frees
            self.waiting.pop(pick)
            si = free_slots.pop(0)
            slot = Slot(req=req, blocks=self.pool.alloc(need),
                        feed=list(req.prompt))
            slot.req.admit_time = now if now != float("inf") else 0.0
            self.slots[si] = slot
            newly.append(si)
        return newly

    # -- eviction -----------------------------------------------------------
    def finish(self, si: int, now: float = 0.0) -> Request:
        slot = self.slots[si]
        assert slot is not None, f"finish on empty slot {si}"
        self.pool.free(slot.blocks)
        self.slots[si] = None
        slot.req.finish_time = now
        return slot.req
