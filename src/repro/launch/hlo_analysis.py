"""Post-SPMD HLO analysis with while-loop trip-count weighting.

XLA's ``compiled.cost_analysis()`` and a naive text scan both count a
``while`` body ONCE, but our models scan over layers — so per-layer
collectives (FSDP all-gathers, grad reduce-scatters) and flops are
undercounted by ~num_layers×.  (Verified: lowering qwen with L=2 vs L=4
changes neither metric.)

This module parses the post-partitioning HLO text into computations, builds
the call graph (while bodies weighted by their trip count, everything else
weight 1), and accumulates collective operand bytes with the correct
multiplicity.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
                "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3": 1, "f8e5m2": 1}

# computation headers sit at column 0 and end with '{'; their parameter
# lists may contain nested parens (tuple-typed params), so only anchor on
# the name + opening paren
_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_WHILE_RE = re.compile(
    r"while\([^)]*\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"(?:to_apply|calls)=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{(\{[0-9,{} ]*\})\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")


def _crosses_boundary(line: str, boundary: int) -> bool:
    """True if any replica group on this line contains devices on both
    sides of ``boundary`` (e.g. pod 0 = devices < 256, pod 1 = >= 256).

    Handles both explicit ``{{0,1},{2,3}}`` lists and the iota form
    ``[G,S]<=[dims]T(perm)``."""
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        import numpy as np
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        perm = ([int(x) for x in m.group(4).split(",")]
                if m.group(4) else list(range(len(dims))))
        devs = np.arange(int(np.prod(dims))).reshape(dims).transpose(perm)
        groups = devs.reshape(g, s)
        lo = groups < boundary
        return bool(np.any(np.any(lo, axis=1) & np.any(~lo, axis=1)))
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        for grp in re.findall(r"\{([0-9, ]*)\}", m.group(1)):
            devs = [int(x) for x in grp.replace(" ", "").split(",") if x]
            if devs and min(devs) < boundary <= max(devs):
                return True
        return False
    return False


_OP_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")


def _result_bytes(line: str) -> int:
    """Result bytes of the collective on this line (result type ~= payload).
    Handles tuple types: ``(f32[..], f32[..]) all-reduce(...)``."""
    head = line.split(" = ", 1)
    type_str = head[1] if len(head) == 2 else line
    # cut at the op keyword so tuple-type parens survive
    cut = len(type_str)
    for kind in _OP_KINDS:
        idx = type_str.find(kind + "(")
        if idx == -1:
            idx = type_str.find(kind + "-start(")
        if idx != -1:
            cut = min(cut, idx)
    type_str = type_str[:cut]
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_computations(hlo: str, pod_boundary: int = 0):
    """Returns (entry, colls, edges) where
    colls[comp]  = [(kind, bytes, crosses_pod), ...]
    edges[comp]  = [(callee, trip_weight), ...]
    """
    colls: Dict[str, List[Tuple[str, int, bool]]] = defaultdict(list)
    edges: Dict[str, List[Tuple[str, float]]] = defaultdict(list)
    cond_consts: Dict[str, int] = {}
    entry = None
    cur = None
    pending_whiles: List[Tuple[str, str, str]] = []  # (parent, cond, body)

    for raw in hlo.splitlines():
        line = raw.strip()
        m = _COMP_START.match(raw)
        if m and (raw.startswith("%") or raw.startswith("ENTRY")
                  or not raw.startswith(" ")):
            cur = m.group(1)
            if raw.startswith("ENTRY"):
                entry = cur
            continue
        if cur is None:
            continue
        cm = _CONST_RE.findall(line)
        if cm:
            cond_consts[cur] = max(cond_consts.get(cur, 0),
                                   max(int(x) for x in cm))
        w = _WHILE_RE.search(line)
        if w:
            pending_whiles.append((cur, w.group(1), w.group(2)))
            continue
        c = _COLL_RE.search(line)
        if c and "=" in line:
            cross = (_crosses_boundary(line, pod_boundary)
                     if pod_boundary else False)
            colls[cur].append((c.group(1), _result_bytes(line), cross))
            continue
        for callee in _CALL_RE.findall(line):
            edges[cur].append((callee, 1.0))
        b = _BRANCH_RE.search(line)
        if b:
            for callee in b.group(1).split(","):
                edges[cur].append((callee.strip().lstrip("%"), 1.0))

    for parent, cond, body in pending_whiles:
        trip = max(cond_consts.get(cond, 1), 1)
        edges[parent].append((body, float(trip)))
        edges[parent].append((cond, float(trip)))
    return entry, colls, edges


def weighted_collective_stats(hlo: str, pod_boundary: int = 0) -> Dict:
    """Collective bytes per device with while-trip multiplicity.

    ``pod_boundary`` > 0 additionally splits traffic into intra-pod (ICI)
    vs cross-pod (DCN) by replica-group span — the distinction DiLoCo's
    existence is about."""
    entry, colls, edges = parse_computations(hlo, pod_boundary)
    weights: Dict[str, float] = defaultdict(float)
    if entry is None:
        entry = next(iter(colls), None)
    if entry is None:
        return {"bytes_by_kind": {}, "count_by_kind": {},
                "wire_bytes_per_device": 0,
                "cross_pod_bytes_per_device": 0}
    # propagate weights through the call graph (it is a DAG in HLO)
    weights[entry] = 1.0
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        comp = order[i]
        i += 1
        for callee, w in edges.get(comp, ()):
            weights[callee] += weights[comp] * w
            if callee not in seen:
                seen.add(callee)
                order.append(callee)

    by_kind: Dict[str, float] = defaultdict(float)
    count: Dict[str, int] = defaultdict(int)
    cross_bytes = 0.0
    for comp, items in colls.items():
        w = weights.get(comp, 0.0)
        if w <= 0:
            # unreachable from entry in our parse; count once, conservatively
            w = 1.0
        for kind, b, cross in items:
            by_kind[kind] += b * w
            count[kind] += 1
            if cross:
                cross_bytes += b * w * (2 if kind == "all-reduce" else 1)
    wire = sum(b * (2 if k == "all-reduce" else 1)
               for k, b in by_kind.items())
    return {"bytes_by_kind": {k: int(v) for k, v in by_kind.items()},
            "count_by_kind": dict(count),
            "wire_bytes_per_device": int(wire),
            "cross_pod_bytes_per_device": int(cross_bytes)}
