"""Abstract train/serve state construction + sharding assignment.

Everything here works on ``ShapeDtypeStruct`` trees (``jax.eval_shape``) so
the dry-run never allocates 100B-parameter models on the CPU host.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.configs.base import ModelConfig, OptimizerConfig, DiLoCoConfig
from repro.models.sharding import spec_for
from repro.models.transformer import abstract_params
from repro.optim import nanochat_optimizer


# ---------------------------------------------------------------------------
# Logical names for non-param trees (path-based)
# ---------------------------------------------------------------------------

def _path_str(path) -> str:
    out = []
    for p in path:
        out.append(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))))
    return "/".join(out)


def opt_state_names(state_sds, param_names) -> Any:
    """Optimizer-state logical names: every state leaf whose path suffix
    matches a param path inherits that param's names; 0-sized sentinels and
    scalars are unsharded."""
    by_path = {}
    flat = jax.tree_util.tree_flatten_with_path(param_names,
                                                is_leaf=lambda x: isinstance(x, tuple))[0]
    for p, names in flat:
        by_path[_path_str(p)] = names

    def assign(path, leaf):
        if leaf.ndim == 0 or leaf.shape == (0,):
            return (None,) * leaf.ndim
        ps = _path_str(path)
        for key, names in by_path.items():
            if ps.endswith(key) and len(names) == leaf.ndim:
                return names
        return (None,) * leaf.ndim

    return jax.tree_util.tree_map_with_path(assign, state_sds)


def decode_cache_names(cache_sds) -> Any:
    """Logical names for a stacked decode cache, keyed by leaf name."""
    def assign(path, leaf):
        ps = _path_str(path)
        leafname = ps.split("/")[-1]
        if leafname in ("k", "v"):
            return ("stack", "batch", "kv_seq", "kv_heads", None)[:leaf.ndim] \
                if leaf.ndim == 5 else ("stack", "batch", "kv_seq", "kv_heads")
        if leafname == "pos":
            return ("stack", "batch", "kv_seq")
        if leafname == "idx":
            return ("stack",)
        if leafname == "conv":
            return ("stack", "batch", None, "heads")
        if leafname == "ssm":
            return ("stack", "batch", "heads", None, None)
        return (None,) * leaf.ndim

    return jax.tree_util.tree_map_with_path(assign, cache_sds)


def shardings_from_names(names_tree, sds_tree, mesh: Mesh):
    """names (logical, per-dim) + abstract shapes -> NamedSharding tree,
    with divisibility-aware fallback per dim."""
    return jax.tree.map(
        lambda names, sds: NamedSharding(mesh, spec_for(names, sds.shape, mesh)),
        names_tree, sds_tree,
        is_leaf=lambda x: (isinstance(x, tuple)
                           and all(n is None or isinstance(n, str) for n in x)))


def add_leading(names_tree, name: str = "pod"):
    """Prepend a logical dim (worker-stacking) to every leaf's names."""
    return jax.tree.map(
        lambda names: (name,) + tuple(names),
        names_tree,
        is_leaf=lambda x: (isinstance(x, tuple)
                           and all(n is None or isinstance(n, str) for n in x)))


# ---------------------------------------------------------------------------
# Abstract states
# ---------------------------------------------------------------------------

def abstract_train_state(cfg: ModelConfig, opt_cfg: OptimizerConfig
                         ) -> Tuple[Any, Any]:
    """(DDP-style single-worker train state SDS, logical names)."""
    from repro.core.ddp import DDPState
    params_sds, param_names = abstract_params(cfg)
    opt = nanochat_optimizer(opt_cfg)
    opt_sds = jax.eval_shape(opt.init, params_sds)
    state_sds = DDPState(params=params_sds, opt=opt_sds,
                         step=jax.ShapeDtypeStruct((), jnp.int32))
    names = DDPState(params=param_names,
                     opt=opt_state_names(opt_sds, param_names),
                     step=())
    return state_sds, names


def abstract_diloco_state(cfg: ModelConfig, opt_cfg: OptimizerConfig,
                          dcfg: DiLoCoConfig) -> Tuple[Any, Any]:
    """(DiLoCoState SDS, logical names) — worker dim stacked over ``pod``."""
    from repro.core.diloco import DiLoCoState, DiLoCoTrainer

    params_sds, param_names = abstract_params(cfg)
    trainer = DiLoCoTrainer(loss_fn=lambda p, b: (jnp.zeros(()), {}),
                            opt_cfg=opt_cfg, cfg=dcfg)
    state_sds = jax.eval_shape(trainer.init, params_sds)
    worker_names = add_leading(param_names, "pod")
    inner_names = add_leading(
        opt_state_names(
            jax.eval_shape(nanochat_optimizer(opt_cfg).init, params_sds),
            param_names), "pod")
    outer_names = type(state_sds.outer)(
        v=opt_state_names(state_sds.outer.v, param_names), t=())
    names = DiLoCoState(global_params=param_names, outer=outer_names,
                        worker_params=worker_names, inner_opt=inner_names,
                        inner_step=())
    return state_sds, names


def tp_kv_repeat(cfg: ModelConfig, tp: int) -> ModelConfig:
    """Repeat KV heads up to the tensor-parallel degree (standard Megatron
    GQA trick) so the decode KV cache shards cleanly over ``model``.  Only
    applies when the result still divides num_heads (grouping invariant);
    archs like llama4-scout (40H) / hymba (25H) instead shard the cache
    sequence dim over ``model`` (see dryrun_lib)."""
    if cfg.num_kv_heads >= tp or cfg.arch_type == "ssm":
        return cfg
    if tp % cfg.num_kv_heads or cfg.num_heads % tp:
        return cfg
    return dataclasses.replace(cfg, num_kv_heads=tp)
