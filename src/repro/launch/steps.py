"""Step functions lowered by the launcher / dry-run.

* ``make_train_step``   — one synchronous (within-worker) training step:
                          fwd + bwd + Muon/AdamW update.  On the single-pod
                          mesh this is DiLoCo's *inner* step and the DDP
                          step at the same time (they only differ in which
                          mesh axes the batch spans).
* ``make_diloco_steps`` — (inner, outer) for the multi-pod mesh: inner is
                          the vmapped per-pod step (no cross-pod traffic);
                          outer is the delta exchange + Nesterov update.
* ``make_inner_chunk``  — the scan-fused H-step inner chunk (the hot path
                          ``DistTrainer`` runs): one program per outer
                          round, for dry-run lowering / HLO inspection.
* ``make_prefill_step`` — full-sequence forward (inference prefill).
* ``make_serve_step``   — one-token decode against a KV cache.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax

from repro.configs.base import DiLoCoConfig, OptimizerConfig
from repro.core.ddp import DDPState
from repro.core.diloco import DiLoCoTrainer
from repro.models.transformer import ModelAPI
from repro.optim import apply_updates, nanochat_optimizer


def make_train_step(model: ModelAPI, opt_cfg: OptimizerConfig) -> Callable:
    opt = nanochat_optimizer(opt_cfg)

    def train_step(state: DDPState, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(state.params, batch)
        updates, opt_state = opt.update(grads, state.opt, state.params,
                                        state.step)
        return (DDPState(apply_updates(state.params, updates), opt_state,
                         state.step + 1), loss)

    return train_step


def make_diloco_steps(model: ModelAPI, opt_cfg: OptimizerConfig,
                      dcfg: DiLoCoConfig,
                      replicate_fn=None) -> Tuple[Callable, Callable]:
    trainer = DiLoCoTrainer(model.loss, opt_cfg, dcfg,
                            replicate_fn=replicate_fn)

    def inner(state, batches):
        new_state, loss, _ = trainer.inner_step(state, batches)
        return new_state, loss

    return inner, trainer.outer_step


def make_inner_chunk(model: ModelAPI, opt_cfg: OptimizerConfig,
                     dcfg: DiLoCoConfig, replicate_fn=None) -> Callable:
    """``chunk(state, batches) -> (state, (T, K) losses)`` with a leading
    (T, ...) time dim on ``batches`` — the scan-fused inner program the
    chunked ``DistTrainer`` loop dispatches once per sync interval.
    Useful for dry-run lowering: the whole H-step round is ONE HLO module
    whose only cross-pod collectives would be bugs (inner steps are
    pod-local by construction)."""
    trainer = DiLoCoTrainer(model.loss, opt_cfg, dcfg,
                            replicate_fn=replicate_fn)
    return trainer.inner_chunk


def make_prefill_step(model: ModelAPI) -> Callable:
    def prefill(params, batch):
        logits, _ = model.forward(params, batch)
        # serving returns next-token logits for the last position
        return logits[:, -1, :]

    return prefill


def make_serve_step(model: ModelAPI) -> Callable:
    def serve_step(params, cache, batch):
        logits, new_cache = model.decode_step(params, cache, batch)
        return logits[:, 0, :], new_cache

    return serve_step
