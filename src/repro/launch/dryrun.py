import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run CLI.

Lowers + compiles every (architecture × input shape) combination on the
single-pod (16×16) and multi-pod (2×16×16) production meshes, printing
memory_analysis / cost_analysis / collective statistics per combination.

Usage:
  python -m repro.launch.dryrun --arch qwen1.5-0.5b --shape train_4k
  python -m repro.launch.dryrun --all                   # 40 baselines
  python -m repro.launch.dryrun --all --multi-pod       # 2-pod sweep
  python -m repro.launch.dryrun --outer --arch nanochat-d20   # outer step
"""
import argparse
import dataclasses
import json
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--outer", action="store_true")
    ap.add_argument("--delta-dtype", type=str, default="float32")
    ap.add_argument("--profile", type=str, default="2d",
                    help="sharding profile: 2d|dp|dp_fsdp|attn_dp|"
                         "expert_parallel|seqpar|auto "
                         "(auto = per-arch \u00a7Perf selection)")
    ap.add_argument("--json-out", type=str, default=None)
    args = ap.parse_args(argv)

    from repro.configs.registry import ARCH_IDS
    from repro.configs.base import SHAPES
    from repro.launch.dryrun_lib import PROFILES, dryrun_combo, dryrun_outer_step
    rules = ({"__auto__": True} if args.profile == "auto"
             else PROFILES[args.profile])

    results = []
    if args.outer:
        archs = [args.arch] if args.arch else ["nanochat-d20"]
        for a in archs:
            results.append(dryrun_outer_step(a, delta_dtype=args.delta_dtype))
    else:
        archs = [args.arch] if args.arch else ARCH_IDS
        shapes = [args.shape] if args.shape else list(SHAPES)
        for a in archs:
            for s in shapes:
                results.append(dryrun_combo(a, s, multi_pod=args.multi_pod,
                                             rules=rules))

    ok = all(r is not None for r in results)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump([dataclasses.asdict(r) for r in results], f, indent=1)
    print(f"[dryrun] {len(results)} combinations compiled successfully"
          if ok else "[dryrun] FAILURES", flush=True)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
