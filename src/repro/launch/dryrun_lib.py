"""Dry-run machinery: lower + compile every (architecture × input shape ×
mesh) combination with ShapeDtypeStruct inputs (no allocation), extract
memory / cost / collective statistics, and derive the roofline terms.

Importable without side effects — ``dryrun.py`` is the CLI entry point that
sets XLA_FLAGS before any jax import.
"""
from __future__ import annotations

import dataclasses
import re
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import (DiLoCoConfig, ModelConfig, OptimizerConfig,
                                ShapeConfig)
from repro.configs.registry import (decode_cache_capacity, get_config,
                                    input_specs, long_context_variant,
                                    shape_by_name)
from repro.launch import steps as steps_mod
from repro.launch.analytic import bytes_per_device, flops_per_device
from repro.launch.hlo_analysis import weighted_collective_stats
from repro.launch.mesh import (DCN_BW, HBM_BW, ICI_BW,
                               PEAK_FLOPS_BF16, make_production_mesh)
from repro.launch.state import (abstract_diloco_state, abstract_train_state,
                                decode_cache_names,
                                shardings_from_names, tp_kv_repeat)
from repro.models.sharding import sharding_ctx, spec_for
from repro.models.transformer import build_model

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "s32": 4, "s16": 2, "s8": 1,
                "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"^\s*(?:%?\S+\s*=\s*)?"
    r"((?:\([^)]*\))|(?:\S+\[[^\]]*\]\S*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
    re.M)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def hlo_collective_stats(hlo_text: str) -> Dict[str, Any]:
    """Per-device collective operand bytes by op kind, from post-SPMD HLO."""
    by_kind: Dict[str, int] = {}
    count: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(type_str)
        by_kind[kind] = by_kind.get(kind, 0) + b
        count[kind] = count.get(kind, 0) + 1
    # wire-bytes estimate: ring all-reduce moves ~2x the payload; the others ~1x
    wire = sum(b * (2 if k == "all-reduce" else 1) for k, b in by_kind.items())
    return {"bytes_by_kind": by_kind, "count_by_kind": count,
            "wire_bytes_per_device": wire}


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------

def _cast_params(sds_tree, dtype):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, dtype if jnp.issubdtype(s.dtype, jnp.floating) else s.dtype),
        sds_tree)


_BATCH_NAMES = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "patches": ("batch", None, None),
    "frames": ("batch", None, None),
    "token": ("batch", None),
    "position": ("batch",),
}


def _batch_shardings(batch_sds, mesh, stacked: bool = False):
    names = {k: (("pod",) + _BATCH_NAMES[k] if stacked else _BATCH_NAMES[k])
             for k in batch_sds}
    return shardings_from_names(names, batch_sds, mesh)


@dataclasses.dataclass
class DryrunResult:
    arch: str
    shape: str
    mesh_desc: str
    step_kind: str
    lower_s: float
    compile_s: float
    memory: Dict[str, int]
    # raw XLA cost_analysis (NOTE: scan/while bodies counted ONCE — kept as
    # a cross-check; the roofline uses the analytic + weighted numbers)
    flops_per_device: float
    hlo_bytes_per_device: float
    collectives: Dict[str, Any]            # naive text parse (body-once)
    collectives_weighted: Dict[str, Any]   # while-trip weighted parse
    analytic: Dict[str, float]             # analytic flops/bytes per device
    n_params: int

    def roofline(self) -> Dict[str, float]:
        t_compute = self.analytic["total_flops"] / PEAK_FLOPS_BF16
        t_memory = self.analytic["bytes"] / HBM_BW
        cross = self.collectives_weighted.get("cross_pod_bytes_per_device", 0)
        ici = self.collectives_weighted["wire_bytes_per_device"] - cross
        t_coll = ici / ICI_BW + cross / DCN_BW
        dom = max((t_compute, "compute"), (t_memory, "memory"),
                  (t_coll, "collective"))
        return {"compute_s": t_compute, "memory_s": t_memory,
                "collective_s": t_coll, "cross_pod_s": cross / DCN_BW,
                "bound": dom[1]}


def _finish(arch, shape_name, mesh, kind, jitted, args, n_params,
            verbose=True, cfg=None, shape=None,
            cache_capacity=0) -> DryrunResult:
    t0 = time.time()
    lowered = jitted.lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    mem = compiled.memory_analysis()
    memd = {k: int(getattr(mem, k, 0)) for k in
            ("temp_size_in_bytes", "argument_size_in_bytes",
             "output_size_in_bytes", "alias_size_in_bytes",
             "generated_code_size_in_bytes")}
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    cost = dict(cost or {})
    hlo_text = compiled.as_text()
    colls = hlo_collective_stats(hlo_text)
    chips = 1
    for v in mesh.shape.values():
        chips *= v
    n_pods = mesh.shape.get("pod", 1)
    boundary = chips // n_pods if n_pods > 1 else 0
    colls_w = weighted_collective_stats(hlo_text, pod_boundary=boundary)
    analytic = {}
    if cfg is not None and shape is not None:
        analytic.update(flops_per_device(cfg, shape, chips,
                                         remat=cfg.remat))
        analytic.update(bytes_per_device(cfg, shape, chips,
                                         cache_capacity=cache_capacity))
    else:
        analytic = {"total_flops": float(cost.get("flops", 0.0)),
                    "fwd_flops": 0.0, "model_flops_6nd": 0.0,
                    "bytes": float(cost.get("bytes accessed", 0.0))}
    res = DryrunResult(
        arch=arch, shape=shape_name,
        mesh_desc="x".join(f"{k}={v}" for k, v in mesh.shape.items()),
        step_kind=kind, lower_s=t1 - t0, compile_s=t2 - t1,
        memory=memd,
        flops_per_device=float(cost.get("flops", 0.0)),
        hlo_bytes_per_device=float(cost.get("bytes accessed", 0.0)),
        collectives=colls, collectives_weighted=colls_w,
        analytic=analytic, n_params=n_params)
    if verbose:
        rl = res.roofline()
        live = (memd["argument_size_in_bytes"] + memd["temp_size_in_bytes"]
                + memd["output_size_in_bytes"])
        print(f"[dryrun] {arch:24s} {shape_name:12s} {res.mesh_desc:24s} "
              f"{kind:12s} lower={res.lower_s:5.1f}s compile={res.compile_s:6.1f}s "
              f"args+temp+out={live/2**30:7.2f}GiB "
              f"flops/dev={res.analytic['total_flops']:.3e} "
              f"hbm/dev={res.analytic['bytes']:.3e} "
              f"coll/dev={colls_w['wire_bytes_per_device']:.3e} "
              f"bound={rl['bound']}")
    return res


def default_opt_cfg() -> OptimizerConfig:
    return OptimizerConfig(total_steps=10000, warmup_steps=100)


# Sharding profiles (beyond-paper §Perf results — see EXPERIMENTS.md).
# Keys are logical-axis rule overrides passed to sharding_ctx.
PROFILES: Dict[str, Dict] = {
    # baseline: FSDP over `data` x tensor-parallel over `model`
    "2d": {},
    # pure data-parallel: batch over all 256 chips, weights replicated.
    # Optimal for <~2B models (H1/H3: 21.6x / 18.3x collective reduction).
    "dp": {"batch": ("data", "model"), "fsdp": (), "model": (), "vocab": (),
           "heads": (), "kv_heads": (), "ffn": (), "expert": ()},
    # data-parallel batch + FSDP weights (fits-HBM variant of "dp")
    "dp_fsdp": {"batch": ("data", "model"), "model": (), "vocab": (),
                "heads": (), "kv_heads": (), "ffn": (), "expert": (),
                "fsdp": ("data",)},
    # attention data-parallel-only; FFN/experts keep TP.  For archs whose
    # head counts cannot shard over the TP degree (llama4 40H, hymba 25H,
    # kv=8 models): removes per-KV-chunk attention all-reduces (H2: 13.7x).
    "attn_dp": {"heads": (), "kv_heads": ()},
    # expert-parallel MoE: experts over `model` (requires num_experts %
    # tp == 0, e.g. llama4's 16) + attention DP (H2 iter 2: another 1.7x).
    "expert_parallel": {"heads": (), "kv_heads": (), "expert": ("model",),
                        "ffn": ()},
    # Megatron-style sequence parallelism: residual stream sharded on seq
    # over `model` — 7.3x activation-memory cut on mistral-large train.
    "seqpar": {"seq": ("model",)},
}


def auto_profile(cfg: ModelConfig, shape: ShapeConfig, tp: int,
                 chips: int = 256) -> Dict:
    """Pick the sharding profile the §Perf hillclimbs identified per model
    class.  Every branch is backed by a measured before/after in
    EXPERIMENTS.md §Perf; branches that measured as regressions (dp on
    small-batch prefill; attention-DP for kv-only indivisibility) were
    removed after the first auto-sweep.

    * train, < 1B, batch % chips == 0 -> dp       (21.6x, fits)
    * train, 1-3B                     -> dp_fsdp  (3.8x, 36->8 GiB)
    * MoE with experts % tp == 0      -> expert_parallel (23x prefill,
                                         1.8x + half memory train)
    * train, > 50B                    -> seqpar   (403->134 GiB, 1.2x)
    * otherwise                       -> 2d baseline
    """
    if shape.kind == "decode":
        return {}
    n = cfg.param_count()
    rules: Dict = {}
    dp_batch_ok = shape.global_batch % chips == 0
    if shape.kind == "train" and n < 1e9 and dp_batch_ok:
        return dict(PROFILES["dp"])
    if shape.kind == "train" and n < 3e9 and dp_batch_ok:
        return dict(PROFILES["dp_fsdp"])
    if cfg.num_experts and cfg.num_experts % tp == 0:
        rules.update(PROFILES["expert_parallel"])
    if n > 5e10 and shape.kind == "train":
        rules.update(PROFILES["seqpar"])
    return rules


def dryrun_combo(arch_id: str, shape_name: str, *, multi_pod: bool = False,
                 mesh: Optional[Mesh] = None,
                 rules: Optional[Dict] = None,
                 cfg_override: Optional[ModelConfig] = None,
                 verbose: bool = True) -> DryrunResult:
    """Lower + compile the step this (arch × shape) pair exercises.

    train_4k    -> train_step   (multi_pod: vmapped DiLoCo inner step)
    prefill_32k -> prefill_step
    decode_*    -> serve_step (1 token vs seq_len KV cache)
    """
    shape = shape_by_name(shape_name)
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    tp = mesh.shape.get("model", 1)
    n_pods = mesh.shape.get("pod", 1)

    cfg = cfg_override or get_config(arch_id)
    cfg = cfg.with_(compute_dtype="bfloat16", param_dtype="bfloat16",
                    vocab_pad_multiple=256)
    if shape.sub_quadratic_required:
        cfg = long_context_variant(cfg)
    if shape.kind == "decode":
        cfg = tp_kv_repeat(cfg, tp)
    model = build_model(cfg)
    n_params = cfg.param_count()

    eff_rules = dict(rules or {})
    if eff_rules.pop("__auto__", False):
        eff_rules = auto_profile(cfg, shape, tp)
    if shape.kind == "decode" and cfg.num_kv_heads % tp and cfg.arch_type != "ssm":
        # KV heads cannot shard over the TP axis (e.g. 40H/25H on a 16-way
        # mesh) -> shard the KV cache SEQUENCE dim over `model` instead
        # (sequence-parallel decode attention; softmax reduces over shards).
        eff_rules.setdefault("kv_seq", ("model",))
    if multi_pod and shape.kind == "train":
        # DiLoCo inner step: the worker dim owns "pod"; batch stays on "data"
        eff_rules.setdefault("batch", ("data",))
        eff_rules.setdefault("pod", ("pod",))

    with sharding_ctx(mesh, eff_rules):
        if shape.kind == "train" and not multi_pod:
            state_sds, names = abstract_train_state(cfg, default_opt_cfg())
            state_sds = state_sds._replace(
                params=_cast_params(state_sds.params, jnp.bfloat16))
            st_sh = shardings_from_names(names, state_sds, mesh)
            batch_sds = input_specs(cfg, shape)
            b_sh = _batch_shardings(batch_sds, mesh)
            step = steps_mod.make_train_step(model, default_opt_cfg())
            jitted = jax.jit(step, in_shardings=(st_sh, b_sh),
                             out_shardings=(st_sh, NamedSharding(mesh, P())))
            return _finish(arch_id, shape_name, mesh, "train", jitted,
                           (state_sds, batch_sds), n_params, verbose,
                           cfg=cfg, shape=shape)

        if shape.kind == "train" and multi_pod:
            dcfg = DiLoCoConfig(num_workers=n_pods)
            state_sds, names = abstract_diloco_state(cfg, default_opt_cfg(), dcfg)
            state_sds = state_sds._replace(
                global_params=_cast_params(state_sds.global_params, jnp.bfloat16),
                worker_params=_cast_params(state_sds.worker_params, jnp.bfloat16))
            st_sh = shardings_from_names(names, state_sds, mesh)
            per_worker = {k: jax.ShapeDtypeStruct((n_pods, s.shape[0] // n_pods)
                                                  + s.shape[1:], s.dtype)
                          for k, s in input_specs(cfg, shape).items()}
            b_sh = _batch_shardings(per_worker, mesh, stacked=True)
            inner, outer = steps_mod.make_diloco_steps(
                model, default_opt_cfg(), dcfg)
            jitted = jax.jit(inner, in_shardings=(st_sh, b_sh),
                             out_shardings=(st_sh, NamedSharding(mesh, P("pod"))))
            return _finish(arch_id, shape_name, mesh, "diloco-inner", jitted,
                           (state_sds, per_worker), n_params, verbose,
                           cfg=cfg, shape=shape)

        from repro.models.transformer import abstract_params
        params_sds, param_names = abstract_params(cfg)
        params_sds = _cast_params(params_sds, jnp.bfloat16)
        p_sh = shardings_from_names(param_names, params_sds, mesh)

        if shape.kind == "prefill":
            batch_sds = input_specs(cfg, shape)
            batch_sds.pop("labels", None)
            b_sh = _batch_shardings(batch_sds, mesh)
            step = steps_mod.make_prefill_step(model)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
            return _finish(arch_id, shape_name, mesh, "prefill", jitted,
                           (params_sds, batch_sds), n_params, verbose,
                           cfg=cfg, shape=shape)

        # decode
        cap = decode_cache_capacity(cfg, shape)
        cache_sds = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, cap,
                                     dtype=jnp.bfloat16))
        c_names = decode_cache_names(cache_sds)
        c_sh = shardings_from_names(c_names, cache_sds, mesh)
        batch_sds = input_specs(cfg, shape)
        b_sh = _batch_shardings(batch_sds, mesh)
        step = steps_mod.make_serve_step(model)
        jitted = jax.jit(step, in_shardings=(p_sh, c_sh, b_sh),
                         out_shardings=(
                             NamedSharding(mesh, spec_for(
                                 ("batch", "vocab"),
                                 (shape.global_batch, cfg.padded_vocab()), mesh)),
                             c_sh))
        return _finish(arch_id, shape_name, mesh, "decode", jitted,
                       (params_sds, cache_sds, batch_sds), n_params, verbose,
                       cfg=cfg, shape=shape, cache_capacity=cap)


def dryrun_outer_step(arch_id: str, *, delta_dtype: str = "float32",
                      drift_aware: bool = False,
                      verbose: bool = True) -> DryrunResult:
    """Lower the DiLoCo OUTER step on the multi-pod mesh — the inter-pod
    delta exchange the paper's ~100x communication saving refers to."""
    mesh = make_production_mesh(multi_pod=True)
    n_pods = mesh.shape["pod"]
    cfg = get_config(arch_id).with_(compute_dtype="bfloat16",
                                    param_dtype="bfloat16",
                                    vocab_pad_multiple=256)
    model = build_model(cfg)
    dcfg = DiLoCoConfig(num_workers=n_pods, delta_dtype=delta_dtype,
                        drift_aware=drift_aware)

    with sharding_ctx(mesh, {"pod": ("pod",)}):
        state_sds, names = abstract_diloco_state(cfg, default_opt_cfg(), dcfg)

    # the delta exchange gathers ONLY over `pod`: each leaf keeps its
    # fsdp/model shards and drops the leading pod dim from its spec
    param_name_leaves = jax.tree.leaves(
        names.global_params,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            n is None or isinstance(n, str) for n in x))

    def replicate(tree):
        leaves, treedef = jax.tree.flatten(tree)
        out = []
        for leaf, pn in zip(leaves, param_name_leaves):
            if leaf.ndim == len(pn) + 1:     # (K, ...param dims)
                spec = spec_for((None,) + tuple(pn), leaf.shape, mesh)
            else:                             # scales etc.
                spec = P()
            out.append(jax.lax.with_sharding_constraint(
                leaf, NamedSharding(mesh, spec)))
        return jax.tree.unflatten(treedef, out)

    with sharding_ctx(mesh, {"pod": ("pod",)}):
        state_sds = state_sds._replace(
            global_params=_cast_params(state_sds.global_params, jnp.bfloat16),
            worker_params=_cast_params(state_sds.worker_params, jnp.bfloat16))
        st_sh = shardings_from_names(names, state_sds, mesh)
        _, outer = steps_mod.make_diloco_steps(model, default_opt_cfg(), dcfg,
                                               replicate_fn=replicate)
        jitted = jax.jit(outer, in_shardings=(st_sh,), out_shardings=st_sh)
        return _finish(arch_id, f"outer[{delta_dtype}]", mesh, "diloco-outer",
                       jitted, (state_sds,), cfg.param_count(), verbose)
