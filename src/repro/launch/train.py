"""Training launcher — the paper's pipeline as a CLI.

Runs the three-stage nanochat pipeline (base pretrain -> dialogue mid-train
-> SFT) under any of the three configurations the paper compares:

  --method ddp         fully synchronous baseline
  --method diloco      DiLoCo wrapper (H, mu, eta from the paper)
  --method streaming   Streaming DiLoCo (fragment-wise staggered sync)
  --method overlapped  delayed outer application + straggler jitter
  --method pipelined   DiLoCoX shape: one fragment per round, delayed apply
  --method gossip      no-all-reduce peer averaging (--topology ring|random|full)
  --method async_gossip gossip on per-worker clocks (H + jitter_i) with a
                       staleness-aware apply rule (--staleness-bound)
  --method hybrid      DiLoCo base, DDP mid+SFT (checkpoint hand-off)

``--method`` accepts any name registered in ``repro.core.sync`` (the list
above plus whatever plugins register_strategy() added) and "hybrid".

``--sync-dtype f32|bf16|int8|fp8|e5m2`` picks the outer-sync wire codec
(int8/fp8 add per-tensor scales + error feedback, see repro.core.transport);
``--grad-compress int8|fp8`` turns ``--method ddp`` into K real workers
exchanging per-step updates through the same codec stack (CompressedDDPSync);
``--worker-speeds 1,1,1.2,1.5`` models a heterogeneous fleet: after the
run, the comm simulator replays the sync schedule with per-worker step
clocks (calibrated from the measured inner-step seconds of the base
stage) and reports the modeled homogeneous vs heterogeneous wall-clock.

On this CPU container the model is a reduced nanochat-style config and the
corpora are synthetic (see repro.data.synthetic); on a TPU fleet the same
entry point drives the production mesh (--arch picks any registered
architecture, DiLoCo workers map to pods).

Examples:
  PYTHONPATH=src python -m repro.launch.train --method diloco --steps 200
  PYTHONPATH=src python -m repro.launch.train --method hybrid --arch nanochat-d20 --reduced
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, Optional, Sequence

import jax


def build_pipeline(vocab_budget: int = 512, seq_len: int = 128,
                   n_pretrain: int = 6000, seed: int = 0):
    """Tokenizer + three-stage datasets + eval suites (synthetic world)."""
    from repro.data import PackedDataset, build_tokenizer, synthetic
    world = synthetic.World.make(40, seed=1234 + seed)
    pre_texts = synthetic.gen_pretrain_texts(world, n_pretrain, seed=seed)
    tok = build_tokenizer(pre_texts[:2000], vocab_budget)
    stages = {
        "base": PackedDataset.from_texts(pre_texts, tok, seq_len),
        "mid": PackedDataset.from_texts(
            synthetic.gen_dialogue_texts(world, n_pretrain // 2, seed=seed + 1),
            tok, seq_len),
        "sft": PackedDataset.from_texts(
            synthetic.gen_sft_texts(world, n_pretrain // 2, seed=seed + 2),
            tok, seq_len),
    }
    suites = {
        "mc": synthetic.gen_mc_eval(world, 32, seed=7),
        "arith": synthetic.gen_arith_eval(32, seed=8),
        "pattern": synthetic.gen_pattern_eval(32, seed=9),
    }
    return world, tok, stages, suites


def make_model(arch: str, reduced: bool, vocab_size: int):
    from repro.configs import get_config, get_reduced
    from repro.models import build_model
    if arch == "tiny":
        from repro.configs.base import ModelConfig
        cfg = ModelConfig(name="tiny-nanochat", num_layers=4, d_model=128,
                          num_heads=4, num_kv_heads=4, d_ff=512,
                          vocab_size=vocab_size, tie_embeddings=True)
    else:
        cfg = get_reduced(arch) if reduced else get_config(arch)
        cfg = cfg.with_(vocab_size=vocab_size)
    return cfg, build_model(cfg)


def run_stage(method: str, model, params, stage_ds, *, steps: int,
              workers: int, per_worker_batch: int, h: int,
              opt_cfg, diloco_cfg, seed: int = 0,
              h_schedule=None, prefetch: int = 0,
              faults=None, min_quorum: int = 1,
              checkpoint_dir=None, checkpoint_every: int = 0,
              resume: bool = False):
    """Run one pipeline stage under any sync strategy; returns
    (final params, history).  All methods go through the unified
    ``DistTrainer`` runtime — ``method`` picks the ``SyncStrategy``."""
    import dataclasses
    import jax.numpy as jnp
    from repro.core import DistTrainer, make_strategy

    if method == "ddp" and diloco_cfg.grad_compress not in ("", "none"):
        # DDP-side gradient compression: K real workers exchanging their
        # per-step updates through the codec (core.sync.CompressedDDPSync)
        from repro.core.sync import compressed_ddp_config
        dcfg = compressed_ddp_config(
            dataclasses.replace(diloco_cfg, num_workers=workers))

        def data(step):
            b = stage_ds.worker_batches(step, workers, per_worker_batch,
                                        seed=seed)
            return {k: jnp.asarray(v) for k, v in b.items()}
    elif method == "ddp":
        dcfg = dataclasses.replace(diloco_cfg, num_workers=1,
                                   h_inner_steps=1, outer_lr=1.0,
                                   outer_momentum=0.0, nesterov=False,
                                   strategy="ddp")

        def data(step):
            b = stage_ds.batch(step, workers * per_worker_batch, seed=seed)
            return {k: jnp.asarray(v)[None] for k, v in b.items()}
    else:
        # clamp the overlap knobs to the stage's H (stage budgets can shrink
        # H below a globally-configured delay/jitter)
        delay = min(diloco_cfg.sync_delay, h - 1)
        jitter = min(diloco_cfg.h_jitter, h - 1 - delay)
        dcfg = dataclasses.replace(diloco_cfg, num_workers=workers,
                                   h_inner_steps=h, strategy=method,
                                   sync_delay=delay, h_jitter=jitter)

        def data(step):
            b = stage_ds.worker_batches(step, workers, per_worker_batch,
                                        seed=seed)
            return {k: jnp.asarray(v) for k, v in b.items()}

    trainer = DistTrainer(model.loss, opt_cfg, dcfg,
                          make_strategy(dcfg, h_schedule=h_schedule))
    state = trainer.init(params)
    state, hist = trainer.run(state, data, steps, prefetch=prefetch,
                              faults=faults, min_quorum=min_quorum,
                              checkpoint_dir=checkpoint_dir,
                              checkpoint_every=checkpoint_every,
                              resume=resume)
    return state.global_params, hist


def comm_report(dcfg, method: str, n_params: int, steps: int, h: int,
                step_time_s: float, worker_speeds: Sequence[float],
                staleness: int = 0, faults=None) -> Dict:
    """Replay the run's sync schedule through the comm simulator: the
    symmetric fleet vs one with per-worker step clocks (``worker_speeds``
    are relative per-worker multipliers on the measured step seconds)."""
    import dataclasses
    from repro.core import make_strategy
    from repro.launch.comm_sim import (default_comm_model, simulate_gossip,
                                       simulate_heterogeneous,
                                       simulate_schedule)
    # mirror run_stage's clamping so the replayed schedule matches the
    # schedule the run actually executed
    delay = min(dcfg.sync_delay, h - 1)
    jitter = min(dcfg.h_jitter, h - 1 - delay)
    dcfg = dataclasses.replace(dcfg, h_inner_steps=h, sync_delay=delay,
                               h_jitter=jitter,
                               strategy=method if method != "hybrid"
                               else "diloco")
    strat = make_strategy(dcfg)
    events = strat.payload_schedule(n_params, steps, dcfg)
    comm = default_comm_model()
    homo = simulate_schedule(events, steps, step_time_s, comm)
    het = simulate_heterogeneous(
        events, steps, [step_time_s * m for m in worker_speeds], comm,
        staleness_steps=staleness, faults=faults)
    report = {"homogeneous": homo, "heterogeneous": het,
              "worker_speeds": list(worker_speeds),
              "step_time_s": step_time_s}
    if hasattr(strat, "gossip_rounds"):
        # gossip strategies synchronize per pair, not per fleet: replay the
        # actual pair dependencies so the wall-clock reflects pair barriers
        rounds = strat.gossip_rounds(n_params, steps, dcfg)
        report["gossip"] = simulate_gossip(
            rounds, steps, [step_time_s * m for m in worker_speeds], comm,
            staleness_steps=dcfg.staleness_bound, faults=faults)
    return report


def run_pipeline(method: str = "diloco", arch: str = "tiny",
                 reduced: bool = True, steps: Dict[str, int] = None,
                 workers: int = 4, per_worker_batch: int = 8,
                 seq_len: int = 128, adaptive_h: bool = False,
                 delta_dtype: str = "float32", grad_compress: str = "none",
                 drift_aware: bool = False,
                 sync_delay: int = 0, h_jitter: int = 0,
                 topology: str = "ring", staleness_bound: int = 0,
                 num_fragments: int = 4, error_feedback: bool = True,
                 worker_speeds: Sequence[float] = (),
                 prefetch: int = 0, fused_adamw: bool = False,
                 seed: int = 0, out_dir: Optional[str] = None,
                 eval_after_each_stage: bool = True,
                 fault_schedule: str = "", min_quorum: int = 1,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 0, resume: bool = False) -> Dict:
    """The full three-stage pipeline under one method.  Returns metrics.

    ``fault_schedule`` (a ``FaultSchedule.from_spec`` string or JSON path)
    injects scripted worker failures into the BASE stage — the long
    DiLoCo pretrain is where fleets churn; mid/SFT are short DDP-ish runs.
    ``checkpoint_dir``/``checkpoint_every``/``resume`` give the base stage
    crash-consistent auto-resume (a rerun with ``--resume`` continues
    bit-exactly from the last complete checkpoint)."""
    from repro.configs.base import DiLoCoConfig, OptimizerConfig
    from repro.core.schedule import AdaptiveH
    from repro.evals import chat_suite, heldout_metrics
    from repro.models.transformer import init_params
    from repro.serving import Engine

    steps = steps or {"base": 300, "mid": 120, "sft": 120}
    if worker_speeds and method != "ddp" and len(worker_speeds) != workers:
        raise ValueError(f"--worker-speeds needs one multiplier per worker: "
                         f"got {len(worker_speeds)} for {workers} workers")
    world, tok, stages, suites = build_pipeline(seq_len=seq_len, seed=seed)
    cfg, model = make_model(arch, reduced, tok.vocab_size)
    params, _ = init_params(cfg, jax.random.key(seed))

    total = sum(steps.values())
    opt_cfg = OptimizerConfig(total_steps=total, warmup_steps=20,
                              schedule="wsd", learning_rate=0.02,
                              adam_lr=1e-3, fused_adamw=fused_adamw)
    dcfg = DiLoCoConfig(num_workers=workers, delta_dtype=delta_dtype,
                        grad_compress=grad_compress,
                        drift_aware=drift_aware, sync_delay=sync_delay,
                        h_jitter=h_jitter, topology=topology,
                        staleness_bound=staleness_bound,
                        num_fragments=num_fragments,
                        error_feedback=error_feedback, sync_seed=seed)

    # paper §3: H=100 base, H=30 mid/SFT (scaled to our step budget: the
    # ratio sync-count/steps matches — base gets ~3 syncs, mid/sft ~4 each)
    h_by_stage = {"base": max(steps["base"] // 3, 1),
                  "mid": max(steps["mid"] // 4, 1),
                  "sft": max(steps["sft"] // 4, 1)}

    faults = None
    if fault_schedule:
        from repro.core import FaultSchedule
        faults = FaultSchedule.from_spec(fault_schedule)

    results: Dict = {"method": method, "arch": cfg.name, "stages": {}}
    for stage in ("base", "mid", "sft"):
        stage_method = method
        if method == "hybrid":
            stage_method = "diloco" if stage == "base" else "ddp"
        hs = AdaptiveH(h0=h_by_stage[stage]) if (
            adaptive_h and stage_method == "diloco") else None
        # faults + checkpoint/resume target the base stage: the long
        # decentralized pretrain is where workers churn and kills land
        is_base = stage == "base"
        params, hist = run_stage(
            stage_method, model, params, stages[stage],
            steps=steps[stage], workers=workers,
            per_worker_batch=per_worker_batch, h=h_by_stage[stage],
            opt_cfg=opt_cfg, diloco_cfg=dcfg, seed=seed, h_schedule=hs,
            prefetch=prefetch,
            faults=faults if is_base else None, min_quorum=min_quorum,
            checkpoint_dir=checkpoint_dir if is_base else None,
            checkpoint_every=checkpoint_every,
            resume=resume and is_base)
        entry = {"loss_first": hist["loss"][0], "loss_last": hist["loss"][-1],
                 "losses": hist["loss"][:: max(1, len(hist["loss"]) // 50)],
                 "method": stage_method,
                 "step_seconds": hist["step_seconds"]}
        for key in ("fault", "quorum", "quorum_skip", "rejoin_drift"):
            if hist.get(key):
                entry[key] = hist[key]
        if eval_after_each_stage:
            engine = Engine(model, params, tok)
            entry["core"] = heldout_metrics(ds=stages["base"], batches=4,
                                            batch_size=8, engine=engine)
            entry["tasks"] = chat_suite(engine, tok, suites)
        results["stages"][stage] = entry
        print(f"[{method}:{stage}] loss {entry['loss_first']:.3f} -> "
              f"{entry['loss_last']:.3f} "
              + (f"tasks={entry.get('tasks')}" if eval_after_each_stage else ""))

    if worker_speeds and method != "ddp":
        n_params = sum(int(x.size) for x in jax.tree.leaves(params))
        # staleness stays 0: the schedules' apply_step already carries the
        # strategy's overlap window (sync_delay) — adding it again would
        # double-count the hiding budget
        rep = comm_report(dcfg, method, n_params, steps["base"],
                          h_by_stage["base"],
                          results["stages"]["base"]["step_seconds"],
                          worker_speeds)
        results["comm_model"] = rep
        homo, het = rep["homogeneous"], rep["heterogeneous"]
        pair = ""
        if "gossip" in rep:
            # the fleet-barrier het number above is the worst case; the
            # per-pair replay is what the gossip runners actually pay
            pair = (f" pair-barrier wall="
                    f"{rep['gossip']['wall_clock_s']:.2f}s")
        print(f"[comm:{method}/{delta_dtype}] "
              f"bytes={homo['total_bytes']/1e6:.2f}MB/worker "
              f"homogeneous wall={homo['wall_clock_s']:.2f}s "
              f"heterogeneous wall={het['wall_clock_s']:.2f}s "
              f"(straggler adds {het['straggler_s']:.2f}s compute, "
              f"stall {het['stall_s']:.2f}s)" + pair)

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        from repro.checkpoint import save_config, save_pytree
        ckpt = os.path.join(out_dir, f"{method}_final")
        save_pytree(params, ckpt)
        save_config(cfg, ckpt)   # so serve.py can rebuild the model
        with open(os.path.join(out_dir, f"{method}_metrics.json"), "w") as f:
            json.dump(results, f, indent=1, default=float)
    return results


def main(argv=None):
    from repro.core import strategy_names
    ap = argparse.ArgumentParser()
    ap.add_argument("--method",
                    choices=list(strategy_names()) + ["hybrid"],
                    default="diloco")
    ap.add_argument("--arch", type=str, default="tiny")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--adaptive-h", action="store_true")
    ap.add_argument("--sync-dtype", default=None,
                    choices=["f32", "bf16", "int8", "fp8", "e5m2",
                             "float32", "bfloat16", "fp8_e5m2"],
                    help="outer-sync wire codec (preferred spelling; "
                         "overrides --delta-dtype)")
    ap.add_argument("--grad-compress", default="none",
                    choices=["none", "int8", "fp8", "fp8_e5m2"],
                    help="--method ddp only: compress the per-step update "
                         "exchange through this codec (K real workers + "
                         "error feedback, core.sync.CompressedDDPSync)")
    ap.add_argument("--delta-dtype", default="float32",
                    help="legacy spelling of --sync-dtype")
    ap.add_argument("--no-error-feedback", action="store_true",
                    help="disable the lossy-codec error-feedback residual")
    ap.add_argument("--drift-aware", action="store_true")
    ap.add_argument("--sync-delay", type=int, default=0,
                    help="overlapped/pipelined: steps between delta capture "
                         "and apply")
    ap.add_argument("--h-jitter", type=int, default=0,
                    help="overlapped/async_gossip: max per-worker straggler "
                         "jitter on the sync period")
    ap.add_argument("--topology", default="ring",
                    choices=["ring", "random", "full"],
                    help="gossip/async_gossip: peer-matching topology "
                         "(full topology is exactly the DiLoCo mean)")
    ap.add_argument("--staleness-bound", type=int, default=0,
                    help="async_gossip: max staleness (in steps) of a peer "
                         "delta before it is dropped; 0 = synchronous pairs")
    ap.add_argument("--fragments", type=int, default=4,
                    help="streaming/pipelined: number of fragments F")
    ap.add_argument("--worker-speeds", type=str, default="",
                    help="comma list of per-worker relative step-time "
                         "multipliers (heterogeneous fleet); feeds the "
                         "post-run comm-simulator report")
    ap.add_argument("--prefetch", type=int, default=0,
                    help="assemble + device_put batches this many steps "
                         "ahead on a background thread (0 = synchronous)")
    ap.add_argument("--fused-adamw", action="store_true",
                    help="use the fused Pallas AdamW update kernel (same "
                         "update math as the unfused path)")
    ap.add_argument("--fault-schedule", type=str, default="",
                    help="scripted fault injection for the base stage: an "
                         "inline spec (crash:2@10,rejoin:2@40,kill@90) or a "
                         "JSON file path (repro.core.faults.FaultSchedule)")
    ap.add_argument("--min-quorum", type=int, default=1,
                    help="minimum live contributors for an outer round; "
                         "below it the round is skipped (workers keep "
                         "training locally)")
    ap.add_argument("--checkpoint-dir", type=str, default=None,
                    help="write crash-consistent checkpoints here at outer "
                         "boundaries (base stage)")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="steps between checkpoints (0 = off)")
    ap.add_argument("--resume", action="store_true",
                    help="resume the base stage from the latest complete "
                         "checkpoint in --checkpoint-dir (bit-exact "
                         "continuation)")
    ap.add_argument("--out-dir", type=str, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    canon = {"f32": "float32", "bf16": "bfloat16", "int8": "int8",
             "fp8": "fp8", "e5m2": "fp8_e5m2", "fp8_e5m2": "fp8_e5m2",
             "float32": "float32", "bfloat16": "bfloat16"}
    delta_dtype = canon[args.sync_dtype] if args.sync_dtype \
        else args.delta_dtype
    speeds = tuple(float(s) for s in args.worker_speeds.split(",") if s)
    run_pipeline(method=args.method, arch=args.arch, reduced=args.reduced,
                 steps={"base": args.steps, "mid": args.steps // 2,
                        "sft": args.steps // 2},
                 workers=args.workers, adaptive_h=args.adaptive_h,
                 delta_dtype=delta_dtype, grad_compress=args.grad_compress,
                 drift_aware=args.drift_aware,
                 sync_delay=args.sync_delay, h_jitter=args.h_jitter,
                 topology=args.topology,
                 staleness_bound=args.staleness_bound,
                 num_fragments=args.fragments,
                 error_feedback=not args.no_error_feedback,
                 worker_speeds=speeds, prefetch=args.prefetch,
                 fused_adamw=args.fused_adamw,
                 fault_schedule=args.fault_schedule,
                 min_quorum=args.min_quorum,
                 checkpoint_dir=args.checkpoint_dir,
                 checkpoint_every=args.checkpoint_every,
                 resume=args.resume,
                 seed=args.seed, out_dir=args.out_dir)


if __name__ == "__main__":
    main()
