"""Event-driven communication simulator: payload schedules -> modeled time.

Converts a ``SyncStrategy.payload_schedule`` (what crosses the slow
inter-pod boundary, and when) into modeled wall-clock, so strategies can be
compared on *time*, not just bytes.  The model is deliberately simple and
fully documented:

* compute: every inner step costs ``step_time_s`` (derive it from the
  analytic roofline via ``modeled_step_time``);
* communication: each worker ships its payload over its own boundary link
  (``CommModel.bandwidth`` bytes/s, plus a fixed per-transfer ``latency``).
  Transfers on one link serialize; workers are symmetric, so one link is
  simulated;
* blocking: a transfer whose ``apply_step`` equals its emit step stalls the
  loop immediately (DDP's per-step all-reduce, DiLoCo's outer step); a
  later ``apply_step`` gives the transfer a window of inner compute to hide
  behind (Streaming / Overlapped DiLoCo) — the loop stalls only for the
  portion that does not fit.

Bandwidth constants for the production fleet live in ``repro.launch.mesh``
(``ICI_BW`` intra-pod, ``DCN_BW`` the inter-pod boundary DiLoCo targets).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List

from repro.launch.mesh import DCN_BW, PEAK_FLOPS_BF16


@dataclasses.dataclass(frozen=True)
class CommModel:
    bandwidth: float            # bytes/s per worker across the boundary
    latency: float = 1e-3       # per-transfer fixed cost (s); DCN-ish default


def transfer_time(nbytes: int, comm: CommModel) -> float:
    return comm.latency + nbytes / comm.bandwidth


def simulate_schedule(events: Iterable, num_steps: int, step_time_s: float,
                      comm: CommModel) -> Dict[str, float]:
    """Walk the step timeline, overlaying transfers on the boundary link.

    ``events`` are ``repro.core.sync.SyncEvent``s sorted by ``step`` (the
    strategies emit them sorted).  Returns wall-clock plus a breakdown:
    ``comm_s`` is total link-busy time, ``stall_s`` the part of it the
    compute timeline actually had to wait for (exposed communication).
    """
    by_step: Dict[int, List] = {}
    total_bytes = 0
    for ev in events:
        by_step.setdefault(ev.step, []).append(ev)
        total_bytes += ev.bytes_per_worker

    now = 0.0            # compute-timeline clock
    link_free = 0.0      # when the boundary link next idles
    comm_s = 0.0
    stall_s = 0.0
    in_flight: List = []  # (done_time, apply_step)

    for step in range(num_steps):
        now += step_time_s
        for ev in by_step.get(step, ()):
            start = max(now, link_free)
            done = start + transfer_time(ev.bytes_per_worker, comm)
            comm_s += done - start
            link_free = done
            in_flight.append((done, ev.apply_step))
        # block on every transfer whose result is due by this step
        still = []
        for done, apply_step in in_flight:
            if apply_step <= step:
                if done > now:
                    stall_s += done - now
                    now = done
            else:
                still.append((done, apply_step))
        in_flight = still

    # results still in flight at the end must land before training finishes
    for done, _ in in_flight:
        if done > now:
            stall_s += done - now
            now = done

    compute_s = num_steps * step_time_s
    return {"wall_clock_s": now, "compute_s": compute_s, "comm_s": comm_s,
            "stall_s": stall_s, "total_bytes": float(total_bytes),
            "overhead_frac": (now - compute_s) / max(now, 1e-12)}


def modeled_step_time(total_flops_per_device: float, mfu: float = 0.4,
                      peak_flops: float = PEAK_FLOPS_BF16) -> float:
    """Inner-step seconds from the analytic per-device FLOPs (see
    ``repro.launch.analytic.flops_per_device``) at an assumed MFU."""
    return total_flops_per_device / (peak_flops * mfu)


def default_comm_model() -> CommModel:
    """The slow inter-pod boundary the paper's DiLoCo targets."""
    return CommModel(bandwidth=DCN_BW)
