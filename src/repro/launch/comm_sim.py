"""Event-driven communication simulator: payload schedules -> modeled time.

Converts a ``SyncStrategy.payload_schedule`` (what crosses the slow
inter-pod boundary, and when) into modeled wall-clock, so strategies can be
compared on *time*, not just bytes.  The model is deliberately simple and
fully documented:

* compute: every inner step costs ``step_time_s`` (derive it from the
  analytic roofline via ``modeled_step_time``, or calibrate it against a
  ``launch.dryrun`` JSON dump via ``load_calibration``);
* communication: each worker ships its payload over its own boundary link
  (``CommModel.bandwidth`` bytes/s, plus a fixed per-transfer ``latency``).
  Transfers on one link serialize.  ``simulate_schedule`` models the
  symmetric fleet (one link); ``simulate_heterogeneous`` gives every
  worker its own step clock (``step_times[w]``) and link, with a
  bounded-staleness apply rule; ``simulate_gossip`` replaces the fleet
  barrier with per-PAIR barriers driven by ``GossipRound`` events
  (``SyncStrategy.gossip_rounds``) — each worker blocks only on its own
  transfer and the peers named by its deps, which is why modeled gossip
  wall-clock stays at or below the bounded-staleness all-reduce baseline;
* blocking: a transfer whose ``apply_step`` equals its emit step stalls the
  loop immediately (DDP's per-step all-reduce, DiLoCo's outer step); a
  later ``apply_step`` gives the transfer a window of inner compute to hide
  behind (Streaming / Overlapped / Pipelined DiLoCo) — the loop stalls only
  for the portion that does not fit.  In the heterogeneous simulator the
  outer update is a fleet barrier: a round completes when the LAST worker's
  payload lands, and every worker may run at most ``staleness_steps`` past
  the round's ``apply_step`` before blocking on the result.

Bytes are accounted per codec (``SyncEvent.codec``): results carry a
``bytes_by_codec`` breakdown next to ``total_bytes``.

Bandwidth constants for the production fleet live in ``repro.launch.mesh``
(``ICI_BW`` intra-pod, ``DCN_BW`` the inter-pod boundary DiLoCo targets).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Iterable, List, Optional, Sequence

from repro.launch.mesh import DCN_BW, HBM_BW, PEAK_FLOPS_BF16


@dataclasses.dataclass(frozen=True)
class CommModel:
    bandwidth: float            # bytes/s per worker across the boundary
    latency: float = 1e-3       # per-transfer fixed cost (s); DCN-ish default


def transfer_time(nbytes: int, comm: CommModel) -> float:
    return comm.latency + nbytes / comm.bandwidth


def _index_events(events: Iterable):
    by_step: Dict[int, List] = {}
    total_bytes = 0
    by_codec: Dict[str, float] = {}
    for ev in events:
        by_step.setdefault(ev.step, []).append(ev)
        total_bytes += ev.bytes_per_worker
        codec = getattr(ev, "codec", "f32")
        by_codec[codec] = by_codec.get(codec, 0.0) + ev.bytes_per_worker
    return by_step, total_bytes, by_codec


def simulate_schedule(events: Iterable, num_steps: int, step_time_s: float,
                      comm: CommModel) -> Dict[str, float]:
    """Walk the step timeline, overlaying transfers on the boundary link.

    ``events`` are ``repro.core.sync.SyncEvent``s sorted by ``step`` (the
    strategies emit them sorted).  Returns wall-clock plus a breakdown:
    ``comm_s`` is total link-busy time, ``stall_s`` the part of it the
    compute timeline actually had to wait for (exposed communication).
    """
    by_step, total_bytes, by_codec = _index_events(events)

    now = 0.0            # compute-timeline clock
    link_free = 0.0      # when the boundary link next idles
    comm_s = 0.0
    stall_s = 0.0
    in_flight: List = []  # (done_time, apply_step)

    for step in range(num_steps):
        now += step_time_s
        for ev in by_step.get(step, ()):
            start = max(now, link_free)
            done = start + transfer_time(ev.bytes_per_worker, comm)
            comm_s += done - start
            link_free = done
            in_flight.append((done, ev.apply_step))
        # block on every transfer whose result is due by this step
        still = []
        for done, apply_step in in_flight:
            if apply_step <= step:
                if done > now:
                    stall_s += done - now
                    now = done
            else:
                still.append((done, apply_step))
        in_flight = still

    # results still in flight at the end must land before training finishes
    for done, _ in in_flight:
        if done > now:
            stall_s += done - now
            now = done

    compute_s = num_steps * step_time_s
    return {"wall_clock_s": now, "compute_s": compute_s, "comm_s": comm_s,
            "stall_s": stall_s, "total_bytes": float(total_bytes),
            "bytes_by_codec": by_codec,
            "overhead_frac": (now - compute_s) / max(now, 1e-12)}


def _fault_tables(faults, w_n: int, num_steps: int):
    """Expand an optional ``FaultSchedule`` into the per-step tables the
    simulators consume; (None, None, {}, {}) when there are no faults, so
    the no-fault arithmetic stays literally the existing code path."""
    if faults is None or faults.empty:
        return None, None, {}, {}
    from repro.core.faults import sim_timeline
    faults.validate(w_n)
    alive_t, factor_t, failed = sim_timeline(faults, w_n, num_steps)
    drops: Dict[int, Dict[int, int]] = {}   # step -> {worker: attempts}
    for e in faults.events:
        if e.kind in ("drop", "corrupt"):
            drops.setdefault(e.step, {})[e.worker] = e.attempts
    return alive_t, factor_t, failed, drops


def simulate_heterogeneous(events: Iterable, num_steps: int,
                           step_times: Sequence[float], comm: CommModel,
                           staleness_steps: int = 0,
                           faults=None) -> Dict[str, float]:
    """Per-worker step clocks + bounded-staleness apply rule.

    ``step_times[w]`` is worker w's inner-step seconds (heterogeneous
    fleet).  Every worker ships each scheduled payload over its own link
    when ITS clock reaches the emit step; the round's outer update is
    ready when the last worker's transfer lands, and workers block on it
    at ``apply_step + staleness_steps`` (staleness 0 = synchronous apply).
    With identical ``step_times`` and staleness 0 this reduces exactly to
    ``simulate_schedule``.

    ``faults`` (a ``repro.core.faults.FaultSchedule``) overlays the same
    script the trainer consumes: crashed workers stop stepping and ship
    nothing (their clock freezes until a rejoin), ``slow`` scales a
    worker's step time, ``drop``/``corrupt`` cost one retry transfer —
    counted in ``retry_bytes`` — and with ``attempts >= 2`` the round
    stops waiting on that worker entirely.  An empty schedule reduces
    exactly (bitwise) to the fault-free model.

    ``compute_s`` is the slowest worker's pure-compute time (the fleet's
    compute critical path); ``straggler_s`` the spread the slowest worker
    adds over the fastest.
    """
    w_n = len(step_times)
    if w_n == 0:
        raise ValueError("need at least one worker step time")
    by_step, total_bytes, by_codec = _index_events(events)
    alive_t, factor_t, failed, drops = _fault_tables(faults, w_n, num_steps)

    clock = [0.0] * w_n
    link_free = [0.0] * w_n
    busy = [0.0] * w_n
    stall = [0.0] * w_n
    retry_bytes = 0.0
    in_flight: List = []  # (round_done_time, block_step)

    def block_on(done: float):
        for w in range(w_n):
            if done > clock[w]:
                stall[w] += done - clock[w]
                clock[w] = done

    for step in range(num_steps):
        for w in range(w_n):
            if alive_t is None:
                clock[w] += step_times[w]
            elif alive_t[step][w]:
                clock[w] += step_times[w] * factor_t[step][w]
        for ev in by_step.get(step, ()):
            round_done = 0.0
            for w in range(w_n):
                if alive_t is not None and not alive_t[step][w]:
                    continue            # dead: ships nothing
                start = max(clock[w], link_free[w])
                t = transfer_time(ev.bytes_per_worker, comm)
                resend = 1 if w in drops.get(step, ()) else 0
                done = start + (1 + resend) * t
                retry_bytes += resend * ev.bytes_per_worker
                busy[w] += done - start
                link_free[w] = done
                if w not in failed.get(step, ()):
                    round_done = max(round_done, done)
            in_flight.append((round_done, ev.apply_step + staleness_steps))
        still = []
        for done, block_step in in_flight:
            if block_step <= step:
                block_on(done)
            else:
                still.append((done, block_step))
        in_flight = still

    for done, _ in in_flight:
        block_on(done)

    now = max(clock)
    compute_s = num_steps * max(step_times)
    return {"wall_clock_s": now, "compute_s": compute_s,
            "comm_s": max(busy), "stall_s": max(stall),
            "straggler_s": num_steps * (max(step_times) - min(step_times)),
            "total_bytes": float(total_bytes), "bytes_by_codec": by_codec,
            "retry_bytes": retry_bytes,
            "overhead_frac": (now - compute_s) / max(now, 1e-12)}


def simulate_gossip(rounds: Iterable, num_steps: int,
                    step_times: Sequence[float], comm: CommModel,
                    staleness_steps: int = 0,
                    faults=None) -> Dict[str, float]:
    """Per-pair event model for the gossip strategies.

    ``rounds`` are ``repro.core.sync.GossipRound``s (duck-typed, like
    ``SyncEvent``): worker w ships ``nbytes`` over its OWN link when its
    clock reaches ``emit_steps[w]`` (-1 = not participating), then blocks
    at ``emit + staleness_steps`` on its own transfer plus the transfers
    named by ``deps[w]`` — a PAIR barrier, not a fleet barrier.  A dropped
    contribution (empty deps) blocks only on the worker's own ship-out.
    Byte totals are denominated per worker (the busiest link), matching
    ``hop_bytes_per_worker``: gossip traffic is flat in fleet size.

    ``faults`` overlays a ``repro.core.faults.FaultSchedule``: crashed
    workers stop stepping, skip their ship-outs, and vanish from peers'
    pair barriers (the ``transfers`` key never lands — peers proceed on
    their own clock, gossip's no-fleet-barrier property); ``slow`` scales
    a worker's step time; ``drop``/``corrupt`` cost one retry transfer
    (``retry_bytes``), with ``attempts >= 2`` also hiding the payload
    from peers.  An empty schedule reduces exactly to the fault-free
    model.
    """
    w_n = len(step_times)
    if w_n == 0:
        raise ValueError("need at least one worker step time")
    by_emit: Dict[int, List] = {}
    for rnd in rounds:
        for w, es in enumerate(rnd.emit_steps):
            if es >= 0:
                by_emit.setdefault(es, []).append((w, rnd))
    alive_t, factor_t, failed, drops = _fault_tables(faults, w_n, num_steps)

    clock = [0.0] * w_n
    link_free = [0.0] * w_n
    busy = [0.0] * w_n
    stall = [0.0] * w_n
    shipped = [0.0] * w_n
    retry_bytes = 0.0
    by_codec_w: List[Dict[str, float]] = [{} for _ in range(w_n)]
    transfers: Dict = {}      # (worker, emit_step) -> done time
    pending: List = []        # (block_step, worker, transfer keys)

    def block(w: int, keys, own: float) -> None:
        done = max((transfers[k] for k in keys if k in transfers),
                   default=0.0)
        done = max(done, own)
        if done > clock[w]:
            stall[w] += done - clock[w]
            clock[w] = done

    for step in range(num_steps):
        for w in range(w_n):
            if alive_t is None:
                clock[w] += step_times[w]
            elif alive_t[step][w]:
                clock[w] += step_times[w] * factor_t[step][w]
        # ship-outs first: a co-due peer's transfer must exist before any
        # same-step pair barrier references it
        for w, rnd in by_emit.get(step, ()):
            if alive_t is not None and not alive_t[step][w]:
                continue                # dead: no ship-out, no barrier
            start = max(clock[w], link_free[w])
            resend = 1 if w in drops.get(step, ()) else 0
            done = start + (1 + resend) * transfer_time(rnd.nbytes, comm)
            retry_bytes += resend * rnd.nbytes
            busy[w] += done - start
            link_free[w] = done
            shipped[w] += rnd.nbytes
            codec = getattr(rnd, "codec", "f32")
            by_codec_w[w][codec] = by_codec_w[w].get(codec, 0.0) + rnd.nbytes
            if w not in failed.get(step, ()):
                # lost payloads never land for PEERS; the sender still
                # blocks on its own attempt (the ``done`` carried below)
                transfers[(w, step)] = done
            keys = [(w, step)] + [tuple(d) for d in rnd.deps[w]]
            pending.append((step + staleness_steps, w, keys, done))
        still = []
        for block_step, w, keys, own in pending:
            if block_step <= step:
                block(w, keys, own)
            else:
                still.append((block_step, w, keys, own))
        pending = still

    for _, w, keys, own in pending:  # in-flight results land before the end
        block(w, keys, own)

    now = max(clock)
    compute_s = num_steps * max(step_times)
    busiest = max(range(w_n), key=lambda w: shipped[w])
    return {"wall_clock_s": now, "compute_s": compute_s,
            "comm_s": max(busy), "stall_s": max(stall),
            "straggler_s": num_steps * (max(step_times) - min(step_times)),
            "total_bytes": float(shipped[busiest]),
            "bytes_by_codec": by_codec_w[busiest],
            "retry_bytes": retry_bytes,
            "overhead_frac": (now - compute_s) / max(now, 1e-12)}


# ---------------------------------------------------------------------------
# Step-time modeling + dry-run calibration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CommCalibration:
    """Measured / HLO-derived overrides for the simulator's two analytic
    assumptions: the inner-step seconds and the outer-sync wire bytes.
    ``sync_dtype`` records which delta dtype the measured outer step was
    compiled with (from the entry's ``outer[<dtype>]`` shape tag), so
    consumers can normalize the bytes against the right analytic width."""
    step_time_s: Optional[float] = None
    sync_bytes_per_worker: Optional[float] = None
    sync_dtype: str = "float32"
    source: str = "analytic"


def load_calibration(path: str, arch: Optional[str] = None
                     ) -> Optional[CommCalibration]:
    """Calibrate against a ``launch.dryrun --json-out`` dump (e.g.
    ``dryrun_outer.json``).

    * step time — from a ``train`` / ``diloco-inner`` entry: its
      ``measured_step_s`` field if present (real profiled seconds merged
      into the dump), else the roofline bound max(flops/peak,
      hbm_bytes/hbm_bw) from its analytic terms — either replaces the
      fixed 40%-MFU assumption;
    * sync bytes — the outer-step entry's HLO-parsed cross-pod wire bytes
      (falling back to total wire bytes), replacing width×n_params.
    """
    try:
        with open(path) as f:
            entries = json.load(f)
    except (OSError, ValueError):
        return None
    if isinstance(entries, dict):
        entries = [entries]
    step_time = None
    sync_bytes = None
    sync_dtype = "float32"
    for e in entries:
        if arch is not None and e.get("arch") != arch:
            continue
        measured = e.get("measured_step_s")
        kind = e.get("step_kind", "")
        analytic = e.get("analytic") or {}
        if step_time is None and kind in ("train", "diloco-inner"):
            # only inner/train entries describe a training step; measured
            # seconds on decode/prefill/outer entries are other latencies
            if measured:
                step_time = float(measured)
            else:
                flops = float(analytic.get("total_flops") or 0.0)
                hbm = float(analytic.get("bytes") or 0.0)
                derived = max(flops / PEAK_FLOPS_BF16, hbm / HBM_BW)
                if derived > 0:
                    step_time = derived
        if sync_bytes is None and kind == "diloco-outer":
            colls = (e.get("collectives_weighted") or e.get("collectives")
                     or {})
            b = (colls.get("cross_pod_bytes_per_device")
                 or colls.get("wire_bytes_per_device"))
            if b:
                sync_bytes = float(b)
                m = re.match(r"outer\[(\w+)\]", e.get("shape", ""))
                if m:
                    sync_dtype = m.group(1)
    if step_time is None and sync_bytes is None:
        return None
    return CommCalibration(step_time_s=step_time,
                           sync_bytes_per_worker=sync_bytes,
                           sync_dtype=sync_dtype, source=path)


def modeled_step_time(total_flops_per_device: float, mfu: float = 0.4,
                      peak_flops: float = PEAK_FLOPS_BF16,
                      calibration: Optional[CommCalibration] = None) -> float:
    """Inner-step seconds from the analytic per-device FLOPs (see
    ``repro.launch.analytic.flops_per_device``) at an assumed MFU — unless
    a ``CommCalibration`` carries a measured / roofline-derived step time,
    which then takes precedence over the MFU guess."""
    if calibration is not None and calibration.step_time_s:
        return calibration.step_time_s
    return total_flops_per_device / (peak_flops * mfu)


def default_comm_model() -> CommModel:
    """The slow inter-pod boundary the paper's DiLoCo targets."""
    return CommModel(bandwidth=DCN_BW)
