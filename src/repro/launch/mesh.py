"""Production meshes.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods × 256 chips as (pod=2, data=16, model=16) — the ``pod``
axis is the DiLoCo worker boundary (slow inter-pod links carry only the
outer-step delta exchange).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run launcher must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5 exposes explicit mesh axis types
    from jax.sharding import AxisType
except ImportError:  # older jax (e.g. 0.4.37): meshes are Auto by default
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False, num_pods: int = 2):
    """Single pod: (16, 16).  Multi-pod: (num_pods, 16, 16) — the default 2
    pods = 512 chips is the required dry-run target; larger DiLoCo fleets
    (one worker per pod) reuse the same axes."""
    shape = (num_pods, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (possibly fake) local devices exist —
    used by tests."""
    return _make_mesh((data, model), ("data", "model"))


# Hardware constants for the roofline (TPU v5e-class chip).
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (intra-pod)
DCN_BW = 6.25e9               # bytes/s per device across pods (50 Gbit/s —
                              # the slow inter-pod boundary DiLoCo targets)
HBM_PER_CHIP = 16e9           # bytes
