from repro.launch.mesh import (HBM_BW, HBM_PER_CHIP, ICI_BW, PEAK_FLOPS_BF16,
                               make_host_mesh, make_production_mesh)

__all__ = ["make_production_mesh", "make_host_mesh", "PEAK_FLOPS_BF16",
           "HBM_BW", "ICI_BW", "HBM_PER_CHIP"]
