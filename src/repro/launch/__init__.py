from repro.launch.mesh import (DCN_BW, HBM_BW, HBM_PER_CHIP, ICI_BW,
                               PEAK_FLOPS_BF16, make_host_mesh,
                               make_production_mesh)
from repro.launch.comm_sim import (CommModel, default_comm_model,
                                   modeled_step_time, simulate_schedule)

__all__ = ["make_production_mesh", "make_host_mesh", "PEAK_FLOPS_BF16",
           "HBM_BW", "ICI_BW", "DCN_BW", "HBM_PER_CHIP", "CommModel",
           "simulate_schedule", "modeled_step_time", "default_comm_model"]
