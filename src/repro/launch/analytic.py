"""Analytic FLOPs / HBM-traffic estimators per (architecture × input shape).

XLA's cost_analysis undercounts scanned layers (loop bodies counted once —
see hlo_analysis.py), so the roofline compute and memory terms are derived
from first principles here.  All formulas are per **device** on the given
mesh and documented inline; the HLO-derived numbers are reported alongside
as a cross-check, not used for the terms.

Conventions: matmul flops = 2·M·N·K; training does forward + backward
(2× forward) + one remat re-forward = 4× forward flops on matmuls
(nothing-saved checkpointing), i.e. the classic 6·N·D becomes 8·N·D with
full remat; we report both.
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig

TRAIN_MULT = 4.0      # fwd + re-fwd(remat) + bwd(2x)
TRAIN_MULT_NOREMAT = 3.0


def _attn_ctx(cfg: ModelConfig, S: int) -> float:
    """Average attended context per query under the config's windows."""
    if cfg.window_pattern:
        ws = [w if w else S for w in cfg.window_pattern]
        return sum(min(w, S) / (2 if w >= S else 1) for w in ws) / len(ws)
    if cfg.window:
        w = min(cfg.window, S)
        return w if w < S else S / 2
    return S / 2


def layer_flops_fwd_per_token(cfg: ModelConfig, S: int) -> float:
    """Forward matmul+attention flops per token per layer."""
    d = cfg.d_model
    hd = cfg.resolved_head_dim()
    nq, nkv = cfg.num_heads * hd, cfg.num_kv_heads * hd
    f = 0.0
    if cfg.arch_type != "ssm":
        f += 2.0 * d * (nq + 2 * nkv + nq)           # qkv + out proj
        ctx = _attn_ctx(cfg, S)
        f += 2.0 * 2.0 * nq * ctx                     # qk^T and pv
    if cfg.hybrid or cfg.arch_type == "ssm":
        d_in = (cfg.num_heads * hd) if cfg.hybrid else cfg.ssm_expand * d
        N = cfg.ssm_state_size
        H = d_in // cfg.ssm_head_dim
        P = cfg.ssm_head_dim
        Q = cfg.ssm_chunk
        f += 2.0 * d * (2 * d_in + 2 * N + H) + 2.0 * d_in * d  # in/out proj
        # SSD: intra-chunk (Q-causal attention in state space) + state path
        f += 2.0 * H * P * Q        # M @ xbar   (per token: Q/2 avg -> Q)
        f += 2.0 * N * Q            # C·B^T scores per token
        f += 4.0 * H * N * P / max(Q, 1) * Q  # state in/out ≈ 4·H·N·P
    if cfg.num_experts:
        e = cfg.num_experts_per_tok + cfg.num_shared_experts
        f += 2.0 * 3.0 * d * cfg.d_ff * e             # gated expert mlp
        f += 2.0 * d * cfg.num_experts                # router
    elif cfg.d_ff:
        n_mats = 3 if cfg.mlp_activation == "swiglu" else 2
        f += 2.0 * n_mats * d * cfg.d_ff
    return f


def flops_per_device(cfg: ModelConfig, shape: ShapeConfig, chips: int,
                     remat: bool = True) -> Dict[str, float]:
    S = shape.seq_len
    B = shape.global_batch
    d = cfg.d_model
    V = cfg.padded_vocab()

    if shape.kind == "decode":
        tokens = B                                    # one token per request
        ctx = (min(cfg.window or S, S) if cfg.arch_type != "ssm" else 0)
        per_tok = 0.0
        for _ in range(1):
            pass
        # per-layer decode: projections + attention against ctx keys + mlp
        dec_cfg = cfg
        per_layer = layer_flops_fwd_per_token(dec_cfg, max(ctx, 1) * 2)
        per_tok = cfg.num_layers * per_layer + 2.0 * d * V
        total = tokens * per_tok
        mult = 1.0
    else:
        tokens = S * B
        per_layer = layer_flops_fwd_per_token(cfg, S)
        per_tok = cfg.num_layers * per_layer + 2.0 * d * V
        if cfg.is_encoder_decoder:
            enc_tokens_ratio = cfg.encoder_seq_len / S
            per_tok += cfg.num_encoder_layers * layer_flops_fwd_per_token(
                cfg.with_(window=0, window_pattern=()), cfg.encoder_seq_len
            ) * enc_tokens_ratio
        total = tokens * per_tok
        mult = 1.0 if shape.kind == "prefill" else (
            TRAIN_MULT if remat else TRAIN_MULT_NOREMAT)
    return {"fwd_flops": total / chips,
            "total_flops": total * mult / chips,
            "model_flops_6nd": 6.0 * cfg.param_count(active_only=True)
            * tokens / chips}


def bytes_per_device(cfg: ModelConfig, shape: ShapeConfig, chips: int,
                     param_bytes: int = 2, cache_capacity: int = 0
                     ) -> Dict[str, float]:
    """HBM traffic per device per step (reads + writes)."""
    S, B = shape.seq_len, shape.global_batch
    d = cfg.d_model
    N = cfg.param_count()
    V = cfg.padded_vocab()
    act_w = 2                                          # bf16 activations

    if shape.kind == "decode":
        # every step streams all weights + the KV cache once
        cap = cache_capacity or S
        if cfg.arch_type == "ssm":
            d_in = cfg.ssm_expand * d
            H = d_in // cfg.ssm_head_dim
            cache = cfg.num_layers * B * (H * cfg.ssm_state_size
                                          * cfg.ssm_head_dim * 4
                                          + cfg.ssm_conv_width * d_in * 2) * 2
        else:
            hd = cfg.resolved_head_dim()
            cache = (cfg.num_layers * B * 2 * cap * cfg.num_kv_heads * hd
                     * act_w)
            if cfg.hybrid:
                H = cfg.num_heads
                cache += cfg.num_layers * B * (
                    H * cfg.ssm_state_size * cfg.ssm_head_dim * 4 * 2)
        traffic = N * param_bytes + cache + 3 * B * V * act_w
        return {"bytes": traffic / chips}

    tokens = S * B
    # weights: fwd read + remat re-read + bwd read + grad write/read +
    # optimizer state (muon mu f32 read+write) + param write
    w_traffic = N * (param_bytes * 3 + param_bytes * 2 + 4 * 2 + param_bytes)
    if shape.kind == "prefill":
        w_traffic = N * param_bytes
    # activations: per layer ~6 intermediate tensors of (tokens × d) each
    # written+read once in fwd (and again in remat+bwd for training)
    width = 6.0
    if cfg.num_experts:
        width += 2.0 * cfg.num_experts_per_tok * cfg.d_ff / d
    elif cfg.d_ff:
        width += 2.0 * cfg.d_ff / d
    if cfg.arch_type == "ssm" or cfg.hybrid:
        width += 4.0 * cfg.ssm_expand
    act_layer = tokens * d * act_w * width
    acts = cfg.num_layers * act_layer
    # saved residual stream (write fwd, read bwd)
    saved = cfg.num_layers * tokens * d * act_w * 2
    logits = 3 * tokens * V * act_w
    if shape.kind == "prefill":
        traffic = w_traffic + acts + logits / 3
    else:
        traffic = w_traffic + 2.5 * acts + saved + logits
    return {"bytes": traffic / chips}


def attention_kv_bytes(cfg: ModelConfig, S: int, B: int) -> float:
    hd = cfg.resolved_head_dim()
    return 2.0 * B * S * cfg.num_kv_heads * hd * 2
