"""Serving launcher: load a checkpoint (or random-init), bring up the batched
KV-cache engine, and answer chat-formatted requests from stdin or --prompt.

  PYTHONPATH=src python -m repro.launch.serve --ckpt runs/diloco_final \
      --prompt "what is the color of ent3 ?"
"""
from __future__ import annotations

import argparse
import sys

import jax


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", type=str, default=None)
    ap.add_argument("--prompt", action="append", default=[])
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    from repro.launch.train import build_pipeline, make_model
    from repro.models.transformer import init_params
    from repro.serving import Engine

    world, tok, stages, suites = build_pipeline()
    cfg, model = make_model("tiny", True, tok.vocab_size)
    params, _ = init_params(cfg, jax.random.key(0))
    if args.ckpt:
        from repro.checkpoint import load_pytree
        params = load_pytree(params, args.ckpt)

    engine = Engine(model, params, tok)
    prompts = args.prompt or [l.strip() for l in sys.stdin if l.strip()]
    wrapped = [f"<|bos|><|user_start|>{p}<|user_end|><|assistant_start|>"
               for p in prompts]
    outs = engine.chat(wrapped, max_new=args.max_new,
                       greedy=args.temperature == 0.0)
    for p, o in zip(prompts, outs):
        print(f">>> {p}\n{o.strip()}")


if __name__ == "__main__":
    main()
