"""Serving launcher: a request-stream driver over the continuous-batching
engine.  Loads a checkpoint (model config comes from the checkpoint's
``.cfg.json`` metadata, with ``--config <arch>`` as the fallback for
checkpoints that predate it), then answers chat-formatted requests.

  # one-shot prompts (stdin also works, one prompt per line)
  PYTHONPATH=src python -m repro.launch.serve --ckpt runs/diloco_final \
      --prompt "what is the color of ent3 ?" --temperature 0.7

  # timestamped request stream; reports per-request latency + tokens/s
  PYTHONPATH=src python -m repro.launch.serve --stream requests.jsonl --report

Stream files are JSONL: {"t": <arrival seconds>, "prompt": "...",
"max_new": N} — requests are admitted against the wall clock, so the report
reflects scheduling (admission/eviction/chunked prefill) under load, not
just raw decode speed.
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import numpy as np


def percentile(xs, q):
    """q-th percentile of a list, NaN when empty (shared with
    ``benchmarks.serving_bench``)."""
    return float(np.percentile(np.asarray(xs), q)) if xs else float("nan")


def build_requests(args, tok):
    from repro.serving import Request
    stop = tok.special_id("<|assistant_end|>")
    items = []
    if args.stream:
        with open(args.stream) as f:
            for line in f:
                if line.strip():
                    d = json.loads(line)
                    items.append((float(d.get("t", 0.0)), d["prompt"],
                                  int(d.get("max_new", args.max_new))))
    else:
        prompts = args.prompt or [l.strip() for l in sys.stdin if l.strip()]
        items = [(0.0, p, args.max_new) for p in prompts]
    reqs = []
    for rid, (t, prompt, max_new) in enumerate(items):
        wrapped = (f"<|bos|><|user_start|>{prompt}<|user_end|>"
                   f"<|assistant_start|>")
        reqs.append((prompt, Request(
            rid=rid, prompt=tok.encode(wrapped), max_new=max_new,
            temperature=args.temperature if args.temperature > 0 else 1.0,
            greedy=args.temperature == 0.0, eos_id=stop, arrival=t)))
    return reqs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", type=str, default=None)
    ap.add_argument("--config", type=str, default="tiny",
                    help="arch name fallback when the checkpoint has no "
                         ".cfg.json metadata")
    ap.add_argument("--prompt", action="append", default=[])
    ap.add_argument("--stream", type=str, default=None,
                    help="JSONL request stream with arrival timestamps")
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; >0 samples at this temperature")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed for fresh-init params "
                         "(ignored once --ckpt loads weights)")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: draft up to K tokens per "
                         "slot per round via prompt-lookup (0 = off)")
    ap.add_argument("--policy",
                    choices=["fifo", "longest_prefill", "cache_aware"],
                    default="fifo")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share prompt-prefix KV blocks across requests "
                         "via a radix tree: matched prefixes skip prefill "
                         "and reserve no pool budget (dense archs only)")
    ap.add_argument("--prefix-cache-blocks", type=int, default=None,
                    help="LRU bound on resident prefix-cache blocks "
                         "(default: bounded only by the pool)")
    ap.add_argument("--kv-dtype", type=str, default=None,
                    choices=["bf16", "f32", "int8", "fp8", "fp8_e5m2"],
                    help="KV-pool storage format override (default: the "
                         "checkpoint config's kv_cache_dtype, else the "
                         "compute dtype); int8/fp8 pools quantize on "
                         "append and halve-to-quarter pool bytes")
    ap.add_argument("--pool-bytes", type=int, default=None,
                    help="size the KV pool by device-byte budget instead "
                         "of slots x blocks (quantized pools fit more "
                         "blocks, admitting more concurrent requests)")
    ap.add_argument("--report", action="store_true",
                    help="print per-request latency + aggregate tokens/s")
    args = ap.parse_args(argv)

    from repro.checkpoint import load_config
    from repro.launch.train import build_pipeline, make_model
    from repro.models import build_model
    from repro.models.transformer import init_params
    from repro.serving import Engine

    world, tok, stages, suites = build_pipeline()
    cfg = load_config(args.ckpt) if args.ckpt else None
    if cfg is not None:
        print(f"# model config from checkpoint metadata: {cfg.name}")
    else:
        cfg, _ = make_model(args.config, True, tok.vocab_size)
    if args.kv_dtype is not None:
        # the pool format is a serving decision: override whatever the
        # checkpoint metadata says BEFORE the engine reads model.cfg
        cfg = cfg.with_(kv_cache_dtype=args.kv_dtype
                        if args.kv_dtype != "f32" else "float32")
    model = build_model(cfg)
    if cfg.vocab_size != tok.vocab_size:
        print(f"# warning: checkpoint vocab {cfg.vocab_size} != pipeline "
              f"tokenizer vocab {tok.vocab_size}", file=sys.stderr)
    params, _ = init_params(cfg, jax.random.key(args.seed))
    if args.ckpt:
        from repro.checkpoint import load_pytree
        params = load_pytree(params, args.ckpt)

    engine = Engine(model, params, tok, max_len=args.max_len,
                    num_slots=args.slots, block_size=args.block_size,
                    policy=args.policy, spec_k=args.spec_k,
                    pool_bytes=args.pool_bytes,
                    prefix_cache=args.prefix_cache,
                    prefix_cache_blocks=args.prefix_cache_blocks)
    reqs = build_requests(args, tok)
    if not reqs:
        print("no requests", file=sys.stderr)
        return

    if engine.continuous:
        stats = engine.run([r for _, r in reqs], use_time=True)
        for prompt, r in reqs:
            row = r.tokens
            if r.eos_id in row:
                row = row[:row.index(r.eos_id)]
            print(f">>> {prompt}\n{tok.decode(row).strip()}")
    else:   # ssm/hybrid fallback: static buckets, grouped by max_new (the
            # already-encoded prompt ids go straight through — no lossy
            # decode/re-encode round-trip)
        rows = [None] * len(reqs)
        by_mn = {}
        for i, (_, r) in enumerate(reqs):
            by_mn.setdefault(r.max_new, []).append(i)
        for mn, idxs in by_mn.items():
            out = engine.generate(
                [reqs[i][1].prompt for i in idxs], max_new=mn,
                greedy=args.temperature == 0.0,
                temperature=args.temperature or 1.0,
                eos_id=reqs[idxs[0]][1].eos_id)
            for i, row in zip(idxs, out):
                rows[i] = list(row)
        for (prompt, r), row in zip(reqs, rows):
            if r.eos_id in row:
                row = row[:row.index(r.eos_id)]
            print(f">>> {prompt}\n{tok.decode(row).strip()}")
        stats = None
        if args.report:
            print("# report unavailable on the static fallback path "
                  "(ssm/hybrid arch): arrival times and per-request "
                  "latency are not modeled", file=sys.stderr)

    if args.report and stats is not None:
        from repro.kernels.common import pallas_mode
        lats = [r.finish_time - r.arrival for _, r in reqs
                if r.finish_time is not None]
        # time-to-first-token: the per-request latency prefix sharing
        # actually moves (a cache hit skips the matched prefill outright)
        ttfts = [r.ttft for _, r in reqs if r.first_token_time is not None]
        print(f"# requests={len(reqs)} generated={stats['generated']} "
              f"step_calls={stats['step_calls']} "
              f"prefill_tokens={stats['prefill_tokens']}")
        print(f"# wall={stats['wall']:.3f}s "
              f"tokens_per_s={stats['generated'] / stats['wall']:.1f} "
              f"latency_p50={percentile(lats, 50):.3f}s "
              f"latency_p95={percentile(lats, 95):.3f}s "
              f"ttft_p50={percentile(ttfts, 50):.3f}s "
              f"ttft_p95={percentile(ttfts, 95):.3f}s")
        if "prefix" in stats:
            p = stats["prefix"]
            print(f"# prefix_cache hit_rate={p['hit_rate']:.2f} "
                  f"matched_tokens={p['matched_tokens']} "
                  f"(matched_frac={p['matched_frac']:.2f}) "
                  f"shared_blocks={p['resident_blocks']} "
                  f"forked={p['forked']} "
                  f"bytes_saved={p['bytes_saved']} "
                  f"skipped_prefill_tokens={stats['prefix_skipped_tokens']}")
        if args.spec_k > 0:
            # per-request accept rates: p50/p95 over requests that drafted
            rates = [r.accept_rate for _, r in reqs if r.drafted]
            print(f"# spec_k={args.spec_k} drafted={stats['drafted']} "
                  f"accepted={stats['accepted']} "
                  f"accept_rate={stats['accept_rate']:.3f} "
                  f"accept_rate_p50={percentile(rates, 50):.3f} "
                  f"accept_rate_p95={percentile(rates, 95):.3f} "
                  f"rolled_back={stats['rolled_back']}")
        if stats.get("recycled_blocks"):
            print(f"# window_recycled_blocks={stats['recycled_blocks']}")
        kv = engine.kv_report()
        print(f"# kv_dtype={kv['kv_cache_dtype']} "
              f"(pool {kv['kv_pool_dtype']}) "
              f"bytes_per_block={kv['bytes_per_block']} "
              f"num_blocks={kv['num_blocks']} "
              f"pool_bytes={kv['pool_bytes']} "
              f"peak_admitted={stats['peak_admitted']}")
        print(f"# attn_impl={engine.attn_impl} pallas_mode={pallas_mode()} "
              f"policy={engine.policy}")


if __name__ == "__main__":
    main()
