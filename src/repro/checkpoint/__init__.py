from repro.checkpoint.checkpoint import (latest_run_checkpoint,
                                         list_run_checkpoints, load_config,
                                         load_pytree, load_run_checkpoint,
                                         save_config, save_pytree,
                                         save_run_checkpoint)

__all__ = ["save_pytree", "load_pytree", "save_config", "load_config",
           "save_run_checkpoint", "load_run_checkpoint",
           "list_run_checkpoints", "latest_run_checkpoint"]
