from repro.checkpoint.checkpoint import (load_config, load_pytree,
                                         save_config, save_pytree)

__all__ = ["save_pytree", "load_pytree", "save_config", "load_config"]
