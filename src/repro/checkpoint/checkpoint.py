"""Checkpointing: pytree <-> .npz (+ structure manifest).

The Hybrid configuration in the paper (DiLoCo-pretrained base handed to a DDP
mid-training/SFT run) requires checkpoints to cross trainer types, so we save
flat path->array maps that can be restored into any template with matching
leaf paths.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _atomic_bytes(path: str, write_fn) -> None:
    """Write a file atomically: ``write_fn(handle)`` fills a temp file in
    the same directory, which is then fsync'd and ``os.replace``d over
    ``path``.  A crash mid-write leaves either the old file or nothing —
    never a torn file at the final name."""
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=os.path.basename(path) + ".tmp.")
    try:
        with os.fdopen(fd, "wb") as f:
            write_fn(f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def _json_default(o):
    """Numpy scalars (loss histories, eval metrics) -> python scalars.
    ``repr``-based float round-trip is exact, so histories survive a
    save/load cycle bitwise."""
    if hasattr(o, "item") and np.ndim(o) == 0:
        return o.item()
    raise TypeError(f"not JSON serializable: {type(o).__name__}")


def _atomic_json(path: str, obj) -> None:
    _atomic_bytes(path, lambda f: f.write(
        json.dumps(obj, indent=1, default=_json_default).encode("utf-8")))


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_pytree(tree: Any, path: str) -> None:
    """Save any pytree of arrays to <path>.npz (+ <path>.json manifest).

    Both files are written atomically (temp file + ``os.replace``), so a
    crash mid-save can never leave a half-written checkpoint at the final
    name for ``--resume`` to load."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {}
    manifest = []
    for i, (p, leaf) in enumerate(flat):
        key = f"a{i}"
        arrays[key] = np.asarray(leaf)
        manifest.append({"key": key, "path": _path_str(p),
                         "dtype": str(arrays[key].dtype),
                         "shape": list(arrays[key].shape)})
    _atomic_bytes(path + ".npz", lambda f: np.savez(f, **arrays))
    _atomic_json(path + ".json", manifest)


def load_pytree(template: Any, path: str) -> Any:
    """Load a checkpoint into ``template``'s structure (leaf paths must
    match; shapes are validated)."""
    data = np.load(path + ".npz")
    with open(path + ".json") as f:
        manifest = json.load(f)
    by_path = {m["path"]: data[m["key"]] for m in manifest}
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat:
        key = _path_str(p)
        if key not in by_path:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = by_path[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch at {key}: ckpt {arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)


# ---------------------------------------------------------------------------
# Model-config metadata (so a checkpoint is servable without knowing its arch)
# ---------------------------------------------------------------------------

def save_config(cfg, path: str) -> None:
    """Write the ModelConfig next to the checkpoint as <path>.cfg.json."""
    _atomic_json(path + ".cfg.json", dataclasses.asdict(cfg))


def load_config(path: str) -> Optional[Any]:
    """Load the ModelConfig saved beside a checkpoint, or None if the
    checkpoint predates config metadata."""
    from repro.configs.base import ModelConfig
    meta = path + ".cfg.json"
    if not os.path.exists(meta):
        return None
    with open(meta) as f:
        d = json.load(f)
    if "window_pattern" in d:                 # tuples round-trip as lists
        d["window_pattern"] = tuple(d["window_pattern"])
    if "adam_betas" in d:
        d["adam_betas"] = tuple(d["adam_betas"])
    return ModelConfig(**d)


# ---------------------------------------------------------------------------
# Run checkpoints: crash-consistent training snapshots with a manifest
# ---------------------------------------------------------------------------
#
# Layout inside a checkpoint dir, per saved step:
#
#   ckpt_00000012.state.npz / .state.json    — the full trainer state pytree
#   ckpt_00000012.extras.npz / .extras.json  — runner-private arrays (EF
#                                              residuals, gossip anchors...)
#                                              only when non-empty
#   ckpt_00000012.manifest.json              — written LAST, atomically
#
# The manifest names every file the checkpoint needs plus the data-pipeline
# cursor (batches are pure functions of the step index, so the cursor IS
# the step), runner JSON metadata, and the recorded loss history.  Because
# the manifest lands last via os.replace, a manifest's existence implies a
# complete checkpoint: readers validate the referenced files and otherwise
# skip the entry, so a torn write degrades to "resume from the previous
# step", never to loading garbage.

_MANIFEST_FORMAT = 1


def save_run_checkpoint(ckpt_dir: str, step: int, state: Any,
                        extras_arrays: Any = None,
                        extras_meta: Optional[Dict] = None,
                        history: Optional[Dict] = None,
                        meta: Optional[Dict] = None) -> str:
    """Write one crash-consistent training checkpoint; returns the
    manifest path.  ``state``/``extras_arrays`` must already be host
    arrays (fetch before calling — this function does no device sync)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    stem = os.path.join(ckpt_dir, f"ckpt_{step:08d}")
    files = {"state": os.path.basename(stem) + ".state"}
    save_pytree(state, stem + ".state")
    has_extras = extras_arrays is not None and jax.tree.leaves(extras_arrays)
    if has_extras:
        save_pytree(extras_arrays, stem + ".extras")
        files["extras"] = os.path.basename(stem) + ".extras"
    manifest = {
        "format": _MANIFEST_FORMAT,
        "step": step,
        "data_cursor": step,
        "files": files,
        "extras_meta": extras_meta or {},
        "history": history or {},
        "meta": meta or {},
    }
    _atomic_json(stem + ".manifest.json", manifest)
    return stem + ".manifest.json"


def _manifest_complete(ckpt_dir: str, manifest: Dict) -> bool:
    for base in manifest.get("files", {}).values():
        stem = os.path.join(ckpt_dir, base)
        if not (os.path.exists(stem + ".npz")
                and os.path.exists(stem + ".json")):
            return False
    return True


def list_run_checkpoints(ckpt_dir: str) -> List[Tuple[int, str]]:
    """(step, manifest_path) for every COMPLETE checkpoint, ascending."""
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in sorted(os.listdir(ckpt_dir)):
        if not name.endswith(".manifest.json"):
            continue
        path = os.path.join(ckpt_dir, name)
        try:
            with open(path) as f:
                manifest = json.load(f)
        except (OSError, json.JSONDecodeError):
            continue
        if manifest.get("format") != _MANIFEST_FORMAT:
            continue
        if not _manifest_complete(ckpt_dir, manifest):
            continue                        # torn write: skip, don't crash
        out.append((int(manifest["step"]), path))
    out.sort()
    return out


def latest_run_checkpoint(ckpt_dir: str) -> Optional[Dict]:
    """The newest complete checkpoint's manifest (with ``_dir`` attached),
    or None when the directory has none."""
    entries = list_run_checkpoints(ckpt_dir)
    if not entries:
        return None
    _, path = entries[-1]
    with open(path) as f:
        manifest = json.load(f)
    manifest["_dir"] = ckpt_dir
    return manifest


def load_run_checkpoint(manifest: Dict, state_template: Any,
                        extras_template: Any = None
                        ) -> Tuple[Any, Optional[Any]]:
    """Restore (state, extras) from a manifest returned by
    ``latest_run_checkpoint``.  ``extras_template`` None (or an entry the
    checkpoint lacks) yields extras None."""
    ckpt_dir = manifest["_dir"]
    files = manifest["files"]
    state = load_pytree(state_template, os.path.join(ckpt_dir, files["state"]))
    extras = None
    if extras_template is not None and "extras" in files:
        extras = load_pytree(extras_template,
                             os.path.join(ckpt_dir, files["extras"]))
    return state, extras
