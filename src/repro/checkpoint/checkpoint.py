"""Checkpointing: pytree <-> .npz (+ structure manifest).

The Hybrid configuration in the paper (DiLoCo-pretrained base handed to a DDP
mid-training/SFT run) requires checkpoints to cross trainer types, so we save
flat path->array maps that can be restored into any template with matching
leaf paths.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Optional

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_pytree(tree: Any, path: str) -> None:
    """Save any pytree of arrays to <path>.npz (+ <path>.json manifest)."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {}
    manifest = []
    for i, (p, leaf) in enumerate(flat):
        key = f"a{i}"
        arrays[key] = np.asarray(leaf)
        manifest.append({"key": key, "path": _path_str(p),
                         "dtype": str(arrays[key].dtype),
                         "shape": list(arrays[key].shape)})
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path + ".npz", **arrays)
    with open(path + ".json", "w") as f:
        json.dump(manifest, f, indent=1)


def load_pytree(template: Any, path: str) -> Any:
    """Load a checkpoint into ``template``'s structure (leaf paths must
    match; shapes are validated)."""
    data = np.load(path + ".npz")
    with open(path + ".json") as f:
        manifest = json.load(f)
    by_path = {m["path"]: data[m["key"]] for m in manifest}
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat:
        key = _path_str(p)
        if key not in by_path:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = by_path[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch at {key}: ckpt {arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)


# ---------------------------------------------------------------------------
# Model-config metadata (so a checkpoint is servable without knowing its arch)
# ---------------------------------------------------------------------------

def save_config(cfg, path: str) -> None:
    """Write the ModelConfig next to the checkpoint as <path>.cfg.json."""
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path + ".cfg.json", "w") as f:
        json.dump(dataclasses.asdict(cfg), f, indent=1)


def load_config(path: str) -> Optional[Any]:
    """Load the ModelConfig saved beside a checkpoint, or None if the
    checkpoint predates config metadata."""
    from repro.configs.base import ModelConfig
    meta = path + ".cfg.json"
    if not os.path.exists(meta):
        return None
    with open(meta) as f:
        d = json.load(f)
    if "window_pattern" in d:                 # tuples round-trip as lists
        d["window_pattern"] = tuple(d["window_pattern"])
    if "adam_betas" in d:
        d["adam_betas"] = tuple(d["adam_betas"])
    return ModelConfig(**d)
