"""SGD with (Nesterov) momentum — DiLoCo's **outer** optimizer (paper §3:
mu_outer = 0.9, eta_outer = 0.8).  Also usable as a plain inner optimizer."""
from __future__ import annotations

from typing import Callable, Union

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer


def sgd_nesterov(lr: Union[float, Callable] = 0.8, momentum: float = 0.9,
                 nesterov: bool = True) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"v": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params, step):
        lr_t = lr_fn(step)

        def upd(g, v):
            g = g.astype(jnp.float32)
            v = momentum * v + g
            eff = g + momentum * v if nesterov else v
            return -lr_t * eff, v

        out = jax.tree.map(upd, grads, state["v"])
        updates = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"v": v}

    return Optimizer(init, update)
