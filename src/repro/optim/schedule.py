"""Learning-rate schedules: warmup+cosine, WSD (warmup-stable-decay, the
nanochat default), constant."""
from __future__ import annotations

import jax.numpy as jnp


def lr_schedule(kind: str, base_lr: float, total_steps: int,
                warmup_steps: int = 0, final_frac: float = 0.0):
    """Returns f(step) -> lr (all jnp ops, safe inside jit)."""
    total = max(total_steps, 1)
    warm = max(warmup_steps, 0)

    def f(step):
        s = jnp.asarray(step, jnp.float32)
        warm_lr = base_lr * jnp.minimum(1.0, (s + 1.0) / max(warm, 1))
        if kind == "constant":
            main = base_lr
        elif kind == "cosine":
            frac = jnp.clip((s - warm) / max(total - warm, 1), 0.0, 1.0)
            main = final_frac * base_lr + (1 - final_frac) * base_lr * 0.5 * (
                1.0 + jnp.cos(jnp.pi * frac))
        elif kind == "wsd":
            # stable until 80% of total, then linear decay to final_frac
            decay_start = 0.8 * total
            frac = jnp.clip((s - decay_start) / max(total - decay_start, 1),
                            0.0, 1.0)
            main = base_lr * (1.0 - (1.0 - final_frac) * frac)
        else:
            raise ValueError(kind)
        return jnp.where(s < warm, warm_lr, main)

    return f
