"""AdamW — the paper's inner optimizer for embeddings / scalars (nanochat
split), and the general-purpose fallback.

``fused=True`` routes each leaf through the fused Pallas update kernel
(``repro.kernels.fused_adamw``): both moment updates, bias correction,
weight decay, and the scaled update in one VMEM-resident pass instead of
per-op HBM round-trips.  The kernel runs the same f32 ops in the same
order as the unfused path — bit-exact against the jnp oracle on its own
flattened view; against the leaf-shaped unfused path the agreement is a
few ulp (XLA's FMA contraction is shape-dependent), so flipping the flag
cannot meaningfully change convergence.  It defaults off and is threaded
from ``OptimizerConfig.fused_adamw``.
"""
from __future__ import annotations

from typing import Callable, Tuple, Union

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer


def adamw(lr: Union[float, Callable] = 3e-4,
          betas: Tuple[float, float] = (0.9, 0.95),
          eps: float = 1e-10,
          weight_decay: float = 0.0,
          fused: bool = False) -> Optimizer:
    b1, b2 = betas
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        t = jnp.asarray(step, jnp.float32) + 1.0
        lr_t = lr_fn(step)

        if fused:
            from repro.kernels.fused_adamw import fused_adamw_update
            lr_arr = jnp.asarray(lr_t, jnp.float32)
            bc1, bc2 = 1 - b1 ** t, 1 - b2 ** t

            def upd(g, m, v, p):
                return fused_adamw_update(p, g, m, v, lr_arr, bc1, bc2,
                                          b1=b1, b2=b2, eps=eps,
                                          wd=weight_decay)
        else:
            def upd(g, m, v, p):
                g = g.astype(jnp.float32)
                m = b1 * m + (1 - b1) * g
                v = b2 * v + (1 - b2) * jnp.square(g)
                mhat = m / (1 - b1 ** t)
                vhat = v / (1 - b2 ** t)
                u = -lr_t * (mhat / (jnp.sqrt(vhat) + eps)
                             + weight_decay * p.astype(jnp.float32))
                return u, m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        updates = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"m": m, "v": v}

    return Optimizer(init, update)
