"""Muon — momentum + Newton-Schulz orthogonalization (nanochat's default
inner optimizer for weight matrices; the paper keeps it inside DiLoCo).

Newton-Schulz is five batched matmuls per step — MXU-native on TPU, no custom
kernel needed.  Stacked layer parameters (L, m, n) are handled by broadcasting
the matmuls over the leading dim.
"""
from __future__ import annotations

from typing import Callable, Union

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer

_NS_COEFFS = (3.4445, -4.7750, 2.0315)


def newton_schulz(G: jax.Array, steps: int = 5, eps: float = 1e-7) -> jax.Array:
    """Approximate orthogonalization of the last two dims (quintic NS)."""
    a, b, c = _NS_COEFFS
    X = G.astype(jnp.float32)
    transposed = X.shape[-2] > X.shape[-1]
    if transposed:
        X = jnp.swapaxes(X, -1, -2)
    norm = jnp.sqrt(jnp.sum(jnp.square(X), axis=(-2, -1), keepdims=True))
    X = X / (norm + eps)

    def body(X, _):
        A = X @ jnp.swapaxes(X, -1, -2)
        B = b * A + c * (A @ A)
        return a * X + B @ X, None

    X, _ = jax.lax.scan(body, X, None, length=steps)
    if transposed:
        X = jnp.swapaxes(X, -1, -2)
    return X


def muon(lr: Union[float, Callable] = 0.02, momentum: float = 0.95,
         ns_steps: int = 5, nesterov: bool = True) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"mu": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params, step):
        lr_t = lr_fn(step)

        def upd(g, mu):
            if g.ndim < 2:   # sentinel / scalar leaf routed here by mistake
                return jnp.zeros_like(g, jnp.float32), mu
            g = g.astype(jnp.float32)
            mu = momentum * mu + g
            eff = g + momentum * mu if nesterov else mu
            o = newton_schulz(eff, ns_steps)
            # scale: matrices update at spectral-norm-equalized magnitude
            m, n = o.shape[-2], o.shape[-1]
            scale = jnp.sqrt(jnp.maximum(1.0, m / n))
            return -lr_t * scale * o, mu

        out = jax.tree.map(upd, grads, state["mu"])
        updates = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"mu": mu}

    return Optimizer(init, update)
