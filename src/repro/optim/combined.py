"""nanochat's optimizer split: **Muon** for transformer weight matrices,
**AdamW** for embeddings / unembedding / norms / biases / SSM scalars /
depthwise conv filters.  The paper keeps exactly this split inside each
DiLoCo worker ("Inner optimizers: AdamW and Muon (default in nanochat)").
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig
from repro.optim.adamw import adamw
from repro.optim.base import Optimizer, clip_by_global_norm
from repro.optim.muon import muon
from repro.optim.schedule import lr_schedule

_ADAM_LEAF_NAMES = {"A_log", "D", "dt_bias", "conv_w", "conv_b", "router",
                    "table", "unembed", "scale", "bias", "norm_scale",
                    "mix_a", "mix_s", "bq", "bk", "bv"}


def partition_label(path, leaf) -> str:
    """'muon' for true weight matrices, 'adamw' for everything else."""
    keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
    if any(k in _ADAM_LEAF_NAMES for k in keys):
        return "adamw"
    if any(k == "embed" for k in keys):
        return "adamw"
    if leaf.ndim < 2:
        return "adamw"
    return "muon"


def _mask(tree, label_fn, want: str):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: label_fn(path, leaf) == want, tree)


_SENTINEL_SHAPE = (0,)


def _masked_tree(tree, mask):
    """Replace masked-out leaves with 0-sized sentinels so per-label optimizer
    state is only allocated for the leaves that label actually owns."""
    return jax.tree.map(
        lambda x, m: x if m else jnp.zeros(_SENTINEL_SHAPE, jnp.float32),
        tree, mask)


def partitioned(opts: dict, label_fn: Callable) -> Optimizer:
    """Route each leaf to the optimizer chosen by ``label_fn(path, leaf)``."""
    labels = sorted(opts)

    def init(params):
        return {lab: opts[lab].init(_masked_tree(params, _mask(params, label_fn, lab)))
                for lab in labels}

    def update(grads, state, params, step):
        total = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
        new_state = {}
        for lab in labels:
            mask = _mask(grads, label_fn, lab)
            upd, new_state[lab] = opts[lab].update(
                _masked_tree(grads, mask), state[lab],
                _masked_tree(params, mask), step)
            total = jax.tree.map(
                lambda acc, u, m: acc + u.astype(jnp.float32) if m else acc,
                total, upd, mask)
        return total, new_state

    return Optimizer(init, update)


def nanochat_optimizer(cfg: OptimizerConfig) -> Optimizer:
    muon_lr = lr_schedule(cfg.schedule, cfg.learning_rate, cfg.total_steps,
                          cfg.warmup_steps, cfg.final_lr_frac)
    adam_lr = lr_schedule(cfg.schedule, cfg.adam_lr, cfg.total_steps,
                          cfg.warmup_steps, cfg.final_lr_frac)
    inner = partitioned(
        {"muon": muon(muon_lr, cfg.muon_momentum, cfg.muon_ns_steps),
         "adamw": adamw(adam_lr, cfg.adam_betas, cfg.adam_eps,
                        cfg.weight_decay, fused=cfg.fused_adamw)},
        partition_label)

    if cfg.grad_clip <= 0:
        return inner

    def update(grads, state, params, step):
        grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
        return inner.update(grads, state, params, step)

    return Optimizer(inner.init, update)
