"""Minimal optimizer framework (optax-shaped, zero dependencies).

An ``Optimizer`` is a pair of pure functions:

  state   = opt.init(params)
  updates, state = opt.update(grads, state, params, step)
  params  = apply_updates(params, updates)

``step`` is a scalar int32 used for schedules / bias correction.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Any]      # (grads, state, params, step) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), tree), norm


def chain_clip(opt: Optimizer, max_norm: float) -> Optimizer:
    """Wrap an optimizer with global-norm gradient clipping."""
    def update(grads, state, params, step):
        grads, _ = clip_by_global_norm(grads, max_norm)
        return opt.update(grads, state, params, step)
    return Optimizer(opt.init, update)
