from repro.optim.base import Optimizer, apply_updates, global_norm
from repro.optim.adamw import adamw
from repro.optim.muon import muon, newton_schulz
from repro.optim.sgd_nesterov import sgd_nesterov
from repro.optim.combined import nanochat_optimizer, partition_label
from repro.optim.schedule import lr_schedule

__all__ = ["Optimizer", "apply_updates", "global_norm", "adamw", "muon",
           "newton_schulz", "sgd_nesterov", "nanochat_optimizer",
           "partition_label", "lr_schedule"]
