"""Grouped-query attention with rotary embeddings, sliding windows and KV
caches (full + ring-buffer) — the reference jnp implementation.

Three execution paths, selected per call site:

* ``_direct``   — materialized scores; short sequences (train_4k, decode).
* ``_blocked``  — lax.scan over KV chunks with an online softmax (the pure-jnp
                  mirror of the Pallas flash kernel); long prefill.
* ``_banded``   — sliding-window prefill that only gathers the W-wide band of
                  keys per query block: O(S·W) instead of O(S²) FLOPs.

GQA is computed grouped — queries reshaped to (B,S,KV,G,D) — so KV heads are
never materialized repeated.  All tensors carry logical sharding annotations;
when a head count does not divide the tensor-parallel degree the constraint
silently relaxes (see models/sharding.py).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, param
from repro.models.sharding import logical_constraint

NEG_INF = -1e30

# ``ModelConfig.kv_cache_dtype`` spellings -> kernels.quantize target names
# (None = plain narrow cast, no scales)
KV_QUANT_TARGETS = {"int8": "int8", "fp8": "fp8_e4m3",
                    "fp8_e4m3": "fp8_e4m3", "fp8_e5m2": "fp8_e5m2"}
_KV_PLAIN = {"": None, "bf16": "bfloat16", "bfloat16": "bfloat16",
             "f32": "float32", "float32": "float32"}


def kv_quant_dtype(cfg: ModelConfig) -> Optional[str]:
    """The quantize-kernel target name the config's KV pool uses, or None
    for an unquantized (plain-dtype) pool."""
    s = cfg.kv_cache_dtype
    if s in _KV_PLAIN:
        return None
    if s not in KV_QUANT_TARGETS:
        raise ValueError(f"unknown kv_cache_dtype {cfg.kv_cache_dtype!r}; "
                         f"expected one of {sorted(_KV_PLAIN)} or "
                         f"{sorted(KV_QUANT_TARGETS)}")
    return KV_QUANT_TARGETS[s]


def kv_pool_dtype(cfg: ModelConfig):
    """Storage dtype of the paged pool's k/v arrays."""
    qd = kv_quant_dtype(cfg)
    if qd is not None:
        from repro.kernels.quantize import target_dtype
        return jnp.dtype(target_dtype(qd))
    return jnp.dtype(_KV_PLAIN[cfg.kv_cache_dtype] or cfg.compute_dtype)


# Force a particular implementation (tests / perf experiments); None = auto.
FORCE_IMPL: Optional[str] = None
# Above this KV length the blocked/banded paths are used.
DIRECT_MAX_KV = 4096
BLOCK_Q = 512
BLOCK_KV = 1024


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, cross: bool = False):
    d = cfg.d_model
    hd = cfg.resolved_head_dim()
    nq, nkv = cfg.num_heads * hd, cfg.num_kv_heads * hd
    ks = jax.random.split(key, 4)
    o_scale = 1.0 / math.sqrt(2 * max(cfg.num_layers, 1) * nq)
    p = {
        "wq": param(ks[0], (d, nq), ("fsdp", "heads")),
        "wk": param(ks[1], (d, nkv), ("fsdp", "kv_heads")),
        "wv": param(ks[2], (d, nkv), ("fsdp", "kv_heads")),
        "wo": param(ks[3], (nq, d), ("heads", "fsdp"), scale=o_scale),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = param(ks[0], (nq,), (None,), init="zeros")
        p["bk"] = param(ks[1], (nkv,), (None,), init="zeros")
        p["bv"] = param(ks[2], (nkv,), (None,), init="zeros")
    return p


def _project_qkv(p, xq, xkv, cfg: ModelConfig):
    dt = cfg.compute_dtype
    hd = cfg.resolved_head_dim()
    q = xq @ p["wq"].astype(dt)
    k = xkv @ p["wk"].astype(dt)
    v = xkv @ p["wv"].astype(dt)
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = logical_constraint(q, "batch", "seq", "heads")
    k = logical_constraint(k, "batch", "seq", "kv_heads")
    v = logical_constraint(v, "batch", "seq", "kv_heads")
    B, Sq = q.shape[:2]
    Skv = k.shape[1]
    q = q.reshape(B, Sq, cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads, hd)
    k = k.reshape(B, Skv, cfg.num_kv_heads, hd)
    v = v.reshape(B, Skv, cfg.num_kv_heads, hd)
    return q, k, v


# ---------------------------------------------------------------------------
# Score paths
# ---------------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, window, causal: bool):
    """Additive mask bias (…, Sq, Sk) from position arrays."""
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    ok = k_pos[..., None, :] >= 0
    if causal:
        ok &= diff >= 0
    if window is not None:
        ok &= diff < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _direct(q, k, v, bias):
    """q: (B,Sq,KV,G,D), k/v: (B,Sk,KV,D), bias: (B,1,1,Sq,Sk) or None."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32) * scale
    if bias is not None:
        s = s + bias
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgqs,bskd->bqkgd", p, v)


def _blocked(q, k, v, q_pos, k_pos, window, causal):
    """Online-softmax scan over KV chunks (flash-attention in jnp)."""
    B, Sq, KV, G, D = q.shape
    Sk = k.shape[1]
    bk = min(BLOCK_KV, Sk)
    nblocks = (Sk + bk - 1) // bk
    pad = nblocks * bk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    kc = k.reshape(B, nblocks, bk, KV, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nblocks, bk, KV, D).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(B, nblocks, bk).transpose(1, 0, 2)
    scale = 1.0 / math.sqrt(D)

    def step(carry, blk):
        m, l, acc = carry
        kb, vb, pb = blk
        s = jnp.einsum("bqkgd,bskd->bkgqs", q, kb).astype(jnp.float32) * scale
        s = s + _mask_bias(q_pos, pb, window, causal)[:, None, None]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(q.dtype), vb).astype(jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)  # (B,Sq,KV,G,D)


def _banded(q, k, v, window, cfg):
    """Sliding-window prefill: per query block gather only the (W + Bq)-wide
    key band.  FLOPs O(S·(W+Bq)) — the sub-quadratic dense-arch path."""
    B, Sq, KV, G, D = q.shape
    bq = min(BLOCK_Q, Sq)
    nq = Sq // bq
    assert Sq % bq == 0, "banded path expects block-aligned sequence"
    band = window + bq
    scale = 1.0 / math.sqrt(D)
    # pad keys on the left so every band gather is in-bounds
    kp = jnp.pad(k, ((0, 0), (band - bq, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (band - bq, 0), (0, 0), (0, 0)))

    def block(i):
        q0 = i * bq
        qb = jax.lax.dynamic_slice_in_dim(q, q0, bq, axis=1)
        kb = jax.lax.dynamic_slice_in_dim(kp, q0, band, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(vp, q0, band, axis=1)
        q_pos = q0 + jnp.arange(bq)
        k_pos = q0 - (band - bq) + jnp.arange(band)  # may be negative -> masked
        s = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb).astype(jnp.float32) * scale
        s = s + _mask_bias(q_pos, k_pos, window, True)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        return jnp.einsum("bkgqs,bskd->bqkgd", p, vb)

    outs = jax.lax.map(block, jnp.arange(nq))          # (nq, B, bq, KV, G, D)
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, KV, G, D)


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, capacity: int,
               dtype=None) -> Dict[str, Any]:
    """A fixed-capacity cache.  For full attention capacity = max_seq_len; for
    sliding-window decode it is the window (ring buffer)."""
    hd = cfg.resolved_head_dim()
    dt = dtype or cfg.compute_dtype
    return {
        "k": jnp.zeros((batch, capacity, cfg.num_kv_heads, hd), dt),
        "v": jnp.zeros((batch, capacity, cfg.num_kv_heads, hd), dt),
        "pos": jnp.full((batch, capacity), -1, jnp.int32),
        "idx": jnp.zeros((), jnp.int32),   # next write slot (mod capacity)
    }


def cache_logical_names(ring: bool = False):
    return {"k": ("batch", "seq", "kv_heads", None),
            "v": ("batch", "seq", "kv_heads", None),
            "pos": ("batch", "seq"),
            "idx": ()}


def _cache_insert(cache, k_new, v_new, pos_new):
    """Insert S_new entries at idx mod capacity.  Decode writes a single
    position, so a ring write never crosses the buffer boundary; prefill
    writes start at slot 0.  Functional (returns a new cache pytree)."""
    cap = cache["k"].shape[1]
    s_new = k_new.shape[1]
    slot = jnp.mod(cache["idx"], cap)

    def upd(buf, new):
        return jax.lax.dynamic_update_slice_in_dim(
            buf, new.astype(buf.dtype), slot, axis=1)

    return {"k": upd(cache["k"], k_new), "v": upd(cache["v"], v_new),
            "pos": upd(cache["pos"], pos_new), "idx": cache["idx"] + s_new}


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def attention(p, x, cfg: ModelConfig, *, positions: jax.Array,
              window: Optional[int] = None,
              memory: Optional[jax.Array] = None,
              impl: Optional[str] = None) -> jax.Array:
    """Training / prefill attention.  ``memory`` switches to cross-attention
    (bidirectional over the encoder output)."""
    B, S = x.shape[:2]
    cross = memory is not None
    xkv = memory if cross else x
    q, k, v = _project_qkv(p, x, xkv, cfg)
    if not cross:
        q = apply_rope(q.reshape(B, S, cfg.num_heads, -1), positions,
                       cfg.rope_theta).reshape(q.shape)
        k = apply_rope(k, positions, cfg.rope_theta)
    k_pos = (jnp.broadcast_to(jnp.arange(xkv.shape[1]), (B, xkv.shape[1]))
             if cross else jnp.broadcast_to(positions, (B, S)))
    q_pos = jnp.broadcast_to(positions, (B, S))
    static_window = isinstance(window, int) or window is None
    if static_window:
        w = None if not window else window
    else:
        w = window  # traced per-layer window (0 already mapped to "huge")
    causal = not cross
    Sk = xkv.shape[1]
    mode = impl or FORCE_IMPL
    if mode is None:
        if (static_window and w and w < Sk and Sk > DIRECT_MAX_KV and causal
                and S == Sk and S % min(BLOCK_Q, S) == 0):
            mode = "banded"
        elif Sk > DIRECT_MAX_KV:
            mode = "blocked"
        else:
            mode = "direct"
    if mode == "banded":
        out = _banded(q, k, v, w, cfg)
    elif mode == "blocked":
        out = _blocked(q, k, v, q_pos, k_pos, w, causal)
    else:
        bias = _mask_bias(q_pos, k_pos, w, causal)[:, None, None]
        out = _direct(q, k, v, bias)
    out = out.reshape(B, S, cfg.num_heads * cfg.resolved_head_dim())
    out = logical_constraint(out, "batch", "seq", "heads")
    y = out @ p["wo"].astype(cfg.compute_dtype)
    return logical_constraint(y, "batch", "seq", None)


def decode_attention(p, x, cfg: ModelConfig, cache: Dict[str, Any], *,
                     position: jax.Array, window: Optional[int] = None,
                     memory_cache: Optional[Dict[str, jax.Array]] = None
                     ) -> Tuple[jax.Array, Dict[str, Any]]:
    """One-token decode against a KV cache.

    x: (B, 1, d).  position: scalar or (B,) absolute position of the new
    token.  ``memory_cache`` holds precomputed cross-attention K/V.
    """
    B = x.shape[0]
    hd = cfg.resolved_head_dim()
    if memory_cache is not None:   # cross-attention: read-only memory
        dt = cfg.compute_dtype
        q = (x @ p["wq"].astype(dt)).reshape(
            B, 1, cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads, hd)
        k, v = memory_cache["k"], memory_cache["v"]
        out = _direct(q, k, v, None)
        out = out.reshape(B, 1, cfg.num_heads * hd)
        y = out @ p["wo"].astype(dt)
        return logical_constraint(y, "batch", "seq", None), cache
    q, k_new, v_new = _project_qkv(p, x, x, cfg)
    pos = jnp.broadcast_to(jnp.asarray(position, jnp.int32).reshape(-1, 1)
                           if jnp.ndim(position) else
                           jnp.asarray(position, jnp.int32), (B, 1))
    q = apply_rope(q.reshape(B, 1, cfg.num_heads, hd), pos, cfg.rope_theta
                   ).reshape(q.shape)
    k_new = apply_rope(k_new, pos, cfg.rope_theta)
    cache = _cache_insert(cache, k_new, v_new, pos)
    if isinstance(window, int) and window == 0:
        window = None
    bias = _mask_bias(pos, cache["pos"], window, True)
    out = _direct(q, cache["k"].astype(q.dtype), cache["v"].astype(q.dtype),
                  bias[:, None, None])
    out = out.reshape(B, 1, cfg.num_heads * hd)
    out = logical_constraint(out, "batch", "seq", "heads")
    y = out @ p["wo"].astype(cfg.compute_dtype)
    return logical_constraint(y, "batch", "seq", None), cache


def paged_decode_attention(p, x, cfg: ModelConfig, k_pool: jax.Array,
                           v_pool: jax.Array, *, positions: jax.Array,
                           block_table: jax.Array,
                           window: Optional[jax.Array] = None,
                           impl: Optional[str] = None,
                           k_scale: Optional[jax.Array] = None,
                           v_scale: Optional[jax.Array] = None):
    """Decode / verify attention against a *paged* KV pool shared by all
    slots.

    x: (S, T, d) — T fresh tokens per serving slot (T = 1 for plain decode;
    T > 1 for speculative verification / multi-token prefill, where a slot's
    tokens occupy *contiguous* positions); k_pool/v_pool: (NB, bs, KV, hd)
    fixed-size physical blocks; positions: (S,) int32 when T == 1, else
    (S, T) int32 — absolute position each token is written at / queries
    from.  −1 marks an inactive slot (T == 1) or a padding token (T > 1):
    its write is dropped and its output row is garbage the caller must
    ignore.  When T > 1 the live positions of a slot must be a contiguous
    prefix ``start .. start + n − 1`` of the row (the padded-script layout
    the engine emits); block_table: (S, MB) int32 physical block ids
    (−1 = unmapped).

    Blocks hold contiguous positions (slot s's logical position i lives at
    offset i % bs of physical block ``block_table[s, i // bs]``), so validity
    is purely positional: lane i is attendable iff ``i <= position of the
    query token`` and its table entry is mapped — the position-gated mask
    that lets slots at different generation depths coexist in one batched
    step.  All T fresh K/V are scattered before the attention reads, so
    causality *among* the T tokens is the same positional gate.

    Quantized pools (``cfg.kv_cache_dtype`` int8/fp8/fp8_e5m2) carry
    ``k_scale``/``v_scale``: (NB, bs, KV) f32 per-token-per-head amax
    scales.  Fresh K/V are quantized along the head dim on scatter
    (``kernels.quantize.reference_quantize_axis``) and dequantized on load
    — jnp path inline, Pallas path via the ``*_dequant`` kernel variants.

    ``cfg.fp8_matmul`` runs the plain-pool Pallas kernels' QK^T on per-row
    fp8 tiles; the dequant variants keep the f32 contraction (their K rows
    are already one narrow cast deep — a second quantization would compound
    the error for no bandwidth win, since the payload is narrow in memory).

    Returns (y (S, T, d), new_k_pool, new_v_pool) for plain pools, plus
    (new_k_scale, new_v_scale) when the pool is quantized.
    """
    S, T = x.shape[:2]
    hd = cfg.resolved_head_dim()
    NB, bs = k_pool.shape[:2]
    MB = block_table.shape[1]
    q, k_new, v_new = _project_qkv(p, x, x, cfg)
    pos = jnp.asarray(positions, jnp.int32)
    if pos.ndim == 1:                                          # (S,) -> (S,T)
        assert T == 1, "1-d positions require a single token per slot"
        pos = pos[:, None]
    active = pos >= 0                                          # (S, T)
    posc = jnp.maximum(pos, 0)
    q = apply_rope(q.reshape(S, T, cfg.num_heads, hd), posc,
                   cfg.rope_theta).reshape(q.shape)
    k_new = apply_rope(k_new, posc, cfg.rope_theta)

    # -- scatter the fresh K/V into the pool (inactive writes fall out of
    # bounds and are dropped) ------------------------------------------------
    col = posc // bs                                           # (S, T)
    blk = jnp.take_along_axis(block_table, col, axis=1)        # (S, T)
    dest = blk * bs + posc % bs
    dest = jnp.where(active & (blk >= 0), dest, NB * bs)       # OOB sentinel
    quantized = k_scale is not None
    if quantized:
        from repro.kernels.quantize import reference_quantize_axis
        qd = kv_quant_dtype(cfg)
        k_w, k_s = reference_quantize_axis(k_new, axis=-1, dtype=qd)
        v_w, v_s = reference_quantize_axis(v_new, axis=-1, dtype=qd)
        ks_flat = k_scale.reshape(NB * bs, cfg.num_kv_heads)
        vs_flat = v_scale.reshape(NB * bs, cfg.num_kv_heads)
        ks_flat = ks_flat.at[dest.reshape(-1)].set(
            k_s.reshape(S * T, cfg.num_kv_heads), mode="drop")
        vs_flat = vs_flat.at[dest.reshape(-1)].set(
            v_s.reshape(S * T, cfg.num_kv_heads), mode="drop")
        new_ks = ks_flat.reshape(NB, bs, cfg.num_kv_heads)
        new_vs = vs_flat.reshape(NB, bs, cfg.num_kv_heads)
    else:
        k_w, v_w = k_new, v_new
        new_ks = new_vs = None
    k_flat = k_pool.reshape(NB * bs, cfg.num_kv_heads, hd)
    v_flat = v_pool.reshape(NB * bs, cfg.num_kv_heads, hd)
    k_flat = k_flat.at[dest.reshape(-1)].set(
        k_w.reshape(S * T, cfg.num_kv_heads, hd).astype(k_flat.dtype),
        mode="drop")
    v_flat = v_flat.at[dest.reshape(-1)].set(
        v_w.reshape(S * T, cfg.num_kv_heads, hd).astype(v_flat.dtype),
        mode="drop")
    new_k = k_flat.reshape(NB, bs, cfg.num_kv_heads, hd)
    new_v = v_flat.reshape(NB, bs, cfg.num_kv_heads, hd)

    static_window = isinstance(window, int) or window is None
    if isinstance(window, int) and window == 0:
        window = None
    if impl == "pallas" and static_window and T == 1 and not quantized:
        from repro.kernels.decode_attention import \
            paged_decode_attention as paged_kernel
        out = paged_kernel(q[:, 0], new_k.astype(q.dtype),
                           new_v.astype(q.dtype), block_table,
                           jnp.where(active[:, 0], pos[:, 0], -1),
                           window=window or 0,
                           fp8=cfg.fp8_matmul)[:, None]         # (S,1,KV,G,hd)
    elif impl == "pallas" and static_window and not quantized:
        from repro.kernels.decode_attention import \
            paged_verify_attention as verify_kernel
        # live tokens are a contiguous prefix: recover (start, n) per slot
        start = jnp.where(active[:, 0], pos[:, 0], -1)
        n_tok = jnp.sum(active.astype(jnp.int32), axis=1)
        out = verify_kernel(q, new_k.astype(q.dtype), new_v.astype(q.dtype),
                            block_table, start, n_tok, window=window or 0,
                            fp8=cfg.fp8_matmul)
    elif impl == "pallas" and static_window and T == 1:
        from repro.kernels.decode_attention import \
            paged_decode_attention_dequant as paged_dq_kernel
        out = paged_dq_kernel(q[:, 0], new_k, new_v, new_ks, new_vs,
                              block_table,
                              jnp.where(active[:, 0], pos[:, 0], -1),
                              window=window or 0)[:, None]
    elif impl == "pallas" and static_window:
        from repro.kernels.decode_attention import \
            paged_verify_attention_dequant as verify_dq_kernel
        start = jnp.where(active[:, 0], pos[:, 0], -1)
        n_tok = jnp.sum(active.astype(jnp.int32), axis=1)
        out = verify_dq_kernel(q, new_k, new_v, new_ks, new_vs, block_table,
                               start, n_tok, window=window or 0)
    else:
        safe = jnp.maximum(block_table, 0)                     # (S, MB)
        if quantized:       # dequant-on-load: payload x per-token-head scale
            from repro.kernels.quantize import fast_dequant_cast
            # dequantize the WHOLE pool once (table-gather convert), then
            # block-gather f32: XLA CPU lowers a per-element fp8 convert
            # fused after the block gather to software emulation, which
            # dominates the step.  This jnp path only serves CPU/test
            # runs — the Pallas dequant kernels own the accelerator path,
            # fusing the convert into the tile load instead.
            kf = fast_dequant_cast(new_k) * new_ks[..., None]
            vf = fast_dequant_cast(new_v) * new_vs[..., None]
            k_all = kf[safe].reshape(S, MB * bs, cfg.num_kv_heads,
                                     hd).astype(q.dtype)
            v_all = vf[safe].reshape(S, MB * bs, cfg.num_kv_heads,
                                     hd).astype(q.dtype)
        else:
            k_all = new_k[safe].reshape(S, MB * bs, cfg.num_kv_heads, hd)
            v_all = new_v[safe].reshape(S, MB * bs, cfg.num_kv_heads, hd)
        k_pos = jnp.broadcast_to(jnp.arange(MB * bs), (S, MB * bs))
        mapped = jnp.repeat(block_table >= 0, bs, axis=1)
        k_pos = jnp.where(mapped, k_pos, -1)
        bias = _mask_bias(pos, k_pos, window, True)            # (S, T, L)
        out = _direct(q, k_all.astype(q.dtype), v_all.astype(q.dtype),
                      bias[:, None, None])
    out = out.reshape(S, T, cfg.num_heads * hd)
    out = logical_constraint(out, "batch", "seq", "heads")
    y = out @ p["wo"].astype(cfg.compute_dtype)
    y = logical_constraint(y, "batch", "seq", None)
    if quantized:
        return y, new_k, new_v, new_ks, new_vs
    return y, new_k, new_v


def precompute_cross_cache(p, memory: jax.Array, cfg: ModelConfig):
    """K/V for cross-attention, computed once per request."""
    dt = cfg.compute_dtype
    B, S = memory.shape[:2]
    hd = cfg.resolved_head_dim()
    k = (memory @ p["wk"].astype(dt)).reshape(B, S, cfg.num_kv_heads, hd)
    v = (memory @ p["wv"].astype(dt)).reshape(B, S, cfg.num_kv_heads, hd)
    return {"k": k, "v": v}
