"""Core layers: parameter specs, norms, activations, rotary embeddings, MLPs.

Parameters are plain pytrees (nested dicts of jnp arrays).  Init functions
build trees whose leaves are ``Px(value, names)`` — the array plus its logical
sharding axes — and ``split_logical`` separates them into (params, names_tree)
so the launcher can derive NamedShardings for pjit without a traced model.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.sharding import logical_constraint


class Px(NamedTuple):
    """A parameter leaf: array + logical axis names (one per dim)."""
    value: jax.Array
    names: Tuple[Optional[str], ...]


def is_px(x: Any) -> bool:
    return isinstance(x, Px)


def split_logical(tree):
    """Split a Px-leaf tree into (params, logical_names) trees."""
    params = jax.tree.map(lambda p: p.value, tree, is_leaf=is_px)
    names = jax.tree.map(lambda p: tuple(p.names), tree, is_leaf=is_px)
    return params, names


def param(key, shape, names, *, init="normal", scale=None, dtype=jnp.float32) -> Px:
    """Create a parameter with standard init.

    init: "normal" (trunc-normal fan-in), "zeros", "ones", "embed" (N(0,1)
    scaled), "ssm_a" (mamba A_log), "ssm_dt" (dt bias).
    """
    assert len(shape) == len(names), (shape, names)
    if init == "zeros":
        v = jnp.zeros(shape, dtype)
    elif init == "ones":
        v = jnp.ones(shape, dtype)
    elif init == "normal":
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        s = scale if scale is not None else 1.0 / math.sqrt(fan_in)
        v = s * jax.random.truncated_normal(key, -3.0, 3.0, shape, dtype)
    elif init == "embed":
        s = scale if scale is not None else 0.02
        v = s * jax.random.normal(key, shape, dtype)
    elif init == "ssm_a":
        # A in [1, 16): A_log = log(uniform)
        v = jnp.log(jax.random.uniform(key, shape, dtype, minval=1.0, maxval=16.0))
    elif init == "ssm_dt":
        # inverse-softplus of dt in [1e-3, 1e-1]
        dt = jnp.exp(jax.random.uniform(key, shape, dtype)
                     * (math.log(1e-1) - math.log(1e-3)) + math.log(1e-3))
        v = dt + jnp.log(-jnp.expm1(-dt))
    else:
        raise ValueError(init)
    return Px(v, tuple(names))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(key, d: int, cfg: ModelConfig):
    p = {"scale": param(key, (d,), (None,), init="ones")}
    if cfg.norm == "layernorm":
        p["bias"] = param(key, (d,), (None,), init="zeros")
    return p


def apply_norm(p, x, cfg: ModelConfig):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        x = x - jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + cfg.norm_eps)
    x = x * p["scale"].astype(jnp.float32)
    if cfg.norm == "layernorm":
        x = x + p["bias"].astype(jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def activation(name: str):
    if name == "swiglu":  # handled by MLP (gated)
        return jax.nn.silu
    if name == "relu2":   # nemotron-4 squared ReLU
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "gelu":
        return jax.nn.gelu
    raise ValueError(name)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # (D/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    v = cfg.padded_vocab()
    p = {"table": param(k1, (v, cfg.d_model), ("vocab", "fsdp"),
                        init="embed")}
    if not cfg.tie_embeddings:
        p["unembed"] = param(k2, (cfg.d_model, v), ("fsdp", "vocab"),
                             init="normal")
    return p


def embed(p, tokens, cfg: ModelConfig):
    h = p["table"].astype(cfg.compute_dtype)[tokens]
    return logical_constraint(h, "batch", "seq", None)


def unembed(p, h, cfg: ModelConfig):
    if cfg.tie_embeddings:
        w = p["table"].astype(cfg.compute_dtype).T
    else:
        w = p["unembed"].astype(cfg.compute_dtype)
    logits = h @ w
    logits = logical_constraint(logits, "batch", "seq", "vocab")
    if cfg.logit_soft_cap > 0:
        logits = cfg.logit_soft_cap * jnp.tanh(logits / cfg.logit_soft_cap)
    return logits


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_up": param(ks[0], (d, f), ("fsdp", "ffn")),
         "w_down": param(ks[1], (f, d), ("ffn", "fsdp"), scale=1.0 / math.sqrt(f))}
    if cfg.mlp_activation == "swiglu":
        p["w_gate"] = param(ks[2], (d, f), ("fsdp", "ffn"))
    return p


def apply_mlp(p, x, cfg: ModelConfig):
    dt = cfg.compute_dtype
    up = x @ p["w_up"].astype(dt)
    if cfg.mlp_activation == "swiglu":
        gate = x @ p["w_gate"].astype(dt)
        h = jax.nn.silu(gate) * up
    else:
        h = activation(cfg.mlp_activation)(up)
    h = logical_constraint(h, "batch", "seq", "ffn")
    out = h @ p["w_down"].astype(dt)
    return logical_constraint(out, "batch", "seq", None)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array] = None,
                          z_loss: float = 0.0) -> jax.Array:
    """Mean CE over valid positions. labels == -1 are ignored."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    ce = lse - gold
    if z_loss:
        ce = ce + z_loss * jnp.square(lse)
    valid = (labels >= 0)
    if mask is not None:
        valid = valid & (mask > 0)
    valid = valid.astype(jnp.float32)
    return jnp.sum(ce * valid) / jnp.maximum(jnp.sum(valid), 1.0)
