"""Mixture-of-Experts layer: top-k router, capacity-based argsort dispatch.

Dispatch avoids the quadratic one-hot einsum (GShard style) in favour of the
sort-based token permutation used by modern TPU MoE stacks: tokens are sorted
by assigned expert, ranked within their expert group, dropped past the
capacity, gathered into an (E, C, d) buffer, processed by a batched expert
MLP, and scatter-added back weighted by the router gate.  All dispatch FLOPs
are O(T·k·log(T·k)) — negligible next to expert compute.

Sharding: expert weights keep the expert dim unsharded (8/16 experts do not
divide the mesh axes) and shard d_model over ``fsdp`` + d_ff over ``model`` —
i.e. every expert is tensor-parallel, experts are ZeRO-sharded.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import param
from repro.models.sharding import logical_constraint


def init_moe(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": param(ks[0], (d, e), ("fsdp", None), scale=0.02),
        "w_gate": param(ks[1], (e, d, f), ("expert", "fsdp", "ffn")),
        "w_up": param(ks[2], (e, d, f), ("expert", "fsdp", "ffn")),
        "w_down": param(ks[3], (e, f, d), ("expert", "ffn", "fsdp"),
                        scale=1.0 / math.sqrt(f)),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        p["shared_gate"] = param(ks[4], (d, fs), ("fsdp", "ffn"))
        p["shared_up"] = param(ks[4], (d, fs), ("fsdp", "ffn"))
        p["shared_down"] = param(ks[4], (fs, d), ("ffn", "fsdp"),
                                 scale=1.0 / math.sqrt(fs))
    return p


def _expert_mlp(p, xe, cfg: ModelConfig):
    """xe: (E, C, d) -> (E, C, d), batched over experts."""
    dt = cfg.compute_dtype
    g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(dt))
    h = jax.nn.silu(g) * u
    h = logical_constraint(h, "expert", None, "ffn")
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt))


def apply_moe(p, x, cfg: ModelConfig,
              rng: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Returns (output, aux_load_balance_loss).  x: (B, S, d).

    Dispatch is vmapped over the batch dim so every (E, C, d) staging buffer
    keeps the batch sharding (data axis) instead of replicating a global
    token buffer on every device (the naive flat-token scatter measured
    224 GiB/device on mixtral-8x7b train_4k — EXPERIMENTS.md §Perf).
    Capacity is therefore per-sequence: C = ceil(S·K/E · capacity_factor).
    """
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    C = max(int(math.ceil(S * K / E * cfg.moe_capacity_factor)), K)

    def dispatch_one(xt):
        """xt: (S, d) -> (buffers (E, C, d), combine metadata)."""
        logits = (xt @ p["router"].astype(jnp.float32)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)                  # (S, E)
        gate_vals, expert_idx = jax.lax.top_k(probs, K)          # (S, K)
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jnp.sum(jax.nn.one_hot(expert_idx, E,
                                             dtype=jnp.float32), axis=1),
                      axis=0)
        aux = E * jnp.sum(me * ce)

        flat_expert = expert_idx.reshape(-1)                     # (S*K,)
        flat_token = jnp.repeat(jnp.arange(S), K)
        flat_gate = gate_vals.reshape(-1)
        order = jnp.argsort(flat_expert, stable=True)
        sorted_expert = flat_expert[order]
        sorted_token = flat_token[order]
        sorted_gate = flat_gate[order]
        ar = jnp.arange(S * K)
        is_start = jnp.concatenate(
            [jnp.ones((1,), jnp.int32),
             (sorted_expert[1:] != sorted_expert[:-1]).astype(jnp.int32)])
        group_start = jax.lax.associative_scan(jnp.maximum, ar * is_start)
        rank = ar - group_start
        keep = rank < C
        dest = jnp.where(keep, sorted_expert * C + rank, E * C)
        buf = jnp.zeros((E * C + 1, d), cfg.compute_dtype)
        buf = buf.at[dest].set(xt[sorted_token].astype(cfg.compute_dtype))
        return (buf[:E * C].reshape(E, C, d),
                (dest, sorted_token, sorted_gate, keep, aux))

    xe, (dest, sorted_token, sorted_gate, keep, aux) = jax.vmap(dispatch_one)(x)
    xe = logical_constraint(xe, "batch", "expert", None, None)

    ye = jnp.einsum("becd,edf->becf", xe, p["w_gate"].astype(cfg.compute_dtype))
    yu = jnp.einsum("becd,edf->becf", xe, p["w_up"].astype(cfg.compute_dtype))
    h = jax.nn.silu(ye) * yu
    h = logical_constraint(h, "batch", "expert", None, "ffn")
    ye = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(cfg.compute_dtype))
    ye = logical_constraint(ye, "batch", "expert", None, None)

    def combine_one(y_e, meta, xt):
        dest, sorted_token, sorted_gate, keep = meta
        y_flat = y_e.reshape(E * C, d)
        contrib = y_flat[jnp.minimum(dest, E * C - 1)] * (
            sorted_gate * keep)[:, None].astype(y_flat.dtype)
        return jnp.zeros((S, d), y_flat.dtype).at[sorted_token].add(contrib)

    out = jax.vmap(combine_one)(ye, (dest, sorted_token, sorted_gate, keep), x)
    aux = jnp.mean(aux)

    if cfg.num_shared_experts:
        dt = cfg.compute_dtype
        xt = x.reshape(B * S, d)
        hs = jax.nn.silu(xt @ p["shared_gate"].astype(dt)) * (
            xt @ p["shared_up"].astype(dt))
        out = out + (hs @ p["shared_down"].astype(dt)).reshape(B, S, d)

    out = logical_constraint(out, "batch", "seq", None)
    return out, aux


def _apply_moe_flat_unused(p, x, cfg: ModelConfig):
    """(kept for reference: the original flat-token dispatch — replicates
    dispatch buffers across the mesh; see §Perf)"""
    B, S, d = x.shape
    T = B * S
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    xt = x.reshape(T, d)

    logits = (xt @ p["router"].astype(jnp.float32)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)              # (T, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- load-balance auxiliary loss (Switch-style) ----------------------
    me = jnp.mean(probs, axis=0)                                  # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, E, dtype=jnp.float32), axis=1),
        axis=0)
    aux = E * jnp.sum(me * ce)

    # ---- sort-based dispatch --------------------------------------------
    C = int(math.ceil(T * K / E * cfg.moe_capacity_factor))
    C = max(C, 1)
    flat_expert = expert_idx.reshape(-1)                          # (T*K,)
    flat_token = jnp.repeat(jnp.arange(T), K)
    flat_gate = gate_vals.reshape(-1)

    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_gate = flat_gate[order]

    # rank of each entry within its expert group
    ar = jnp.arange(T * K)
    is_start = jnp.concatenate(
        [jnp.ones((1,), jnp.int32),
         (sorted_expert[1:] != sorted_expert[:-1]).astype(jnp.int32)])
    group_start = jax.lax.associative_scan(jnp.maximum, ar * is_start)
    rank = ar - group_start
    keep = rank < C

    dest = jnp.where(keep, sorted_expert * C + rank, E * C)       # E*C = trash
    buf = jnp.zeros((E * C + 1, d), cfg.compute_dtype)
    buf = buf.at[dest].set(xt[sorted_token].astype(cfg.compute_dtype))
    xe = buf[:E * C].reshape(E, C, d)
    xe = logical_constraint(xe, "expert", None, None)

    ye = _expert_mlp(p, xe, cfg).reshape(E * C, d)

    # ---- combine ----------------------------------------------------------
    contrib = ye[jnp.minimum(dest, E * C - 1)] * (
        sorted_gate * keep)[:, None].astype(ye.dtype)
    out = jnp.zeros((T, d), ye.dtype).at[sorted_token].add(contrib)

    if cfg.num_shared_experts:
        dt = cfg.compute_dtype
        h = jax.nn.silu(xt @ p["shared_gate"].astype(dt)) * (
            xt @ p["shared_up"].astype(dt))
        out = out + h @ p["shared_down"].astype(dt)

    out = out.reshape(B, S, d)
    return logical_constraint(out, "batch", "seq", None), aux
