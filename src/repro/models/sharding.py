"""Logical-axis sharding for the repro framework.

We annotate tensors with *logical* axis names; a ``ShardingRules`` table maps
each logical name to zero or more mesh axes.  ``logical_constraint`` applies a
``with_sharding_constraint`` inside jit, silently dropping any mapping whose
mesh-axis product does not divide the tensor dimension (e.g. 25 attention
heads over a 16-way ``model`` axis) — XLA's SPMD propagation then picks the
layout.  Outside a mesh context everything is a no-op so the same model code
runs on a single CPU device in tests.

This mirrors how MaxText/t5x handle logical axes, in ~100 lines.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

# Default logical->mesh rules for the production meshes.  "pod" appears only
# in the multi-pod mesh; missing axes are dropped automatically.
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),       # data parallel (DiLoCo worker = pod)
    "fsdp": ("data",),              # parameter dim sharded ZeRO-3 style
    "model": ("model",),            # tensor parallel
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ffn": ("model",),
    "expert": (),                   # experts replicated (FSDP on inner dims)
    "seq": (),                      # sequence not sharded (no context parallel)
    "stack": (),                    # scan-stacked layer dim
    "state": (),
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Dict[str, Tuple[str, ...]] = dict(DEFAULT_RULES)


_CTX = _Ctx()


@contextlib.contextmanager
def sharding_ctx(mesh: Optional[Mesh], rules: Optional[Dict[str, Tuple[str, ...]]] = None):
    """Activate a mesh + logical rules for model code executed inside."""
    prev_mesh, prev_rules = _CTX.mesh, _CTX.rules
    _CTX.mesh = mesh
    _CTX.rules = dict(DEFAULT_RULES)
    if rules:
        _CTX.rules.update(rules)
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev_mesh, prev_rules


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def _mesh_axes_for(logical: Optional[str], mesh: Mesh) -> Tuple[str, ...]:
    if logical is None:
        return ()
    axes = _CTX.rules.get(logical, ())
    if isinstance(axes, str):
        axes = (axes,)
    return tuple(a for a in axes if a in mesh.shape)


def spec_for(dim_names: Sequence[Optional[str]],
             dims: Optional[Sequence[int]] = None,
             mesh: Optional[Mesh] = None) -> P:
    """Resolve logical dim names to a PartitionSpec, enforcing divisibility."""
    mesh = mesh or _CTX.mesh
    if mesh is None:
        return P()
    out = []
    used: set = set()
    for i, name in enumerate(dim_names):
        axes = _mesh_axes_for(name, mesh)
        axes = tuple(a for a in axes if a not in used)
        if axes and dims is not None:
            prod = 1
            for a in axes:
                prod *= mesh.shape[a]
            if dims[i] % prod != 0:
                axes = ()
        used.update(axes)
        if not axes:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(tuple(axes))
    return P(*out)


def logical_constraint(x: jax.Array, *dim_names: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without a mesh."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    assert len(dim_names) == x.ndim, (dim_names, x.shape)
    spec = spec_for(dim_names, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(dim_names: Sequence[Optional[str]],
                   dims: Optional[Sequence[int]] = None,
                   mesh: Optional[Mesh] = None) -> Optional[NamedSharding]:
    mesh = mesh or _CTX.mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, spec_for(dim_names, dims, mesh))


def tree_shardings(logical_tree, shape_tree, mesh: Mesh):
    """Map a pytree of logical-name tuples + matching ShapeDtypeStructs to
    NamedShardings (used for pjit in/out shardings in the launcher)."""
    return jax.tree.map(
        lambda names, sds: NamedSharding(mesh, spec_for(names, sds.shape, mesh)),
        logical_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            n is None or isinstance(n, str) for n in x),
    )
