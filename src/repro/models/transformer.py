"""Model assembly: decoder-only LM (dense / MoE / SSM / hybrid blocks),
encoder-decoder (audio), and VLM variants behind one functional ``ModelAPI``.

Layers are parameter-stacked and executed with ``lax.scan`` (+ optional
``jax.checkpoint``), so HLO size and compile time are O(1) in depth — a hard
requirement for the 88-layer mistral-large dry-run on a single CPU host.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (Px, apply_mlp, apply_norm, embed,
                                 init_embedding, init_mlp, init_norm, is_px,
                                 param, softmax_cross_entropy, split_logical,
                                 unembed)
from repro.models.sharding import logical_constraint


# ---------------------------------------------------------------------------
# Stacked-layer helpers
# ---------------------------------------------------------------------------

def init_stack(key, n_layers: int, init_layer: Callable):
    trees = [init_layer(k) for k in jax.random.split(key, n_layers)]

    def stack(*leaves):
        return Px(jnp.stack([l.value for l in leaves]),
                  ("stack",) + tuple(leaves[0].names))

    return jax.tree.map(stack, *trees, is_leaf=is_px)


def layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    """Per-layer attention windows; 0 = global.  Shape (L,) int32."""
    L = cfg.num_layers
    if cfg.window_pattern:
        pat = list(cfg.window_pattern)
        ws = [pat[i % len(pat)] for i in range(L)]
    else:
        ws = [cfg.window] * L
    return jnp.asarray(ws, jnp.int32)


def _effective_window(w_scalar, seq_hint: int):
    """Traced per-layer window -> value usable in masks (0 -> no limit)."""
    return jnp.where(w_scalar > 0, w_scalar, jnp.int32(2 ** 30))


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"ln1": init_norm(ks[0], cfg.d_model, cfg)}
    if cfg.arch_type == "ssm":
        p["mamba"] = ssm_mod.init_mamba(ks[1], cfg)
        return p
    p["attn"] = attn.init_attention(ks[1], cfg)
    p["ln2"] = init_norm(ks[2], cfg.d_model, cfg)
    if cfg.hybrid:
        hd = cfg.resolved_head_dim()
        d_inner = cfg.num_heads * hd
        p["mamba"] = ssm_mod.init_mamba(ks[3], cfg, d_inner=d_inner)
        p["mix_a"] = param(ks[4], (cfg.d_model,), (None,), init="ones")
        p["mix_s"] = param(ks[4], (cfg.d_model,), (None,), init="ones")
    if cfg.num_experts:
        p["moe"] = moe_mod.init_moe(ks[5], cfg)
    else:
        p["mlp"] = init_mlp(ks[5], cfg)
    return p


def _block_fwd(p, h, cfg: ModelConfig, positions, window, impl=None):
    """Full-sequence block.  Returns (h, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.arch_type == "ssm":
        x = apply_norm(p["ln1"], h, cfg)
        return h + ssm_mod.apply_mamba(p["mamba"], x, cfg), aux
    x = apply_norm(p["ln1"], h, cfg)
    a = attn.attention(p["attn"], x, cfg, positions=positions, window=window,
                       impl=impl)
    if cfg.hybrid:
        hd = cfg.resolved_head_dim()
        s = ssm_mod.apply_mamba(p["mamba"], x, cfg,
                                d_inner=cfg.num_heads * hd)
        a = 0.5 * (_chan_norm(a, cfg) * p["mix_a"].astype(a.dtype)
                   + _chan_norm(s, cfg) * p["mix_s"].astype(a.dtype))
    h = h + a
    x = apply_norm(p["ln2"], h, cfg)
    if cfg.num_experts:
        y, aux = moe_mod.apply_moe(p["moe"], x, cfg)
    else:
        y = apply_mlp(p["mlp"], x, cfg)
    return h + y, aux


def _chan_norm(x, cfg):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + cfg.norm_eps)).astype(x.dtype)


def _block_decode(p, h, cfg: ModelConfig, cache, position, window):
    """One-token block step against the layer cache."""
    if cfg.arch_type == "ssm":
        x = apply_norm(p["ln1"], h, cfg)
        y, new = ssm_mod.decode_mamba(p["mamba"], x, cfg, cache["mamba"])
        return h + y, {"mamba": new}
    new_cache = dict(cache)
    x = apply_norm(p["ln1"], h, cfg)
    w = _effective_window(window, 0)
    a, new_attn = attn.decode_attention(
        p["attn"], x, cfg, cache["attn"], position=position, window=w)
    new_cache["attn"] = new_attn
    if cfg.hybrid:
        hd = cfg.resolved_head_dim()
        s, new_m = ssm_mod.decode_mamba(p["mamba"], x, cfg, cache["mamba"],
                                        d_inner=cfg.num_heads * hd)
        new_cache["mamba"] = new_m
        a = 0.5 * (_chan_norm(a, cfg) * p["mix_a"].astype(a.dtype)
                   + _chan_norm(s, cfg) * p["mix_s"].astype(a.dtype))
    h = h + a
    x = apply_norm(p["ln2"], h, cfg)
    if cfg.num_experts:
        y, _ = moe_mod.apply_moe(p["moe"], x, cfg)
    else:
        y = apply_mlp(p["mlp"], x, cfg)
    return h + y, new_cache


# ---------------------------------------------------------------------------
# Decoder-only LM
# ---------------------------------------------------------------------------

def init_lm(key, cfg: ModelConfig):
    k_emb, k_layers, k_fin = jax.random.split(key, 3)
    p = {
        "embed": init_embedding(k_emb, cfg),
        "layers": init_stack(k_layers, cfg.num_layers,
                             lambda k: _init_block(k, cfg)),
        "final_norm": init_norm(k_fin, cfg.d_model, cfg),
    }
    if cfg.is_encoder_decoder:
        k_enc, k_cross = jax.random.split(k_emb)
        enc_cfg = cfg
        p["encoder"] = init_stack(
            k_enc, cfg.num_encoder_layers,
            lambda k: {
                "ln1": init_norm(k, cfg.d_model, cfg),
                "attn": attn.init_attention(k, enc_cfg),
                "ln2": init_norm(k, cfg.d_model, cfg),
                "mlp": init_mlp(k, enc_cfg),
            })
        p["enc_norm"] = init_norm(k_enc, cfg.d_model, cfg)
        p["cross"] = init_stack(
            k_cross, cfg.num_layers,
            lambda k: {
                "ln": init_norm(k, cfg.d_model, cfg),
                "attn": attn.init_attention(k, enc_cfg, cross=True),
            })
    return p


def _run_layers(params, h, cfg: ModelConfig, positions, *,
                memory: Optional[jax.Array] = None, impl=None):
    """scan over stacked layers (+ optional cross-attention interleave).

    Uniform-window configs pass the window STATICALLY (enabling the banded
    O(S·W) attention path); heterogeneous ``window_pattern`` configs thread
    per-layer windows through the scan as traced scalars."""
    heterogeneous = bool(cfg.window_pattern)
    windows = layer_windows(cfg) if heterogeneous else None
    static_w = (cfg.window if cfg.window else None) if not heterogeneous \
        else None

    def body(carry, xs):
        if heterogeneous:
            if memory is not None:
                lp, cp, w = xs
            else:
                lp, w = xs
            w = _effective_window(w, h.shape[1])
        else:
            if memory is not None:
                lp, cp = xs
            else:
                lp = xs
            w = static_w
        hh, aux_acc = carry
        hh, aux = _block_fwd_pre_cross(lp, cp, hh, cfg, positions, w,
                                       memory, impl) if memory is not None \
            else _block_fwd(lp, hh, cfg, positions, w, impl)
        return (hh, aux_acc + aux), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    if memory is not None:
        xs = (params["layers"], params["cross"], windows) if heterogeneous \
            else (params["layers"], params["cross"])
    else:
        xs = (params["layers"], windows) if heterogeneous \
            else params["layers"]
    (h, aux), _ = jax.lax.scan(body_fn, (h, jnp.zeros((), jnp.float32)), xs)
    return h, aux


def _block_fwd_pre_cross(lp, cp, h, cfg, positions, w, memory, impl):
    """Decoder block with cross-attention inserted after self-attention."""
    h, aux = _block_fwd_selfattn_only(lp, h, cfg, positions, w, impl)
    x = apply_norm(cp["ln"], h, cfg)
    h = h + attn.attention(cp["attn"], x, cfg, positions=positions,
                           memory=memory)
    x = apply_norm(lp["ln2"], h, cfg)
    y = apply_mlp(lp["mlp"], x, cfg)
    return h + y, aux


def _block_fwd_selfattn_only(p, h, cfg, positions, window, impl):
    x = apply_norm(p["ln1"], h, cfg)
    a = attn.attention(p["attn"], x, cfg, positions=positions, window=window,
                       impl=impl)
    return h + a, jnp.zeros((), jnp.float32)


def encode(params, frames, cfg: ModelConfig):
    """Bidirectional encoder over (stubbed) frame embeddings (B,S,d)."""
    h = frames.astype(cfg.compute_dtype)

    def body(hh, lp):
        x = apply_norm(lp["ln1"], hh, cfg)
        s = attn._project_qkv(lp["attn"], x, x, cfg)
        q, k, v = s
        out = attn._direct(q, k, v, None)
        out = out.reshape(hh.shape[0], hh.shape[1], -1)
        hh = hh + out @ lp["attn"]["wo"].astype(cfg.compute_dtype)
        x = apply_norm(lp["ln2"], hh, cfg)
        return hh + apply_mlp(lp["mlp"], x, cfg), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(body_fn, h, params["encoder"])
    return apply_norm(params["enc_norm"], h, cfg)


def forward_hidden(params, batch: Dict[str, jax.Array], cfg: ModelConfig,
                   impl: Optional[str] = None) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward up to the final norm.  Returns (h, aux_loss)."""
    tokens = batch["tokens"]
    h = embed(params["embed"], tokens, cfg)
    memory = None
    if cfg.is_encoder_decoder:
        memory = encode(params, batch["frames"], cfg)
    if cfg.num_image_tokens:
        patches = batch["patches"].astype(cfg.compute_dtype)
        h = jnp.concatenate([patches, h], axis=1)
        h = logical_constraint(h, "batch", "seq", None)
    positions = jnp.arange(h.shape[1])
    h, aux = _run_layers(params, h, cfg, positions, memory=memory, impl=impl)
    h = apply_norm(params["final_norm"], h, cfg)
    if cfg.num_image_tokens:
        h = h[:, cfg.num_image_tokens:]
    return h, aux


def forward_lm(params, batch: Dict[str, jax.Array], cfg: ModelConfig,
               impl: Optional[str] = None) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  Returns (logits, aux_loss)."""
    h, aux = forward_hidden(params, batch, cfg, impl=impl)
    logits = unembed(params["embed"], h, cfg)
    return logits, aux


def _chunked_ce(params, h, labels, cfg: ModelConfig) -> jax.Array:
    """Cross-entropy without materializing the full (B,S,V) logits: scan
    over sequence chunks, projecting each chunk to the vocab separately.
    Peak logits memory drops S/chunk-fold — the memory-term fix for
    256k-vocab configs (see EXPERIMENTS.md §Perf)."""
    B, S, d = h.shape
    C = min(cfg.loss_chunk, S)
    pad = (-S) % C
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    n = (S + pad) // C
    hc = h.reshape(B, n, C, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, C).transpose(1, 0, 2)

    def body(carry, xs):
        tot, cnt = carry
        h_i, l_i = xs
        logits = unembed(params["embed"], h_i, cfg).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(l_i, 0)[..., None], axis=-1)[..., 0]
        ce = lse - gold
        if cfg.z_loss:
            ce = ce + cfg.z_loss * jnp.square(lse)
        valid = (l_i >= 0).astype(jnp.float32)
        return (tot + jnp.sum(ce * valid), cnt + jnp.sum(valid)), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, lc))
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(params, batch, cfg: ModelConfig) -> Tuple[jax.Array, Dict]:
    if cfg.loss_chunk:
        h, aux = forward_hidden(params, batch, cfg)
        ce = _chunked_ce(params, h, batch["labels"], cfg)
    else:
        logits, aux = forward_lm(params, batch, cfg)
        ce = softmax_cross_entropy(logits, batch["labels"], z_loss=cfg.z_loss)
    loss = ce + cfg.router_aux_coef * aux if cfg.num_experts else ce
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_decode_cache(cfg: ModelConfig, batch: int, capacity: int,
                      dtype=None) -> Dict[str, Any]:
    """Stacked (L, ...) caches.  ``capacity`` is the KV length for attention
    archs (window size for ring-buffer SWA decode); SSM state is O(1)."""
    L = cfg.num_layers

    def stacked(make):
        one = make()
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (L,) + x.shape), one)

    cache: Dict[str, Any] = {}
    if cfg.arch_type == "ssm":
        cache["mamba"] = stacked(lambda: ssm_mod.init_mamba_cache(cfg, batch, dtype=dtype))
        return cache
    cache["attn"] = stacked(lambda: attn.init_cache(cfg, batch, capacity, dtype=dtype))
    if cfg.hybrid:
        hd = cfg.resolved_head_dim()
        cache["mamba"] = stacked(
            lambda: ssm_mod.init_mamba_cache(cfg, batch,
                                             d_inner=cfg.num_heads * hd,
                                             dtype=dtype))
    if cfg.is_encoder_decoder:
        hd = cfg.resolved_head_dim()
        dt = dtype or cfg.compute_dtype
        cache["cross"] = {
            "k": jnp.zeros((L, batch, cfg.encoder_seq_len, cfg.num_kv_heads, hd), dt),
            "v": jnp.zeros((L, batch, cfg.encoder_seq_len, cfg.num_kv_heads, hd), dt),
        }
    return cache


def decode_step_lm(params, cache, batch, cfg: ModelConfig
                   ) -> Tuple[jax.Array, Dict[str, Any]]:
    """One decode step.  batch: {"token": (B,1) int32, "position": scalar/(B,)}.
    Returns (logits (B,1,V), new_cache)."""
    token, position = batch["token"], batch["position"]
    h = embed(params["embed"], token, cfg)
    windows = layer_windows(cfg)

    if cfg.is_encoder_decoder:
        def body(hh, xs):
            lp, cp, lc, cc, w = xs
            x = apply_norm(lp["ln1"], hh, cfg)
            a, new_attn = attn.decode_attention(lp["attn"], x, cfg, lc,
                                                position=position,
                                                window=_effective_window(w, 0))
            hh = hh + a
            x = apply_norm(cp["ln"], hh, cfg)
            c, _ = attn.decode_attention(cp["attn"], x, cfg, None,
                                         position=position,
                                         memory_cache=cc)
            hh = hh + c
            x = apply_norm(lp["ln2"], hh, cfg)
            hh = hh + apply_mlp(lp["mlp"], x, cfg)
            return hh, new_attn

        h, new_attn = jax.lax.scan(
            body, h, (params["layers"], params["cross"], cache["attn"],
                      cache["cross"], windows))
        new_cache = {"attn": new_attn, "cross": cache["cross"]}
    else:
        def body(hh, xs):
            lp, lc, w = xs
            hh, new = _block_decode(lp, hh, cfg, lc, position, w)
            return hh, new

        layer_cache = {k: v for k, v in cache.items()}
        h, new_cache = jax.lax.scan(body, h, (params["layers"], layer_cache,
                                              windows))
    h = apply_norm(params["final_norm"], h, cfg)
    logits = unembed(params["embed"], h, cfg)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Paged decode (continuous-batching serving path)
# ---------------------------------------------------------------------------

def paged_cache_supported(cfg: ModelConfig) -> bool:
    """The paged pool stores attention K/V only; position-gating cannot mask
    an SSM recurrence (state updates are unconditional), and cross-attention
    memories are per-request, so ssm/hybrid/encoder-decoder archs stay on the
    static-bucket path."""
    return (cfg.arch_type != "ssm" and not cfg.hybrid
            and not cfg.is_encoder_decoder)


def paged_block_bytes(cfg: ModelConfig, block_size: int) -> int:
    """Bytes one physical KV block costs across ALL layers — the unit the
    byte-budget pool sizing and the scheduler's capacity report use.
    Quantized pools pay the narrow payload plus the f32 per-token-per-head
    scale planes."""
    hd = cfg.resolved_head_dim()
    dt = attn.kv_pool_dtype(cfg)
    per_layer = 2 * block_size * cfg.num_kv_heads * hd * dt.itemsize
    if attn.kv_quant_dtype(cfg) is not None:
        per_layer += 2 * block_size * cfg.num_kv_heads * 4
    return cfg.num_layers * per_layer


def init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                     dtype=None) -> Dict[str, jax.Array]:
    """A pool of ``num_blocks`` fixed-size KV blocks shared by all serving
    slots, stacked over layers: (L, NB, bs, KV, hd).

    ``cfg.kv_cache_dtype`` picks the storage format: "" / bf16 / f32 pools
    are plain arrays in that dtype ("" = compute dtype, or the ``dtype``
    override); int8 / fp8 / fp8_e5m2 pools store the narrow payload plus
    ``k_scale`` / ``v_scale`` (L, NB, bs, KV) f32 per-token-per-head amax
    scales, quantized on scatter and dequantized on load by the attention
    layer (``dtype`` is ignored — the wire format is the config's)."""
    if not paged_cache_supported(cfg):
        raise NotImplementedError(
            f"paged KV cache unsupported for arch {cfg.arch_type!r} "
            f"(hybrid={cfg.hybrid}, enc-dec={cfg.is_encoder_decoder})")
    hd = cfg.resolved_head_dim()
    shape = (cfg.num_layers, num_blocks, block_size, cfg.num_kv_heads, hd)
    if attn.kv_quant_dtype(cfg) is not None:
        dt = attn.kv_pool_dtype(cfg)
        return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
                "k_scale": jnp.zeros(shape[:-1], jnp.float32),
                "v_scale": jnp.zeros(shape[:-1], jnp.float32)}
    dt = dtype or (attn.kv_pool_dtype(cfg) if cfg.kv_cache_dtype
                   else cfg.compute_dtype)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def _paged_layers(params, h, pool, cfg: ModelConfig, positions, block_table,
                  impl=None):
    """Scan the stacked layers over the paged pool.  h: (S, 1, d);
    positions: (S,); block_table: (S, MB).  Returns (h, new pool).

    Uniform-window configs keep the window STATIC (so the Pallas block-table
    kernel can specialize on it); heterogeneous ``window_pattern`` configs
    thread traced per-layer windows through the scan (jnp path only)."""
    heterogeneous = bool(cfg.window_pattern)
    windows = layer_windows(cfg) if heterogeneous else None
    static_w = None if heterogeneous else (cfg.window or None)
    quantized = "k_scale" in pool
    # XLA CPU moves fp8 arrays through scan slice/stack via per-element
    # convert paths (~70x a 1-byte memcpy); thread fp8 pools through the
    # scan as their uint8 bit patterns and reinterpret inside the body.
    narrow = pool["k"].dtype
    carrier = quantized and narrow in (jnp.float8_e4m3fn, jnp.float8_e5m2)
    pk, pv = pool["k"], pool["v"]
    if carrier:
        pk = jax.lax.bitcast_convert_type(pk, jnp.uint8)
        pv = jax.lax.bitcast_convert_type(pv, jnp.uint8)

    def body(hh, xs):
        if heterogeneous:
            *rest, w = xs
            w = _effective_window(w, 0)
        else:
            rest, w = xs, static_w
        if quantized:
            lp, kc, vc, ks, vs = rest
            if carrier:
                kc = jax.lax.bitcast_convert_type(kc, narrow)
                vc = jax.lax.bitcast_convert_type(vc, narrow)
        else:
            (lp, kc, vc), (ks, vs) = rest, (None, None)
        x = apply_norm(lp["ln1"], hh, cfg)
        out = attn.paged_decode_attention(
            lp["attn"], x, cfg, kc, vc, positions=positions,
            block_table=block_table, window=w, impl=impl,
            k_scale=ks, v_scale=vs)
        a, new_kv = out[0], out[1:]
        if carrier:
            new_kv = (jax.lax.bitcast_convert_type(new_kv[0], jnp.uint8),
                      jax.lax.bitcast_convert_type(new_kv[1], jnp.uint8),
                      ) + tuple(new_kv[2:])
        hh = hh + a
        x = apply_norm(lp["ln2"], hh, cfg)
        if cfg.num_experts:
            y, _ = moe_mod.apply_moe(lp["moe"], x, cfg)
        else:
            y = apply_mlp(lp["mlp"], x, cfg)
        return hh + y, new_kv

    xs = (params["layers"], pk, pv)
    if quantized:
        xs = xs + (pool["k_scale"], pool["v_scale"])
    h, new_kv = jax.lax.scan(body, h, xs + (windows,) if heterogeneous
                             else xs)
    keys = ("k", "v", "k_scale", "v_scale") if quantized else ("k", "v")
    out_pool = dict(zip(keys, new_kv))
    if carrier:
        out_pool["k"] = jax.lax.bitcast_convert_type(out_pool["k"], narrow)
        out_pool["v"] = jax.lax.bitcast_convert_type(out_pool["v"], narrow)
    return h, out_pool


def decode_step_paged(params, pool, batch, cfg: ModelConfig,
                      impl: Optional[str] = None
                      ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step over the active slot set.  batch: {"token": (S,1)
    int32, "position": (S,) int32 (−1 = inactive slot), "block_table":
    (S, MB) int32}.  Returns (logits (S,1,V), new pool)."""
    h = embed(params["embed"], batch["token"], cfg)
    h, pool = _paged_layers(params, h, pool, cfg, batch["position"],
                            batch["block_table"], impl=impl)
    h = apply_norm(params["final_norm"], h, cfg)
    return unembed(params["embed"], h, cfg), pool


def verify_step_paged(params, pool, batch, cfg: ModelConfig,
                      impl: Optional[str] = None
                      ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Multi-token verification step (speculative decoding / batched
    prefill): every slot feeds up to T scripted tokens at contiguous
    positions and gets logits for ALL of them from ONE forward pass.

    batch: {"tokens": (S, T) int32, "positions": (S, T) int32 — the
    absolute position of each token, −1 for padding tokens and inactive
    slots (live positions must be a contiguous prefix of the row),
    "block_table": (S, MB) int32}.  Returns (logits (S, T, V), new pool).
    """
    h = embed(params["embed"], batch["tokens"], cfg)
    h, pool = _paged_layers(params, h, pool, cfg, batch["positions"],
                            batch["block_table"], impl=impl)
    h = apply_norm(params["final_norm"], h, cfg)
    return unembed(params["embed"], h, cfg), pool


# ---------------------------------------------------------------------------
# Model API
# ---------------------------------------------------------------------------

class ModelAPI(NamedTuple):
    cfg: ModelConfig
    init: Callable            # key -> Px tree
    loss: Callable            # (params, batch) -> (loss, metrics)
    forward: Callable         # (params, batch) -> (logits, aux)
    init_cache: Callable      # (batch, capacity) -> cache
    decode_step: Callable     # (params, cache, batch) -> (logits, cache)
    init_paged_cache: Callable  # (num_blocks, block_size) -> pool
    decode_step_paged: Callable  # (params, pool, batch) -> (logits, pool)
    verify_step_paged: Callable  # (params, pool, batch) -> (logits, pool)


def build_model(cfg: ModelConfig) -> ModelAPI:
    return ModelAPI(
        cfg=cfg,
        init=lambda key: init_lm(key, cfg),
        loss=lambda params, batch: lm_loss(params, batch, cfg),
        forward=lambda params, batch: forward_lm(params, batch, cfg),
        init_cache=lambda batch, capacity, dtype=None: init_decode_cache(
            cfg, batch, capacity, dtype=dtype),
        decode_step=lambda params, cache, batch: decode_step_lm(
            params, cache, batch, cfg),
        init_paged_cache=lambda num_blocks, block_size, dtype=None:
            init_paged_cache(cfg, num_blocks, block_size, dtype=dtype),
        decode_step_paged=lambda params, pool, batch, impl=None:
            decode_step_paged(params, pool, batch, cfg, impl=impl),
        verify_step_paged=lambda params, pool, batch, impl=None:
            verify_step_paged(params, pool, batch, cfg, impl=impl),
    )


def init_params(cfg: ModelConfig, key) -> Tuple[Any, Any]:
    """Materialized (params, logical_names)."""
    tree = init_lm(key, cfg)
    return split_logical(tree)


def abstract_params(cfg: ModelConfig) -> Tuple[Any, Any]:
    """(ShapeDtypeStruct params, logical-name tree) with **no allocation** —
    the dry-run path.  Names are static, so they are captured through the
    eval_shape trace."""
    captured = {}

    def capture(key):
        tree = init_lm(key, cfg)
        params, names = split_logical(tree)
        captured["names"] = names
        return params

    params_sds = jax.eval_shape(capture, jax.random.key(0))
    return params_sds, captured["names"]
