"""Mamba-2 (SSD — state-space duality) block: chunked training/prefill scan,
single-step decode, depthwise conv, gated RMSNorm.

The chunked SSD algorithm (Dao & Gu, arXiv:2405.21060) splits the sequence
into chunks of Q tokens: an intra-chunk quadratic (attention-like) term plus
an inter-chunk linear recurrence over per-chunk states.  This file is the
pure-jnp reference; ``repro.kernels.ssd`` holds the Pallas TPU kernel for the
intra-chunk term and must match it bit-for-bit in interpret mode.

Shapes: x (B,S,H,P) heads×head_dim, dt (B,S,H), A (H,), B/C (B,S,N) (single
group, as in mamba2-1.3b).  State h is (B,H,N,P).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import param
from repro.models.sharding import logical_constraint


# ---------------------------------------------------------------------------
# Core SSD math (reference)
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, A, Bm, Cm, D, chunk: int,
                h0: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.  Returns (y, final_state).

    x: (B,S,H,P) float; dt: (B,S,H) >=0; A: (H,) negative; Bm/Cm: (B,S,N);
    D: (H,); h0: (B,H,N,P) or None.
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    f32 = jnp.float32
    x32, dt32 = x.astype(f32), dt.astype(f32)
    Bm32, Cm32 = Bm.astype(f32), Cm.astype(f32)

    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x32 = jnp.pad(x32, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt32 = jnp.pad(dt32, ((0, 0), (0, pad), (0, 0)))
        Bm32 = jnp.pad(Bm32, ((0, 0), (0, pad), (0, 0)))
        Cm32 = jnp.pad(Cm32, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q

    xc = logical_constraint(x32.reshape(Bsz, nc, Q, H, P),
                            "batch", None, None, "heads", None)
    dtc = logical_constraint(dt32.reshape(Bsz, nc, Q, H),
                             "batch", None, None, "heads")
    Bc = Bm32.reshape(Bsz, nc, Q, N)
    Cc = Cm32.reshape(Bsz, nc, Q, N)

    dA = dtc * A.astype(f32)                      # (B,nc,Q,H), <= 0
    cum = jnp.cumsum(dA, axis=2)                  # inclusive within-chunk
    xbar = xc * dtc[..., None]

    # intra-chunk: y_i = sum_{j<=i} (C_i.B_j) exp(cum_i - cum_j) xbar_j
    CB = jnp.einsum("bnqN,bnkN->bnqk", Cc, Bc)
    cumT = cum.transpose(0, 1, 3, 2)              # (B,nc,H,Q)
    L = jnp.exp(cumT[..., :, None] - cumT[..., None, :])
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(mask, L, 0.0)
    M = logical_constraint(CB[:, :, None] * L,    # (B,nc,H,Q,Q)
                           "batch", None, "heads", None, None)
    y_intra = logical_constraint(
        jnp.einsum("bnhqk,bnkhp->bnqhp", M, xbar),
        "batch", None, None, "heads", None)

    # per-chunk state contribution: S_c = sum_j exp(cum_last - cum_j) B_j xbar_j
    # NOTE: pre-scale xbar by the decay, then contract k with a single
    # dot_general — the naive 3-operand einsum materializes a (k, N, h)
    # intermediate that is ~16x larger than either operand (measured 61.8
    # GiB/device on mamba2-1.3b train_4k; see EXPERIMENTS.md §Perf).
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,nc,Q,H)
    xbar_dec = xbar * decay_end[..., None]        # (B,nc,Q,H,P)
    S_c = logical_constraint(
        jnp.einsum("bnkN,bnkhp->bnhNp", Bc, xbar_dec),
        "batch", None, "heads", None, None)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cum[:, :, -1, :])       # (B,nc,H)
    h_init = (jnp.zeros((Bsz, H, N, P), f32) if h0 is None
              else h0.astype(f32))

    def step(h, inp):
        dcy, s_c = inp                            # (B,H), (B,H,N,P)
        h_prev = h
        h = dcy[..., None, None] * h + s_c
        return h, h_prev

    hT, h_prevs = jax.lax.scan(
        step, h_init,
        (chunk_decay.transpose(1, 0, 2), S_c.transpose(1, 0, 2, 3, 4)))
    h_prevs = logical_constraint(
        h_prevs.transpose(1, 0, 2, 3, 4),         # (B,nc,H,N,P)
        "batch", None, "heads", None, None)

    # contract N first (output-sized result), then scale by the decay — same
    # association-order fix as S_c above.
    y_inter = jnp.einsum("bnqN,bnhNp->bnqhp", Cc, h_prevs) \
        * jnp.exp(cum)[..., None]

    y = (y_intra + y_inter).reshape(Bsz, Sp, H, P)[:, :S]
    y = y + x32[:, :S] * D.astype(f32)[None, None, :, None]
    return y.astype(x.dtype), hT.astype(f32)


def ssd_decode_step(h, x, dt, A, Bm, Cm, D):
    """One-token SSD update.  h: (B,H,N,P); x: (B,H,P); dt: (B,H);
    Bm/Cm: (B,N).  Returns (y, h_new)."""
    f32 = jnp.float32
    a = jnp.exp(dt.astype(f32) * A.astype(f32))                  # (B,H)
    xbar = x.astype(f32) * dt.astype(f32)[..., None]             # (B,H,P)
    h_new = (a[..., None, None] * h.astype(f32)
             + jnp.einsum("bN,bhp->bhNp", Bm.astype(f32), xbar))
    y = jnp.einsum("bN,bhNp->bhp", Cm.astype(f32), h_new)
    y = y + x.astype(f32) * D.astype(f32)[None, :, None]
    return y.astype(x.dtype), h_new


# ---------------------------------------------------------------------------
# Mamba-2 block
# ---------------------------------------------------------------------------

def mamba_dims(cfg: ModelConfig, d_inner: Optional[int] = None):
    d_in = d_inner or cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    N = cfg.ssm_state_size
    conv_dim = d_in + 2 * N
    return d_in, H, N, conv_dim


def init_mamba(key, cfg: ModelConfig, d_inner: Optional[int] = None):
    d = cfg.d_model
    d_in, H, N, conv_dim = mamba_dims(cfg, d_inner)
    ks = jax.random.split(key, 6)
    return {
        # order: [z(d_in), x(d_in), B(N), C(N), dt(H)]
        "in_proj": param(ks[0], (d, 2 * d_in + 2 * N + H), ("fsdp", "heads")),
        "conv_w": param(ks[1], (cfg.ssm_conv_width, conv_dim), (None, "heads"),
                        scale=1.0 / math.sqrt(cfg.ssm_conv_width)),
        "conv_b": param(ks[1], (conv_dim,), (None,), init="zeros"),
        "A_log": param(ks[2], (H,), (None,), init="ssm_a"),
        "D": param(ks[3], (H,), (None,), init="ones"),
        "dt_bias": param(ks[4], (H,), (None,), init="ssm_dt"),
        "norm_scale": param(ks[5], (d_in,), (None,), init="ones"),
        "out_proj": param(ks[5], (d_in, d), ("heads", "fsdp"),
                          scale=1.0 / math.sqrt(d_in)),
    }


def _split_proj(zxbcdt, d_in, N, H):
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in:2 * d_in + 2 * N]
    dt = zxbcdt[..., 2 * d_in + 2 * N:]
    return z, xBC, dt


def _gated_norm(p, y, z, eps):
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    return (y * jax.lax.rsqrt(var + eps)) * p["norm_scale"].astype(jnp.float32)


def apply_mamba(p, x, cfg: ModelConfig, d_inner: Optional[int] = None,
                use_pallas: Optional[bool] = None) -> jax.Array:
    """Full-sequence (training / prefill) mamba-2 block.  x: (B,S,d)."""
    Bsz, S, d = x.shape
    d_in, H, N, conv_dim = mamba_dims(cfg, d_inner)
    dt_c = cfg.compute_dtype
    zxbcdt = logical_constraint(x @ p["in_proj"].astype(dt_c),
                                "batch", "seq", "heads")
    z, xBC, dt_raw = _split_proj(zxbcdt, d_in, N, H)

    # depthwise causal conv over the (x,B,C) channels
    w = p["conv_w"].astype(jnp.float32)                      # (W, conv_dim)
    W = w.shape[0]
    xp = jnp.pad(xBC.astype(jnp.float32), ((0, 0), (W - 1, 0), (0, 0)))
    conv = sum(xp[:, i:i + S] * w[i] for i in range(W)) + p["conv_b"].astype(jnp.float32)
    xBC = logical_constraint(jax.nn.silu(conv), "batch", "seq", "heads")

    xs = xBC[..., :d_in].reshape(Bsz, S, H, cfg.ssm_head_dim)
    Bm = xBC[..., d_in:d_in + N]
    Cm = xBC[..., d_in + N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if use_pallas if use_pallas is not None else cfg.use_pallas:
        from repro.kernels.ssd import ops as ssd_ops
        y, _ = ssd_ops.ssd(xs, dt, A, Bm, Cm, p["D"], chunk=cfg.ssm_chunk)
    else:
        y, _ = ssd_chunked(xs, dt, A, Bm, Cm, p["D"], chunk=cfg.ssm_chunk)
    y = y.reshape(Bsz, S, d_in).astype(jnp.float32)
    y = _gated_norm(p, y, z, cfg.norm_eps)
    out = y.astype(dt_c) @ p["out_proj"].astype(dt_c)
    return logical_constraint(out, "batch", "seq", None)


# ---------------------------------------------------------------------------
# Decode (stateful, single token)
# ---------------------------------------------------------------------------

def init_mamba_cache(cfg: ModelConfig, batch: int,
                     d_inner: Optional[int] = None, dtype=None):
    d_in, H, N, conv_dim = mamba_dims(cfg, d_inner)
    dt = dtype or jnp.float32
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dt),
        "ssm": jnp.zeros((batch, H, N, cfg.ssm_head_dim), dt),
    }


def mamba_cache_logical_names():
    return {"conv": ("batch", None, "heads"),
            "ssm": ("batch", "heads", "state", None)}


def decode_mamba(p, x, cfg: ModelConfig, cache: Dict[str, Any],
                 d_inner: Optional[int] = None
                 ) -> Tuple[jax.Array, Dict[str, Any]]:
    """One-token mamba step.  x: (B,1,d)."""
    Bsz = x.shape[0]
    d_in, H, N, conv_dim = mamba_dims(cfg, d_inner)
    dt_c = cfg.compute_dtype
    zxbcdt = x[:, 0] @ p["in_proj"].astype(dt_c)             # (B, ·)
    z, xBC, dt_raw = _split_proj(zxbcdt, d_in, N, H)

    # conv ring: window = [cache_conv, new]
    w = p["conv_w"].astype(jnp.float32)
    win = jnp.concatenate(
        [cache["conv"].astype(jnp.float32), xBC.astype(jnp.float32)[:, None]],
        axis=1)                                               # (B, W, conv_dim)
    conv = jnp.einsum("bwc,wc->bc", win, w) + p["conv_b"].astype(jnp.float32)
    xBC_c = jax.nn.silu(conv)
    new_conv = win[:, 1:]

    xs = xBC_c[..., :d_in].reshape(Bsz, H, cfg.ssm_head_dim)
    Bm = xBC_c[..., d_in:d_in + N]
    Cm = xBC_c[..., d_in + N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    y, h_new = ssd_decode_step(cache["ssm"], xs, dt, A, Bm, Cm, p["D"])
    y = y.reshape(Bsz, d_in).astype(jnp.float32)
    y = _gated_norm(p, y, z, cfg.norm_eps)
    out = (y.astype(dt_c) @ p["out_proj"].astype(dt_c))[:, None]
    out = logical_constraint(out, "batch", "seq", None)
    return out, {"conv": new_conv.astype(cache["conv"].dtype), "ssm": h_new}
