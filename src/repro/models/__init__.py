from repro.models.transformer import (ModelAPI, abstract_params, build_model,
                                      init_params)

__all__ = ["ModelAPI", "build_model", "init_params", "abstract_params"]
