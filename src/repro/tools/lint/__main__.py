"""CLI: ``python -m repro.tools.lint [paths] [--json] [--select a,b]``.

Exit codes: 0 clean, 1 violations found, 2 usage/parse trouble.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from repro.tools.lint.core import LintError, default_passes, run_lint
from repro.tools.lint.reporter import render_human, render_json


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tools.lint",
        description="replint: JAX/Pallas correctness linter for this repo")
    ap.add_argument("paths", nargs="*", default=["src", "tests"],
                    help="files or directories to lint (default: src tests)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit a JSON report instead of human-readable text")
    ap.add_argument("--select", default=None,
                    help="comma-separated pass names to run (default: all)")
    ap.add_argument("--list-passes", action="store_true",
                    help="list available passes and exit")
    args = ap.parse_args(argv)

    if args.list_passes:
        for p in default_passes():
            print(f"{p.name:24s} {p.description}")
        return 0

    select = ([s.strip() for s in args.select.split(",") if s.strip()]
              if args.select else None)
    try:
        violations, files, errors = run_lint(args.paths, select=select)
    except LintError as e:
        print(f"replint: {e}", file=sys.stderr)
        return 2
    report = (render_json if args.as_json else render_human)(
        violations, files, errors)
    print(report)
    if errors:
        return 2
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
