"""replint core: pass registry, file walking, suppression, orchestration.

An AST-based static-analysis framework for the repo's JAX/Pallas
correctness idioms.  The contracts the test suite guards *dynamically*
(chunked-vs-per-step bit-exactness, kernel-vs-oracle, codec round-trips)
all have a static shadow — an edit pattern that breaks them — and each
lint pass rejects one such pattern at review time:

* ``donate-safety``        — a value passed to a donating jit and read again
* ``retrace-hazard``       — per-call retraces / non-hashable static args
* ``prng-discipline``      — PRNG key reuse and literal keys in library code
* ``host-sync-in-hot-path``— device->host syncs inside the training chunk
                             loop or the serving step loop
* ``kernel-contract``      — kernels/<name>/ packaging: ops/kernel/ref files,
                             shared interpret resolution, oracle-backed tests

Suppression syntax (both spellings, comma-separated pass names, ``all``):

* ``# replint: disable=<pass>[,<pass>]``       — this line only
* ``# replint: disable-file=<pass>[,<pass>]``  — the whole file

Fixture corpora live in directories named ``lint_fixtures`` — they exist to
*contain* violations, so the default walker skips them; the self-tests lint
them explicitly via ``lint_file``/``check_file``.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set

# Directory names the default walker never descends into.
SKIP_DIRS = {"__pycache__", ".git", ".github", "lint_fixtures",
             ".pytest_cache", ".hypothesis", "build", "dist"}

_SUPPRESS_RE = re.compile(
    r"#\s*replint:\s*(disable|disable-file)=([A-Za-z0-9_,-]+)")


@dataclasses.dataclass(frozen=True, order=True)
class Violation:
    """One finding.  ``line`` is 1-indexed in ``path``."""
    path: str
    line: int
    col: int
    pass_name: str
    message: str

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"[{self.pass_name}] {self.message}")

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


class LintError(Exception):
    """A file could not be linted (syntax error, unreadable)."""


@dataclasses.dataclass
class FileContext:
    """Parsed view of one source file, shared by every per-file pass."""
    path: str
    src: str
    tree: ast.Module
    # line -> set of pass names suppressed on that line ('all' wildcard kept)
    line_suppressions: Dict[int, Set[str]]
    file_suppressions: Set[str]

    @classmethod
    def parse(cls, path: str, src: Optional[str] = None) -> "FileContext":
        if src is None:
            src = Path(path).read_text()
        try:
            tree = ast.parse(src, filename=path)
        except SyntaxError as e:
            raise LintError(f"{path}: syntax error at line {e.lineno}: "
                            f"{e.msg}") from e
        line_sup: Dict[int, Set[str]] = {}
        file_sup: Set[str] = set()
        for i, line in enumerate(src.splitlines(), start=1):
            for kind, names in _SUPPRESS_RE.findall(line):
                parsed = {n.strip() for n in names.split(",") if n.strip()}
                if kind == "disable-file":
                    file_sup |= parsed
                else:
                    line_sup.setdefault(i, set()).update(parsed)
        return cls(path=path, src=src, tree=tree,
                   line_suppressions=line_sup, file_suppressions=file_sup)

    def suppressed(self, v: Violation) -> bool:
        if {"all", v.pass_name} & self.file_suppressions:
            return True
        on_line = self.line_suppressions.get(v.line, set())
        return bool({"all", v.pass_name} & on_line)


class LintPass:
    """Base class.  Per-file passes implement ``check_file``; repo-level
    passes (kernel-contract) implement ``check_project`` over the whole
    file set.  A pass may implement both."""

    name = "base"
    description = ""

    def check_file(self, ctx: FileContext) -> List[Violation]:
        return []

    def check_project(self, contexts: Sequence[FileContext],
                      root: Optional[Path]) -> List[Violation]:
        return []


def find_repo_root(start: Path) -> Optional[Path]:
    """Nearest ancestor carrying a pyproject.toml (or .git)."""
    p = start.resolve()
    if p.is_file():
        p = p.parent
    for cand in (p, *p.parents):
        if (cand / "pyproject.toml").exists() or (cand / ".git").exists():
            return cand
    return None


def iter_python_files(paths: Iterable[str]) -> List[Path]:
    """Expand files/directories into a sorted, deduplicated .py file list,
    skipping ``SKIP_DIRS`` (fixture corpora included — they exist to hold
    violations)."""
    out: Set[Path] = set()
    for p in paths:
        path = Path(p)
        if path.is_file():
            if path.suffix == ".py":
                out.add(path)
        elif path.is_dir():
            for f in path.rglob("*.py"):
                if not any(part in SKIP_DIRS for part in f.parts):
                    out.add(f)
        else:
            raise LintError(f"no such file or directory: {p}")
    return sorted(out)


def default_passes() -> List[LintPass]:
    from repro.tools.lint.passes import build_passes
    return build_passes()


def select_passes(names: Optional[Sequence[str]]) -> List[LintPass]:
    passes = default_passes()
    if not names:
        return passes
    by_name = {p.name: p for p in passes}
    unknown = [n for n in names if n not in by_name]
    if unknown:
        raise LintError(f"unknown pass(es): {', '.join(unknown)}; "
                        f"available: {', '.join(sorted(by_name))}")
    return [by_name[n] for n in names]


def check_file(ctx: FileContext,
               passes: Optional[Sequence[LintPass]] = None
               ) -> List[Violation]:
    """Run per-file passes over one parsed file (no project-level passes,
    no suppression filtering — callers filter via ``ctx.suppressed``)."""
    out: List[Violation] = []
    for p in passes if passes is not None else default_passes():
        out.extend(p.check_file(ctx))
    return out


def lint_file(path: str, passes: Optional[Sequence[LintPass]] = None,
              src: Optional[str] = None) -> List[Violation]:
    """Lint one file (per-file passes only), honoring suppressions."""
    ctx = FileContext.parse(path, src)
    return sorted((v for v in check_file(ctx, passes)
                   if not ctx.suppressed(v)),
                  key=lambda v: (v.path, v.line, v.col, v.pass_name))


def run_lint(paths: Sequence[str],
             select: Optional[Sequence[str]] = None,
             root: Optional[Path] = None):
    """Lint ``paths`` with the selected passes (default: all).

    Returns ``(violations, files, errors)`` where ``errors`` is a list of
    human-readable parse-failure strings (a parse failure never aborts the
    whole run)."""
    passes = select_passes(select)
    files = iter_python_files(paths)
    contexts: List[FileContext] = []
    errors: List[str] = []
    for f in files:
        try:
            contexts.append(FileContext.parse(str(f)))
        except LintError as e:
            errors.append(str(e))
    violations: List[Violation] = []
    for ctx in contexts:
        violations.extend(v for v in check_file(ctx, passes)
                          if not ctx.suppressed(v))
    if root is None and files:
        root = find_repo_root(files[0])
    by_path = {ctx.path: ctx for ctx in contexts}
    for p in passes:
        for v in p.check_project(contexts, root):
            ctx = by_path.get(v.path)
            if ctx is None or not ctx.suppressed(v):
                violations.append(v)
    violations.sort(key=lambda v: (v.path, v.line, v.col, v.pass_name))
    return violations, [str(f) for f in files], errors
