"""Human and JSON reporters for replint results."""
from __future__ import annotations

import json
from typing import List, Sequence

from repro.tools.lint.core import Violation


def render_human(violations: Sequence[Violation], files: Sequence[str],
                 errors: Sequence[str]) -> str:
    lines: List[str] = [v.format() for v in violations]
    lines.extend(f"ERROR: {e}" for e in errors)
    n = len(violations)
    noun = "violation" if n == 1 else "violations"
    lines.append(f"replint: {n} {noun} in {len(files)} files"
                 + (f" ({len(errors)} files failed to parse)"
                    if errors else ""))
    return "\n".join(lines)


def render_json(violations: Sequence[Violation], files: Sequence[str],
                errors: Sequence[str]) -> str:
    return json.dumps({
        "violations": [v.to_json() for v in violations],
        "files_checked": len(files),
        "errors": list(errors),
    }, indent=2, sort_keys=True)
