"""prng-discipline: key reuse and literal keys in library code.

* **key-reuse** — within one function scope, the same key name is consumed
  by two ``jax.random.*`` sampling calls (or by ``split`` without rebinding
  the key) with no intervening reassignment.  Reusing a key yields
  correlated samples; ``fold_in``/``clone`` are non-consuming and fine.

* **literal-key** — ``jax.random.key(<const>)`` / ``PRNGKey(<const>)`` in
  library code (paths under ``src/``).  The repo's streams are
  ``(seed, rid, position)``-derived; a hard-coded literal bypasses seed
  threading and silently decorrelates nothing across workers.  Exemption:
  keys inside ``jax.eval_shape(...)`` arguments (abstract evaluation only —
  no randomness is ever generated).  Tests/benchmarks/examples may use
  literal seeds freely.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.tools.lint.core import FileContext, LintPass, Violation
from repro.tools.lint.passes import _astutil as A

# Non-consuming producers/utilities: using a key here is not a "draw".
_PRODUCERS = {"key", "PRNGKey", "fold_in", "clone", "wrap_key_data",
              "key_data", "key_impl", "default_prng_impl"}

_KEYISH_PARAMS = {"key", "rng", "rngs", "prng", "prng_key", "root_key"}


def _key_expr(node: ast.expr) -> Optional[str]:
    """'key' for a Name, 'keys[0]' for a const-subscript of a Name."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Subscript) and isinstance(node.value, ast.Name) \
            and isinstance(node.slice, ast.Constant):
        return f"{node.value.id}[{node.slice.value!r}]"
    return None


def _scope_body(fn: ast.AST):
    """(node, branch_path) for one scope, excluding nested
    function/class/lambda bodies.  ``branch_path`` is a tuple of
    ``(branch_point_id, arm_index)`` for each enclosing If/Try arm — two
    nodes on different arms of the same branch point never execute
    together, so consuming the same key in each is fine."""
    todo = [(c, ()) for c in ast.iter_child_nodes(fn)]
    while todo:
        node, path = todo.pop(0)
        yield node, path
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda, ast.ClassDef)):
            continue
        if isinstance(node, ast.If):
            todo.extend((c, path) for c in (node.test,))
            todo.extend((c, path + ((id(node), 0),)) for c in node.body)
            todo.extend((c, path + ((id(node), 1),)) for c in node.orelse)
        elif isinstance(node, ast.Try):
            todo.extend((c, path + ((id(node), 0),))
                        for c in (*node.body, *node.orelse))
            for i, h in enumerate(node.handlers, start=1):
                todo.extend((c, path + ((id(node), i),))
                            for c in ast.iter_child_nodes(h))
            todo.extend((c, path) for c in node.finalbody)
        else:
            todo.extend((c, path) for c in ast.iter_child_nodes(node))


def _compatible(p1, p2) -> bool:
    """True if the two branch paths can lie on one execution path."""
    arms = dict(p1)
    return all(arms.get(bp, arm) == arm for bp, arm in p2)


class PrngDisciplinePass(LintPass):
    name = "prng-discipline"
    description = ("PRNG key consumed twice without split/fold_in, or a "
                   "literal key in library code")

    def _resolve_random(self, node: ast.Call,
                        imports: Dict[str, str]) -> Optional[str]:
        fname = A.dotted_name(node.func)
        if fname is None:
            return None
        full = A.resolve_dotted(fname, imports)
        if full.startswith("jax.random."):
            return full[len("jax.random."):]
        return None

    def _check_reuse(self, ctx: FileContext, scope: ast.AST,
                     imports: Dict[str, str],
                     params: Tuple[str, ...]) -> List[Violation]:
        # events: (line, col, kind, key, branch_path)
        events: List[Tuple[int, int, str, str, tuple]] = []
        key_like = {p for p in params
                    if p in _KEYISH_PARAMS or p.endswith(("_key", "_rng"))}

        for node, path in _scope_body(scope):
            if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign,
                                 ast.NamedExpr)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                names: List[str] = []
                for t in targets:
                    names.extend(A.flatten_targets(t))
                    ke = _key_expr(t)
                    if ke is not None and ke not in names:
                        names.append(ke)
                value = getattr(node, "value", None)
                from_producer = False
                if isinstance(value, ast.Call):
                    rnd = self._resolve_random(value, imports)
                    from_producer = rnd in ("key", "PRNGKey", "fold_in",
                                            "split", "clone")
                elif isinstance(value, ast.Subscript) and \
                        isinstance(value.value, ast.Call):
                    rnd = self._resolve_random(value.value, imports)
                    from_producer = rnd == "split"
                for n in names:
                    events.append((node.lineno, node.col_offset,
                                   "store", n, path))
                    if from_producer:
                        key_like.add(n)
            if isinstance(node, ast.Call):
                rnd = self._resolve_random(node, imports)
                if rnd is None or not node.args:
                    continue
                ke = _key_expr(node.args[0])
                if ke is None:
                    continue
                if rnd == "split":
                    # split(key) without rebinding key consumes it
                    rebinds = any(e[2] == "store" and e[3] == ke
                                  and e[0] == node.lineno for e in events)
                    if not rebinds:
                        events.append((node.lineno, node.col_offset,
                                       "consume", ke, path))
                    continue
                if rnd in _PRODUCERS:
                    continue
                events.append((node.lineno, node.col_offset,
                               "consume", ke, path))

        events.sort(key=lambda e: (e[0], e[1]))
        out: List[Violation] = []
        # key -> list of (line, col, branch_path) of still-live consumes
        live: Dict[str, List[Tuple[int, int, tuple]]] = {}
        for line, col, kind, key, path in events:
            if kind == "store":
                # a rebind of 'keys' also kills live draws from 'keys[i]'
                for k in list(live):
                    if k == key or k.startswith(key + "["):
                        live[k] = [c for c in live[k]
                                   if not _compatible(c[2], path)]
            elif key in key_like or key.split("[")[0] in key_like:
                clash = next((c for c in live.get(key, [])
                              if _compatible(c[2], path)), None)
                if clash is not None:
                    out.append(Violation(
                        path=ctx.path, line=line, col=col,
                        pass_name=self.name,
                        message=(f"key '{key}' already consumed at line "
                                 f"{clash[0]} and is drawn from again "
                                 f"without an intervening split/fold_in "
                                 f"— samples will be correlated")))
                live.setdefault(key, []).append((line, col, path))
        return out

    def _in_eval_shape(self, parents: List[ast.AST],
                       imports: Dict[str, str]) -> bool:
        for p in parents:
            if isinstance(p, ast.Call):
                fname = A.dotted_name(p.func)
                if fname and A.resolve_dotted(fname, imports) == \
                        "jax.eval_shape":
                    return True
        return False

    def check_file(self, ctx: FileContext) -> List[Violation]:
        imports = A.import_table(ctx.tree)
        out: List[Violation] = []

        out.extend(self._check_reuse(ctx, ctx.tree, imports, ()))
        for fn, _cls in A.functions_with_class(ctx.tree):
            params = tuple(a.arg for a in (*fn.args.posonlyargs,
                                           *fn.args.args,
                                           *fn.args.kwonlyargs))
            out.extend(self._check_reuse(ctx, fn, imports, params))

        parts = Path(ctx.path).parts
        if "src" in parts:
            for node, parents in A.walk_with_parents(ctx.tree):
                if not isinstance(node, ast.Call):
                    continue
                rnd = self._resolve_random(node, imports)
                if rnd not in ("key", "PRNGKey"):
                    continue
                if not (node.args and isinstance(node.args[0], ast.Constant)):
                    continue
                if self._in_eval_shape(parents, imports):
                    continue
                out.append(Violation(
                    path=ctx.path, line=node.lineno, col=node.col_offset,
                    pass_name=self.name,
                    message=(f"literal PRNG key jax.random.{rnd}"
                             f"({node.args[0].value!r}) in library code; "
                             f"thread a seed from config/CLI so streams "
                             f"stay (seed, rid, position)-derived")))
        return out
