"""The five repo-specific passes.  ``build_passes`` is the registry the
core consults; order here is the report order for same-line findings."""
from __future__ import annotations

from typing import List

from repro.tools.lint.core import LintPass


def build_passes() -> List[LintPass]:
    from repro.tools.lint.passes.donate_safety import DonateSafetyPass
    from repro.tools.lint.passes.host_sync import HostSyncPass
    from repro.tools.lint.passes.kernel_contract import KernelContractPass
    from repro.tools.lint.passes.prng_discipline import PrngDisciplinePass
    from repro.tools.lint.passes.retrace_hazard import RetraceHazardPass
    return [DonateSafetyPass(), RetraceHazardPass(), PrngDisciplinePass(),
            HostSyncPass(), KernelContractPass()]
