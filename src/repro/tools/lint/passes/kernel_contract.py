"""kernel-contract: packaging rules for ``src/repro/kernels/<name>/``.

Every kernel package must

1. ship the three-file layout — ``ops.py`` (public jitted wrappers),
   ``kernel.py`` (the Pallas kernel), ``ref.py`` (the jnp oracle);
2. resolve its interpret default through the shared helper
   (``from repro.kernels.common import default_interpret/resolve_interpret``)
   rather than a private copy — one ``REPRO_PALLAS_INTERPRET`` override
   point for the whole repo;
3. be exercised by at least one test under ``tests/`` that imports its
   ``reference_*`` oracle (or the ``ref`` module) — the kernel-vs-oracle
   comparison is the repo's correctness contract for compiled TPU runs.

This is a *project* pass: it inspects the tree under the repo root
directly, so it fires even when only a subset of files is linted.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Optional, Sequence, Set

from repro.tools.lint.core import (FileContext, LintPass, Violation,
                                   SKIP_DIRS)

_COMMON = "repro.kernels.common"
_COMMON_NAMES = {"default_interpret", "resolve_interpret", "pallas_mode"}
_REQUIRED_FILES = ("ops.py", "kernel.py", "ref.py")


class KernelContractPass(LintPass):
    name = "kernel-contract"
    description = ("kernels/<name>/ must ship ops/kernel/ref, use the "
                   "shared interpret helper, and have an oracle-backed test")

    def __init__(self, kernels_rel: str = "src/repro/kernels",
                 tests_rel: str = "tests") -> None:
        self.kernels_rel = kernels_rel
        self.tests_rel = tests_rel

    def _oracle_packages(self, tests_dir: Path) -> Set[str]:
        """Kernel package names whose ref oracle some test imports."""
        found: Set[str] = set()
        if not tests_dir.is_dir():
            return found
        for f in tests_dir.rglob("*.py"):
            if any(part in SKIP_DIRS
                   for part in f.relative_to(tests_dir).parts):
                continue
            try:
                tree = ast.parse(f.read_text(), filename=str(f))
            except SyntaxError:
                continue
            for node in ast.walk(tree):
                if isinstance(node, ast.ImportFrom) and node.module and \
                        node.module.startswith("repro.kernels."):
                    parts = node.module.split(".")
                    pkg = parts[2]
                    if len(parts) > 3 and parts[3] == "ref":
                        found.add(pkg)
                        continue
                    for a in node.names:
                        if a.name == "ref" or \
                                a.name.startswith("reference"):
                            found.add(pkg)
                elif isinstance(node, ast.Import):
                    for a in node.names:
                        parts = a.name.split(".")
                        if len(parts) >= 4 and parts[:2] == \
                                ["repro", "kernels"] and parts[3] == "ref":
                            found.add(parts[2])
        return found

    def check_project(self, contexts: Sequence[FileContext],
                      root: Optional[Path]) -> List[Violation]:
        if root is None:
            return []
        kernels_dir = root / self.kernels_rel
        if not kernels_dir.is_dir():
            return []
        oracled = self._oracle_packages(root / self.tests_rel)
        out: List[Violation] = []
        for pkg in sorted(kernels_dir.iterdir()):
            if not pkg.is_dir() or not (pkg / "__init__.py").exists():
                continue
            anchor = str(pkg / "__init__.py")
            missing = [f for f in _REQUIRED_FILES if not (pkg / f).exists()]
            if missing:
                out.append(Violation(
                    path=anchor, line=1, col=0, pass_name=self.name,
                    message=(f"kernel package '{pkg.name}' is missing "
                             f"{', '.join(missing)}; the contract is "
                             f"ops.py (jitted wrappers) + kernel.py "
                             f"(Pallas) + ref.py (jnp oracle)")))
            ops = pkg / "ops.py"
            if ops.exists():
                out.extend(self._check_ops(ops))
            if pkg.name not in oracled:
                out.append(Violation(
                    path=str(ops if ops.exists() else pkg / "__init__.py"),
                    line=1, col=0, pass_name=self.name,
                    message=(f"no test under {self.tests_rel}/ imports "
                             f"'{pkg.name}'s ref oracle (a reference_* "
                             f"name or the ref module); every kernel "
                             f"needs a kernel-vs-oracle test")))
        return out

    def _check_ops(self, ops: Path) -> List[Violation]:
        try:
            tree = ast.parse(ops.read_text(), filename=str(ops))
        except SyntaxError as e:
            return [Violation(path=str(ops), line=e.lineno or 1, col=0,
                              pass_name=self.name,
                              message=f"ops.py does not parse: {e.msg}")]
        out: List[Violation] = []
        imports_common = False
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom) and node.module == _COMMON \
                    and any(a.name in _COMMON_NAMES for a in node.names):
                imports_common = True
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name in _COMMON_NAMES:
                out.append(Violation(
                    path=str(ops), line=node.lineno, col=node.col_offset,
                    pass_name=self.name,
                    message=(f"ops.py defines a private '{node.name}'; "
                             f"use the shared copy in {_COMMON} so "
                             f"REPRO_PALLAS_INTERPRET has one override "
                             f"point")))
        if not imports_common:
            out.append(Violation(
                path=str(ops), line=1, col=0, pass_name=self.name,
                message=(f"ops.py does not import "
                         f"default_interpret/resolve_interpret from "
                         f"{_COMMON}; interpret defaults must be "
                         f"backend-selected through the shared helper")))
        return out
