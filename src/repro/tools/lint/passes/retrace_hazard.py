"""retrace-hazard: patterns that defeat the repo's one-compile contract.

Two checks:

* **construct-in-loop** — ``jax.jit(...)`` or ``pallas_call(...)`` invoked
  lexically inside a ``for``/``while`` body (with no intervening function
  boundary).  Every iteration builds a fresh callable with an empty cache,
  so every iteration retraces and recompiles.  Hoist the construction out
  of the loop.

* **non-hashable-static** — a list/dict/set/comprehension literal passed in
  a ``static_argnums``/``static_argnames`` position of a locally-registered
  jit product.  Static args are cache keys; non-hashables raise at call
  time, and per-call-varying values retrace silently.  Pass a tuple (or
  hash the config up front).
"""
from __future__ import annotations

import ast
from typing import List, Optional

from repro.tools.lint.core import FileContext, LintPass, Violation
from repro.tools.lint.passes import _astutil as A

_CONSTRUCTORS = {
    "jax.jit": "jax.jit",
    "jax.experimental.pallas.pallas_call": "pallas_call",
}

_NON_HASHABLE = (ast.List, ast.Dict, ast.Set,
                 ast.ListComp, ast.SetComp, ast.DictComp, ast.GeneratorExp)


def _loop_enclosing(parents: List[ast.AST]) -> Optional[ast.AST]:
    """Innermost loop ancestor (for/while/comprehension) with no function
    boundary in between."""
    for p in reversed(parents):
        if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            return None
        if isinstance(p, (ast.For, ast.AsyncFor, ast.While, ast.ListComp,
                          ast.SetComp, ast.DictComp, ast.GeneratorExp)):
            return p
    return None


class RetraceHazardPass(LintPass):
    name = "retrace-hazard"
    description = ("jit/pallas_call built inside a loop, or non-hashable "
                   "literals in static arg positions")

    def check_file(self, ctx: FileContext) -> List[Violation]:
        imports = A.import_table(ctx.tree)
        registry = A.JitRegistry.scan(ctx.tree, imports)
        out: List[Violation] = []
        cls_stack_cache = {}

        for node, parents in A.walk_with_parents(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            fname = A.dotted_name(node.func)
            resolved = A.resolve_dotted(fname, imports) if fname else None

            if resolved in _CONSTRUCTORS:
                loop = _loop_enclosing(parents)
                if loop is not None:
                    out.append(Violation(
                        path=ctx.path, line=node.lineno,
                        col=node.col_offset, pass_name=self.name,
                        message=(f"{_CONSTRUCTORS[resolved]} constructed "
                                 f"inside the loop at line {loop.lineno}; "
                                 f"each iteration gets a fresh callable "
                                 f"and retraces — hoist it out of the "
                                 f"loop")))

            # static-arg check at call sites of known jit products
            cls_name = None
            for p in reversed(parents):
                if isinstance(p, ast.ClassDef):
                    cls_name = cls_stack_cache.setdefault(id(p), p.name)
                    break
            info = registry.lookup(node, cls_name)
            if info is None:
                continue
            for i, arg in enumerate(node.args):
                if i in info.static_argnums and \
                        isinstance(arg, _NON_HASHABLE):
                    out.append(Violation(
                        path=ctx.path, line=arg.lineno, col=arg.col_offset,
                        pass_name=self.name,
                        message=(f"non-hashable literal in static position "
                                 f"{i} of '{info.target}'; static args are "
                                 f"cache keys — pass a tuple or hashable "
                                 f"config")))
            for kw in node.keywords:
                if kw.arg in info.static_argnames and \
                        isinstance(kw.value, _NON_HASHABLE):
                    out.append(Violation(
                        path=ctx.path, line=kw.value.lineno,
                        col=kw.value.col_offset, pass_name=self.name,
                        message=(f"non-hashable literal for static arg "
                                 f"'{kw.arg}' of '{info.target}'; static "
                                 f"args are cache keys — pass a tuple or "
                                 f"hashable config")))
        return out
