"""host-sync-in-hot-path: device->host synchronization inside the training
chunk loop or the serving step loop.

The repo's throughput story is "one fetch per chunk" (``dist_trainer``'s
module-level ``_fetch``) and "batched fetches per engine step".  Any stray
``.item()``, ``float()``, ``np.asarray``, ``jax.device_get`` or
``block_until_ready`` on a device value inside those loops serializes the
dispatch pipeline.

This is a *project* pass: it builds a heuristic call graph over the linted
file set, BFS-es from the hot roots —

    DistTrainer.run / DistTrainer._run_per_step
    Engine.run / Engine._run_spec

— and flags, inside any reachable function:

* ``.item()`` / ``.tolist()`` / ``.block_until_ready()`` calls;
* ``np.asarray`` / ``np.array`` / ``jax.device_get`` /
  ``jax.block_until_ready`` / ``float`` / ``int`` applied to a value
  produced by a registered jit callable (directly or via one assignment
  hop).

Allowlist: any callee literally named ``_fetch`` — that is the documented
once-per-chunk fetch point; values routed through it count as host-side.
Nested function bodies are skipped (they are usually jit-traced closures,
where these ops are traced, not synced).
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.tools.lint.core import FileContext, LintPass, Violation
from repro.tools.lint.passes import _astutil as A

HOT_ROOTS = {("DistTrainer", "run"), ("DistTrainer", "_run_per_step"),
             ("Engine", "run"), ("Engine", "_run_spec")}

_SYNC_METHODS = {"item", "tolist", "block_until_ready"}
_SYNC_FUNCS = {"numpy.asarray", "numpy.array", "jax.device_get",
               "jax.block_until_ready"}
_SYNC_BUILTINS = {"float", "int"}


@dataclasses.dataclass
class _Func:
    module: str
    cls: Optional[str]
    name: str
    node: ast.AST
    path: str
    imports: Dict[str, str]
    registry: A.JitRegistry


def _module_name(path: str, root: Optional[Path]) -> str:
    p = Path(path)
    if root is not None:
        try:
            rel = p.resolve().relative_to(root.resolve())
        except ValueError:
            rel = p
        parts = list(rel.with_suffix("").parts)
        if parts and parts[0] == "src":
            parts = parts[1:]
        return ".".join(parts)
    return p.stem


def _own_body(fn: ast.AST):
    """Statements/expressions of ``fn`` excluding nested def/lambda bodies."""
    todo = list(ast.iter_child_nodes(fn))
    while todo:
        node = todo.pop(0)
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda, ast.ClassDef)):
            todo.extend(ast.iter_child_nodes(node))


class HostSyncPass(LintPass):
    name = "host-sync-in-hot-path"
    description = ("device->host sync reachable from the chunk/step hot "
                   "loops; route through the module's _fetch")

    def check_project(self, contexts: Sequence[FileContext],
                      root: Optional[Path]) -> List[Violation]:
        funcs: List[_Func] = []
        by_bare: Dict[Tuple[str, str], _Func] = {}
        by_method: Dict[str, List[_Func]] = {}
        module_set: Set[str] = set()
        for ctx in contexts:
            mod = _module_name(ctx.path, root)
            module_set.add(mod)
            imports = A.import_table(ctx.tree)
            registry = A.JitRegistry.scan(ctx.tree, imports)
            for fn, cls in A.functions_with_class(ctx.tree):
                f = _Func(module=mod, cls=cls, name=fn.name, node=fn,
                          path=ctx.path, imports=imports, registry=registry)
                funcs.append(f)
                if cls is None:
                    by_bare.setdefault((mod, fn.name), f)
                else:
                    by_method.setdefault(fn.name, []).append(f)

        def edges(f: _Func) -> List[_Func]:
            out: List[_Func] = []
            for node in _own_body(f.node):
                if not isinstance(node, ast.Call):
                    continue
                name = A.dotted_name(node.func)
                if name is None:
                    continue
                if "." not in name:
                    tgt = by_bare.get((f.module, name))
                    if tgt is None:
                        full = f.imports.get(name)
                        if full and "." in full:
                            m, _, n = full.rpartition(".")
                            tgt = by_bare.get((m, n))
                    if tgt is not None:
                        out.append(tgt)
                elif name.startswith("self."):
                    attr = name[5:]
                    if "." in attr:
                        continue
                    same_cls = [m for m in by_method.get(attr, [])
                                if m.cls == f.cls and m.module == f.module]
                    out.extend(same_cls or by_method.get(attr, []))
                else:
                    head, _, rest = name.partition(".")
                    full_mod = f.imports.get(head)
                    if full_mod in module_set and "." not in rest:
                        tgt = by_bare.get((full_mod, rest))
                        if tgt is not None:
                            out.append(tgt)
                    elif "." not in rest:
                        # unknown receiver: fan out to every same-named method
                        out.extend(by_method.get(rest, []))
            return out

        roots = [f for f in funcs if (f.cls, f.name) in HOT_ROOTS]
        hot: List[_Func] = []
        seen: Set[int] = set()
        origin: Dict[int, str] = {}
        queue = list(roots)
        for r in roots:
            origin[id(r)] = f"{r.cls}.{r.name}"
        while queue:
            f = queue.pop(0)
            if id(f) in seen:
                continue
            seen.add(id(f))
            hot.append(f)
            for tgt in edges(f):
                if id(tgt) not in seen:
                    origin.setdefault(id(tgt), origin[id(f)])
                    queue.append(tgt)

        out: List[Violation] = []
        for f in hot:
            out.extend(self._check_hot(f, origin[id(f)]))
        return out

    def _check_hot(self, f: _Func, root_name: str) -> List[Violation]:
        out: List[Violation] = []
        device_vars: Set[str] = set()
        for node in _own_body(f.node):
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, (ast.Call, ast.Name,
                                            ast.Subscript, ast.Attribute)):
                names: List[str] = []
                for t in node.targets:
                    names.extend(A.flatten_targets(t))
                src = node.value
                tainted = False
                if isinstance(src, ast.Call):
                    callee = A.dotted_name(src.func) or ""
                    if callee.rsplit(".", 1)[-1] == "_fetch":
                        for n in names:
                            device_vars.discard(n)
                        continue
                    info = f.registry.lookup(
                        src, f.cls) if isinstance(src, ast.Call) else None
                    tainted = info is not None
                elif isinstance(src, ast.Name):
                    tainted = src.id in device_vars
                elif isinstance(src, (ast.Subscript, ast.Attribute)):
                    base = A.dotted_name(
                        src.value if isinstance(src, ast.Subscript)
                        else src.value)
                    tainted = base in device_vars
                if tainted:
                    device_vars.update(names)

            if not isinstance(node, ast.Call):
                continue
            fname = A.dotted_name(node.func)
            if fname is None:
                continue
            if fname.rsplit(".", 1)[-1] == "_fetch":
                continue
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr in _SYNC_METHODS and not node.args:
                out.append(Violation(
                    path=f.path, line=node.lineno, col=node.col_offset,
                    pass_name=self.name,
                    message=(f".{node.func.attr}() in "
                             f"'{f.name}', reachable from the "
                             f"{root_name} hot loop; it blocks on the "
                             f"device — route through _fetch and batch "
                             f"once per chunk/step")))
                continue
            resolved = A.resolve_dotted(fname, f.imports)
            is_sync = resolved in _SYNC_FUNCS or (
                fname in _SYNC_BUILTINS and fname not in f.imports)
            if not is_sync or not node.args:
                continue
            arg = node.args[0]
            arg_hot = (isinstance(arg, ast.Name) and arg.id in device_vars)
            if isinstance(arg, ast.Call):
                arg_hot = f.registry.lookup(arg, f.cls) is not None
            if arg_hot:
                out.append(Violation(
                    path=f.path, line=node.lineno, col=node.col_offset,
                    pass_name=self.name,
                    message=(f"{fname}(...) fetches a jit-produced value "
                             f"in '{f.name}', reachable from the "
                             f"{root_name} hot loop; route through "
                             f"_fetch and batch once per chunk/step")))
        return out
