"""Shared AST plumbing for the passes: dotted-name resolution, import
tables, and the jit-callable registry (who is a ``jax.jit`` product, what
does it donate, which args are static)."""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterator, List, Optional, Set, Tuple


def dotted_name(node: ast.AST) -> Optional[str]:
    """'jax.random.normal' for nested Attributes over a Name; 'self.x' for
    self-attributes; None for anything unresolvable (calls, subscripts)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def import_table(tree: ast.Module) -> Dict[str, str]:
    """local alias -> full dotted module/object path, from top-level and
    nested import statements."""
    table: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                table[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                if a.name != "*":
                    table[a.asname or a.name] = f"{node.module}.{a.name}"
    return table


def resolve_dotted(name: str, imports: Dict[str, str]) -> str:
    """Expand the leading alias of a dotted name through the import table:
    ``jr.normal`` -> ``jax.random.normal`` under ``import jax.random as jr``."""
    head, _, rest = name.partition(".")
    base = imports.get(head, head)
    return f"{base}.{rest}" if rest else base


def const_int_elts(node: ast.AST) -> Optional[Set[int]]:
    """The int elements of a literal tuple/list, or None if not literal.
    An ``X if c else ()`` conditional (the repo's donate-toggle idiom)
    resolves to whichever branch is a non-empty literal."""
    if isinstance(node, ast.IfExp):
        for branch in (node.body, node.orelse):
            got = const_int_elts(branch)
            if got:
                return got
        return set()
    if isinstance(node, (ast.Tuple, ast.List)):
        out: Set[int] = set()
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, int):
                out.add(e.value)
            else:
                return None
        return out
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return {node.value}
    return None


def const_str_elts(node: ast.AST) -> Optional[Set[str]]:
    """Same, for string tuples (static_argnames/donate_argnames)."""
    if isinstance(node, ast.IfExp):
        for branch in (node.body, node.orelse):
            got = const_str_elts(branch)
            if got:
                return got
        return set()
    if isinstance(node, (ast.Tuple, ast.List)):
        out: Set[str] = set()
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                out.add(e.value)
            else:
                return None
        return out
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return {node.value}
    return None


@dataclasses.dataclass
class JitInfo:
    """One ``<target> = jax.jit(...)`` product."""
    target: str                 # 'name' or 'self.attr'
    donate_argnums: Set[int]
    donate_argnames: Set[str]
    static_argnums: Set[int]
    static_argnames: Set[str]
    line: int


def _is_jax_jit(call: ast.Call, imports: Dict[str, str]) -> bool:
    name = dotted_name(call.func)
    if name is None:
        return False
    return resolve_dotted(name, imports) in ("jax.jit", "jax.api.jit")


def jit_info_from_call(call: ast.Call, target: str,
                       imports: Dict[str, str]) -> Optional[JitInfo]:
    if not _is_jax_jit(call, imports):
        return None
    info = JitInfo(target=target, donate_argnums=set(), donate_argnames=set(),
                   static_argnums=set(), static_argnames=set(),
                   line=call.lineno)
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            info.donate_argnums = const_int_elts(kw.value) or set()
        elif kw.arg == "donate_argnames":
            info.donate_argnames = const_str_elts(kw.value) or set()
        elif kw.arg == "static_argnums":
            info.static_argnums = const_int_elts(kw.value) or set()
        elif kw.arg == "static_argnames":
            info.static_argnames = const_str_elts(kw.value) or set()
    return info


@dataclasses.dataclass
class JitRegistry:
    """Module-wide map of jitted callables.

    * ``by_name``: bare names bound to a jit product anywhere in the module
      (module level or function-local — call sites are matched by name, so
      a local registry entry is visible to the whole module; in this
      codebase jit locals never shadow an unrelated same-name callable).
    * ``by_attr``: ``(class_name, attr)`` for ``self.<attr> = jax.jit(...)``
      made in any method of the class.
    """
    by_name: Dict[str, JitInfo]
    by_attr: Dict[Tuple[str, str], JitInfo]

    @classmethod
    def scan(cls, tree: ast.Module, imports: Dict[str, str]) -> "JitRegistry":
        by_name: Dict[str, JitInfo] = {}
        by_attr: Dict[Tuple[str, str], JitInfo] = {}

        def visit(node: ast.AST, cls_name: Optional[str]) -> None:
            for child in ast.iter_child_nodes(node):
                inner_cls = cls_name
                if isinstance(child, ast.ClassDef):
                    inner_cls = child.name
                if isinstance(child, ast.Assign) and \
                        isinstance(child.value, ast.Call):
                    for tgt in child.targets:
                        tname = dotted_name(tgt)
                        if tname is None:
                            continue
                        info = jit_info_from_call(child.value, tname, imports)
                        if info is None:
                            continue
                        if tname.startswith("self.") and cls_name:
                            by_attr[(cls_name, tname[5:])] = info
                        elif "." not in tname:
                            by_name[tname] = info
                visit(child, inner_cls)

        visit(tree, None)
        return cls(by_name=by_name, by_attr=by_attr)

    def lookup(self, call: ast.Call,
               cls_name: Optional[str]) -> Optional[JitInfo]:
        name = dotted_name(call.func)
        if name is None:
            return None
        if name.startswith("self.") and cls_name:
            return self.by_attr.get((cls_name, name[5:]))
        if "." not in name:
            return self.by_name.get(name)
        return None


def walk_with_parents(tree: ast.AST
                      ) -> Iterator[Tuple[ast.AST, List[ast.AST]]]:
    """Yield ``(node, ancestors)`` (outermost first) for every node."""
    stack: List[Tuple[ast.AST, List[ast.AST]]] = [(tree, [])]
    while stack:
        node, parents = stack.pop()
        yield node, parents
        child_parents = parents + [node]
        for child in ast.iter_child_nodes(node):
            stack.append((child, child_parents))


def functions_with_class(tree: ast.Module
                         ) -> Iterator[Tuple[ast.FunctionDef, Optional[str]]]:
    """Every (async) function def with its enclosing class name (innermost),
    including nested functions."""
    for node, parents in walk_with_parents(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            cls_name = None
            for p in reversed(parents):
                if isinstance(p, ast.ClassDef):
                    cls_name = p.name
                    break
                if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
            yield node, cls_name


def flatten_targets(target: ast.AST) -> List[str]:
    """Assignment-target names: ``a, (b, self.c) = ...`` -> [a, b, self.c]."""
    out: List[str] = []
    if isinstance(target, (ast.Tuple, ast.List)):
        for e in target.elts:
            out.extend(flatten_targets(e))
    elif isinstance(target, ast.Starred):
        out.extend(flatten_targets(target.value))
    else:
        name = dotted_name(target)
        if name is not None:
            out.append(name)
    return out
