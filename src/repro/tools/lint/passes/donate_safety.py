"""donate-safety: a value passed to a donate-marked jit callable must not
be read again in the same scope.

Donation invalidates the caller's buffer, so the only safe idioms after
``f = jax.jit(g, donate_argnums=(0,))`` are

* rebind in the same statement: ``state, aux = f(state, x)``
* never touch the donated name again (tail call / return).

The pass registers every ``<name> = jax.jit(..., donate_argnums=...)`` and
``self.<attr> = jax.jit(...)`` product (the ``(0,) if donate else ()``
toggle resolves to the donating branch), then checks each call site: a
donated argument that is a plain name or ``self.<attr>`` must either be
rebound by the enclosing statement or have no textually-later read before
its next rebind.

Known limitation (documented, not detected): a read at the *top* of a loop
body whose donating call sits *below* it is a runtime use-after-donate but
textually precedes the call.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Tuple

from repro.tools.lint.core import FileContext, LintPass, Violation
from repro.tools.lint.passes import _astutil as A


def _stmt_of(call: ast.Call, parents: List[ast.AST]) -> Optional[ast.stmt]:
    for p in reversed(parents):
        if isinstance(p, ast.stmt):
            return p
    return None


def _occurrences(fn: ast.AST) -> List[Tuple[int, int, str, bool]]:
    """(line, col, dotted_key, is_store) for every maximal Name/Attribute
    expression in ``fn`` (nested defs included — a closure read of a donated
    buffer is still a read)."""
    out: List[Tuple[int, int, str, bool]] = []
    for node, parents in A.walk_with_parents(fn):
        if isinstance(node, (ast.Name, ast.Attribute)):
            if parents and isinstance(parents[-1], ast.Attribute):
                continue  # not maximal: covered by the outer attribute
            key = A.dotted_name(node)
            if key is None:
                continue
            is_store = isinstance(getattr(node, "ctx", None),
                                  (ast.Store, ast.Del))
            out.append((node.lineno, node.col_offset, key, is_store))
    out.sort()
    return out


class DonateSafetyPass(LintPass):
    name = "donate-safety"
    description = ("value passed to a donate-marked jit callable and read "
                   "again in the same scope")

    def check_file(self, ctx: FileContext) -> List[Violation]:
        imports = A.import_table(ctx.tree)
        registry = A.JitRegistry.scan(ctx.tree, imports)
        if not any(i.donate_argnums or i.donate_argnames
                   for i in (*registry.by_name.values(),
                             *registry.by_attr.values())):
            return []
        out: List[Violation] = []
        for fn, cls_name in A.functions_with_class(ctx.tree):
            occ = None  # computed lazily, once per function
            for node, parents in A.walk_with_parents(fn):
                if not isinstance(node, ast.Call):
                    continue
                info = registry.lookup(node, cls_name)
                if info is None or not (info.donate_argnums
                                        or info.donate_argnames):
                    continue
                donated: List[Tuple[ast.expr, str]] = []
                for i, arg in enumerate(node.args):
                    if i in info.donate_argnums:
                        key = A.dotted_name(arg)
                        if key is not None:
                            donated.append((arg, key))
                for kw in node.keywords:
                    if kw.arg in info.donate_argnames:
                        key = A.dotted_name(kw.value)
                        if key is not None:
                            donated.append((kw.value, key))
                if not donated:
                    continue
                stmt = _stmt_of(node, parents)
                rebound: List[str] = []
                if isinstance(stmt, ast.Assign):
                    for t in stmt.targets:
                        rebound.extend(A.flatten_targets(t))
                elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)) \
                        and stmt.target is not None:
                    rebound.extend(A.flatten_targets(stmt.target))
                end = (getattr(stmt, "end_lineno", node.lineno) or node.lineno,
                       getattr(stmt, "end_col_offset", 0) or 0)
                for arg, key in donated:
                    if key in rebound:
                        continue
                    if occ is None:
                        occ = _occurrences(fn)
                    for line, col, k, is_store in occ:
                        if (line, col) <= end:
                            continue
                        if k != key and not k.startswith(key + "."):
                            continue
                        if is_store:
                            break  # rebound before any read: safe
                        out.append(Violation(
                            path=ctx.path, line=line, col=col,
                            pass_name=self.name,
                            message=(f"'{key}' was donated to "
                                     f"'{info.target}' at line "
                                     f"{node.lineno} and is read again "
                                     f"here; its buffer is invalidated — "
                                     f"rebind the result or copy before "
                                     f"donating")))
                        break
        return out
