"""replint — AST-based static analysis for this repo's JAX/Pallas
correctness idioms.  Run as ``python -m repro.tools.lint [paths]``."""
from repro.tools.lint.core import (FileContext, LintError, LintPass,
                                   Violation, check_file, default_passes,
                                   lint_file, run_lint, select_passes)

__all__ = ["FileContext", "LintError", "LintPass", "Violation",
           "check_file", "default_passes", "lint_file", "run_lint",
           "select_passes"]
