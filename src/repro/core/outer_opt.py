"""DiLoCo outer synchronization: delta averaging + Nesterov outer SGD.

The outer step (paper §2.2):

    Δθ_i   = θ_i^H − θ_t          (per-worker parameter delta)
    Δθ̄     = (1/k) Σ_i Δθ_i       (cross-worker average — THE communication)
    v_{t+1} = μ v_t + Δθ̄
    θ_{t+1} = θ_t + η v_{t+1}      (Nesterov variant applies μ v + Δθ̄ lookahead)

Beyond-paper extensions (both listed as future work in §5):

* **Delta compression** — quantize Δθ_i to bf16/int8 before the cross-worker
  exchange.  In the mesh implementation the quantized stacked deltas are
  explicitly resharded to replicated, which forces the all-gather to move the
  *narrow* dtype on the wire (2–4× fewer inter-pod bytes on top of DiLoCo's
  ~H× reduction).
* **Drift-aware averaging** — weight workers by the cosine alignment of their
  delta with the mean delta, down-weighting stragglers/outliers:
  w_i = softmax(τ · cos(Δθ_i, Δθ̄)).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DiLoCoConfig

# wire width (bytes/element) of each supported delta payload dtype — shared
# by the trainers' byte accounting and the strategies' payload schedules
DELTA_WIDTH = {"float32": 4, "bfloat16": 2, "int8": 1}


class OuterState(NamedTuple):
    v: Any          # momentum pytree (same structure as params)
    t: jax.Array    # outer step counter


def init_outer_state(params) -> OuterState:
    return OuterState(
        v=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        t=jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# Delta compression
# ---------------------------------------------------------------------------

def quantize_delta(delta, dtype: str):
    """Per-tensor symmetric quantization of a (K, ...) stacked delta tree.
    Returns (payload_tree, scales_tree) — the payload is what crosses the
    inter-pod link."""
    if dtype == "float32":
        return delta, None
    if dtype == "bfloat16":
        return jax.tree.map(lambda d: d.astype(jnp.bfloat16), delta), None
    if dtype == "int8":
        def q(d):
            amax = jnp.max(jnp.abs(d), axis=tuple(range(1, d.ndim)),
                           keepdims=True)
            scale = jnp.maximum(amax, 1e-12) / 127.0
            return (jnp.clip(jnp.round(d / scale), -127, 127)
                    .astype(jnp.int8), scale)
        out = jax.tree.map(q, delta)
        payload = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        scales = jax.tree.map(lambda o: o[1], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        return payload, scales
    raise ValueError(dtype)


def dequantize_delta(payload, scales):
    if scales is None:
        return jax.tree.map(lambda p: p.astype(jnp.float32), payload)
    return jax.tree.map(lambda p, s: p.astype(jnp.float32) * s,
                        payload, scales)


# ---------------------------------------------------------------------------
# Averaging
# ---------------------------------------------------------------------------

def _tree_dot(a, b) -> jax.Array:
    return sum(jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def average_deltas(stacked_delta, cfg: DiLoCoConfig,
                   replicate_fn=None) -> Any:
    """(K, ...) stacked per-worker deltas -> averaged delta pytree.

    ``replicate_fn(tree)`` reshards the stacked payload to replicated — on a
    pod mesh this is where the inter-pod all-gather happens (in the payload
    dtype).  On a single device it is the identity.
    """
    payload, scales = quantize_delta(stacked_delta, cfg.delta_dtype)
    if replicate_fn is not None:
        if cfg.delta_dtype == "bfloat16":
            # bitcast to u16 around the exchange: XLA may otherwise fold the
            # f32->bf16->f32 convert pair into the gather's producer and move
            # full-width f32 on the wire (observed on the CPU backend)
            payload = jax.tree.map(
                lambda x: jax.lax.bitcast_convert_type(x, jnp.uint16), payload)
        if cfg.delta_dtype != "float32":
            # keep the narrow payload opaque so XLA cannot fold the
            # dequant-convert into the producer and all-gather f32 instead
            # (it legally can: s8 roundtrip == round+clamp in f32)
            payload = jax.lax.optimization_barrier(payload)
        payload = replicate_fn(payload)
        if cfg.delta_dtype == "bfloat16":
            payload = jax.tree.map(
                lambda x: jax.lax.bitcast_convert_type(x, jnp.bfloat16),
                payload)
        if scales is not None:
            scales = replicate_fn(scales)
    delta = dequantize_delta(payload, scales)

    if not cfg.drift_aware:
        return jax.tree.map(lambda d: jnp.mean(d, axis=0), delta)

    # drift-aware: weight workers by cosine(Δ_i, Δ̄), τ = 4
    k = jax.tree.leaves(delta)[0].shape[0]
    mean = jax.tree.map(lambda d: jnp.mean(d, axis=0), delta)
    mean_norm = jnp.sqrt(_tree_dot(mean, mean)) + 1e-12

    def cos_i(i):
        di = jax.tree.map(lambda d: d[i], delta)
        ni = jnp.sqrt(_tree_dot(di, di)) + 1e-12
        return _tree_dot(di, mean) / (ni * mean_norm)

    cos = jnp.stack([cos_i(i) for i in range(k)])
    w = jax.nn.softmax(4.0 * cos)                       # (K,)
    return jax.tree.map(
        lambda d: jnp.tensordot(w, d.astype(jnp.float32), axes=(0, 0)), delta)


# ---------------------------------------------------------------------------
# Outer update
# ---------------------------------------------------------------------------

def outer_update(global_params, avg_delta, state: OuterState,
                 cfg: DiLoCoConfig) -> Tuple[Any, OuterState]:
    """Nesterov-momentum SGD on the averaged delta (treated as the descent
    direction, i.e. pseudo-gradient = −Δθ̄)."""
    mu, eta = cfg.outer_momentum, cfg.outer_lr

    def upd(p, v, d):
        d = d.astype(jnp.float32)
        v_new = mu * v + d
        step_dir = d + mu * v_new if cfg.nesterov else v_new
        return (p.astype(jnp.float32) + eta * step_dir).astype(p.dtype), v_new

    out = jax.tree.map(upd, global_params, state.v, avg_delta)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OuterState(new_v, state.t + 1)
