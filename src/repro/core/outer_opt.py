"""DiLoCo outer synchronization: delta averaging + Nesterov outer SGD.

The outer step (paper §2.2):

    Δθ_i   = θ_i^H − θ_t          (per-worker parameter delta)
    Δθ̄     = (1/k) Σ_i Δθ_i       (cross-worker average — THE communication)
    v_{t+1} = μ v_t + Δθ̄
    θ_{t+1} = θ_t + η v_{t+1}      (Nesterov variant applies μ v + Δθ̄ lookahead)

The cross-worker exchange itself lives in ``repro.core.transport``: deltas
are encoded into ``OuterPayload`` objects by a pluggable ``Codec``
(f32 passthrough / bf16 cast / symmetric int8 with per-tensor scales and
error-feedback residuals), shipped over the replicate hop in the wire
dtype, and decoded back to f32 before the averaging below.  This module
keeps the *optimizer* semantics: plain vs drift-aware averaging and the
Nesterov outer update.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DiLoCoConfig
from repro.core.transport import Transport, make_codec, wire_width

# wire width (bytes/element) of each supported delta payload dtype — a
# compat view of the transport table for older byte-accounting calls
DELTA_WIDTH = {d: wire_width(d)
               for d in ("float32", "bfloat16", "int8", "fp8", "fp8_e5m2")}


class OuterState(NamedTuple):
    v: Any          # momentum pytree (same structure as params)
    t: jax.Array    # outer step counter


def init_outer_state(params) -> OuterState:
    return OuterState(
        v=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        t=jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# Transport construction + compat wrappers
# ---------------------------------------------------------------------------

def make_transport(cfg: DiLoCoConfig, replicate_fn=None) -> Transport:
    """The transport the config describes.  The Pallas quantize kernels are
    used on the single-device simulation path; mesh paths (``replicate_fn``
    set) fall back to the jnp oracle, which XLA partitions like any other
    elementwise code."""
    codec = make_codec(cfg.delta_dtype, use_kernel=replicate_fn is None)
    return Transport(codec, replicate_fn)


def quantize_delta(delta, dtype: str):
    """Compat wrapper: per-tensor symmetric quantization of a (K, ...)
    stacked delta tree via the codec's jnp oracle.  Returns
    (payload_tree, scales_tree)."""
    payload, _ = make_codec(dtype, use_kernel=False).encode(delta)
    return payload.data, payload.scales


def dequantize_delta(payload, scales):
    if scales is None:
        return jax.tree.map(lambda p: p.astype(jnp.float32), payload)
    return jax.tree.map(lambda p, s: p.astype(jnp.float32) * s,
                        payload, scales)


# ---------------------------------------------------------------------------
# Averaging
# ---------------------------------------------------------------------------

def _tree_dot(a, b) -> jax.Array:
    return sum(jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _mask_rows(mask: jax.Array, ref: jax.Array) -> jax.Array:
    """(K,) mask broadcast against a (K, ...) leaf — the fixed-signature
    quorum jits reshape rather than index so the live set never retraces."""
    return mask.reshape((-1,) + (1,) * (ref.ndim - 1))


def _average(delta, cfg: DiLoCoConfig, live: Optional[jax.Array] = None
             ) -> Any:
    """Decoded f32 (K, ...) stacked deltas -> averaged delta pytree.

    ``live`` is an optional (K,) bool contribution mask for quorum rounds:
    masked-out rows are excluded from the mean (and get -inf drift-aware
    logits).  ``live=None`` keeps the original all-workers expressions
    verbatim — the no-fault path stays bit-exact.
    """
    if not cfg.drift_aware:
        if live is None:
            return jax.tree.map(lambda d: jnp.mean(d, axis=0), delta)
        n = jnp.maximum(jnp.sum(live.astype(jnp.float32)), 1.0)
        return jax.tree.map(
            lambda d: jnp.sum(jnp.where(_mask_rows(live, d), d, 0.0),
                              axis=0) / n, delta)

    # drift-aware: weight workers by cosine(Δ_i, Δ̄), τ = 4
    k = jax.tree.leaves(delta)[0].shape[0]
    if live is None:
        mean = jax.tree.map(lambda d: jnp.mean(d, axis=0), delta)
    else:
        delta = jax.tree.map(
            lambda d: jnp.where(_mask_rows(live, d), d, 0.0), delta)
        n = jnp.maximum(jnp.sum(live.astype(jnp.float32)), 1.0)
        mean = jax.tree.map(lambda d: jnp.sum(d, axis=0) / n, delta)
    mean_norm = jnp.sqrt(_tree_dot(mean, mean)) + 1e-12

    def cos_i(i):
        di = jax.tree.map(lambda d: d[i], delta)
        ni = jnp.sqrt(_tree_dot(di, di)) + 1e-12
        return _tree_dot(di, mean) / (ni * mean_norm)

    cos = jnp.stack([cos_i(i) for i in range(k)])
    logits = 4.0 * cos
    if live is not None:
        logits = jnp.where(live, logits, -jnp.inf)
    w = jax.nn.softmax(logits)                          # (K,)
    return jax.tree.map(
        lambda d: jnp.tensordot(w, d.astype(jnp.float32), axes=(0, 0)), delta)


def exchange_and_average(stacked_delta, cfg: DiLoCoConfig, replicate_fn=None,
                         residual=None, kind: str = "delta",
                         fragment: int = -1, live=None
                         ) -> Tuple[Any, Optional[Any]]:
    """Full outer-sync data path: encode -> ship -> decode -> average.

    ``residual`` is the per-worker error-feedback carry for lossy codecs
    (None disables error feedback); returns (averaged delta, new residual).
    ``live`` is the optional (K,) quorum contribution mask — see
    ``_average``.
    """
    transport = make_transport(cfg, replicate_fn)
    full, new_residual = transport.exchange(stacked_delta, residual,
                                            kind=kind, fragment=fragment)
    return _average(full, cfg, live=live), new_residual


def average_deltas(stacked_delta, cfg: DiLoCoConfig,
                   replicate_fn=None) -> Any:
    """(K, ...) stacked per-worker deltas -> averaged delta pytree.

    ``replicate_fn(tree)`` reshards the stacked payload to replicated — on a
    pod mesh this is where the inter-pod all-gather happens (in the payload
    dtype).  On a single device it is the identity.
    """
    avg, _ = exchange_and_average(stacked_delta, cfg, replicate_fn)
    return avg


# ---------------------------------------------------------------------------
# Outer update
# ---------------------------------------------------------------------------

def outer_update(global_params, avg_delta, state: OuterState,
                 cfg: DiLoCoConfig) -> Tuple[Any, OuterState]:
    """Nesterov-momentum SGD on the averaged delta (treated as the descent
    direction, i.e. pseudo-gradient = −Δθ̄)."""
    mu, eta = cfg.outer_momentum, cfg.outer_lr

    def upd(p, v, d):
        d = d.astype(jnp.float32)
        v_new = mu * v + d
        step_dir = d + mu * v_new if cfg.nesterov else v_new
        return (p.astype(jnp.float32) + eta * step_dir).astype(p.dtype), v_new

    out = jax.tree.map(upd, global_params, state.v, avg_delta)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OuterState(new_v, state.t + 1)
