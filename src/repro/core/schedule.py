"""Synchronization (H) schedules.

The paper uses fixed H per stage (H=100 base, H=30 mid/SFT) and proposes
adaptive H as future work (§5): "dynamically adjusting H, reducing it during
critical stages (end of base training, mid-training, SFT) and increasing it
during stable pretraining".  ``AdaptiveH`` implements exactly that policy
from the observed loss slope.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Optional


class HSchedule:
    def should_sync(self, step: int, since_sync: int, loss: float) -> bool:
        raise NotImplementedError

    @property
    def current_h(self) -> int:
        raise NotImplementedError


@dataclasses.dataclass
class FixedH(HSchedule):
    h: int

    def should_sync(self, step, since_sync, loss):
        return since_sync >= self.h

    @property
    def current_h(self):
        return self.h


@dataclasses.dataclass
class StagedH(HSchedule):
    """Fixed H with per-stage values — the paper's actual setup
    (base: H=100, mid-training/SFT: H=30)."""
    h: int

    def should_sync(self, step, since_sync, loss):
        return since_sync >= self.h

    @property
    def current_h(self):
        return self.h


class AdaptiveH(HSchedule):
    """Loss-slope-driven H (paper §5 future work).

    Keeps a window of recent losses; the fitted slope decides:
      steep descent (|slope| > hi)  -> critical phase  -> shrink H (×0.5)
      flat           (|slope| < lo) -> stable phase    -> grow   H (×1.25)
    H clamped to [h_min, h_max].  Synchronizes when since_sync >= current H.
    """

    def __init__(self, h0: int = 50, h_min: int = 10, h_max: int = 200,
                 window: int = 32, hi: float = 5e-3, lo: float = 5e-4):
        self.h = float(h0)
        self.h_min, self.h_max = h_min, h_max
        self.window = window
        self.hi, self.lo = hi, lo
        self.losses: Deque[float] = deque(maxlen=window)

    def _slope(self) -> Optional[float]:
        n = len(self.losses)
        if n < self.window:
            return None
        xs = range(n)
        mx = (n - 1) / 2.0
        my = sum(self.losses) / n
        num = sum((x - mx) * (y - my) for x, y in zip(xs, self.losses))
        den = sum((x - mx) ** 2 for x in xs)
        return num / den

    def should_sync(self, step, since_sync, loss):
        self.losses.append(loss)
        if since_sync < int(self.h):
            return False
        slope = self._slope()
        if slope is not None:
            if abs(slope) > self.hi:
                self.h = max(self.h_min, self.h * 0.5)
            elif abs(slope) < self.lo:
                self.h = min(self.h_max, self.h * 1.25)
        return True

    @property
    def current_h(self):
        return int(self.h)
