"""Representation-drift diagnostics (paper §4.3: "representation drift",
"alignment fragility").

The paper *hypothesizes* that prolonged local optimization makes workers'
embedding spaces diverge so their averaged deltas are "globally coherent but
locally inconsistent".  These diagnostics make that measurable:

* ``param_drift``      — per-worker L2 / cosine dispersion of parameter deltas
* ``linear_cka``       — centered kernel alignment between two activation
                         matrices (standard representation-similarity metric)
* ``worker_cka_matrix``— pairwise CKA of per-worker hidden states on a probe
                         batch (K×K) — low off-diagonal = drifted workers
* ``subspace_overlap`` — principal-angle overlap of the top-r activation
                         subspaces (captures "feature geometry" changes the
                         Hybrid run cannot undo)
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp


def _flatten(tree) -> jax.Array:
    return jnp.concatenate([x.astype(jnp.float32).reshape(-1)
                            for x in jax.tree.leaves(tree)])


def delta_cosine(tree_a, tree_b) -> jax.Array:
    """Cosine similarity between two delta pytrees (flattened).  The
    async-gossip apply rule uses this as its observed-drift signal: a
    stale peer delta pointing away from the local one gets down-weighted
    toward zero instead of averaged in at full weight."""
    a, b = _flatten(tree_a), _flatten(tree_b)
    return jnp.vdot(a, b) / (jnp.linalg.norm(a) * jnp.linalg.norm(b) + 1e-12)


def param_drift(worker_params, global_params) -> Dict[str, jax.Array]:
    """Dispersion of per-worker deltas.  worker_params has leading K."""
    k = jax.tree.leaves(worker_params)[0].shape[0]
    deltas = jnp.stack([
        _flatten(jax.tree.map(lambda w, g: w[i] - g, worker_params,
                              global_params))
        for i in range(k)])                                   # (K, P)
    norms = jnp.linalg.norm(deltas, axis=1)
    mean = jnp.mean(deltas, axis=0)
    mean_norm = jnp.linalg.norm(mean) + 1e-12
    cos = (deltas @ mean) / (norms * mean_norm + 1e-12)
    # pairwise cosine
    unit = deltas / (norms[:, None] + 1e-12)
    pair = unit @ unit.T
    off = (jnp.sum(pair) - k) / (k * (k - 1)) if k > 1 else jnp.ones(())
    return {"delta_norm_mean": jnp.mean(norms),
            "delta_norm_std": jnp.std(norms),
            "cos_to_mean": jnp.mean(cos),
            "pairwise_cos": off}


def linear_cka(X: jax.Array, Y: jax.Array) -> jax.Array:
    """Linear CKA between (n, d1) and (n, d2) activation matrices."""
    X = X - jnp.mean(X, axis=0)
    Y = Y - jnp.mean(Y, axis=0)
    xty = jnp.linalg.norm(X.T @ Y) ** 2
    xtx = jnp.linalg.norm(X.T @ X)
    yty = jnp.linalg.norm(Y.T @ Y)
    return xty / (xtx * yty + 1e-12)


def worker_cka_matrix(worker_params, probe_fn: Callable, probe_batch) -> jax.Array:
    """probe_fn(params, batch) -> (n, d) hidden states.  Returns (K, K) CKA."""
    k = jax.tree.leaves(worker_params)[0].shape[0]
    acts = [probe_fn(jax.tree.map(lambda w: w[i], worker_params), probe_batch)
            for i in range(k)]
    acts = [a.reshape(-1, a.shape[-1]) for a in acts]
    mat = jnp.stack([jnp.stack([linear_cka(acts[i], acts[j])
                                for j in range(k)]) for i in range(k)])
    return mat


def subspace_overlap(X: jax.Array, Y: jax.Array, r: int = 8) -> jax.Array:
    """Overlap of top-r right singular subspaces of two (n, d) matrices:
    (1/r)·||U_x^T U_y||_F^2 ∈ [0, 1]."""
    X = X - jnp.mean(X, axis=0)
    Y = Y - jnp.mean(Y, axis=0)
    _, _, vx = jnp.linalg.svd(X, full_matrices=False)
    _, _, vy = jnp.linalg.svd(Y, full_matrices=False)
    ux, uy = vx[:r], vy[:r]
    return jnp.linalg.norm(ux @ uy.T) ** 2 / r
