"""Streaming DiLoCo (Douillard et al., arXiv:2501.18512 — the paper's
reference [4]): instead of synchronizing ALL parameters every H steps,
partition them into F fragments and synchronize one fragment every H/F
steps, staggered.

Each fragment still syncs every H steps (same per-parameter staleness as
vanilla DiLoCo), but the instantaneous inter-pod bandwidth demand drops F×
and the exchange can overlap inner compute — the "distributed free lunch".

Fragmenting follows the layer stack: stacked ``layers/*`` leaves are sliced
into F contiguous layer ranges; non-stacked leaves (embeddings, final norm)
join fragment 0 / F-1 (embedding with the first fragment, head with the
last, mirroring the reference's schedule).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core.diloco import DiLoCoState, DiLoCoTrainer
from repro.core import outer_opt


def _is_stacked(path) -> bool:
    return any(str(getattr(p, "key", "")) == "layers" for p in path)


def fragment_masks(params, num_fragments: int) -> List[Any]:
    """Boolean mask pytrees, one per fragment; stacked layer leaves are
    split along their leading (layer) dim, the rest assigned to the first
    (embeddings) / last (output head) fragment."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    masks = []
    for f in range(num_fragments):
        leaves = []
        for path, leaf in flat:
            keys = [str(getattr(p, "key", "")) for p in path]
            if _is_stacked(path):
                L = leaf.shape[0]
                lo = f * L // num_fragments
                hi = (f + 1) * L // num_fragments
                m = jnp.zeros((L,) + (1,) * (leaf.ndim - 1), bool)
                m = m.at[lo:hi].set(True)
                leaves.append(jnp.broadcast_to(m, leaf.shape))
            else:
                owner = (num_fragments - 1 if any(
                    k in ("final_norm", "unembed") for k in keys) else 0)
                leaves.append(jnp.broadcast_to(jnp.asarray(f == owner),
                                               leaf.shape))
        masks.append(jax.tree_util.tree_unflatten(treedef, leaves))
    return masks


def fragment_fraction(params, mask) -> float:
    tot = sum(x.size for x in jax.tree.leaves(params))
    sel = sum(int(m.sum()) for m in jax.tree.leaves(mask))
    return sel / max(tot, 1)


@dataclasses.dataclass(frozen=True)
class StreamingDiLoCoTrainer(DiLoCoTrainer):
    """DiLoCoTrainer whose outer step touches ONE fragment.

    ``outer_step_fragment(state, frag)`` averages only that fragment's
    deltas, applies the outer Nesterov update to it, and re-broadcasts just
    that slice — the rest of the worker params keep diverging until their
    fragment's slot comes up.
    """
    num_fragments: int = 4

    def fragment_schedule(self) -> int:
        """Steps between fragment syncs (every fragment syncs each H)."""
        return max(self.cfg.h_inner_steps // self.num_fragments, 1)

    def outer_step_fragment_ef(self, state: DiLoCoState, mask, residual=None):
        """One fragment's outer sync through the codec transport.  The
        error-feedback residual is masked on the way in and merged on the
        way out, so each element's carry only ever reflects its own
        fragment's quantization error.  Returns (state, new residual)."""
        delta = jax.tree.map(
            lambda w, g, m: (w.astype(jnp.float32)
                             - g.astype(jnp.float32)[None]) * m[None],
            state.worker_params, state.global_params, mask)
        res_in = residual if residual is None else jax.tree.map(
            lambda r, m: r * m[None], residual, mask)
        avg, new_res = outer_opt.exchange_and_average(
            delta, self.cfg, self.replicate_fn, residual=res_in,
            kind="fragment")
        new_global, new_outer = outer_opt.outer_update(
            state.global_params, avg, state.outer, self.cfg)
        # merge: fragment slots take the synced value, others keep global
        new_global = jax.tree.map(
            lambda ng, g, m: jnp.where(m, ng, g),
            new_global, state.global_params, mask)
        # workers: fragment slots reset to the synced value, others diverge on
        new_wp = jax.tree.map(
            lambda w, ng, m: jnp.where(m[None], ng[None].astype(w.dtype), w),
            state.worker_params, new_global, mask)
        if residual is not None:
            new_res = jax.tree.map(
                lambda nr, r, m: jnp.where(m[None], nr, r), new_res, residual,
                mask)
        return state._replace(global_params=new_global,
                              worker_params=new_wp, outer=new_outer), new_res

    def outer_step_fragment(self, state: DiLoCoState, mask) -> DiLoCoState:
        return self.outer_step_fragment_ef(state, mask)[0]

    def outer_step_fragment_quorum(self, state: DiLoCoState, mask, residual,
                                   contrib, adopt, reset):
        """``outer_step_fragment_ef`` under (K,) quorum masks (semantics as
        ``DiLoCoTrainer.outer_step_quorum``): ``contrib`` rows enter the
        fragment's masked average, ``adopt`` rows take the synced fragment
        slots, ``reset`` rows (rejoiners) take the FULL new global — every
        fragment, regardless of the round's fragment mask — with zeroed
        inner-opt/EF state, and dead rows pass through frozen."""
        rows = outer_opt._mask_rows
        delta = jax.tree.map(
            lambda w, g, m: (w.astype(jnp.float32)
                             - g.astype(jnp.float32)[None]) * m[None],
            state.worker_params, state.global_params, mask)
        res_in = residual if residual is None else jax.tree.map(
            lambda r, m: r * m[None], residual, mask)
        avg, new_res = outer_opt.exchange_and_average(
            delta, self.cfg, self.replicate_fn, residual=res_in,
            kind="fragment", live=contrib)
        new_global, new_outer = outer_opt.outer_update(
            state.global_params, avg, state.outer, self.cfg)
        new_global = jax.tree.map(
            lambda ng, g, m: jnp.where(m, ng, g),
            new_global, state.global_params, mask)
        new_wp = jax.tree.map(
            lambda w, ng, m: jnp.where(
                jnp.logical_and(rows(adopt, w), m[None]),
                ng[None].astype(w.dtype), w),
            state.worker_params, new_global, mask)
        new_wp = jax.tree.map(
            lambda w, ng: jnp.where(rows(reset, w),
                                    ng[None].astype(w.dtype), w),
            new_wp, new_global)
        new_opt = jax.tree.map(
            lambda o: jnp.where(rows(reset, o), jnp.zeros_like(o), o),
            state.inner_opt)
        if residual is not None:
            new_res = jax.tree.map(
                lambda nr, r, m: jnp.where(
                    jnp.logical_and(rows(contrib, r), m[None]), nr, r),
                new_res, residual, mask)
            new_res = jax.tree.map(
                lambda r: jnp.where(rows(reset, r), jnp.zeros_like(r), r),
                new_res)
        return state._replace(global_params=new_global,
                              worker_params=new_wp,
                              inner_opt=new_opt,
                              outer=new_outer), new_res

    def bytes_per_fragment_sync(self, params, mask) -> int:
        from repro.core.transport import wire_width
        return int(sum(int(m.sum()) for m in jax.tree.leaves(mask))
                   * wire_width(self.cfg.delta_dtype))


def run_streaming_diloco(trainer: StreamingDiLoCoTrainer, state, data_fn,
                         num_steps: int, record_every: int = 1
                         ) -> Tuple[Any, Dict]:
    """Inner steps with a staggered fragment-sync schedule: fragment
    (t / (H/F)) mod F syncs every H/F steps.  Thin wrapper over the
    unified ``DistTrainer`` runtime."""
    from repro.core.dist_trainer import DistTrainer
    from repro.core.sync import StreamingSync
    dt = DistTrainer(trainer.loss_fn, trainer.opt_cfg, trainer.cfg,
                     StreamingSync(num_fragments=trainer.num_fragments),
                     trainer.replicate_fn)
    return dt.run(state, data_fn, num_steps, record_every=record_every)
