"""DiLoCo trainer — the paper's core contribution as a composable JAX module.

The trainer wraps ANY loss function (the full nanochat-style pipeline, or one
of the ten assigned architectures) exactly like the paper wraps nanochat's
training loop:

    each worker:  H inner steps (AdamW+Muon)   — no cross-worker traffic
    every H:      average parameter deltas, outer Nesterov SGD, re-broadcast

Workers are encoded as a leading ``K`` dimension on params / optimizer state,
and the inner step is ``jax.vmap`` of the single-worker step.  That single
encoding serves both deployments:

* **simulation** (paper reproduction on one CPU device): K workers vmapped
  on one chip — bit-faithful algorithm, no hardware needed;
* **multi-pod** (production): the K dim is sharded over the mesh's ``pod``
  axis — XLA keeps inner steps pod-local (verified: inner-step HLO contains
  only within-pod collectives) and the outer step's delta exchange becomes
  the only inter-pod communication.

The DDP baseline (``repro.core.ddp``) is the same inner step with K=1 and the
global batch, synchronizing every step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DiLoCoConfig, OptimizerConfig
from repro.core import outer_opt
from repro.core.outer_opt import OuterState
from repro.optim import apply_updates, nanochat_optimizer
from repro.optim.base import Optimizer


class DiLoCoState(NamedTuple):
    global_params: Any        # θ_t — the synchronized snapshot
    outer: OuterState
    worker_params: Any        # (K, ...) per-worker divergent copies
    inner_opt: Any            # (K, ...) per-worker inner optimizer state
    inner_step: jax.Array     # total inner steps taken (scalar int32)


def _broadcast(tree, k: int):
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (k,) + x.shape), tree)


@dataclasses.dataclass(frozen=True)
class DiLoCoTrainer:
    """loss_fn(params, batch) -> (loss, metrics-dict)."""
    loss_fn: Callable
    opt_cfg: OptimizerConfig
    cfg: DiLoCoConfig
    replicate_fn: Optional[Callable] = None   # mesh: reshard stacked->replicated

    # -- construction -------------------------------------------------------
    def init(self, params) -> DiLoCoState:
        k = self.cfg.num_workers
        inner = self._inner_opt()
        worker_params = _broadcast(params, k)
        inner_state = jax.vmap(inner.init)(worker_params)
        return DiLoCoState(
            global_params=params,
            outer=outer_opt.init_outer_state(params),
            worker_params=worker_params,
            inner_opt=inner_state,
            inner_step=jnp.zeros((), jnp.int32))

    def _inner_opt(self) -> Optimizer:
        return nanochat_optimizer(self.opt_cfg)

    # -- inner step ----------------------------------------------------------
    def _one_worker_step(self, params, opt_state, batch, step):
        (loss, metrics), grads = jax.value_and_grad(
            self.loss_fn, has_aux=True)(params, batch)
        updates, opt_state = self._inner_opt().update(
            grads, opt_state, params, step)
        return apply_updates(params, updates), opt_state, loss, metrics

    def inner_step(self, state: DiLoCoState, batches) -> Tuple[DiLoCoState, jax.Array, Dict]:
        """batches: pytree with leading (K, ...) — one shard per worker."""
        new_wp, new_opt, loss, metrics = jax.vmap(
            self._one_worker_step, in_axes=(0, 0, 0, None))(
                state.worker_params, state.inner_opt, batches,
                state.inner_step)
        return (state._replace(worker_params=new_wp, inner_opt=new_opt,
                               inner_step=state.inner_step + 1),
                loss, metrics)

    def inner_chunk(self, state: DiLoCoState, batches
                    ) -> Tuple[DiLoCoState, jax.Array]:
        """Scan-fused run of T inner steps — the device-speed hot path.

        ``batches`` carry a leading (T, K, ...) time dim; the scan compiles
        ONE program for the whole chunk, so the T per-step dispatches (and
        their host round-trips) collapse into a single device call.
        Returns ``(state, losses)`` with ``losses`` the (T, K) per-worker
        per-step losses — fetched once per chunk by the caller, never per
        step.  The losses leave the program RAW, exactly like the
        per-step jit's loss output: reducing them on device here would
        let XLA fuse (and reassociate) the loss reduction differently
        than the per-step program does, breaking recorded-loss
        bit-exactness; the worker mean is instead taken on the host in a
        fixed order (``dist_trainer._host_mean``) in both loops.

        The scan carry holds ONLY what the inner step mutates
        (``worker_params``, ``inner_opt``, ``inner_step``);
        ``global_params`` and the outer-optimizer state are loop-invariant
        closures, so XLA hoists them instead of threading (and on some
        backends copying) them through every iteration.
        """
        def body(carry, batch):
            wp, opt, istep = carry
            st = state._replace(worker_params=wp, inner_opt=opt,
                                inner_step=istep)
            st, loss, _ = self.inner_step(st, batch)
            return (st.worker_params, st.inner_opt, st.inner_step), loss

        carry = (state.worker_params, state.inner_opt, state.inner_step)
        (wp, opt, istep), losses = jax.lax.scan(body, carry, batches)
        return (state._replace(worker_params=wp, inner_opt=opt,
                               inner_step=istep), losses)

    def inner_chunk_live(self, state: DiLoCoState, batches, live
                         ) -> Tuple[DiLoCoState, jax.Array]:
        """``inner_chunk`` under a (K,) liveness mask: dead rows' params and
        optimizer state pass through frozen (``jnp.where`` merge — the mask
        is a traced argument, so a changing live set never retraces).  The
        (T, K) losses still cover every row; the trainer masks dead rows
        out of the recorded mean on the host.  Only dispatched when at
        least one worker is down — the all-live path keeps using
        ``inner_chunk``'s unmodified program."""
        rows = outer_opt._mask_rows

        def body(carry, batch):
            wp, opt, istep = carry
            st = state._replace(worker_params=wp, inner_opt=opt,
                                inner_step=istep)
            st, loss, _ = self.inner_step(st, batch)
            new_wp = jax.tree.map(
                lambda n, o: jnp.where(rows(live, n), n, o),
                st.worker_params, wp)
            new_opt = jax.tree.map(
                lambda n, o: jnp.where(rows(live, n), n, o),
                st.inner_opt, opt)
            return (new_wp, new_opt, st.inner_step), loss

        carry = (state.worker_params, state.inner_opt, state.inner_step)
        (wp, opt, istep), losses = jax.lax.scan(body, carry, batches)
        return (state._replace(worker_params=wp, inner_opt=opt,
                               inner_step=istep), losses)

    # -- outer step ----------------------------------------------------------
    def init_residual(self, params):
        """Per-worker (K, ...) error-feedback residual for lossy codecs, or
        None when the codec is lossless / error feedback is disabled.  Held
        host-side by the sync runners, NOT in ``DiLoCoState`` — checkpoints
        and the multi-pod abstract state stay unchanged."""
        from repro.core.transport import make_codec
        if not (self.cfg.error_feedback
                and make_codec(self.cfg.delta_dtype).lossy):
            return None
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        return _broadcast(zeros, self.cfg.num_workers)

    def outer_step_ef(self, state: DiLoCoState, residual=None):
        """Outer sync through the codec transport with an optional
        error-feedback residual; returns (new state, new residual)."""
        delta = jax.tree.map(
            lambda w, g: w.astype(jnp.float32) - g.astype(jnp.float32)[None],
            state.worker_params, state.global_params)
        avg, new_residual = outer_opt.exchange_and_average(
            delta, self.cfg, self.replicate_fn, residual=residual)
        new_global, new_outer = outer_opt.outer_update(
            state.global_params, avg, state.outer, self.cfg)
        # re-broadcast the synchronized params; inner optimizer state is kept
        # per-worker across syncs (paper §3 — AdamW/Muon state is local)
        new_wp = _broadcast(new_global, self.cfg.num_workers)
        return state._replace(global_params=new_global,
                              worker_params=new_wp,
                              outer=new_outer), new_residual

    def outer_step(self, state: DiLoCoState) -> DiLoCoState:
        return self.outer_step_ef(state)[0]

    # -- quorum outer step + elastic rejoin (fault-tolerant variants) --------
    def outer_step_quorum(self, state: DiLoCoState, residual,
                          contrib, adopt, reset):
        """``outer_step_ef`` under (K,) quorum masks (all traced bools —
        fixed signature, a changing live set never retraces):

        * ``contrib`` — rows whose deltas enter the masked average;
        * ``adopt``   — live rows that take the new anchor (keeps their
          inner optimizer state, exactly like a normal sync);
        * ``reset``   — rejoiners: take the new anchor AND restart inner
          optimizer + error-feedback state from zero (AdamW/Muon moments
          init to zeros, so zeroing IS re-initialization);
        * rows in none of the masks (dead workers) pass through frozen.
        """
        rows = outer_opt._mask_rows
        delta = jax.tree.map(
            lambda w, g: w.astype(jnp.float32) - g.astype(jnp.float32)[None],
            state.worker_params, state.global_params)
        avg, new_residual = outer_opt.exchange_and_average(
            delta, self.cfg, self.replicate_fn, residual=residual,
            live=contrib)
        new_global, new_outer = outer_opt.outer_update(
            state.global_params, avg, state.outer, self.cfg)
        take = jnp.logical_or(adopt, reset)
        new_wp = jax.tree.map(
            lambda g, o: jnp.where(rows(take, o), g[None], o),
            new_global, state.worker_params)
        new_opt = jax.tree.map(
            lambda o: jnp.where(rows(reset, o), jnp.zeros_like(o), o),
            state.inner_opt)
        if new_residual is not None:
            # non-contributors never shipped, so their EF carry is
            # unchanged; rejoiners restart with a clean carry
            new_residual = jax.tree.map(
                lambda n, o: jnp.where(
                    rows(reset, n), jnp.zeros_like(n),
                    jnp.where(rows(contrib, n), n, o)),
                new_residual, residual)
        return state._replace(global_params=new_global,
                              worker_params=new_wp,
                              inner_opt=new_opt,
                              outer=new_outer), new_residual

    def adopt_anchor(self, state: DiLoCoState, residual, reset):
        """Rejoin without a round (quorum skipped): ``reset`` rows adopt
        the CURRENT anchor with zeroed inner-opt/EF state; the anchor and
        outer momentum are untouched."""
        rows = outer_opt._mask_rows
        new_wp = jax.tree.map(
            lambda g, o: jnp.where(rows(reset, o), g[None], o),
            state.global_params, state.worker_params)
        new_opt = jax.tree.map(
            lambda o: jnp.where(rows(reset, o), jnp.zeros_like(o), o),
            state.inner_opt)
        if residual is not None:
            residual = jax.tree.map(
                lambda o: jnp.where(rows(reset, o), jnp.zeros_like(o), o),
                residual)
        return state._replace(worker_params=new_wp,
                              inner_opt=new_opt), residual

    # -- jitted entry points ---------------------------------------------------
    def jit_steps(self):
        return jax.jit(self.inner_step), jax.jit(self.outer_step)

    # -- communication accounting (paper: "communication reduced ~100x") ------
    def bytes_per_sync(self, params) -> int:
        """Bytes each worker ships per outer sync (payload dtype)."""
        from repro.core.transport import wire_width
        n = sum(x.size for x in jax.tree.leaves(params))
        return n * wire_width(self.cfg.delta_dtype)

    def ddp_bytes_per_step(self, params) -> int:
        """What synchronous DDP would ship per *inner* step (fp32 grads)."""
        return sum(x.size for x in jax.tree.leaves(params)) * 4


# ---------------------------------------------------------------------------
# Training loop — thin wrapper over the unified DistTrainer runtime
# ---------------------------------------------------------------------------

def run_diloco(trainer: DiLoCoTrainer, state: DiLoCoState, data_fn,
               num_steps: int, h_schedule=None,
               record_every: int = 1,
               eval_fn: Optional[Callable] = None,
               eval_every: int = 0) -> Tuple[DiLoCoState, Dict]:
    """data_fn(step) -> per-worker-stacked batch pytree.

    ``h_schedule`` decides when to synchronize (defaults to fixed H from the
    config); supports the adaptive-H controller (paper §5 future work).
    """
    from repro.core.dist_trainer import DistTrainer
    from repro.core.sync import DiLoCoSync
    dt = DistTrainer(trainer.loss_fn, trainer.opt_cfg, trainer.cfg,
                     DiLoCoSync(h_schedule=h_schedule), trainer.replicate_fn)
    return dt.run(state, data_fn, num_steps, record_every=record_every,
                  eval_fn=eval_fn, eval_every=eval_every)
