"""The unified distributed-training loop.

One loop runs every configuration the paper compares (and the ones it
proposes as future work): the strategy object owns *when and what* to
synchronize, the loop owns everything else — vmapped inner steps, loss
recording, eval hooks, history.  ``run_ddp`` / ``run_diloco`` /
``run_streaming_diloco`` remain as thin wrappers over this loop.

    trainer = DistTrainer(model.loss, opt_cfg, dcfg, DiLoCoSync())
    state = trainer.init(params)
    state, hist = trainer.run(state, data_fn, num_steps)

History keys: ``step`` / ``loss`` (every ``record_every``), ``sync_steps``
(full outer exchanges), ``frag_syncs`` (``(step, fragment)`` pairs),
``evals`` (``(step, eval_fn(global_params))`` pairs), ``step_seconds``
(median measured seconds per inner step — robust to jit-compile spikes;
feeds the comm simulator's calibration).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DiLoCoConfig, OptimizerConfig
from repro.core.diloco import DiLoCoState
from repro.core.streaming import StreamingDiLoCoTrainer
from repro.core.sync import SyncStrategy


@dataclasses.dataclass(frozen=True)
class DistTrainer:
    """loss_fn(params, batch) -> (loss, metrics-dict); batches carry a
    leading (K, ...) worker dim (K=1 for DDP with the global batch)."""
    loss_fn: Callable
    opt_cfg: OptimizerConfig
    cfg: DiLoCoConfig
    strategy: SyncStrategy
    replicate_fn: Optional[Callable] = None

    # The compute engine: StreamingDiLoCoTrainer is the most general
    # DiLoCoTrainer (inner step + full outer step + fragment outer step);
    # strategies pick which pieces they drive.
    def engine(self) -> StreamingDiLoCoTrainer:
        return StreamingDiLoCoTrainer(
            self.loss_fn, self.opt_cfg, self.cfg, self.replicate_fn,
            num_fragments=getattr(self.strategy, "num_fragments", 4))

    def init(self, params) -> DiLoCoState:
        return self.engine().init(params)

    def run(self, state: DiLoCoState, data_fn, num_steps: int,
            record_every: int = 1, eval_fn: Optional[Callable] = None,
            eval_every: int = 0) -> Tuple[DiLoCoState, Dict]:
        """data_fn(step) -> per-worker-stacked batch pytree."""
        eng = self.engine()
        runner = self.strategy.bind(eng, state.global_params)
        inner_jit = jax.jit(eng.inner_step)
        history: Dict[str, list] = {"step": [], "loss": [], "sync_steps": [],
                                    "frag_syncs": [], "evals": []}

        def record(recs):
            for key, val in recs:
                history[key].append(val)

        step_durations = []
        t_prev = time.time()
        for step in range(num_steps):
            state, loss, _ = inner_jit(state, data_fn(step))
            loss_mean = float(jnp.mean(loss))
            if step % record_every == 0:
                history["step"].append(step)
                history["loss"].append(loss_mean)
            state, recs = runner.after_step(state, step, loss_mean)
            record(recs)
            # loss_mean + after_step forced this step (and any sync it
            # triggered) to complete before the clock is read
            t_now = time.time()
            step_durations.append(t_now - t_prev)
            t_prev = t_now
            if eval_fn is not None and eval_every and (step + 1) % eval_every == 0:
                state = runner.refresh(state)
                history["evals"].append((step, eval_fn(state.global_params)))
        state, recs = runner.finalize(state, num_steps)
        record(recs)
        # measured steady-state seconds/step: the median is robust to the
        # one-off jit-compile spikes (inner step at 0, outer step at the
        # first sync) that a mean over a short run would smear in
        history["step_seconds"] = sorted(step_durations)[
            len(step_durations) // 2] if step_durations else 0.0
        return state, history

    # -- communication accounting -------------------------------------------
    def payload_schedule(self, params, num_steps: int) -> list:
        """The strategy's payload footprint for ``num_steps`` inner steps —
        feed to ``repro.launch.comm_sim.simulate_schedule`` for modeled
        wall-clock."""
        n = sum(int(x.size) for x in jax.tree.leaves(params))
        return self.strategy.payload_schedule(n, num_steps, self.cfg)
